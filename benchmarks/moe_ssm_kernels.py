"""MoE grouped-GEMM + selective-scan kernel benchmark.

Three arms, each reporting a deterministic headline metric next to the
(informational, interpreter-bound on this CPU container) wall times:

  moe    dense capacity-buffer dispatch vs the grouped-GEMM backend on
         a skewed router.  Headline: ``dropfree_flop_ratio`` -- matmul
         rows a DROP-FREE dense dispatch would need (capacity sized to
         the most loaded expert, times E) over the rows the grouped
         kernel actually sweeps (live tiles x block_m, from the same
         tile-intersection accounting the kernel's ``pl.when`` uses).
         Routing is seeded, so the ratio is exact and platform-free.

  ssm    fused selective-scan kernel vs the chunked ``lax.scan``
         backend.  Headline: ``state_traffic_ratio`` -- analytic HBM
         bytes of a scan that round-trips the [di, N] state every step
         (what the unfused backward replays) over the kernel's streams
         + per-chunk checkpoints.

  autotune  sweep ``scan_candidates`` block shapes for the scan kernel
         via ``kernels/autotune.py`` (roofline-pruned, measured picks).
         Headline: ``best_speedup`` = default-blocks wall time over the
         winner's; >= 1.0 by construction because the default is swept
         too, > 1.0 when the tuner finds a better shape.

Both kernel arms assert forward AND gradient parity against their
reference backends -- CI runs ``--smoke`` and the regression gate
(``check_regression.py``) bands all three headline metrics.

    PYTHONPATH=src python -m benchmarks.moe_ssm_kernels [--smoke] \
        [--out BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.moe_ssm_kernels`

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.grouped_gemm import count_live_group_tiles
from repro.kernels.ops import selective_scan_op
from repro.models.moe import moe_ffn
from repro.models.ssm import mamba1_scan

# (tokens, d_model, d_ff, experts, top_k)
MOE_FULL = [(1024, 64, 256, 8, 2), (2048, 64, 256, 8, 2)]
MOE_SMOKE = [(512, 32, 128, 4, 2)]
ROUTER_SKEW = 0.3  # expert-0 weight bias: realistic routing imbalance

# (T, d_inner, N)
SSM_FULL = [(512, 128, 16), (1024, 128, 16)]
SSM_SMOKE = [(256, 64, 8)]

# autotune sweep shape + the call-site default it must beat or match
TUNE_FULL = (512, 128, 16)
TUNE_SMOKE = (128, 64, 8)
TUNE_DEFAULT = (128, 64)  # (block_d, chunk) -- configs/base.py defaults


def _timed(fn, repeat):
    best = np.inf
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


# ----------------------------------------------------------------------
# Arm 1: MoE dispatch.
# ----------------------------------------------------------------------
def _moe_inputs(rng, n, d, f, E):
    # Positive-mean activations + a weight bias toward expert 0 give it
    # a disproportionate share of top-k slots (with zero-mean x a
    # weight-column bias cancels and routing stays balanced).
    x = jnp.asarray(rng.normal(0.3, 1.0, size=(1, n, d)), jnp.float32)
    router_w = jnp.asarray(rng.normal(0, 0.5, size=(d, E)), jnp.float32)
    router_w = router_w.at[:, 0].add(ROUTER_SKEW)
    w = [jnp.asarray(rng.normal(0, 0.1, size=s), jnp.float32)
         for s in ((E, d, f), (E, d, f), (E, f, d))]
    return x, router_w, w


def _routing_counts(x, router_w, top_k):
    """Replicates moe_ffn's routing prologue to get per-expert counts."""
    n = x.shape[0] * x.shape[1]
    logits = jnp.einsum("nd,de->ne", x.reshape(n, -1), router_w)
    _, ids = jax.lax.top_k(jax.nn.softmax(logits, -1), top_k)
    E = router_w.shape[1]
    return np.bincount(np.asarray(ids).reshape(-1), minlength=E)


def bench_moe(grid, repeat, block_m, block_n):
    rows = []
    for n, d, f, E, k in grid:
        rng = np.random.default_rng(hash((n, d, E)) % (2**32))
        x, router_w, (wg, wu, wd) = _moe_inputs(rng, n, d, f, E)

        def make(backend, cap):
            def step(x):
                out, aux = moe_ffn(x, router_w, wg, wu, wd, top_k=k,
                                   capacity_factor=cap, backend=backend,
                                   block_m=block_m, block_n=block_n)
                return out
            fwd = jax.jit(step)
            grad = jax.jit(jax.grad(lambda x: jnp.sum(step(x) ** 2)))
            return fwd, grad

        counts = _routing_counts(x, router_w, k)
        # Capacity a dense dispatch needs for ZERO drops: the most
        # loaded expert's count (uniform buffer => everyone pays it).
        cap_dropfree = counts.max() * E / (n * k)
        fwd_g, grad_g = make("grouped", 1.0)
        fwd_d, grad_d = make("dense", float(cap_dropfree))

        out_g = jax.block_until_ready(fwd_g(x))
        out_d = jax.block_until_ready(fwd_d(x))
        err = float(jnp.abs(out_g - out_d).max())
        assert err < 1e-4, f"grouped/dense parity: {err}"
        gerr = float(jnp.abs(grad_g(x) - grad_d(x)).max())
        assert gerr < 1e-4, f"grouped/dense grad parity: {gerr}"

        live = count_live_group_tiles(counts, block_m)
        rows_dense = int(counts.max()) * E
        rows_grouped = live * block_m
        row = {
            "tokens": n, "d_model": d, "d_ff": f, "experts": E, "top_k": k,
            "block_m": block_m,
            "max_expert_count": int(counts.max()),
            "mean_expert_count": round(float(counts.mean()), 1),
            "dense_dropfree_rows": rows_dense,
            "grouped_rows": rows_grouped,
            "dropfree_flop_ratio": round(rows_dense / rows_grouped, 4),
            "parity_max_err": err, "grad_parity_max_err": gerr,
            "grouped": {"fwd_ms": round(_timed(lambda: fwd_g(x), repeat), 3),
                        "fwd_grad_ms": round(_timed(lambda: grad_g(x), repeat), 3)},
            "dense": {"fwd_ms": round(_timed(lambda: fwd_d(x), repeat), 3),
                      "fwd_grad_ms": round(_timed(lambda: grad_d(x), repeat), 3)},
        }
        rows.append(row)
        print(f"moe n={n} E={E} skew max/mean="
              f"{counts.max()}/{counts.mean():.0f} "
              f"flop_ratio={row['dropfree_flop_ratio']:.2f} "
              f"grouped={row['grouped']['fwd_grad_ms']:.0f}ms "
              f"dense={row['dense']['fwd_grad_ms']:.0f}ms")
    return rows


# ----------------------------------------------------------------------
# Arm 2: selective scan.
# ----------------------------------------------------------------------
def _ssm_inputs(rng, T, di, N):
    u = jnp.asarray(rng.normal(size=(T, di)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.05, 0.02, size=(T, di))), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(1.0, 0.3, size=(di, N))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(T, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    seg = np.ones(T, np.int32)
    seg[T // 2:] = 2
    return u, dt, A, B, C, D, jnp.asarray(seg)


def bench_ssm(grid, repeat, block_d, chunk):
    rows = []
    for T, di, N in grid:
        rng = np.random.default_rng(hash((T, di, N)) % (2**32))
        u, dt, A, B, C, D, seg = _ssm_inputs(rng, T, di, N)
        bd, ct = min(block_d, di), min(chunk, T)

        def pallas_y(u):
            return selective_scan_op(u, dt, A, B, C, D, seg, block_d=bd,
                                     chunk=ct, interpret=True)

        def scan_y(u):
            y, _ = mamba1_scan(u, dt, A, B, C, D, seg, backend="scan",
                               chunk=ct)
            return y

        arms = {}
        outs = {}
        for name, fn in (("pallas", pallas_y), ("scan", scan_y)):
            fwd = jax.jit(fn)
            grad = jax.jit(jax.grad(lambda u, f=fn: jnp.sum(f(u) ** 2)))
            outs[name] = (jax.block_until_ready(fwd(u)),
                          jax.block_until_ready(grad(u)))
            arms[name] = {
                "fwd_ms": round(_timed(lambda: fwd(u), repeat), 3),
                "fwd_grad_ms": round(_timed(lambda: grad(u), repeat), 3),
            }
        err = float(jnp.abs(outs["pallas"][0] - outs["scan"][0]).max())
        gerr = float(jnp.abs(outs["pallas"][1] - outs["scan"][1]).max())
        assert err < 1e-4, f"pallas/scan parity: {err}"
        assert gerr < 1e-4, f"pallas/scan grad parity: {gerr}"

        # Analytic HBM traffic (f32): an unfused scan round-trips the
        # [di, N] state every step (and the backward replays it); the
        # kernel streams the operands once per channel block and stores
        # one checkpoint per chunk.
        n_d, n_t = di // bd, T // ct
        naive = 4 * (3 * T * di + 2 * T * N + 2 * T * di * N)
        fused = 4 * (3 * T * di + n_d * 2 * T * N + n_t * di * N)
        row = {
            "T": T, "di": di, "N": N, "block_d": bd, "chunk": ct,
            "parity_max_err": err, "grad_parity_max_err": gerr,
            "naive_state_bytes": naive, "fused_bytes": fused,
            "state_traffic_ratio": round(naive / fused, 4),
            "backends": arms,
        }
        rows.append(row)
        print(f"ssm T={T} di={di} traffic_ratio="
              f"{row['state_traffic_ratio']:.1f} "
              f"pallas={arms['pallas']['fwd_grad_ms']:.0f}ms "
              f"scan={arms['scan']['fwd_grad_ms']:.0f}ms")
    return rows


# ----------------------------------------------------------------------
# Arm 3: block autotuning on the scan kernel.
# ----------------------------------------------------------------------
def bench_autotune(shape, repeat):
    T, di, N = shape
    rng = np.random.default_rng(hash(shape) % (2**32))
    u, dt, A, B, C, D, seg = _ssm_inputs(rng, T, di, N)

    def run(blocks):
        bd, ct = blocks
        y = selective_scan_op(u, dt, A, B, C, D, seg, block_d=bd, chunk=ct,
                              interpret=True)
        jax.block_until_ready(y)

    # The call sites clamp the config default to the shape
    # (models/ssm.py _fit_block), so compare against the effective one.
    default_blocks = (min(TUNE_DEFAULT[0], di), min(TUNE_DEFAULT[1], T))
    cands = autotune.scan_candidates(T, di)
    assert default_blocks in cands, (default_blocks, cands)
    res = autotune.autotune(
        "scan", {"T": T, "di": di, "N": N, "dtype": "float32"}, cands, run,
        predict_fn=lambda b: autotune.predict_scan(b, T=T, di=di, N=N),
        prune=2.0, repeat=repeat, use_cache=False)
    by_blocks = {tuple(c["blocks"]): c for c in res["candidates"]}
    default = by_blocks[default_blocks]
    if default["measured_ms"] is None:  # pruned: measure it explicitly
        run(default_blocks)
        default["measured_ms"] = _timed(lambda: run(default_blocks), repeat)
    speedup = default["measured_ms"] / res["measured_ms"]
    doc = {
        "shape": {"T": T, "di": di, "N": N},
        "candidates_total": len(cands),
        "candidates_measured": sum(
            1 for c in res["candidates"] if c["measured_ms"] is not None),
        "default_blocks": list(default_blocks),
        "default_ms": round(default["measured_ms"], 3),
        "tuned_blocks": list(res["blocks"]),
        "tuned_ms": round(res["measured_ms"], 3),
        "best_speedup": round(speedup, 4),
    }
    print(f"autotune T={T} di={di}: default{default_blocks}="
          f"{doc['default_ms']:.0f}ms tuned{tuple(res['blocks'])}="
          f"{doc['tuned_ms']:.0f}ms speedup={speedup:.2f}x "
          f"({doc['candidates_measured']}/{len(cands)} measured)")
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--repeat", type=int, default=None)
    args = ap.parse_args(argv)
    repeat = args.repeat or (2 if args.smoke else 3)
    moe_rows = bench_moe(MOE_SMOKE if args.smoke else MOE_FULL, repeat,
                         block_m=64 if args.smoke else 128,
                         block_n=64 if args.smoke else 128)
    ssm_rows = bench_ssm(SSM_SMOKE if args.smoke else SSM_FULL, repeat,
                         block_d=64, chunk=64)
    tune = bench_autotune(TUNE_SMOKE if args.smoke else TUNE_FULL, repeat)
    doc = {
        "note": (
            "Pallas kernels run in interpret mode on CPU: wall times "
            "measure the interpreter.  The gated headline metrics are "
            "platform-free: dropfree_flop_ratio comes from routing "
            "counts + live-tile accounting, state_traffic_ratio is "
            "analytic bytes, best_speedup is a within-run wall-time "
            "ratio with the default shape in the sweep (>= 1.0 by "
            "construction)."),
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "moe": moe_rows,
        "ssm": ssm_rows,
        "autotune": tune,
        "headline": {
            "moe_dropfree_flop_ratio": min(
                r["dropfree_flop_ratio"] for r in moe_rows),
            "ssm_state_traffic_ratio": min(
                r["state_traffic_ratio"] for r in ssm_rows),
            "autotune_best_speedup": tune["best_speedup"],
        },
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
