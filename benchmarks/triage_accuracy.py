"""Triage accuracy benchmark: does the attribution engine name the
injected fault?

Runs the full attribution pipeline -- :class:`repro.obs.GapWaterfall`
per step, :class:`repro.obs.AnomalyMonitor` over the waterfall series,
alert routing through :class:`repro.obs.AlertBridge`, ranked root-cause
triage via :func:`repro.obs.triage` -- over a seeded pure-numpy step
simulator, injecting ONE known fault per scenario at mid-run:

  straggler_llm / straggler_vision   one shard's phase cost inflates
  cost_drift                         step time moves, cost vectors don't
                                     (+ CUSUM drift alerts)
  moe_drop_spike                     moe_dropped_frac 0 -> 0.25
  preemption_storm                   preemption recompute burns 15% of
                                     the useful compute
  dispatcher_exposed                 exposed plan latency 2ms -> 28ms
  checkpoint_stall                   a 30ms save charged to every step
  kernel_dead_tiles                  dead-tile fraction 0.02 -> 0.30

Headline metrics (gated by ``benchmarks/check_regression.py``):

  * ``triage_top1_accuracy`` -- fraction of scenarios whose #1 ranked
    cause is the injected fault (gate: >= 0.75);
  * ``waterfall_closure_ok`` -- max per-step closure error across every
    scenario that keeps a truthful cost model stays <= 5% (the
    cost-drift scenario is excluded: blowing up the unattributed
    residual there is the *detection mechanism*, not an error);
  * ``metrics_endpoint_valid`` -- a 3-DP-shard + 2-engine-replica
    aggregated registry served live by :class:`repro.obs.MetricsServer`
    passes the strict OpenMetrics parser on two consecutive scrapes
    (``_total`` monotonicity included) and serves a JSON ``/triage``.

    PYTHONPATH=src python -m benchmarks.triage_accuracy [--smoke] \
        [--check] [--out BENCH_triage.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import urllib.request

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.triage_accuracy`

from repro.obs import (AlertBridge, AnomalyMonitor, GapWaterfall,
                       MetricsRegistry, MetricsServer, aggregate_registries,
                       triage, validate_openmetrics)

CLOSURE_GATE = 0.05

# Healthy-regime constants: post-balanced cost vectors (1% shard noise),
# a fixed true cost->ms scale the waterfall has to re-learn online.
PHASE_BASE = {"vision": 800.0, "audio": 400.0, "llm": 3000.0}
SCALE_MS_PER_COST = 0.02  # => ~86 ms compute per step
EXPOSED_MS = 2.0
DEAD_TILE_BASE = 0.02
STEP_NOISE_MS = 0.08
D = 4


class SimReport:
    """Duck-typed OrchestratorReport: just what the waterfall reads."""

    def __init__(self, phase_costs, exposed_ms):
        self.phase_costs = phase_costs
        self.exposed_ms = exposed_ms


def _healthy_costs(rng):
    return {p: base * rng.normal(1.0, 0.01, size=D).clip(0.9, 1.1)
            for p, base in PHASE_BASE.items()}


def run_scenario(name, *, steps, fault_step, seed, mutate):
    """Simulate one run; ``mutate(state, it)`` applies the fault to the
    per-step state dict from ``fault_step`` on.  Returns the triage
    report plus per-run closure stats."""
    rng = np.random.default_rng(seed)
    registry = MetricsRegistry()
    alerts = AlertBridge(None, registry)
    waterfall = GapWaterfall(registry=registry)
    monitor = AnomalyMonitor(alerts=alerts, registry=registry)
    for it in range(steps):
        state = {
            "costs": _healthy_costs(rng),
            "exposed_ms": EXPOSED_MS,
            "ckpt_ms": 0.0,
            "dead_tile_frac": DEAD_TILE_BASE,
            "recompute_frac": 0.0,
            "moe_dropped_frac": 0.0,
            "step_ms_extra": 0.0,  # unmodeled time (cost drift)
        }
        if it >= fault_step:
            mutate(state, it, alerts)
        sum_max = sum(float(np.max(c)) for c in state["costs"].values())
        step_ms = (sum_max * SCALE_MS_PER_COST + state["exposed_ms"]
                   + state["ckpt_ms"] + state["step_ms_extra"]
                   + abs(rng.normal(0.0, STEP_NOISE_MS)))
        report = SimReport(state["costs"], state["exposed_ms"])
        waterfall.observe(
            it, report=report, step_ms=step_ms,
            metrics={"moe_dropped_frac": state["moe_dropped_frac"]},
            ckpt_ms=state["ckpt_ms"],
            dead_tile_frac=state["dead_tile_frac"],
            recompute_frac=state["recompute_frac"])
        monitor.poll(waterfall.series)
    rep = triage([w.to_dict() for w in waterfall.history],
                 anomalies=[a.to_dict() for a in monitor.anomalies],
                 alerts=list(alerts.alerts),
                 meta={"scenario": name})
    closure = waterfall.closure()
    return rep, closure


def scenarios(steps, fault_step):
    """(name, expected_cause, mutate) triples -- one injected fault each."""

    def straggler(phase, shard, factor):
        def mutate(state, it, alerts):
            state["costs"][phase][shard] *= factor
        return mutate

    def cost_drift(state, it, alerts):
        # Step time moves while the cost vectors do not: the residual
        # the waterfall cannot attribute.  The CUSUM detector (modeled
        # by its alert) corroborates the rename to cost_model_drift.
        state["step_ms_extra"] = 30.0
        if (it - fault_step) % 5 == 0:
            alerts.on_drift({"llm": True}, step=it)

    def drop_spike(state, it, alerts):
        state["moe_dropped_frac"] = 0.25
        if (it - fault_step) % 5 == 0:
            alerts.emit("moe_drop_spike", step=it, moe_dropped_frac=0.25,
                        threshold=0.05)

    def preempt(state, it, alerts):
        state["recompute_frac"] = 0.15
        if (it - fault_step) % 4 == 0:
            alerts.on_preemptions(4, step=it)

    def dispatcher(state, it, alerts):
        state["exposed_ms"] = 28.0
        if (it - fault_step) % 5 == 0:
            alerts.emit("stale_plan_replanned", step=it, coeff_version=it)

    def ckpt(state, it, alerts):
        state["ckpt_ms"] = 30.0

    def dead_tiles(state, it, alerts):
        state["dead_tile_frac"] = 0.30

    return [
        ("straggler_shard_llm", "straggler_llm", straggler("llm", 0, 1.6)),
        ("straggler_shard_vision", "straggler_vision",
         straggler("vision", 2, 2.2)),
        ("cost_drift", "cost_model_drift", cost_drift),
        ("moe_drop_spike", "moe_drop_spike", drop_spike),
        ("preemption_storm", "preemption_storm", preempt),
        ("dispatcher_exposed", "dispatcher_exposed", dispatcher),
        ("checkpoint_stall", "checkpoint_stall", ckpt),
        ("kernel_dead_tiles", "kernel_dead_tiles", dead_tiles),
    ]


# ----------------------------------------------------------------------
# Aggregated multi-rank /metrics endpoint round trip.
# ----------------------------------------------------------------------
def _rank_registry(rank, kind, rng):
    """One DP shard's / engine replica's registry with overlapping
    families, so aggregation actually has to merge."""
    reg = MetricsRegistry()
    c = reg.counter("train_tokens", "tokens", labels=("rank",))
    c.inc(float(rng.integers(1000, 5000)), rank=str(rank))
    g = reg.gauge("train_mfu_simulated", "mfu")
    g.set(float(rng.uniform(0.7, 0.95)))
    h = reg.histogram("step_ms", "step wall", labels=("kind",),
                      buckets=(1.0, 5.0, 10.0, 50.0, float("inf")))
    for v in rng.uniform(0.5, 40.0, size=200):
        h.observe(float(v), kind=kind)
    return reg


def _scrape(url):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.read().decode()


def check_endpoint(seed=0):
    """Serve an aggregated 3-shard + 2-replica view; validate strictly."""
    rng = np.random.default_rng(seed)
    shard_regs = [_rank_registry(r, "train", rng) for r in range(3)]
    replica_regs = [_rank_registry(100 + r, "serve", rng) for r in range(2)]
    all_regs = shard_regs + replica_regs

    def provider():
        return aggregate_registries(all_regs, gauge_mode="mean")

    report = {"causes": [], "fault_step": None, "meta": {"source": "bench"}}
    with MetricsServer(provider, triage_provider=lambda: report) as srv:
        first = validate_openmetrics(_scrape(srv.url + "/metrics"))
        # Counters move between scrapes; the second scrape must parse
        # AND be monotone against the first.
        for reg in all_regs:
            reg.get("train_tokens").inc(64.0, rank="x")
        second = validate_openmetrics(_scrape(srv.url + "/metrics"),
                                      previous=first)
        got = json.loads(_scrape(srv.url + "/triage"))
    # The aggregate must equal the union stream on the exact kinds.
    want_tokens = sum(
        child.value for reg in all_regs
        for _, child in reg.get("train_tokens").children())
    agg_tokens = sum(v for k, v in second.items()
                     if k.startswith("train_tokens_total"))
    if abs(agg_tokens - want_tokens) > 1e-6:
        raise AssertionError(
            f"aggregated counter {agg_tokens} != union {want_tokens}")
    if got.get("meta", {}).get("source") != "bench":
        raise AssertionError(f"/triage did not round-trip: {got}")
    return {"series": len(second), "tokens_match": True}


# ----------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shorter runs (CI lane); same scenarios")
    ap.add_argument("--check", action="store_true",
                    help="assert the headline gates instead of only "
                         "reporting them")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    steps = 40 if args.smoke else 80
    fault_step = steps // 2
    rows = []
    hits = 0
    closure_max = 0.0
    for i, (name, expected, mutate) in enumerate(
            scenarios(steps, fault_step)):
        rep, closure = run_scenario(name, steps=steps, fault_step=fault_step,
                                    seed=args.seed * 1000 + i, mutate=mutate)
        got = rep["causes"][0]["cause"] if rep["causes"] else None
        top1 = got == expected
        hits += top1
        if name != "cost_drift":  # drift MUST blow the residual up
            closure_max = max(closure_max, closure["max_closure_err"])
        rows.append({
            "scenario": name, "expected": expected, "got": got,
            "top1": bool(top1), "fault_step_true": fault_step,
            "fault_step_est": rep["fault_step"],
            "gap_delta": rep["gap_delta"], "n_anomalies": rep["n_anomalies"],
            "n_alerts": rep["n_alerts"],
            "closure_max": closure["max_closure_err"],
            "top3": [c["cause"] for c in rep["causes"][:3]],
        })
        print(f"{'OK ' if top1 else 'MISS'} {name}: expected {expected} "
              f"got {got} (fault@{fault_step} est@{rep['fault_step']}, "
              f"closure {closure['max_closure_err']:.3f})")

    try:
        endpoint = check_endpoint(seed=args.seed)
        endpoint_valid = True
    except Exception as e:  # noqa: BLE001 -- a flag, not a crash
        endpoint = {"error": str(e)}
        endpoint_valid = False
    print(f"aggregated /metrics endpoint: "
          f"{'valid' if endpoint_valid else 'INVALID'} {endpoint}")

    accuracy = hits / len(rows)
    doc = {
        "config": {"steps": steps, "fault_step": fault_step,
                   "d": D, "seed": args.seed, "smoke": args.smoke},
        "headline": {
            "triage_top1_accuracy": accuracy,
            "waterfall_closure_max": closure_max,
            "waterfall_closure_ok": bool(closure_max <= CLOSURE_GATE),
            "metrics_endpoint_valid": endpoint_valid,
        },
        "scenarios": rows,
        "endpoint": endpoint,
    }
    print(f"\ntriage_top1_accuracy={accuracy:.3f} "
          f"waterfall_closure_max={closure_max:.4f} "
          f"(gate <= {CLOSURE_GATE})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {args.out}")
    if args.check:
        assert accuracy >= 0.75, f"top-1 accuracy {accuracy} < 0.75"
        assert closure_max <= CLOSURE_GATE, \
            f"closure {closure_max} > {CLOSURE_GATE}"
        assert endpoint_valid, f"metrics endpoint invalid: {endpoint}"
        print("checks OK")
    return doc


if __name__ == "__main__":
    main()
