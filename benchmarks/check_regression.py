"""Benchmark regression gate: diff fresh smoke results against committed
baselines.

The committed ``BENCH_*.json`` artifacts record full-scale headline
metrics, but full runs are too slow (and too noisy) for a per-PR lane.
This gate instead compares a fresh ``--smoke --check`` run of every
benchmark against ``BENCH_baseline_smoke.json`` -- a committed snapshot
of the *smoke* headline metrics -- with a per-metric tolerance band, so
a PR that silently halves the dispatcher speedup or the serving
throughput ratio fails CI instead of only updating an artifact.

Metrics and their bands:

  dispatch     headline.aggregate_speedup      wall-time ratio (noisy on
                                               shared runners): generous
                                               relative band + abs floor
  attention    mean/min skip_fraction          deterministic tile counts:
                                               tight band
  serving      slot_throughput_speedup         deterministic slot counts:
                                               tight band; streams_match
                                               must hold
  calibration  recovered_fraction              seeded simulation: medium
                                               band; within_5pct flag
                                               must hold
  kernels      moe_dropfree_flop_ratio         seeded routing + live-tile
                                               accounting: tight band
               ssm_state_traffic_ratio         analytic bytes: tight band
               autotune_best_speedup           within-run wall ratio, >= 1
                                               by construction: abs floor
                                               only; kernel parity flags
                                               must hold
  triage       triage_top1_accuracy            seeded fault injection:
                                               >= 0.75 of scenarios name
                                               the injected fault #1;
                                               waterfall closure and live
                                               /metrics validity flags
                                               must hold
  pipeline     bubble_fill_fraction            deterministic seeded plan:
                                               medium band + the >= 0.5
                                               contract as abs floor
               projected_mfu_uplift            fill-vs-no-fill MFU delta;
                                               must stay positive (flag)
                                               and within band; pipeline-
                                               mode waterfall closure
                                               flag must hold

Usage:
    python -m benchmarks.check_regression --fresh-dir /tmp
    python -m benchmarks.check_regression --fresh-dir /tmp --update

``--update`` rewrites the committed baseline from the fresh results
(run it when a PR *intentionally* moves a headline metric).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Any, Callable

BASELINE = "BENCH_baseline_smoke.json"


def _mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


@dataclasses.dataclass(frozen=True)
class Metric:
    """One gated headline metric (higher is better)."""

    bench: str  # fresh results file stem, e.g. "BENCH_serving"
    name: str
    extract: Callable[[dict], float]
    rel_tol: float  # fail when fresh < baseline * (1 - rel_tol)
    abs_floor: float = 0.0  # and always fail below this


@dataclasses.dataclass(frozen=True)
class Flag:
    """A boolean invariant that must hold in the fresh results."""

    bench: str
    name: str
    extract: Callable[[dict], bool]


METRICS = [
    Metric("BENCH_dispatch", "aggregate_speedup",
           lambda d: float(d["headline"]["aggregate_speedup"]),
           rel_tol=0.5, abs_floor=1.5),
    Metric("BENCH_attention", "mean_skip_fraction",
           lambda d: _mean(r["skip_fraction"] for r in d["rows"]),
           rel_tol=0.1, abs_floor=0.25),
    Metric("BENCH_attention", "min_skip_fraction",
           lambda d: min(r["skip_fraction"] for r in d["rows"]),
           rel_tol=0.1),
    Metric("BENCH_serving", "slot_throughput_speedup",
           lambda d: float(d["slot_throughput_speedup"]),
           rel_tol=0.15, abs_floor=2.0),
    Metric("BENCH_calibration", "recovered_fraction",
           lambda d: float(d["recovered_fraction"]),
           rel_tol=0.2, abs_floor=0.8),
    Metric("BENCH_kernels", "moe_dropfree_flop_ratio",
           lambda d: float(d["headline"]["moe_dropfree_flop_ratio"]),
           rel_tol=0.1, abs_floor=1.1),
    Metric("BENCH_kernels", "ssm_state_traffic_ratio",
           lambda d: float(d["headline"]["ssm_state_traffic_ratio"]),
           rel_tol=0.1, abs_floor=2.0),
    # Wall-time ratio (noisy on shared runners), but the default block
    # shape is inside the sweep so the winner can never be slower:
    # gate only on the >= 1.0 invariant.
    Metric("BENCH_kernels", "autotune_best_speedup",
           lambda d: float(d["headline"]["autotune_best_speedup"]),
           rel_tol=1.0, abs_floor=1.0),
    # Observability must stay effectively free: efficiency is
    # 1 - obs_cost / (2%-budget reference step), floored at the <2%
    # overhead contract (see benchmarks/observability_overhead.py).
    Metric("BENCH_observability", "metrics_efficiency",
           lambda d: float(d["headline"]["metrics_efficiency"]),
           rel_tol=0.02, abs_floor=0.98),
    # Root-cause attribution: fraction of injected faults named as the
    # #1 ranked triage cause (benchmarks/triage_accuracy.py).
    Metric("BENCH_triage", "triage_top1_accuracy",
           lambda d: float(d["headline"]["triage_top1_accuracy"]),
           rel_tol=0.1, abs_floor=0.75),
    # Pipeline bubble fill: seeded plan-only runs are deterministic;
    # the abs floor is the docs/pipeline.md >= 0.5 fill contract.
    Metric("BENCH_pipeline", "bubble_fill_fraction",
           lambda d: float(d["headline"]["bubble_fill_fraction"]),
           rel_tol=0.15, abs_floor=0.5),
    Metric("BENCH_pipeline", "projected_mfu_uplift",
           lambda d: float(d["headline"]["projected_mfu_uplift"]),
           rel_tol=0.25, abs_floor=0.02),
]

FLAGS = [
    Flag("BENCH_serving", "streams_match",
         lambda d: bool(d["streams_match"])),
    Flag("BENCH_calibration", "within_5pct_of_oracle",
         lambda d: bool(d["within_5pct_of_oracle"])),
    Flag("BENCH_dispatch", "max_cost_match",
         lambda d: all(r["max_cost_match"] for r in d["rows"])),
    Flag("BENCH_kernels", "moe_grouped_dense_parity",
         lambda d: all(r["parity_max_err"] < 1e-4
                       and r["grad_parity_max_err"] < 1e-4
                       for r in d["moe"])),
    Flag("BENCH_kernels", "ssm_pallas_scan_parity",
         lambda d: all(r["parity_max_err"] < 1e-4
                       and r["grad_parity_max_err"] < 1e-4
                       for r in d["ssm"])),
    Flag("BENCH_observability", "exports_valid",
         lambda d: bool(d["headline"]["exports_valid"])),
    # Waterfall closure: on truthful-cost scenarios the unattributed
    # residual stays <= 5% of the gap per step (cost-drift excluded by
    # the benchmark -- blowing the residual up there is the detector).
    Flag("BENCH_triage", "waterfall_closure_ok",
         lambda d: bool(d["headline"]["waterfall_closure_ok"])),
    # Live aggregated /metrics endpoint parses strictly across scrapes.
    Flag("BENCH_triage", "metrics_endpoint_valid",
         lambda d: bool(d["headline"]["metrics_endpoint_valid"])),
    # Bubble fill must never cost MFU, and the pipeline-mode waterfall
    # (pipeline_bubble_s{k} components) must stay closure-checked <= 5%.
    Flag("BENCH_pipeline", "mfu_uplift_positive",
         lambda d: float(d["headline"]["projected_mfu_uplift"]) > 0.0),
    Flag("BENCH_pipeline", "waterfall_closure_ok",
         lambda d: bool(d["headline"]["waterfall_closure_ok"])),
]


def _load(path: str) -> dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def collect(fresh_dir: str) -> tuple[dict[str, float], list[str]]:
    """Extract every gated metric from the fresh result files."""
    values: dict[str, float] = {}
    failures: list[str] = []
    cache: dict[str, dict] = {}
    for m in METRICS:
        path = os.path.join(fresh_dir, m.bench + ".json")
        if m.bench not in cache:
            cache[m.bench] = _load(path)
        values[f"{m.bench}.{m.name}"] = m.extract(cache[m.bench])
    for fl in FLAGS:
        path = os.path.join(fresh_dir, fl.bench + ".json")
        if fl.bench not in cache:
            cache[fl.bench] = _load(path)
        if not fl.extract(cache[fl.bench]):
            failures.append(f"FLAG {fl.bench}.{fl.name} does not hold")
    return values, failures


def compare(values: dict[str, float], baseline: dict[str, float]) -> list[str]:
    failures = []
    for m in METRICS:
        key = f"{m.bench}.{m.name}"
        fresh = values[key]
        base = baseline.get(key)
        if base is None:
            # A gated metric with no committed baseline must fail loudly
            # (someone added a Metric without running --update), never
            # silently pass with floor=0.
            failures.append(
                f"{key} has no committed baseline entry; run "
                f"`python -m benchmarks.check_regression --fresh-dir ... "
                f"--update` and commit {BASELINE}")
            continue
        floor_parts = [base * (1.0 - m.rel_tol)]
        if m.abs_floor:
            floor_parts.append(m.abs_floor)
        floor = max(floor_parts)
        status = "OK " if fresh >= floor else "FAIL"
        print(f"{status} {key}: fresh={fresh:.4f} "
              f"baseline={base if base is not None else 'n/a'} "
              f"floor={floor:.4f} (rel_tol={m.rel_tol:.0%})")
        if fresh < floor:
            failures.append(
                f"{key} regressed: {fresh:.4f} < floor {floor:.4f} "
                f"(baseline {base}, rel_tol {m.rel_tol:.0%})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", required=True,
                    help="directory holding the fresh BENCH_*.json smoke "
                         "results")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: {BASELINE} next to the "
                         f"repo's committed artifacts)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh results "
                         "instead of gating")
    args = ap.parse_args()
    baseline_path = args.baseline or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), BASELINE)

    values, flag_failures = collect(args.fresh_dir)
    if args.update:
        doc = {
            "note": "Committed smoke-run headline metrics; CI's "
                    "bench-regression step gates fresh --smoke runs "
                    "against these with per-metric tolerance bands "
                    "(benchmarks/check_regression.py).",
            "metrics": values,
        }
        with open(baseline_path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"baseline updated: {baseline_path}")
        if flag_failures:
            print("\n".join(flag_failures))
            sys.exit(1)
        return

    baseline = _load(baseline_path)["metrics"]
    failures = flag_failures + compare(values, baseline)
    if failures:
        print("\nBENCH REGRESSION:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print("\nbench-regression OK: all headline metrics within tolerance")


if __name__ == "__main__":
    main()
