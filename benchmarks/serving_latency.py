"""Serving benchmark: fixed-batch decode vs the continuous-batching engine.

Both arms serve the same bursty heterogeneous trace (synthetic
multimodal examples from ``data.synthetic``: prompt lengths and
generation budgets are heavy-tailed, per Modality Composition
Incoherence at serving time) with greedy sampling, so they produce the
IDENTICAL per-request token streams -- the benchmark cross-checks this
-- and differ only in scheduling:

  fixed       today's ``serve_step`` pattern: requests are taken in
              arrival order in fixed batches of ``batch_size``; each
              batch pads every prompt to the group max and decodes
              until the LAST member finishes.
  continuous  the engine: iteration-level scheduling over the paged KV
              pool with post-balanced token-budget admission.

The headline metric is deterministic on any host: ``token_slots`` = the
padded (sequence, position) decode-step computations each arm executes
(padding waste included), so slot throughput = useful tokens / slots.
Wall-clock tok/s is reported too but jitter-prone on CI.  ``--check``
asserts continuous batching reaches >= 2x the fixed-batch slot
throughput on the imbalanced trace (the ISSUE 3 acceptance bar).

    PYTHONPATH=src python -m benchmarks.serving_latency [--smoke] \
        [--check] [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.serving_latency`

import jax
import jax.numpy as jnp

from repro.configs import EngineConfig, get_config
from repro.data.synthetic import TaskMix, sample_examples
from repro.models.model import init_params
from repro.serving.engine import Engine, requests_from_examples
from repro.serving.serve_step import init_cache, make_serve_step

ARCH = "olmo_1b"


def build_trace(cfg, n_requests, *, seed=1, max_total_len=448, burst=6,
                burst_gap=4):
    """Bursty heavy-tailed trace: synthetic multimodal prefill lengths
    (scaled to serving size) + heavy-tailed generation budgets.  The
    heterogeneity is the point: a fixed batch pads every prompt to its
    longest member and decodes until its slowest member finishes."""
    rng = np.random.default_rng(seed)
    examples = sample_examples(rng, n_requests, TaskMix(), ("vision", "audio"))
    reqs = requests_from_examples(
        examples, vocab=cfg.vocab_size, max_total_len=max_total_len, rng=rng,
        max_new_lo=2, max_new_hi=5, length_scale=16,
        arrival_step_fn=lambda i: burst_gap * (i // burst))
    # Heavy-tailed max_new: most requests stop quickly, a few run long.
    for r in reqs:
        if rng.random() < 0.25:
            r.max_new_tokens = int(rng.integers(64, 97))
    return reqs


def run_fixed_batch(cfg, params, requests, *, batch_size, seq_len):
    """Static batching baseline: groups of ``batch_size`` in arrival
    order; batch b+1 starts only when batch b fully drains."""
    serve = jax.jit(make_serve_step(cfg))
    outputs = {}
    slots = 0
    steps_total = 0
    wall = 0.0
    reqs = sorted(requests, key=lambda r: (r.arrival_step, r.req_id))
    for g in range(0, len(reqs), batch_size):
        group = reqs[g : g + batch_size]
        B = len(group)
        max_prompt = max(r.prompt_len for r in group)
        prompts = np.zeros((B, max_prompt), np.int32)
        lens = np.array([r.prompt_len for r in group])
        for i, r in enumerate(group):
            prompts[i, : r.prompt_len] = r.prompt
        cache = init_cache(cfg, B, seq_len)
        tok = jnp.asarray(prompts[:, :1])
        outs = [[] for _ in range(B)]
        t0 = time.perf_counter()
        # Row r's last token lands at step (prompt_len - 1) + max_new - 1;
        # the batch drains when its slowest member does.
        n_steps = max(r.prompt_len + r.max_new_tokens - 1 for r in group)
        for t in range(n_steps):
            nxt, _, cache = serve(params, tok, cache, jnp.int32(t))
            nxt_np = np.asarray(nxt)
            for i in range(B):
                if t >= lens[i] - 1 and len(outs[i]) < group[i].max_new_tokens:
                    outs[i].append(int(nxt_np[i, 0]))
            feed = np.where(t + 1 < lens,
                            prompts[:, min(t + 1, max_prompt - 1)], nxt_np[:, 0])
            tok = jnp.asarray(feed[:, None].astype(np.int32))
        wall += time.perf_counter() - t0
        slots += B * n_steps
        steps_total += n_steps
        for r, o in zip(group, outs):
            outputs[r.req_id] = o
    useful = sum(r.prompt_len for r in reqs) + sum(len(o) for o in outputs.values())
    generated = sum(len(o) for o in outputs.values())
    return {
        "mode": "fixed",
        "batch_size": batch_size,
        "token_slots": int(slots),
        "useful_tokens": int(useful),
        "generated_tokens": int(generated),
        "slot_throughput": useful / slots,
        "steps": int(steps_total),
        "wall_s": round(wall, 3),
        "wall_tok_s": round(generated / wall, 1) if wall else 0.0,
    }, outputs


def run_continuous(cfg, params, requests, *, engine_cfg):
    engine = Engine(cfg, engine_cfg, params)
    report = engine.run(requests)
    outputs = {r.req_id: list(r.output_tokens) for r in engine.requests}
    useful = report.prompt_tokens + report.generated_tokens
    return {
        "mode": "continuous",
        "token_budget": engine_cfg.token_budget,
        "max_num_seqs": engine_cfg.max_num_seqs,
        "num_blocks": engine_cfg.num_blocks,
        "token_slots": int(report.token_slots),
        "useful_tokens": int(useful),
        "generated_tokens": int(report.generated_tokens),
        "slot_throughput": useful / report.token_slots,
        "steps": int(report.n_steps),
        "preemptions": int(report.n_preemptions),
        "recompute_tokens": int(report.recompute_tokens),
        "ttft_steps_mean": round(report.ttft_steps_mean, 2),
        "occupancy_mean": round(report.occupancy_mean, 3),
        "wall_s": round(report.wall_s, 3),
        "wall_tok_s": round(report.throughput_tok_s, 1),
    }, outputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="assert continuous >= 2x fixed slot throughput "
                         "and identical token streams")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args(argv)

    n_requests = args.requests or (16 if args.smoke else 32)
    cfg = get_config(ARCH).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = build_trace(cfg, n_requests)
    seq_len = 544  # max prompt (<= 448) + heavy-tail max_new (96)
    engine_cfg = EngineConfig(block_size=16, num_blocks=273, max_num_seqs=8,
                              token_budget=1024, max_model_len=seq_len,
                              prefill_pad=16, decode_pad=2)

    fixed, fixed_out = run_fixed_batch(
        cfg, params, [r for r in build_trace(cfg, n_requests)],
        batch_size=8, seq_len=seq_len)
    cont, cont_out = run_continuous(cfg, params, trace, engine_cfg=engine_cfg)
    streams_match = fixed_out == cont_out
    speedup = cont["slot_throughput"] / fixed["slot_throughput"]

    doc = {
        "benchmark": "serving_latency",
        "arch": ARCH + "-smoke",
        "n_requests": n_requests,
        "smoke": bool(args.smoke),
        "trace": "bursty heterogeneous (synthetic multimodal, heavy-tailed "
                 "prompts and max_new)",
        "rows": [fixed, cont],
        "slot_throughput_speedup": round(speedup, 2),
        "streams_match": bool(streams_match),
        "wall_note": "wall times on the CPU smoke model are dominated by "
                     "XLA compiles of the engine's distinct prefill shapes; "
                     "slot_throughput is the deterministic metric CI checks",
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(json.dumps(doc, indent=1))

    if args.check:
        assert streams_match, "continuous and fixed-batch token streams differ"
        assert speedup >= 2.0, (
            f"continuous batching is only {speedup:.2f}x fixed-batch "
            f"slot throughput (need >= 2x)")
        print(f"CHECK OK: {speedup:.2f}x >= 2x, streams identical")
    return doc


if __name__ == "__main__":
    main()
