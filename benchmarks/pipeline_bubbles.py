"""Pipeline bubble-fill benchmark: how much 1F1B idle time do the
encoder microbatches reclaim, and what is that worth in MFU?

Runs the full planning stack on the staged 84B recipe
(``repro.configs.mllm_84b.STAGED_CONFIG``: pp=4, 16 microbatches):
per-phase Batch Post-Balancing dispatchers -> LPT microbatch split ->
event-driven 1F1B simulation -> EDF encoder bubble fill with the
DIP-style cross-iteration steady-state pass (docs/pipeline.md).  The
baseline is the SAME post-balanced plan with ``bubble_fill=False``,
where every encoder microbatch runs as pipeline prologue/epilogue --
identical work, so the comparison isolates the scheduler.

Headline metrics (gated by ``benchmarks/check_regression.py``):

  * ``bubble_fill_fraction`` -- encoder compute placed inside 1F1B
    warm-up/cool-down bubbles as a fraction of the theoretical bubble
    time ``pp * makespan - busy`` (gate: >= 0.5);
  * ``projected_mfu_uplift`` -- projected MFU (useful compute over
    ``d * pp * critical rank time``) of the filled schedule minus the
    no-fill baseline (gate: > 0);
  * ``waterfall_closure_ok`` -- the pipeline-mode gap waterfall
    (``pipeline_bubble_s{k}`` components, docs/observability.md) stays
    closure-checked within 5% on a simulated step loop, for BOTH the
    filled and the no-fill schedule.  Step times are synthesized from
    the plan's critical cost with small measurement noise; the check is
    out-of-sample because the waterfall attributes with the EWMA scale
    learned from *previous* steps.

Rows sweep pp in {2, 4, 8} and report fill/no-fill makespans, per-stage
partition, fill fraction and solve overhead per (pp, microbatches).

    PYTHONPATH=src python -m benchmarks.pipeline_bubbles [--smoke] \
        [--check] [--out BENCH_pipeline.json]
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.pipeline_bubbles`

from repro.configs.mllm_84b import STAGED_CONFIG
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.synthetic import TaskMix, sample_examples
from repro.obs.decompose import GapWaterfall
from repro.obs.registry import MetricsRegistry

FILL_GATE = 0.5
CLOSURE_GATE = 0.05
D = 8  # DP ranks (per-rank plan; each rank spans pp stage groups)

# Simulated wall-clock for the closure loop: a fixed true cost->ms scale
# the waterfall must re-learn online, plus small step-time noise.  The
# filled schedule's gap is intentionally tiny (that is the feature), so
# its closure check runs at measurement-noise the algebra must beat;
# the no-fill schedule's bubble-dominated gap is checked under coarser
# noise.  Measurement-noise *robustness* at scale is the triage
# benchmark's domain -- this flag checks that the component model
# telescopes out-of-sample.
SCALE_MS_PER_COST = 0.004
EXPOSED_MS = 2.0
NOISE = {"fill": 0.0002, "nofill": 0.002}


def plan_once(per: int, *, pp: int, n_micro: int, bubble_fill: bool,
              seed: int):
    """One plan-only orchestrator pass on the staged config."""
    rng = np.random.default_rng(seed)
    examples = [sample_examples(rng, per, TaskMix(), ("vision", "audio"))
                for _ in range(D)]
    orch = MLLMGlobalOrchestrator(
        STAGED_CONFIG, D, pp=pp, microbatches=n_micro,
        bubble_fill=bubble_fill, vocab=512)
    plans = orch.plan_phases(examples)
    return plans.pipeline


def closure_check(plan, *, steps: int, noise: float, seed: int) -> float:
    """Max out-of-sample closure error of the pipeline-mode waterfall."""
    wf = GapWaterfall(registry=MetricsRegistry())
    crit = float(plan.rank_total.max())
    rng = np.random.default_rng(seed)
    for step in range(steps):
        step_ms = (crit * SCALE_MS_PER_COST * (1.0 + rng.normal(0, noise))
                   + EXPOSED_MS)
        wf.observe(step, step_ms=step_ms, exposed_ms=EXPOSED_MS,
                   pipeline=plan)
    return float(wf.closure()["max_closure_err"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller per-rank batch (CI lane); same schedule "
                         "shape (pp=4, 16 microbatches)")
    ap.add_argument("--check", action="store_true",
                    help="assert the headline gates instead of only "
                         "reporting them")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    per = 64 if args.smoke else 128
    steps = 12 if args.smoke else 24
    sweep = [(2, 8), (4, 16), (8, 32)]
    headline_pp, headline_m = 4, 16

    rows = []
    headline = None
    for pp, m in sweep:
        fill = plan_once(per, pp=pp, n_micro=m, bubble_fill=True,
                         seed=args.seed)
        nofill = plan_once(per, pp=pp, n_micro=m, bubble_fill=False,
                           seed=args.seed)
        assert np.allclose(fill.useful, nofill.useful), \
            "fill/no-fill must compare identical work"
        row = {
            "pp": pp, "n_micro": m, "d": D, "per_rank_examples": per,
            "partition": list(fill.partition),
            "bubble_total": float(fill.bubble_total.sum()),
            "filled": float(fill.filled.sum()),
            "bubble_fill_fraction": fill.fill_fraction,
            "projected_mfu_fill": fill.projected_mfu,
            "projected_mfu_nofill": fill.projected_mfu_nofill,
            "projected_mfu_uplift": fill.mfu_uplift,
            "critical_rank_total_fill": float(fill.rank_total.max()),
            "critical_rank_total_nofill": float(nofill.rank_total.max()),
            "solve_ms": fill.solve_ms,
        }
        if pp == headline_pp and m == headline_m:
            closure = max(
                closure_check(fill, steps=steps, noise=NOISE["fill"],
                              seed=args.seed + 1),
                closure_check(nofill, steps=steps, noise=NOISE["nofill"],
                              seed=args.seed + 2))
            row["waterfall_closure_max"] = closure
            headline = {
                "bubble_fill_fraction": row["bubble_fill_fraction"],
                "projected_mfu_fill": row["projected_mfu_fill"],
                "projected_mfu_nofill": row["projected_mfu_nofill"],
                "projected_mfu_uplift": row["projected_mfu_uplift"],
                "waterfall_closure_max": closure,
                "waterfall_closure_ok": bool(closure <= CLOSURE_GATE),
                "plan_solve_ms": row["solve_ms"],
            }
        rows.append(row)
        print(f"pp={pp} m={m}: fill={row['bubble_fill_fraction']:.3f} "
              f"mfu {row['projected_mfu_nofill']:.3f} -> "
              f"{row['projected_mfu_fill']:.3f} "
              f"(+{row['projected_mfu_uplift']:.3f}) "
              f"solve={row['solve_ms']:.1f}ms")

    assert headline is not None
    doc = {
        "config": {
            "arch": "mllm_84b (STAGED_CONFIG)", "d": D,
            "per_rank_examples": per, "headline_pp": headline_pp,
            "headline_microbatches": headline_m,
            "closure_steps": steps, "seed": args.seed,
            "smoke": args.smoke,
        },
        "headline": headline,
        "rows": rows,
    }
    print(f"\nbubble_fill_fraction={headline['bubble_fill_fraction']:.3f} "
          f"(gate >= {FILL_GATE}) "
          f"projected_mfu_uplift={headline['projected_mfu_uplift']:+.4f} "
          f"(gate > 0) "
          f"waterfall_closure_max={headline['waterfall_closure_max']:.4f} "
          f"(gate <= {CLOSURE_GATE})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {args.out}")
    if args.check:
        assert headline["bubble_fill_fraction"] >= FILL_GATE, \
            f"fill fraction {headline['bubble_fill_fraction']} < {FILL_GATE}"
        assert headline["projected_mfu_uplift"] > 0.0, \
            f"uplift {headline['projected_mfu_uplift']} not positive"
        assert headline["waterfall_closure_ok"], \
            f"closure {headline['waterfall_closure_max']} > {CLOSURE_GATE}"
        print("checks OK")
    return doc


if __name__ == "__main__":
    main()
