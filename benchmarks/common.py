"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.synthetic import TaskMix, sample_examples
from repro.obs.ledger import simulated_mfu


def sample_instances(rng, d, per, modalities=("vision", "audio")):
    return [sample_examples(rng, per, TaskMix(), modalities) for _ in range(d)]


def timed(fn, *args, repeat=3, **kw):
    best = np.inf
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6  # us


def simulated_iteration_utilization(report) -> float:
    """Paper's MFU proxy -- now just the ledger's canonical formula
    (:func:`repro.obs.ledger.simulated_mfu`) applied to the report's
    phase cost vectors; kept as a named alias for existing callers."""
    return simulated_mfu(report.phase_costs)


def orchestrate(arch, d, per, *, balance=True, balance_encoders=True,
                encoder_algorithm_override=None, instances_per_node=None,
                seed=0, margin=3.0, skip_pack=True):
    """Plan-only run (packing skipped for speed when skip_pack)."""
    cfg = get_config(arch)
    rng = np.random.default_rng(seed)
    examples = sample_instances(rng, d, per)
    orch = MLLMGlobalOrchestrator(
        cfg, d, balance=balance, balance_encoders=balance_encoders,
        encoder_algorithm_override=encoder_algorithm_override,
        instances_per_node=instances_per_node, vocab=512,
    )
    if skip_pack:
        report = plan_only(orch, examples)
        return orch, examples, report
    caps = orch.default_capacities(examples, margin=margin)
    batch, report = orch.plan_and_pack(examples, caps, rng)
    return orch, examples, report


def plan_only(orch: MLLMGlobalOrchestrator, examples):
    """Run dispatchers + composition without array packing."""
    plans = orch.plan_phases(examples)
    return orch._report(plans.llm_plan, plans.enc_plans, plans.composed,
                        plans.solve_ms, phase_solve_ms=plans.phase_solve_ms)
