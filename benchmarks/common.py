"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.synthetic import TaskMix, sample_examples


def sample_instances(rng, d, per, modalities=("vision", "audio")):
    return [sample_examples(rng, per, TaskMix(), modalities) for _ in range(d)]


def timed(fn, *args, repeat=3, **kw):
    best = np.inf
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6  # us


def simulated_iteration_utilization(report) -> float:
    """Paper's MFU proxy: one iteration's useful/straggler time over all
    phases (each phase synchronizes across DP, so phase time = max cost)."""
    total_max = sum(report.phase_max_cost.values())
    total_mean = sum(float(np.mean(c)) for c in report.phase_costs.values())
    return total_mean / total_max if total_max else 1.0


def orchestrate(arch, d, per, *, balance=True, balance_encoders=True,
                encoder_algorithm_override=None, instances_per_node=None,
                seed=0, margin=3.0, skip_pack=True):
    """Plan-only run (packing skipped for speed when skip_pack)."""
    cfg = get_config(arch)
    rng = np.random.default_rng(seed)
    examples = sample_instances(rng, d, per)
    orch = MLLMGlobalOrchestrator(
        cfg, d, balance=balance, balance_encoders=balance_encoders,
        encoder_algorithm_override=encoder_algorithm_override,
        instances_per_node=instances_per_node, vocab=512,
    )
    if skip_pack:
        report = plan_only(orch, examples)
        return orch, examples, report
    caps = orch.default_capacities(examples, margin=margin)
    batch, report = orch.plan_and_pack(examples, caps, rng)
    return orch, examples, report


def plan_only(orch: MLLMGlobalOrchestrator, examples):
    """Run dispatchers + composition without array packing."""
    import dataclasses
    import time as _t

    import numpy as _np

    from repro.core.rearrangement import compose
    from repro.core.orchestrator import _remap_subset_slots

    cfg = orch.cfg
    t0 = _t.perf_counter()
    key = "text" if cfg.family == "audio" else "total"
    llm_lengths = [
        _np.array([ex.text_len if key == "text" else ex.total_len(orch.downsample)
                   for ex in insts], _np.int64)
        for insts in examples
    ]
    llm_plan = orch.llm_dispatcher.plan(llm_lengths)
    enc_plans, composed = {}, {}
    for e in cfg.encoders:
        lens = [
            _np.array([getattr(ex, f"{e.name}_meta") for ex in insts
                       if getattr(ex, f"{e.name}_meta") > 0], _np.int64)
            for insts in examples
        ]
        plan = orch.enc_dispatchers[e.name].plan(lens)
        enc_plans[e.name] = plan
        pi_e = _remap_subset_slots(plan.pi, examples, e.name)
        comp = compose(llm_plan.pi, pi_e)
        comp = dataclasses.replace(
            comp, lengths=_np.ceil(comp.lengths / e.downsample).astype(_np.int64))
        composed[e.name] = comp
    solve_ms = (_t.perf_counter() - t0) * 1e3
    return orch._report(llm_plan, enc_plans, composed, solve_ms)
