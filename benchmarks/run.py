"""Benchmark harness -- one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]

Output: ``name,us_per_call,derived`` CSV rows, one per measurement, plus
a trailing comment line per benchmark comparing against the paper's own
claim (reproduction check).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.run` from repo root

from repro.configs import get_config
from repro.core.balancing import post_balance
from repro.core.cost_model import CostModel
from repro.core.nodewise import nodewise_rearrange
from repro.data.synthetic import modality_ratio_stats, sample_examples

from benchmarks.common import (
    orchestrate,
    plan_only,
    sample_instances,
    simulated_iteration_utilization,
    timed,
)

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str) -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def note(text: str) -> None:
    print(f"# {text}", flush=True)


# ----------------------------------------------------------------------
# Fig. 3: Modality Composition Incoherence in the synthetic mix.
# ----------------------------------------------------------------------
def bench_incoherence(quick=False):
    rng = np.random.default_rng(0)
    n = 2_000 if quick else 20_000
    (examples), us = timed(lambda: sample_examples(rng, n), repeat=1)
    stats = modality_ratio_stats(examples, {"vision": 1, "audio": 2})
    for mod in ("vision", "audio"):
        r = stats[mod]
        emit(f"incoherence_{mod}_ratio_std", us / n,
             f"mean={r.mean():.3f} std={r.std():.3f} p95={np.percentile(r, 95):.3f}")
    note("paper Fig.3: both modality ratios 'bear substantial variance' -> "
         "std well above 0.1 reproduces the premise")


# ----------------------------------------------------------------------
# Fig. 8/9/10: simulated MFU -- no balance vs LLM-only (pre-balancing
# equivalent) vs full OrchMLLM, for the paper's three MLLM sizes.
# ----------------------------------------------------------------------
def bench_balance_mfu(quick=False):
    d = 32 if quick else 128  # paper microbenchmarks use 128 GPUs
    per = {"mllm_10b": 60, "mllm_18b": 40, "mllm_84b": 20}
    for arch in ("mllm_10b", "mllm_18b", "mllm_84b"):
        p = max(8, per[arch] // (4 if quick else 1))
        utils = {}
        for mode, kw in (
            ("none", dict(balance=False)),
            ("llm_only", dict(balance=True, balance_encoders=False)),
            ("full", dict(balance=True)),
        ):
            (_, _, report), us = timed(
                lambda kw=kw: orchestrate(arch, d, p, **kw), repeat=1)
            u = simulated_iteration_utilization(report)
            utils[mode] = u
            emit(f"mfu_sim_{arch}_{mode}", us, f"util={u:.3f} "
                 + " ".join(f"{k}={v:.2f}" for k, v in report.phase_utilization.items()))
        ratio = utils["full"] / max(utils["none"], 1e-9)
        ratio2 = utils["full"] / max(utils["llm_only"], 1e-9)
        emit(f"mfu_sim_{arch}_speedup", 0.0,
             f"full/none={ratio:.2f}x full/llm_only={ratio2:.2f}x")
    note("paper Fig.8/9: OrchMLLM vs no-balance = 1.5-2.0x; "
         "Fig.10: full > LLM-only balance, gap grows with model size")


# ----------------------------------------------------------------------
# Fig. 11: rigid algorithms (all rmpad / all pad) vs tailored.
# ----------------------------------------------------------------------
def bench_algorithms(quick=False):
    d = 32 if quick else 128
    for arch in ("mllm_10b", "mllm_18b"):
        utils = {}
        for mode, override in (("tailored", None), ("all_rmpad", "nopad"),
                               ("all_pad", "pad")):
            (_, _, report), us = timed(
                lambda o=override: orchestrate(
                    arch, d, 24, encoder_algorithm_override=o), repeat=1)
            u = simulated_iteration_utilization(report)
            utils[mode] = u
            emit(f"algo_{arch}_{mode}", us, f"util={u:.3f}")
        emit(f"algo_{arch}_tailored_gain", 0.0,
             f"vs_rmpad={utils['tailored'] / utils['all_rmpad']:.3f}x "
             f"vs_pad={utils['tailored'] / utils['all_pad']:.3f}x")
    note("paper Fig.11: a single rigid algorithm for all phases loses MFU "
         "vs per-phase tailored algorithms (>= 1.0x gains expected)")


# ----------------------------------------------------------------------
# Fig. 12/13: communicator volume -- All-Gather vs All-to-All; node-wise
# rearrangement inter-node reduction.
# ----------------------------------------------------------------------
def bench_comm_volume(quick=False):
    rng = np.random.default_rng(1)
    d, c = (32, 4) if quick else (64, 8)
    lens = [rng.lognormal(5.5, 0.8, size=24).astype(np.int64) + 1 for _ in range(d)]
    cm = CostModel()
    pi, us = timed(lambda: post_balance(lens, d, cm), repeat=3)
    cap = int(max(l.sum() for l in lens))
    total = int(pi.lengths.sum())
    moved = total - pi.self_volume()
    allgather = d * (d - 1) * cap
    emit("comm_allgather_tokens", us, f"volume={allgather}")
    emit("comm_a2a_tokens", us, f"volume={moved} "
         f"ratio_vs_allgather={moved / allgather:.4f}")
    note("paper Eq.3 vs Eq.4: All-to-All volume is O(max L_i), All-Gather "
         "O((d-1) max L_i) -> ratio ~ 1/d expected")

    before = int(pi.internode_volume(c).max())
    pi_nw, us2 = timed(lambda: nodewise_rearrange(pi, c), repeat=1)
    after = int(pi_nw.internode_volume(c).max())
    emit("comm_nodewise_internode_max", us2,
         f"before={before} after={after} ratio={after / max(before, 1):.3f}")
    # Per-modality analog on a real multimodal plan:
    _, _, report = orchestrate("mllm_10b", d, 16, instances_per_node=c, seed=3)
    for mod, v in report.comm_volume.items():
        inter = report.internode_volume.get(mod, 0)
        emit(f"comm_nodewise_{mod}", 0.0,
             f"total={v['total']} self={v['self']} internode_max={inter}")
    note("paper Fig.13: node-wise rearrangement cuts inter-node volume to "
         "0.436-0.722x of the plain plan")


# ----------------------------------------------------------------------
# Table 2: dispatcher overhead vs cluster size.
# ----------------------------------------------------------------------
def bench_overhead(quick=False):
    sizes = (64, 128, 256) if quick else (64, 128, 256, 512, 1024, 2560)
    rng = np.random.default_rng(2)
    for d in sizes:
        examples = sample_instances(rng, d, 8)
        cfg = get_config("mllm_10b")
        from repro.core.orchestrator import MLLMGlobalOrchestrator

        orch = MLLMGlobalOrchestrator(cfg, d, vocab=512)
        report, us = timed(lambda: plan_only(orch, examples), repeat=1)
        emit(f"overhead_d{d}", us, f"solve_ms={report.solve_ms:.1f}")
    note("paper Table 2: overhead 16.7ms @64 -> 53.9ms @2560 GPUs (<2% of "
         "fwd); ours is host-side solve time (comm overlapped per S6)")


# ----------------------------------------------------------------------
# Dispatcher latency: python vs vectorized backends + overlap harness
# (full grid in benchmarks.dispatch_latency -> BENCH_dispatch.json).
# ----------------------------------------------------------------------
def bench_dispatch_latency(quick=False):
    from benchmarks.dispatch_latency import bench_backends, bench_overlap

    ns = (1024,) if quick else (4096, 16384)
    ds = (64,) if quick else (64, 256)
    for r in bench_backends(ns, ds, repeat=3 if quick else 5):
        emit(f"dispatch_{r['algorithm']}_n{r['n']}_d{r['d']}",
             r["python_ms"] * 1e3,
             f"vectorized_ms={r['vectorized_ms']} speedup={r['speedup']}x")
    ov = bench_overlap(steps=4 if quick else 8, forward_ms=30.0,
                       d=8, per=4)
    emit("dispatch_overlap_exposed", ov["mean_exposed_ms"] * 1e3,
         f"solve_ms={ov['mean_solve_ms']} hidden={ov['hidden_fraction']}")
    note("paper Table 2 analog: dispatcher solve is host-side and "
         "overlapped; BENCH_dispatch.json carries the committed full grid")


# ----------------------------------------------------------------------
# Kernel microbench: Pallas (interpret) vs pure-jnp reference.
# ----------------------------------------------------------------------
def bench_kernels(quick=False):
    import jax.numpy as jnp

    from repro.kernels.ops import flash_attention_op, selective_scan_op
    from repro.kernels.ref import flash_attention_ref, selective_scan_ref

    rng = np.random.default_rng(3)
    B, H, T, D = 1, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg = jnp.ones((B, T), jnp.int32)
    pos = jnp.asarray(np.arange(T, dtype=np.int32)[None])
    args = (q, q, q, seg, seg, pos, pos)
    out_k, us_k = timed(lambda: flash_attention_op(*args, interpret=True)
                        .block_until_ready())
    out_r, us_r = timed(lambda: flash_attention_ref(*args).block_until_ready())
    err = float(np.abs(np.asarray(out_k) - np.asarray(out_r)).max())
    emit("kernel_flash_attn_interpret", us_k, f"max_err_vs_ref={err:.2e}")
    emit("kernel_flash_attn_ref", us_r, "oracle")

    T2, di, N = 256, 128, 16
    u = jnp.asarray(rng.normal(size=(T2, di)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.05, 0.02, size=(T2, di))), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(1, 0.3, size=(di, N))), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(T2, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(T2, N)), jnp.float32)
    Dv = jnp.zeros((di,), jnp.float32)
    sg = jnp.ones((T2,), jnp.int32)
    out_k, us_k = timed(lambda: selective_scan_op(
        u, dt, A, Bm, Cm, Dv, sg, block_d=64, chunk=64, interpret=True
    ).block_until_ready())
    out_r, us_r = timed(lambda: selective_scan_ref(
        u, dt, A, Bm, Cm, Dv, sg).block_until_ready())
    err = float(np.abs(np.asarray(out_k) - np.asarray(out_r)).max())
    emit("kernel_selective_scan_interpret", us_k, f"max_err_vs_ref={err:.2e}")
    emit("kernel_selective_scan_ref", us_r, "oracle")
    note("interpret mode prices correctness, not TPU speed; see "
         "EXPERIMENTS.md roofline for the compiled-path analysis")


BENCHES = {
    "incoherence": bench_incoherence,
    "balance_mfu": bench_balance_mfu,
    "algorithms": bench_algorithms,
    "comm_volume": bench_comm_volume,
    "overhead": bench_overhead,
    "dispatch_latency": bench_dispatch_latency,
    "kernels": bench_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        note(f"=== {name} ===")
        fn(quick=args.quick)
    note(f"total wall time {time.time() - t0:.1f}s; {len(ROWS)} rows")


if __name__ == "__main__":
    main()
