"""Dispatcher-latency benchmark: python vs vectorized post-balancing.

Times all four Post-Balancing algorithms through ``post_balance`` for
both backends over n (total examples) x d (DP instances) grids, asserts
objective parity while doing so, and runs the plan-ahead overlap harness
(a dry-run training loop with a simulated forward pass) to measure how
much dispatcher host time stays exposed on the critical path.

    PYTHONPATH=src python -m benchmarks.dispatch_latency [--smoke] \
        [--out BENCH_dispatch.json]

The committed ``BENCH_dispatch.json`` is the full run; CI re-runs the
``--smoke`` grid on every push.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.dispatch_latency`

from repro.core.balancing import post_balance
from repro.core.cost_model import CostModel

FULL_NS = (256, 1024, 4096, 16384)
FULL_DS = (8, 64, 256)
SMOKE_NS = (256, 1024)
SMOKE_DS = (8, 64)

ALGOS = {
    "nopad": CostModel(alpha=1.0, beta=0.0),
    "pad": CostModel(alpha=1.0, beta=1e-4, padding=True),
    "quad": CostModel(alpha=1.0, beta=1e-3),
    "conv": CostModel(alpha=1.0, beta=1e-3, conv_attention=True),
}


def _lengths(rng: np.random.Generator, n: int, d: int) -> list[np.ndarray]:
    """Heavy-tailed per-instance lengths (lognormal, the MLLM regime)."""
    per = max(1, n // d)
    return [(rng.lognormal(5.5, 0.8, size=per).astype(np.int64) + 1)
            for _ in range(d)]


def _timed(fn, repeat: int) -> float:
    best = np.inf
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def _max_cost(pi, cm: CostModel) -> float:
    return float(cm.segment_costs(pi.lengths, pi.dst_inst, pi.d).max())


def bench_backends(ns, ds, repeat: int) -> list[dict]:
    rows = []
    for n in ns:
        for d in ds:
            if n < d:
                continue
            rng = np.random.default_rng(hash((n, d)) % (2**32))
            lens = _lengths(rng, n, d)
            for algo, cm in ALGOS.items():
                pi_py = post_balance(lens, d, cm, algorithm=algo,
                                     backend="python")
                pi_vec = post_balance(lens, d, cm, algorithm=algo,
                                      backend="vectorized")
                mc_py, mc_vec = _max_cost(pi_py, cm), _max_cost(pi_vec, cm)
                assert abs(mc_py - mc_vec) <= 1e-9 * max(abs(mc_py), 1.0), (
                    f"objective mismatch {algo} n={n} d={d}: "
                    f"python={mc_py} vectorized={mc_vec}")
                t_py = _timed(
                    lambda: post_balance(lens, d, cm, algorithm=algo,
                                         backend="python"), repeat)
                t_vec = _timed(
                    lambda: post_balance(lens, d, cm, algorithm=algo,
                                         backend="vectorized"), repeat)
                rows.append({
                    "n": n, "d": d, "algorithm": algo,
                    "python_ms": round(t_py, 3),
                    "vectorized_ms": round(t_vec, 3),
                    "speedup": round(t_py / t_vec, 2),
                    "max_cost_match": True,
                })
                print(f"n={n:6d} d={d:4d} {algo:5s}  "
                      f"python {t_py:8.2f} ms  vectorized {t_vec:7.2f} ms  "
                      f"{t_py / t_vec:6.1f}x", flush=True)
    return rows


def bench_overlap(steps: int, forward_ms: float, d: int, per: int) -> dict:
    """Dry-run overlap harness: PrefetchingLoader in plan-ahead mode vs a
    simulated forward pass; exposed dispatcher latency should be ~0."""
    from repro.configs import get_config
    from repro.core.orchestrator import MLLMGlobalOrchestrator
    from repro.data.pipeline import PrefetchingLoader
    from repro.data.synthetic import sample_examples

    cfg = get_config("mllm_10b").smoke()
    orch = MLLMGlobalOrchestrator(cfg, d, vocab=256, concurrent_dispatch=True)
    rng = np.random.default_rng(0)
    probe = [sample_examples(rng, per) for _ in range(d)]
    # Generous margin so pathological draws don't trigger resampling
    # mid-measurement (a resample restarts that step's plan cold).
    caps = orch.default_capacities(probe, margin=6.0)
    loader = PrefetchingLoader(orch, caps, examples_per_instance=per,
                               seed=1, plan_ahead=True)
    solve, exposed = [], []
    try:
        for _ in range(steps):
            batch, report, _ = next(loader)
            solve.append(report.solve_ms)
            exposed.append(report.exposed_ms)
            time.sleep(forward_ms / 1e3)  # the "forward pass"
    finally:
        loader.close()
    # Step 0 has no previous step to hide behind -- report it apart from
    # the steady state the acceptance criterion is about.
    ss_solve, ss_exposed = solve[1:] or solve, exposed[1:] or exposed
    out = {
        "steps": steps,
        "forward_ms": forward_ms,
        "warmup_exposed_ms": round(float(exposed[0]), 3),
        "mean_solve_ms": round(float(np.mean(ss_solve)), 3),
        "mean_exposed_ms": round(float(np.mean(ss_exposed)), 3),
        "hidden_fraction": round(
            1.0 - float(np.sum(ss_exposed)) / max(float(np.sum(ss_solve)), 1e-9),
            4),
        "loader_stats": {k: round(v, 3) for k, v in
                         loader.overlap_stats().items()},
    }
    print(f"overlap: solve {out['mean_solve_ms']:.2f} ms/step, exposed "
          f"{out['mean_exposed_ms']:.3f} ms/step steady-state "
          f"({out['hidden_fraction']*100:.1f}% hidden; warmup step "
          f"{out['warmup_exposed_ms']:.2f} ms)", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + short overlap run (CI)")
    ap.add_argument("--out", default="BENCH_dispatch.json")
    ap.add_argument("--repeat", type=int, default=None)
    args = ap.parse_args()

    ns, ds = (SMOKE_NS, SMOKE_DS) if args.smoke else (FULL_NS, FULL_DS)
    repeat = args.repeat or (3 if args.smoke else 10)
    rows = bench_backends(ns, ds, repeat)

    # Headline: aggregate dispatcher latency at the largest grid point.
    n_h, d_h = max(ns), max(ds)
    head = [r for r in rows if r["n"] == n_h and r["d"] == d_h]
    agg_py = sum(r["python_ms"] for r in head)
    agg_vec = sum(r["vectorized_ms"] for r in head)
    # Forward time is a stand-in for the device step; the paper's regime
    # has forward >> solve (Table 2: <= 54 ms solve vs multi-second
    # steps), so 150 ms already over-represents the dispatcher's share.
    overlap = bench_overlap(steps=4 if args.smoke else 12,
                            forward_ms=60.0 if args.smoke else 150.0,
                            d=8 if args.smoke else 16,
                            per=4 if args.smoke else 8)
    result = {
        "benchmark": "dispatch_latency",
        "distribution": "lognormal(5.5, 0.8)",
        "repeat": repeat,
        "rows": rows,
        "headline": {
            "n": n_h, "d": d_h,
            "aggregate_python_ms": round(agg_py, 2),
            "aggregate_vectorized_ms": round(agg_vec, 2),
            "aggregate_speedup": round(agg_py / agg_vec, 2),
            "per_algorithm_speedup": {r["algorithm"]: r["speedup"]
                                      for r in head},
        },
        "overlap": overlap,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
        f.write("\n")
    print(f"headline @ n={n_h} d={d_h}: aggregate "
          f"{agg_py:.1f} -> {agg_vec:.1f} ms "
          f"({agg_py / agg_vec:.1f}x); wrote {args.out}")


if __name__ == "__main__":
    main()
