"""Calibration-gain benchmark: mis-calibrated vs online-calibrated vs
oracle cost coefficients, on the full orchestrator (ISSUE 4).

Three arms plan the SAME synthetic multimodal example stream (identical
tokens/streams -- calibration changes only the plan, never the math):

  miscalibrated  every phase's f(S) starts 3x off on the quadratic
                 coefficient and never moves (today's static priors
                 when the analytic derivation mis-models the hardware)
  adaptive       the same 3x-off priors behind ``AdaptiveOrchestration``:
                 each step's simulated per-shard phase times (oracle
                 cost + 3% noise -- the "hardware") are fed back through
                 ``observe_phase_times`` and the NNLS fit swaps in
                 calibrated coefficients once confident
  oracle         the true coefficients, known a priori (upper bound)

The headline metric is deterministic on any host (seeded rng, host-time
free): the ORACLE-cost imbalance ``sum_phase max_i f*(S_i) / sum_phase
mean_i f*(S_i)`` of each arm's plans -- i.e. how long the straggler
shard makes everyone wait, priced by what the hardware actually costs,
summed over the per-phase sync points.  ``--check`` asserts (a) online
calibration recovers >= 80% of the oracle-vs-miscalibrated gap and (b)
the calibrated arm lands within 5% of the oracle arm's imbalance
(ISSUE 4 acceptance bar), both on the post-warmup half of the run.

    PYTHONPATH=src python -m benchmarks.calibration_gain [--smoke] \
        [--check] [--out BENCH_calibration.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.calibration_gain`

from repro.configs import get_config
from repro.core.cost_model import encoder_cost_model, llm_cost_model
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.telemetry import AdaptiveOrchestration
from benchmarks.common import sample_instances

ARCH = "mllm_10b"  # packed LLM + packed vision + padded/conv audio:
                   # every f(S) variant calibrates in one run
MISCAL = 3.0  # the prior's quadratic coefficient is 3x the true one
NOISE = 0.03  # relative noise on simulated phase times

# "Hardware" quadratic/linear ratios (pronounced attention fractions on
# bimodal synthetic lengths, so a 3x-off prior measurably mis-balances).
ORACLE_LAM = {"llm": 8e-4, "vision": 1.5e-3, "audio": 4e-4}


def phase_models(cfg):
    oracle = {
        "llm": llm_cost_model(cfg).with_coeffs(1.0, ORACLE_LAM["llm"]),
    }
    for e in cfg.encoders:
        oracle[e.name] = encoder_cost_model(e).with_coeffs(
            1.0, ORACLE_LAM[e.name])
    prior = {k: m.with_coeffs(m.alpha, m.beta * MISCAL)
             for k, m in oracle.items()}
    return oracle, prior


def make_orch(cfg, d, models=None, adaptive=None):
    o = MLLMGlobalOrchestrator(cfg, d, vocab=512, adaptive=adaptive)
    if models:
        o.llm_dispatcher.cost_model = models["llm"]
        for n, disp in o.enc_dispatchers.items():
            disp.cost_model = models[n]
    return o


def oracle_imbalance(plans, oracle):
    """sum_phase max f*(S) / sum_phase mean f*(S) of one step's plans."""
    tot_max = tot_mean = 0.0
    for ph, F in plans.features.items():
        c = oracle[ph].cost_from_features(F)
        tot_max += float(c.max())
        tot_mean += float(c.mean())
    return tot_max / tot_mean


def run(d, per, steps, *, seed=0):
    cfg = get_config(ARCH)
    oracle, prior = phase_models(cfg)
    noise_rng = np.random.default_rng(seed)
    arms = {
        "miscalibrated": make_orch(cfg, d, models=prior),
        "adaptive": make_orch(
            cfg, d, adaptive=AdaptiveOrchestration(priors=prior)),
        "oracle": make_orch(cfg, d, models=oracle),
    }
    imb = {k: [] for k in arms}
    observe_ms = []
    for step in range(steps):
        # Same stream for every arm (and rearrangements never change
        # example payloads), so the arms differ ONLY in the plan.
        examples = sample_instances(np.random.default_rng(1000 + step), d, per)
        for name, orch in arms.items():
            plans = orch.plan_phases(examples)
            imb[name].append(oracle_imbalance(plans, oracle))
            if name == "adaptive":
                times = {
                    ph: oracle[ph].cost_from_features(F)
                    * (1 + noise_rng.normal(0, NOISE, size=d))
                    for ph, F in plans.features.items()
                }
                t0 = time.perf_counter()
                orch.observe_phase_times(times, plans=plans, step=step)
                observe_ms.append((time.perf_counter() - t0) * 1e3)

    half = steps // 2
    mis = float(np.mean(imb["miscalibrated"][half:]))
    orc = float(np.mean(imb["oracle"][half:]))
    cal = float(np.mean(imb["adaptive"][half:]))
    ad = arms["adaptive"].adaptive
    return {
        "arch": ARCH,
        "d": d,
        "per_instance": per,
        "steps": steps,
        "miscalibration": MISCAL,
        "noise": NOISE,
        "oracle_lam": ORACLE_LAM,
        "imbalance": {
            "miscalibrated": mis,
            "adaptive": cal,
            "oracle": orc,
            "adaptive_first10": float(np.mean(imb["adaptive"][:10])),
        },
        # Straggler overhead the mis-fit coefficients cost, and the
        # fraction of it online calibration claws back.
        "gap_miscal_vs_oracle": mis - orc,
        "recovered_fraction": (mis - cal) / (mis - orc) if mis > orc else None,
        "within_5pct_of_oracle": bool(cal <= 1.05 * orc),
        "calibration": {
            ph: {
                "calibrated": m.calibrated,
                "lam_fitted": m.current().lam,
                "lam_true": ORACLE_LAM[ph],
                "lam_prior": ORACLE_LAM[ph] * MISCAL,
                "drift_events": m.drift_events,
            }
            for ph, m in ad.models.items()
        },
        "replans": arms["adaptive"].replans,
        "observe_ms_mean": float(np.mean(observe_ms)),
        "trace_samples": len(ad.trace),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        row = run(d=4, per=16, steps=24)
    else:
        row = run(d=8, per=16, steps=60)

    print(json.dumps(row, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(row, f, indent=1)
            f.write("\n")

    if args.check:
        rec = row["recovered_fraction"]
        assert rec is not None and rec >= 0.8, (
            f"calibration recovered only {rec} of the miscalibration gap "
            f"(need >= 0.8)")
        assert row["within_5pct_of_oracle"], (
            f"calibrated imbalance {row['imbalance']['adaptive']} not within "
            f"5% of oracle {row['imbalance']['oracle']}")
        assert all(c["calibrated"] for c in row["calibration"].values()), (
            f"not every phase reached calibration confidence: "
            f"{row['calibration']}")
        print("CHECK OK: recovered "
              f"{rec:.1%} of the miscalibration gap; calibrated imbalance "
              f"within 5% of oracle")


if __name__ == "__main__":
    main()
