"""Observability overhead benchmark: metrics-on vs metrics-off.

An always-on metrics plane is only acceptable if it is effectively
free.  This benchmark runs the same plan-only orchestration loop on
``mllm_10b`` twice -- once bare, once with the full obs pipeline wired
in exactly as ``launch/train.py`` wires it (a live MetricsRegistry in
the orchestrator, a StepLedger accounting every step, periodic
OpenMetrics rewrites and flight-recorder flushes) -- and isolates the
obs cost per step:

    obs_ms_per_step = (t_metrics_on - t_metrics_off) / steps

The gate compares that cost against a 2% budget of ``REF_STEP_MS``, a
deliberately conservative reference train-step wall time: 50 ms is far
below any real MLLM train step at the paper's scale (the mllm_10b
train_4k roofline projects hundreds of ms on v5e; smoke-config CPU
steps measure in the tens of seconds), so passing here means the obs
plane is <2% of even an implausibly fast step.  A measured-step
denominator would need a jitted train step per CI run (minutes of
compile) and would gate on runner noise instead of on the obs code.

    metrics_efficiency = 1 - obs_ms_per_step / REF_STEP_MS

CI gates ``metrics_efficiency >= 0.98`` via ``check_regression.py``.

    PYTHONPATH=src python -m benchmarks.observability_overhead [--smoke] \
        [--out BENCH_observability.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.observability_overhead`

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.obs import (FlightRecorder, MetricsRegistry, StepLedger,
                       read_flight_record, render_openmetrics,
                       write_openmetrics)

from benchmarks.common import plan_only, sample_instances

# 2% budget denominator: a train step this fast does not exist at the
# paper's scale, so the gate is strictly conservative (see docstring).
REF_STEP_MS = 50.0
FLUSH_EVERY = 10  # matches launch/train.py's --metrics-every default


def _loop(orch, batches, ledger=None, recorder=None, registry=None,
          prom_path=None):
    """One orchestration pass over ``batches``; the metrics-on variant
    does per step and per flush interval exactly what launch/train.py
    does (ledger accounting, OpenMetrics rewrite, flight flush)."""
    for it, examples in enumerate(batches):
        report = plan_only(orch, examples)
        if ledger is not None:
            events = ledger.record_step(
                it, report=report, step_ms=10.0,
                metrics={"loss": 1.0, "tokens": 1024.0})
            for ev in events:
                recorder.record("alert", **ev)
            if it % FLUSH_EVERY == 0:
                write_openmetrics(prom_path, registry)
                recorder.record("flush", step=it)
                recorder.flush()
    return report


def measure(arch: str, *, d: int, per: int, steps: int, repeat: int,
            smoke: bool) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    rng = np.random.default_rng(0)
    batches = [sample_instances(rng, d, per) for _ in range(steps)]
    tmp = tempfile.mkdtemp(prefix="bench_obs_")

    # Both variants are built once and warmed identically, then timed
    # over the same batches -- the subtraction isolates the obs code,
    # not first-touch/lazy-init asymmetry.
    orch_off = MLLMGlobalOrchestrator(cfg, d, vocab=512)
    registry = MetricsRegistry()
    orch_on = MLLMGlobalOrchestrator(cfg, d, vocab=512, metrics=registry)
    ledger = StepLedger(cfg, d=d, registry=registry, peak_flops=197e12)
    recorder = FlightRecorder(os.path.join(tmp, "flight.jsonl"),
                              meta={"bench": "observability_overhead"})
    prom_path = os.path.join(tmp, "metrics.prom")
    on_kw = dict(ledger=ledger, recorder=recorder, registry=registry,
                 prom_path=prom_path)
    _loop(orch_off, batches[:3])
    _loop(orch_on, batches[:3], **on_kw)

    t_off = t_on = np.inf
    for _ in range(repeat):
        t0 = time.perf_counter()
        _loop(orch_off, batches)
        t_off = min(t_off, time.perf_counter() - t0)
        t0 = time.perf_counter()
        _loop(orch_on, batches, **on_kw)
        t_on = min(t_on, time.perf_counter() - t0)
    recorder.close()

    # Validity: the on-run must have produced a scrapeable exposition
    # and a readable flight record (overhead numbers for broken
    # exporters would gate nothing).
    prom_text = render_openmetrics(registry)
    exports_valid = (
        "# EOF" in prom_text
        and "train_mfu_simulated" in prom_text
        and "orch_plan_solve_ms" in prom_text
        and len(read_flight_record(recorder.path)) >= 1 + steps // FLUSH_EVERY)

    obs_ms = max(0.0, (t_on - t_off) / steps * 1e3)
    return {
        "arch": cfg.name,
        "d": d,
        "per": per,
        "steps": steps,
        "repeat": repeat,
        "plan_step_ms_metrics_off": t_off / steps * 1e3,
        "plan_step_ms_metrics_on": t_on / steps * 1e3,
        "obs_ms_per_step": obs_ms,
        "ref_step_ms": REF_STEP_MS,
        "overhead_frac_of_ref_step": obs_ms / REF_STEP_MS,
        "metrics_efficiency": 1.0 - obs_ms / REF_STEP_MS,
        "exports_valid": bool(exports_valid),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for CI")
    ap.add_argument("--out", default="BENCH_observability.json")
    ap.add_argument("--repeat", type=int, default=None)
    args = ap.parse_args()
    steps = 30 if args.smoke else 100
    repeat = args.repeat or (3 if args.smoke else 5)
    row = measure("mllm_10b", d=4, per=8, steps=steps, repeat=repeat,
                  smoke=args.smoke)
    print(f"plan step {row['plan_step_ms_metrics_off']:.3f} ms off / "
          f"{row['plan_step_ms_metrics_on']:.3f} ms on -> obs cost "
          f"{row['obs_ms_per_step']:.4f} ms/step = "
          f"{row['overhead_frac_of_ref_step']:.2%} of a {REF_STEP_MS:.0f} ms "
          f"step (efficiency {row['metrics_efficiency']:.4f}), "
          f"exports_valid={row['exports_valid']}")
    doc = {"headline": row}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
