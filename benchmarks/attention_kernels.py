"""Attention-backend benchmark: reference vs Pallas flash vs flash +
block-skip over multi-segment packed streams.

For each (T, segments) layout (lognormal lengths packed contiguously by
``pack_stream``, padded tail) it times forward and forward+grad steps of
every backend and reports the block-skip tile accounting -- KV tiles
visited vs the dense grid, the platform-independent result.  On this CPU
container the Pallas kernel executes in interpret mode, so its wall
times measure the interpreter, not the MXU; the tile counts (and the
asserted backend parity) are what CI checks.

    PYTHONPATH=src python -m benchmarks.attention_kernels [--smoke] \
        [--out BENCH_attention.json]

The committed ``BENCH_attention.json`` is the full run; CI re-runs the
``--smoke`` grid on every push.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.attention_kernels`

import jax
import jax.numpy as jnp

from repro.data.packing import pack_stream
from repro.kernels.flash_attention import count_live_tiles
from repro.models.attention import attention

FULL_GRID = [(512, 4), (1024, 8), (1024, 16), (2048, 16)]
SMOKE_GRID = [(256, 4), (512, 8)]
BLOCK = {256: 64, 512: 64, 1024: 128, 2048: 128}

BACKENDS = ("reference", "flash", "flash_skip")


def _layout(rng, T, n_seg):
    """n_seg lognormal example lengths packed into a [1, T] stream."""
    raw = rng.lognormal(0.0, 0.6, size=n_seg)
    lens = np.maximum(1, (raw / raw.sum() * T * 0.9).astype(np.int64))
    seg, pos, _ = pack_stream([lens], T)
    return jnp.asarray(seg), jnp.asarray(pos), lens


def _timed(fn, repeat):
    best = np.inf
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def _make_fwd(backend, seg, pos, blk):
    """Forward closure for one arm; "flash" is the dense-grid kernel
    (block_skip=False), "flash_skip" the default skipping one."""
    if backend == "reference":
        def fwd(x):
            return attention(x, x, x, q_seg=seg, kv_seg=seg, q_pos=pos,
                             kv_pos=pos, backend="reference",
                             block_q=blk, block_kv=blk)
        return fwd

    from repro.kernels.ops import flash_attention_op

    def fwd(x):
        xt = jnp.moveaxis(x, 1, 2)
        o = flash_attention_op(xt, xt, xt, seg, seg, pos, pos,
                               interpret=True, block_q=blk, block_kv=blk,
                               block_skip=(backend == "flash_skip"))
        return jnp.moveaxis(o, 1, 2)

    return fwd


def _run_backend(backend, q, seg, pos, blk, repeat):
    fwd = jax.jit(_make_fwd(backend, seg, pos, blk))
    grad = jax.jit(jax.grad(lambda x: jnp.sum(fwd(x) ** 2)))
    out = jax.block_until_ready(fwd(q))  # warm the caches
    t_fwd = _timed(lambda: fwd(q), repeat)
    t_grad = _timed(lambda: grad(q), repeat)
    return {"fwd_ms": round(t_fwd, 3), "fwd_grad_ms": round(t_grad, 3)}, out


def bench(grid, repeat):
    rows = []
    for T, n_seg in grid:
        rng = np.random.default_rng(hash((T, n_seg)) % (2**32))
        seg, pos, lens = _layout(rng, T, n_seg)
        blk = BLOCK[T]
        H, D = 2, 64
        q = jnp.asarray(rng.normal(size=(1, T, H, D)), jnp.float32)
        visited, total = count_live_tiles(seg, seg, pos, pos, block_q=blk,
                                          block_kv=blk, causal=True,
                                          window=None)
        assert visited < total, (
            f"block-skip must visit strictly fewer KV tiles than the dense "
            f"grid on a packed stream (T={T}, segments={n_seg}): "
            f"{visited} vs {total}")
        row = {
            "T": T,
            "segments": int(n_seg),
            "block": blk,
            "tiles_dense": total,
            "tiles_visited": visited,
            "tiles_skipped": total - visited,
            "skip_fraction": round(1 - visited / total, 4),
            "backends": {},
        }
        ref_out = None
        for backend in BACKENDS:
            row["backends"][backend], out = _run_backend(backend, q, seg,
                                                         pos, blk, repeat)
            if ref_out is None:
                ref_out = out
            else:
                err = float(jnp.abs(out - ref_out).max())
                assert err < 2e-5, f"{backend} diverges from reference: {err}"
        rows.append(row)
        print(f"T={T} segs={n_seg} tiles {visited}/{total} "
              f"(skip {row['skip_fraction']:.0%}) "
              + " ".join(f"{b}={row['backends'][b]['fwd_grad_ms']:.1f}ms"
                         for b in BACKENDS))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_attention.json")
    ap.add_argument("--repeat", type=int, default=None)
    args = ap.parse_args(argv)
    grid = SMOKE_GRID if args.smoke else FULL_GRID
    repeat = args.repeat or (2 if args.smoke else 3)
    rows = bench(grid, repeat)
    doc = {
        "note": (
            "Pallas kernels run via interpret mode on CPU: wall times "
            "measure the interpreter; tiles_visited/tiles_dense is the "
            "platform-independent block-skip result (grad timings cover "
            "the custom-VJP dq/dk/dv kernels)."
        ),
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
