"""Serving example: the continuous-batching engine on a multimodal trace.

Builds a bursty request trace from the synthetic multimodal dataset
(``data.synthetic`` -- mixed prefill lengths per Modality Composition
Incoherence), drives the paged-KV continuous-batching engine over it,
and prints the EngineReport.  A second run uses two post-balanced
replicas, and a third shows temperature/top-k sampling behind a PRNG
key.

    PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import EngineConfig, get_config
from repro.data.synthetic import TaskMix, sample_examples
from repro.models.model import init_params
from repro.serving.engine import Engine, MultiReplicaEngine, requests_from_examples
from repro.serving.serve_step import make_sample_fn


def build_trace(cfg, n_requests: int, *, seed: int = 0, burst: int = 4):
    """n_requests synthetic multimodal requests arriving in bursts."""
    rng = np.random.default_rng(seed)
    examples = sample_examples(rng, n_requests, TaskMix(), ("vision", "audio"))
    return requests_from_examples(
        examples, vocab=cfg.vocab_size, max_total_len=192, rng=rng,
        max_new_lo=4, max_new_hi=24, length_scale=24,
        arrival_step_fn=lambda i: 3 * (i // burst))


def main():
    cfg = get_config("llava_next_mistral_7b").smoke()  # vlm: vision-weighted prefills
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(block_size=16, num_blocks=65, max_num_seqs=6,
                        token_budget=512, max_model_len=192,
                        prefill_pad=32, decode_pad=2)

    print(f"== {cfg.name}: single replica, greedy ==")
    engine = Engine(cfg, ecfg, params)
    report = engine.run(build_trace(cfg, 12))
    print(report.summary())
    print(f"sample stream (req 0): {engine.requests[0].output_tokens[:10]}")

    print("\n== two post-balanced replicas ==")
    multi = MultiReplicaEngine(
        cfg, dataclasses.replace(ecfg, replicas=2), params)
    report = multi.run(build_trace(cfg, 12))
    print(report.summary())
    loads = np.concatenate(multi.assignment_loads)
    print(f"per-burst replica loads (weighted tokens): {loads.astype(int).tolist()}")

    print("\n== temperature 0.8 / top-k 16 sampling ==")
    engine = Engine(cfg, ecfg, params,
                    sample_fn=make_sample_fn(temperature=0.8, top_k=16),
                    rng_key=jax.random.PRNGKey(42))
    report = engine.run(build_trace(cfg, 8))
    print(report.summary())
    print("OK: continuous batching, post-balanced replicas, sampling")


if __name__ == "__main__":
    main()
