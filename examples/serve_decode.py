"""Serving example: batched single-token decode with per-family caches.

Decodes a batch of requests for three different architecture families
(dense+SWA ring buffer, SSM constant state, hybrid) to show the
serve_step contract the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.serving.serve_step import init_cache, make_serve_step
from repro.training.train_step import init_train_state


def run(arch: str, batch: int = 4, prompt_len: int = 12, new_tokens: int = 16):
    cfg = get_config(arch).smoke()
    params, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, batch, 128)
    if cfg.family == "audio":
        cache["cross_seg"] = cache["cross_seg"].at[:, :8].set(1)
    serve = jax.jit(make_serve_step(cfg))

    # "Prefill" by decoding the prompt token by token (keeps the example
    # dependent only on serve_step; batch prefill is the prefill_32k path).
    rng = jax.random.PRNGKey(1)
    prompt = jax.random.randint(rng, (batch, prompt_len), 1, cfg.vocab_size)
    tok = prompt[:, :1]
    t0 = time.time()
    out = []
    for t in range(prompt_len + new_tokens):
        nxt, logits, cache = serve(params, tok, cache, jnp.int32(t))
        tok = prompt[:, t + 1 : t + 2] if t + 1 < prompt_len else nxt
        if t >= prompt_len:
            out.append(nxt[:, 0])
    toks = jnp.stack(out, axis=1)
    dt = time.time() - t0
    print(f"{arch:24s} [{cfg.family:6s}] generated {toks.shape} tokens in "
          f"{dt:.2f}s ({batch * new_tokens / dt:.1f} tok/s); "
          f"sample={toks[0, :8].tolist()}")


def main():
    for arch in ("h2o_danube_3_4b", "falcon_mamba_7b", "zamba2_2_7b",
                 "whisper_large_v3"):
        run(arch)
    print("OK: all families decode with their native cache types")


if __name__ == "__main__":
    main()
