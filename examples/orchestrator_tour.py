"""A guided tour of the paper's machinery, numerically.

Walks one iteration of the MLLM Global Orchestrator on a skewed batch
and prints every intermediate the paper defines: per-phase costs before
and after post-balancing, the rearrangements, the composed plan
(Pi_M o Pi_E^-1), communicator volumes (Eq. 3 vs 4), and the node-wise
rearrangement's inter-node reduction (Eq. 5).

    PYTHONPATH=src python examples/orchestrator_tour.py

With --pp 4 the tour adds the pipeline-mode step: the 1F1B microbatch
schedule over the post-balanced shard, the per-stage layer partition
and the encoder bubble-fill result (docs/pipeline.md).  The rest of the
machinery is documented in docs/architecture.md.
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.synthetic import sample_examples


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages; >1 appends the 1F1B + "
                         "bubble-fill schedule step (docs/pipeline.md)")
    args = ap.parse_args()
    cfg = get_config("mllm_10b")
    d, c = 16, 4  # 16 DP instances, 4 per node
    rng = np.random.default_rng(7)
    examples = [sample_examples(rng, 8) for _ in range(d)]

    print("=" * 72)
    print("1. Modality Composition Incoherence (paper S3.1)")
    for i in (0, 1):
        ratios = [
            f"{ex.task}:{ex.vision_meta}v/{ex.audio_meta}a/{ex.text_len}t"
            for ex in examples[i][:4]
        ]
        print(f"   instance {i}: {ratios}")

    for balance in (False, True):
        orch = MLLMGlobalOrchestrator(cfg, d, balance=balance,
                                      instances_per_node=c, vocab=512,
                                      pp=args.pp if balance else 1)
        caps = orch.default_capacities(examples, margin=3.0)
        _, rep = orch.plan_and_pack(examples, caps, rng)
        tag = "post-balanced" if balance else "as-sampled   "
        print("=" * 72)
        print(f"2. {tag}: per-phase cost spread (f from Eq. 2)")
        for ph, costs in rep.phase_costs.items():
            print(f"   {ph:8s} max={costs.max():9.3g} mean={costs.mean():9.3g} "
                  f"util={rep.phase_utilization[ph]:.3f}")
        if balance:
            print("3. composed communicator volumes (Pi_M o Pi_E^-1, S6)")
            for mod, v in rep.comm_volume.items():
                print(f"   {mod:8s} total={v['total']:8d} tokens, "
                      f"stay-local={v['self']:6d}, "
                      f"inter-node max={rep.internode_volume[mod]:6d} "
                      f"(node-wise ILP applied)")
            print(f"4. dispatcher solve time: {rep.solve_ms:.1f} ms "
                  f"(overlapped with forward pass per S6)")
            if rep.pipeline is not None:
                p = rep.pipeline
                print("5. pipeline schedule: 1F1B + encoder bubble-fill "
                      "(docs/pipeline.md)")
                print(f"   stages={p.pp} microbatches={p.n_micro} "
                      f"layers/stage={list(p.partition)}")
                print(f"   bubble filled {p.fill_fraction:.1%}; projected "
                      f"MFU {p.projected_mfu_nofill:.3f} -> "
                      f"{p.projected_mfu:.3f} (+{p.mfu_uplift:.3f})")


if __name__ == "__main__":
    main()
