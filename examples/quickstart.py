"""Quickstart: post-balanced multimodal training in ~60 lines.

Builds a tiny LLaVA-family model, runs the MLLM Global Orchestrator on
synthetic multimodal batches (with Modality Composition Incoherence),
and takes a few optimizer steps -- loss should drop.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.synthetic import Example
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def sample(rng, per):
    """CPU-sized multimodal examples (the full-scale distribution lives
    in repro.data.synthetic; a smoke model wants smoke-sized lengths)."""
    out = []
    for _ in range(per):
        if rng.random() < 0.6:
            out.append(Example("vqa", int(rng.integers(8, 48)),
                               int(rng.integers(1, 4)) * 16, 0,
                               ("vision", "text")))
        else:
            out.append(Example("text", int(rng.integers(8, 96)), 0, 0, ("text",)))
    return out


def main():
    cfg = get_config("llava_next_mistral_7b").smoke()
    d = 4  # DP instances (the post-balancing width)
    rng = np.random.default_rng(0)
    orch = MLLMGlobalOrchestrator(cfg, d, vocab=cfg.vocab_size)

    # Sample per-instance mini-batches the way a real loader would --
    # independently per instance (batching randomness, paper S2.3).
    first = [sample(rng, 4) for _ in range(d)]
    caps = orch.default_capacities(first, margin=3.0)

    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3)))

    losses = []
    for it in range(8):
        examples = first if it == 0 else [sample(rng, 4) for _ in range(d)]
        batch_np, report = orch.plan_and_pack(examples, caps, rng)
        batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        print(f"step {it}: loss={losses[-1]:.4f} "
              f"util(llm)={report.phase_utilization['llm']:.2f} "
              f"util(vision)={report.phase_utilization['vision']:.2f} "
              f"solve={report.solve_ms:.1f}ms")
    assert losses[-1] < losses[0], "loss did not decrease"
    print("OK: loss decreased under post-balanced training")


if __name__ == "__main__":
    main()
