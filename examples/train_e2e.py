"""End-to-end driver: train a ~100M-param multimodal model for a few
hundred steps with the full production stack -- prefetching loader with
overlapped dispatcher computation, MLLM Global Orchestrator, post-
balanced packed batches, AdamW, cosine schedule.

    PYTHONPATH=src python examples/train_e2e.py --steps 300

On this CPU container a step takes a few seconds; pass --steps 20 for a
quick check.  (On TPU the same script runs under the production mesh via
repro.launch.train.)

Pass --pp 2 (or more) to additionally plan the 1F1B pipeline schedule
with encoder bubble-fill each step and print the reclaimed-bubble
fraction and projected MFU uplift -- see docs/pipeline.md for the
schedule model and docs/architecture.md for where the planner sits in
the stack.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.pipeline import PrefetchingLoader
from repro.data.synthetic import Example
from repro.training.optimizer import AdamWConfig, cosine_schedule
from repro.training.train_step import init_train_state, make_loss_fn
from repro.training.optimizer import adamw_update


def build_cfg():
    """~100M-param LLaVA-family config that still trains on CPU."""
    base = get_config("llava_next_mistral_7b")
    enc = tuple(dataclasses.replace(e, embed_dim=256, tokens_per_example_max=128)
                for e in base.encoders)
    return dataclasses.replace(
        base, n_layers=12, d_model=640, n_heads=8, n_kv_heads=4, d_ff=1792,
        vocab_size=32000, encoders=enc, block_q=128, block_kv=128,
        name="llava-100m",
    )


def sampler(rng, per):
    out = []
    for _ in range(per):
        if rng.random() < 0.5:
            tiles = int(rng.integers(1, 4))
            out.append(Example("vqa", int(rng.integers(16, 96)), tiles * 32, 0,
                               ("vision", "text")))
        else:
            out.append(Example("text", int(rng.integers(16, 160)), 0, 0, ("text",)))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--per", type=int, default=6)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages; >1 plans 1F1B + encoder "
                         "bubble-fill per step (docs/pipeline.md)")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="microbatches per pipeline iteration (0: 2*pp)")
    args = ap.parse_args()

    cfg = build_cfg()
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params~{n_params/1e6:.0f}M")

    orch = MLLMGlobalOrchestrator(cfg, args.d, vocab=cfg.vocab_size,
                                  pp=args.pp, microbatches=args.microbatches)
    probe = [sampler(np.random.default_rng(s), args.per) for s in range(args.d)]
    caps = orch.default_capacities(probe, margin=3.0)
    loader = PrefetchingLoader(orch, caps, examples_per_instance=args.per,
                               sampler=sampler, depth=2)

    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr)
    loss_fn = make_loss_fn(cfg)

    @jax.jit
    def step(params, opt_state, batch, lr):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg,
                                             lr=lr)
        return params, opt_state, {**metrics, **om}

    t0 = time.time()
    ema = None
    try:
        for it in range(args.steps):
            batch_np, report, fetch_ms = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            lr = cosine_schedule(it, peak_lr=args.lr, warmup=20, total=args.steps)
            params, opt_state, m = step(params, opt_state, batch, lr)
            loss = float(m["loss"])
            ema = loss if ema is None else 0.9 * ema + 0.1 * loss
            if it % 10 == 0 or it == args.steps - 1:
                pipe = ""
                if report.pipeline is not None:
                    pipe = (f" pp={report.pipeline.pp} "
                            f"fill={report.pipeline.fill_fraction:.2f} "
                            f"mfu+{report.pipeline.mfu_uplift:.3f}")
                print(f"step {it:4d} loss={loss:.4f} ema={ema:.4f} "
                      f"gnorm={float(m['grad_norm']):.2f} "
                      f"util={report.phase_utilization['llm']:.2f} "
                      f"tok={int(m['tokens'])}{pipe} "
                      f"{(time.time()-t0)/(it+1):.2f}s/step", flush=True)
    finally:
        stats = loader.overlap_stats()
        loader.close()
    print(f"done: final ema loss {ema:.4f}; dispatcher solve "
          f"{stats['mean_solve_ms']:.1f}ms/batch fully overlapped with compute")


if __name__ == "__main__":
    main()
