"""Continuous-batching engine tests: output-stream exactness against the
dense-cache serve_step path, scheduler invariants (budget, FIFO, no
starvation, preemption recompute), post-balanced replica assignment, and
the pluggable sampling satellite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import EngineConfig, get_config
from repro.core.balancing import post_balance
from repro.models.model import init_params
from repro.serving.engine import (
    Engine,
    MultiReplicaEngine,
    Request,
    assign_replicas,
    serving_cost_model,
)
from repro.serving.serve_step import greedy_sample, init_cache, make_sample_fn, make_serve_step

PARITY_ARCHS = ["olmo_1b", "qwen3_8b", "h2o_danube_3_4b"]


def _smoke(arch):
    return get_config(arch).smoke()


def _params(cfg):
    return init_params(cfg, jax.random.PRNGKey(0))


def _trace(cfg, rng, n, *, max_prompt=30, max_new=8, bursty=True):
    reqs = []
    for i in range(n):
        L = int(rng.integers(3, max_prompt))
        reqs.append(Request(
            req_id=i,
            prompt=rng.integers(1, cfg.vocab_size, L).astype(np.int32),
            max_new_tokens=int(rng.integers(2, max_new)),
            arrival_step=(i // 2) if bursty else 0))
    return reqs


def _solo_stream(cfg, params, req, seq_len):
    """Reference: the request alone through the dense-cache serve path."""
    serve = jax.jit(make_serve_step(cfg))
    cache = init_cache(cfg, 1, seq_len)
    toks, tok = [], jnp.asarray(req.prompt[:1][None])
    for t in range(req.prompt_len + req.max_new_tokens - 1):
        nxt, _, cache = serve(params, tok, cache, jnp.int32(t))
        if t + 1 < req.prompt_len:
            tok = jnp.asarray(req.prompt[t + 1 : t + 2][None])
        else:
            toks.append(int(nxt[0, 0]))
            tok = nxt
    return toks


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_engine_streams_match_solo_dense(arch):
    """ISSUE 3 acceptance: engine output token streams are identical to
    running each request alone through the dense-cache serve_step path
    (dense, GQA, and windowed attention)."""
    cfg = _smoke(arch)
    params = _params(cfg)
    ecfg = EngineConfig(block_size=16, num_blocks=17, max_num_seqs=3,
                        token_budget=64, max_model_len=64,
                        prefill_pad=16, decode_pad=2)
    rng = np.random.default_rng(0)
    reqs = _trace(cfg, rng, 5)
    engine = Engine(cfg, ecfg, params)
    report = engine.run(reqs, max_steps=300)
    engine.pool.check()
    assert report.n_finished == len(reqs)
    assert report.generated_tokens == sum(r.max_new_tokens for r in reqs)
    for r in reqs:
        assert r.output_tokens == _solo_stream(cfg, params, r, 64), r.req_id


def test_scheduler_budget_and_fifo_invariants():
    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    ecfg = EngineConfig(block_size=8, num_blocks=25, max_num_seqs=4,
                        token_budget=40, max_model_len=64,
                        prefill_pad=8, decode_pad=2)
    rng = np.random.default_rng(1)
    reqs = _trace(cfg, rng, 8, max_prompt=40)
    engine = Engine(cfg, ecfg, params)
    engine.run(reqs, max_steps=500)
    scm = engine.scheduler.cost_model
    admitted_order = []
    for plan in engine.plans:
        # Token budget respected, except a lone head admission on an
        # otherwise idle step (anti-livelock rule).
        if plan.budget_used > plan.budget:
            assert len(plan.prefill) == 1 and not plan.decode
        assert len(plan.decode) * scm.decode_cost <= plan.budget
        admitted_order.extend(plan.admitted)
        # Decodes are FIFO by arrival within their step.
        arrivals = [s.request.arrival_step for s in plan.decode]
        assert arrivals == sorted(arrivals)
    # No starvation: every request admitted, first admissions in FIFO
    # (arrival) order.
    first_admission = {}
    for rid in admitted_order:
        first_admission.setdefault(rid, len(first_admission))
    assert len(first_admission) == len(reqs)
    by_arrival = sorted(reqs, key=lambda r: (r.arrival_step, r.req_id))
    assert [r.req_id for r in by_arrival] == list(first_admission)


def test_preemption_recomputes_exactly():
    """Pool exhaustion evicts the youngest sequence; its recompute must
    regenerate the identical greedy stream."""
    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    # 6 usable blocks; two prompts of 30 (2 blocks) growing to 70 slots
    # (5 blocks) each -- they cannot both finish without eviction.
    ecfg = EngineConfig(block_size=16, num_blocks=7, max_num_seqs=4,
                        token_budget=96, max_model_len=96,
                        prefill_pad=16, decode_pad=2)
    rng = np.random.default_rng(1)
    reqs = [Request(req_id=i,
                    prompt=rng.integers(1, cfg.vocab_size, 30).astype(np.int32),
                    max_new_tokens=40) for i in range(2)]
    engine = Engine(cfg, ecfg, params)
    report = engine.run(reqs, max_steps=500)
    engine.pool.check()
    assert report.n_preemptions > 0
    assert report.n_finished == 2
    preempted = [r for r in reqs if r.n_preemptions]
    assert preempted and preempted[0].req_id == 1  # youngest arrival evicted
    # Recomputed context is accounted as overhead, not useful prompt work.
    assert report.prompt_tokens == sum(r.prompt_len for r in reqs)
    assert report.recompute_tokens > 0
    for r in reqs:
        assert r.output_tokens == _solo_stream(cfg, params, r, 96), r.req_id


def test_replica_assignment_matches_post_balance_objective():
    """Multi-replica admission must reproduce post_balance's objective
    exactly (same items, same cost model, same backend)."""
    cfg = _smoke("llava_next_mistral_7b")
    scm = serving_cost_model(cfg)
    rng = np.random.default_rng(2)
    reqs = []
    for i in range(24):
        L = int(rng.integers(4, 200))
        mt = {"vision": int(rng.integers(0, 120))} if rng.random() < 0.5 else {}
        prompt = rng.integers(1, 64, L + sum(mt.values())).astype(np.int32)
        reqs.append(Request(req_id=i, prompt=prompt, max_new_tokens=4,
                            modality_tokens=mt))
    d = 4
    groups, loads = assign_replicas(reqs, d, scm)
    assert sorted(r.req_id for g in groups for r in g) == list(range(24))
    lens = np.maximum(1, np.rint(scm.weighted_lengths(
        [r.text_len for r in reqs],
        [r.modality_tokens for r in reqs])).astype(np.int64))
    re = post_balance([lens], d, scm.model, backend="vectorized")
    per_replica = scm.model.segment_costs(
        lens[re.orig_slot].astype(np.float64), re.dst_inst, d)
    got = np.array([sum(float(lens[r.req_id]) for r in g) for g in groups])
    np.testing.assert_allclose(np.sort(loads), np.sort(got))
    # Objective match: the engine's max weighted load equals the
    # dispatcher's max segment cost (alpha=1 regime: cost ~ load).
    assert scm.model.cost([int(v) for v in []] or [1]) > 0  # sanity
    got_cost = np.array([scm.model.cost(
        [float(lens[r.req_id]) for r in g]) for g in groups])
    np.testing.assert_allclose(got_cost.max(), per_replica.max(), rtol=1e-12)


def test_multi_replica_engine_drains_and_balances():
    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    ecfg = EngineConfig(block_size=16, num_blocks=33, max_num_seqs=4,
                        token_budget=128, max_model_len=96, replicas=2,
                        prefill_pad=16, decode_pad=2)
    rng = np.random.default_rng(3)
    reqs = _trace(cfg, rng, 8, max_prompt=40, bursty=False)
    multi = MultiReplicaEngine(cfg, ecfg, params)
    report = multi.run(reqs, max_steps=300)
    assert report.n_finished == 8
    assert len(multi.assignment_loads) == 1  # one burst
    loads = multi.assignment_loads[0]
    assert loads.sum() > 0 and len(loads) == 2
    for r in reqs:
        assert r.replica in (0, 1)
        assert r.output_tokens == _solo_stream(cfg, params, r, 96), r.req_id


def test_engine_report_metrics_consistent():
    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    ecfg = EngineConfig(block_size=16, num_blocks=33, max_num_seqs=4,
                        token_budget=96, max_model_len=64,
                        prefill_pad=16, decode_pad=2)
    rng = np.random.default_rng(4)
    reqs = _trace(cfg, rng, 6)
    engine = Engine(cfg, ecfg, params)
    report = engine.run(reqs, max_steps=300)
    assert report.prompt_tokens == sum(r.prompt_len for r in reqs)
    assert report.recompute_tokens == 0  # no preemption on this trace
    assert report.token_slots >= report.prompt_tokens + report.generated_tokens
    assert 0.0 < report.slot_efficiency <= 1.0
    assert 0.0 <= report.occupancy_mean <= report.occupancy_max <= 1.0
    assert report.ttft_steps_mean >= 0.0
    assert report.itl_steps_mean >= 1.0  # one decode step per token min
    # sketch-backed tail latencies: present and monotone in q
    assert 0.0 <= report.ttft_steps_p50 <= report.ttft_steps_p95 \
        <= report.ttft_steps_p99
    assert 1.0 <= report.itl_steps_p50 <= report.itl_steps_p95 \
        <= report.itl_steps_p99
    assert report.wall_s > 0 and report.throughput_tok_s > 0
    assert "finished" in report.summary()
    # Pool fully drained after the run.
    assert engine.pool.num_used == 0


def test_engine_populates_metrics_registry():
    """With a metrics registry attached, the engine's SLO histograms
    (TTFT / ITL / occupancy) fill with labeled observations."""
    from repro.obs import MetricsRegistry

    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    ecfg = EngineConfig(block_size=16, num_blocks=33, max_num_seqs=4,
                        token_budget=96, max_model_len=64,
                        prefill_pad=16, decode_pad=2)
    reg = MetricsRegistry()
    engine = Engine(cfg, ecfg, params, metrics=reg)
    reqs = _trace(cfg, np.random.default_rng(4), 4)
    report = engine.run(reqs, max_steps=300)
    assert report.n_finished == 4

    ttft = reg.get("serving_ttft_steps").labels(replica="0")
    itl = reg.get("serving_itl_steps").labels(replica="0")
    occ = reg.get("serving_occupancy_frac").labels(replica="0")
    assert ttft.count == 4  # one TTFT observation per request
    assert itl.count == 4  # one mean-ITL observation per finished request
    assert occ.count == report.n_steps
    # histogram quantiles agree with the report's sketch-backed tails
    assert ttft.quantile(0.5) <= ttft.quantile(0.95) <= ttft.quantile(0.99)
    assert report.ttft_steps_p95 >= report.ttft_steps_p50


def test_engine_validation_errors():
    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    with pytest.raises(ValueError):  # stateful family
        Engine(_smoke("falcon_mamba_7b"), EngineConfig(), params)
    with pytest.raises(ValueError):  # window not divisible by block size
        Engine(_smoke("h2o_danube_3_4b"),
               EngineConfig(block_size=24, num_blocks=9, max_model_len=96),
               params)
    with pytest.raises(ValueError):  # ring smaller than the window (64)
        Engine(_smoke("h2o_danube_3_4b"),
               EngineConfig(block_size=16, num_blocks=9, max_model_len=32),
               params)
    eng = Engine(cfg, EngineConfig(block_size=16, num_blocks=9,
                                   max_model_len=32), params)
    with pytest.raises(ValueError):  # prompt + max_new exceeds cache
        eng.submit(Request(req_id=0, prompt=np.arange(1, 30, dtype=np.int32),
                           max_new_tokens=8))
    eng = Engine(cfg, EngineConfig(block_size=16, num_blocks=5,
                                   max_model_len=96), params)
    with pytest.raises(ValueError):  # needs 5 blocks, pool has 4 usable:
        eng.submit(Request(req_id=0,  # would livelock the FIFO head
                           prompt=np.full(70, 3, dtype=np.int32),
                           max_new_tokens=8))
    with pytest.raises(ValueError):  # EngineConfig validation
        EngineConfig(block_size=16, max_model_len=40)


# ----------------------------------------------------------------------
# Sampling satellite.
# ----------------------------------------------------------------------
def test_sample_fn_greedy_default_and_temperature_zero():
    assert make_sample_fn(temperature=0.0) is greedy_sample
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 16)),
                         jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(greedy_sample(logits)),
        np.asarray(logits).argmax(-1)[:, None])


def test_sample_fn_top_k_restriction_and_determinism():
    logits = jnp.asarray(np.arange(16, dtype=np.float32)[None])
    s = make_sample_fn(temperature=0.9, top_k=4)
    ids = [int(s(logits, jax.random.PRNGKey(i))[0, 0]) for i in range(25)]
    assert all(i >= 12 for i in ids)  # top-4 of arange(16)
    assert len(set(ids)) > 1  # actually stochastic
    a = s(logits, jax.random.PRNGKey(5))
    b = s(logits, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        s(logits, None)
    with pytest.raises(ValueError):
        make_sample_fn(temperature=-1.0)


def test_engine_stochastic_sampling_reproducible():
    """Same rng_key => same streams; streams differ from greedy."""
    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    ecfg = EngineConfig(block_size=16, num_blocks=17, max_num_seqs=2,
                        token_budget=64, max_model_len=64,
                        prefill_pad=16, decode_pad=2)

    def run(key):
        rng = np.random.default_rng(5)
        reqs = _trace(cfg, rng, 3, max_prompt=12, max_new=8, bursty=False)
        eng = Engine(cfg, ecfg, params,
                     sample_fn=make_sample_fn(temperature=2.0),
                     rng_key=key)
        eng.run(reqs, max_steps=200)
        return [r.output_tokens for r in reqs]

    assert run(jax.random.PRNGKey(7)) == run(jax.random.PRNGKey(7))


def test_engine_report_phase_time_breakdown():
    """ISSUE 4 satellite: EngineReport carries the per-step
    prefill/decode wall-time breakdown (and Engine.step_timings the
    per-step rows)."""
    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    ecfg = EngineConfig(block_size=16, num_blocks=33, max_num_seqs=4,
                        token_budget=96, max_model_len=64,
                        prefill_pad=16, decode_pad=2)
    rng = np.random.default_rng(6)
    reqs = _trace(cfg, rng, 6)
    engine = Engine(cfg, ecfg, params)
    report = engine.run(reqs, max_steps=300)
    assert len(engine.step_timings) == engine.n_steps
    assert report.prefill_steps == sum(
        1 for t in engine.step_timings if t.n_prefill_seqs)
    assert report.decode_steps == sum(
        1 for t in engine.step_timings if t.n_decode_seqs)
    assert report.prefill_steps > 0 and report.decode_steps > 0
    assert report.prefill_s_total > 0 and report.decode_s_total > 0
    assert report.prefill_ms_mean > 0 and report.decode_ms_mean > 0
    # Totals agree with the per-step rows; phase time fits in the wall.
    assert report.prefill_s_total == pytest.approx(
        sum(t.prefill_ms for t in engine.step_timings) * 1e-3)
    assert (report.schedule_s_total + report.prefill_s_total
            + report.decode_s_total) <= report.wall_s + 1e-6
    # Prefilled tokens ledger matches the prompt+recompute accounting.
    assert sum(t.prefill_tokens for t in engine.step_timings) == (
        report.prompt_tokens + report.recompute_tokens)
    assert "phases" in report.summary()


def test_engine_feeds_adaptive_serving_cost_model():
    """The engine streams prefill compositions / decode batch sizes into
    an AdaptiveServingCostModel, and admission math stays on the prior
    until the fit is confident."""
    from repro.core.cost_model import serving_cost_model
    from repro.telemetry import AdaptiveServingCostModel

    cfg = _smoke("olmo_1b")
    params = _params(cfg)
    ecfg = EngineConfig(block_size=16, num_blocks=33, max_num_seqs=4,
                        token_budget=96, max_model_len=64,
                        prefill_pad=16, decode_pad=2)
    adaptive = AdaptiveServingCostModel(serving_cost_model(cfg))
    rng = np.random.default_rng(7)
    reqs = _trace(cfg, rng, 6)
    engine = Engine(cfg, ecfg, params, cost_model=adaptive)
    engine.run(reqs, max_steps=300)
    cal = adaptive.calibrator
    assert len(cal._t) > 0, "no prefill observations reached the calibrator"
    assert len(cal._dec) > 0, "no decode observations reached the calibrator"
    # Text-only trace: no modality columns, weights stay on the prior.
    assert adaptive.modality_weights == adaptive.prior.modality_weights
    # Greedy streams are untouched by the adaptive wrapper.
    ref = Engine(cfg, ecfg, params)
    ref.run(_trace(cfg, np.random.default_rng(7), 6), max_steps=300)
    assert ([r.output_tokens for r in engine.requests]
            == [r.output_tokens for r in ref.requests])
