"""Serving tests: decode consistency against the training forward, SWA
ring-buffer behavior, SSM state equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.serving.serve_step import init_cache, make_serve_step


def _decode_n(cfg, params, tokens, cache, n, start_t=0):
    serve = jax.jit(make_serve_step(cfg))
    logits_all = []
    tok = tokens
    for t in range(start_t, start_t + n):
        nxt, logits, cache = serve(params, tok, cache, jnp.int32(t))
        logits_all.append(logits)
        tok = nxt
    return jnp.stack(logits_all, 1), cache


@pytest.mark.parametrize("arch", ["qwen3_8b", "falcon_mamba_7b", "zamba2_2_7b"])
def test_decode_matches_train_forward(arch):
    """Greedy decode logits must match the packed training forward's
    next-token distribution on the same prefix (teacher forcing)."""
    cfg = dataclasses.replace(get_config(arch).smoke(), remat=False,
                              attention_impl="reference")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 1, cfg.vocab_size)

    # Decode path: feed tokens one by one.
    cache = init_cache(cfg, B, 32)
    serve = jax.jit(make_serve_step(cfg))
    dec_logits = []
    for t in range(T):
        _, logits, cache = serve(params, toks[:, t : t + 1], cache, jnp.int32(t))
        dec_logits.append(logits)
    dec_logits = jnp.stack(dec_logits, 1)  # [B,T,V]

    # Train-forward path on the same sequence (packed stream of 1 example).
    from repro.models.model import _final_norm
    from repro.models.transformer import decoder_stack

    x = jnp.take(params["embed"], toks, axis=0)
    seg = jnp.ones((B, T), jnp.int32)
    pos = jnp.arange(T, dtype=jnp.int32)[None]
    y, _ = decoder_stack(cfg, params, x, seg, pos)
    y = _final_norm(cfg, params, y)
    lm = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    fwd_logits = jnp.einsum("btd,dv->btv", y.astype(jnp.float32),
                            lm.astype(jnp.float32))

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(fwd_logits, np.float32),
        atol=0.15, rtol=0.15,  # bf16 params, different contraction orders
    )
    # Argmax agreement is the functional requirement.
    agree = (dec_logits.argmax(-1) == fwd_logits.argmax(-1)).mean()
    assert float(agree) >= 0.8


def test_swa_ring_buffer_wraps():
    """h2o-danube SWA cache: decoding past the window must keep working
    and only attend within the window."""
    cfg = get_config("h2o_danube_3_4b").smoke()  # window=64 in smoke
    assert cfg.sliding_window == 64
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = init_cache(cfg, B, 64)  # cache sized to the window
    assert cache["k"].shape[2] == 64
    toks = jnp.ones((B, 1), jnp.int32)
    serve = jax.jit(make_serve_step(cfg))
    for t in range(80):  # wraps past the ring
        nxt, logits, cache = serve(params, toks, cache, jnp.int32(t))
        toks = nxt
    assert bool(jnp.isfinite(logits).all())


def test_ssm_decode_state_is_constant_memory():
    cfg = get_config("falcon_mamba_7b").smoke()
    cache = init_cache(cfg, 2, 10_000)
    # SSM cache size is independent of seq_len.
    assert cache["h"].shape == (cfg.n_layers, 2, cfg.d_inner, cfg.ssm_state)
    assert cache["conv"].shape[2] == cfg.ssm_conv - 1


def test_decode_is_deterministic():
    cfg = get_config("olmo_1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        cache = init_cache(cfg, 2, 32)
        logits, _ = _decode_n(cfg, params, jnp.ones((2, 1), jnp.int32), cache, 5)
        outs.append(np.asarray(logits))
    np.testing.assert_array_equal(outs[0], outs[1])
