"""Model-level attention: chunked (flash-style) vs reference oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.attention import attention, make_segment_mask


def _mk(rng, B, Tq, Tkv, H, Hkv, D, n_seg=3):
    q = jnp.asarray(rng.normal(size=(B, Tq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Tkv, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Tkv, Hkv, D)), jnp.float32)
    seg = np.zeros((B, Tkv), np.int32)
    pos = np.zeros((B, Tkv), np.int32)
    for b in range(B):
        cuts = np.sort(rng.choice(np.arange(1, Tkv), n_seg - 1, replace=False))
        bounds = np.r_[0, cuts, Tkv - 2]
        for s in range(len(bounds) - 1):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            seg[b, lo:hi] = s + 1
            pos[b, lo:hi] = np.arange(hi - lo)
    return q, k, v, jnp.asarray(seg[:, :Tq]), jnp.asarray(pos[:, :Tq]), \
        jnp.asarray(seg), jnp.asarray(pos)


@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("window", [None, 17])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_reference(gqa, window, causal):
    rng = np.random.default_rng(0)
    B, T, H, D = 2, 96, 4, 32
    q, k, v, qs, qp, ks, kp = _mk(rng, B, T, T, H, H // gqa, D)
    ref = attention(q, k, v, q_seg=qs, kv_seg=ks, q_pos=qp, kv_pos=kp,
                    causal=causal, window=window, impl="reference")
    chk = attention(q, k, v, q_seg=qs, kv_seg=ks, q_pos=qp, kv_pos=kp,
                    causal=causal, window=window, impl="chunked",
                    block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(chk),
                               atol=1e-5, rtol=1e-5)


def test_chunked_nondivisible_blocks():
    rng = np.random.default_rng(1)
    B, T, H, D = 1, 70, 2, 16  # 70 not divisible by 32
    q, k, v, qs, qp, ks, kp = _mk(rng, B, T, T, H, H, D, n_seg=2)
    ref = attention(q, k, v, q_seg=qs, kv_seg=ks, q_pos=qp, kv_pos=kp,
                    impl="reference")
    chk = attention(q, k, v, q_seg=qs, kv_seg=ks, q_pos=qp, kv_pos=kp,
                    impl="chunked", block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(chk),
                               atol=1e-5, rtol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_property_mask_blocks_cross_segment(seed):
    rng = np.random.default_rng(seed)
    B, T = 1, 32
    seg = jnp.asarray(rng.integers(0, 3, size=(B, T)).astype(np.int32))
    pos = jnp.asarray(rng.integers(0, T, size=(B, T)).astype(np.int32))
    m = make_segment_mask(seg, seg, pos, pos, causal=True, window=None)
    m = np.asarray(m)[0]
    s = np.asarray(seg)[0]
    p = np.asarray(pos)[0]
    for i in range(T):
        for j in range(T):
            if m[i, j]:
                assert s[i] == s[j] and s[i] > 0 and p[j] <= p[i]


def test_gqa_head_mismatch_raises():
    rng = np.random.default_rng(2)
    q, k, v, qs, qp, ks, kp = _mk(rng, 1, 32, 32, 3, 2, 16)
    with pytest.raises(ValueError):
        attention(q, k, v, q_seg=qs, kv_seg=ks, q_pos=qp, kv_pos=kp)


@pytest.mark.parametrize("W", [16, 32])
def test_windowed_matches_reference(W):
    """Window-chunked attention is exact when segments fit in W."""
    rng = np.random.default_rng(5)
    B, T, H, D = 1, 96, 2, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    # Segments of length <= W packed back to back.
    seg = np.zeros((B, T), np.int32)
    pos = np.zeros((B, T), np.int32)
    off, sid = 0, 1
    while off < T - 2:
        l = int(rng.integers(3, W + 1))
        l = min(l, T - off)
        seg[0, off : off + l] = sid
        pos[0, off : off + l] = np.arange(l)
        off += l
        sid += 1
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    ref = attention(q, q, q, q_seg=seg, kv_seg=seg, q_pos=pos, kv_pos=pos,
                    impl="reference")
    win = attention(q, q, q, q_seg=seg, kv_seg=seg, q_pos=pos, kv_pos=pos,
                    impl="windowed", block_q=16, block_kv=16, chunk_w=W)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(win),
                               atol=1e-5, rtol=1e-5)


def test_windowed_gradients_match():
    rng = np.random.default_rng(6)
    B, T, H, D, W = 1, 64, 2, 8, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    seg = jnp.asarray(np.repeat(np.arange(1, 5), 16)[None].astype(np.int32))
    pos = jnp.asarray(np.tile(np.arange(16), 4)[None].astype(np.int32))

    def loss(impl):
        def f(x):
            o = attention(x, x, x, q_seg=seg, kv_seg=seg, q_pos=pos,
                          kv_pos=pos, impl=impl, block_q=16, block_kv=16,
                          chunk_w=W)
            return jnp.sum(o * o)
        return jax.grad(f)(q)

    import jax
    g_ref = loss("reference")
    g_win = loss("windowed")
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_win),
                               atol=2e-4, rtol=2e-4)
