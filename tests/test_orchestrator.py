"""Orchestrator integration tests.

The core scientific claim (paper S3.3): rearranging examples across DP
instances is CONSEQUENCE-INVARIANT -- global loss and gradients do not
change.  With per-example deterministic content, we verify it end to end
for every family that exercises the orchestrator.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.synthetic import Example, sample_examples
from repro.training.train_step import init_train_state, make_loss_fn
from tests.test_arch_smoke import _tiny_examples


def _global_loss(cfg, examples, balance, balance_encoders=True, seed=0):
    rng = np.random.default_rng(seed)
    d = len(examples)
    orch = MLLMGlobalOrchestrator(
        cfg, d, balance=balance, balance_encoders=balance_encoders,
        vocab=cfg.vocab_size,
    )
    caps = orch.default_capacities(examples, margin=2.5)
    batch_np, report = orch.plan_and_pack(examples, caps, rng)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params, _ = init_train_state(cfg, jax.random.PRNGKey(42))
    loss_fn = make_loss_fn(cfg)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    return metrics, grads, report


@pytest.mark.parametrize(
    "arch", ["qwen3_8b", "falcon_mamba_7b", "llava_next_mistral_7b", "whisper_large_v3"]
)
def test_consequence_invariance(arch):
    """Same examples, balanced vs not -> identical loss sum & gradients."""
    cfg = get_config(arch).smoke()
    rng = np.random.default_rng(1)
    examples = _tiny_examples(cfg, rng, d=4, per=3)

    m_bal, g_bal, rep_bal = _global_loss(cfg, examples, balance=True)
    m_no, g_no, rep_no = _global_loss(cfg, examples, balance=False)

    # Token counts identical (same examples).
    assert int(m_bal["tokens"]) == int(m_no["tokens"])
    # Loss identical up to float accumulation order.
    np.testing.assert_allclose(
        float(m_bal["loss"]), float(m_no["loss"]), rtol=2e-2, atol=2e-2
    )
    # Gradients identical (the strong form of S3.3).
    la = jax.tree_util.tree_leaves(g_bal)
    lb = jax.tree_util.tree_leaves(g_no)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-2, atol=5e-2,
        )


def test_balancing_improves_utilization():
    """With skewed per-instance loads, post-balancing must raise the
    simulated utilization of every phase."""
    cfg = get_config("llava_next_mistral_7b").smoke()
    # Instance 0 gets huge examples, others tiny -> badly imbalanced.
    examples = [
        [Example("vqa", 120, 5 * 24, 0, ("vision", "text")) for _ in range(3)],
        [Example("t", 10, 0, 0, ("text",)) for _ in range(3)],
        [Example("t", 12, 24, 0, ("vision", "text")) for _ in range(3)],
        [Example("t", 8, 0, 0, ("text",)) for _ in range(3)],
    ]
    rng = np.random.default_rng(0)
    orch_b = MLLMGlobalOrchestrator(cfg, 4, balance=True, vocab=64)
    orch_n = MLLMGlobalOrchestrator(cfg, 4, balance=False, vocab=64)
    # Capacities are per-orchestrator (the unbalanced baseline needs a
    # full-batch chunk capacity).
    _, rep_b = orch_b.plan_and_pack(
        examples, orch_b.default_capacities(examples, margin=4.0), rng)
    _, rep_n = orch_n.plan_and_pack(
        examples, orch_n.default_capacities(examples, margin=4.0), rng)
    assert rep_b.phase_utilization["llm"] > rep_n.phase_utilization["llm"]
    assert rep_b.phase_utilization["vision"] >= rep_n.phase_utilization["vision"]


def test_pre_balancing_leaves_encoder_imbalance():
    """Fig 10's point: balancing ONLY the LLM phase (pre-balancing
    equivalent) leaves the encoder phases imbalanced under Modality
    Composition Incoherence."""
    cfg = get_config("mllm_10b").smoke()
    rng = np.random.default_rng(3)
    d = 8
    examples = [sample_examples(rng, 6) for _ in range(d)]
    orch_full = MLLMGlobalOrchestrator(cfg, d, vocab=128)
    orch_llm_only = MLLMGlobalOrchestrator(cfg, d, balance_encoders=False, vocab=128)
    caps = orch_full.default_capacities(examples, margin=3.0)
    _, rep_full = orch_full.plan_and_pack(examples, caps, rng)
    _, rep_llm = orch_llm_only.plan_and_pack(examples, caps, rng)
    # LLM phase: both balanced.
    assert rep_llm.phase_utilization["llm"] == pytest.approx(
        rep_full.phase_utilization["llm"], abs=0.05
    )
    # Encoder phases: full orchestrator strictly better on max cost.
    for ph in ("vision", "audio"):
        assert rep_full.phase_max_cost[ph] <= rep_llm.phase_max_cost[ph]


def test_report_comm_accounting():
    cfg = get_config("mllm_10b").smoke()
    rng = np.random.default_rng(4)
    d = 4
    examples = [sample_examples(rng, 4) for _ in range(d)]
    orch = MLLMGlobalOrchestrator(cfg, d, instances_per_node=2, vocab=128)
    caps = orch.default_capacities(examples, margin=3.0)
    _, rep = orch.plan_and_pack(examples, caps, rng)
    for ph in ("vision", "audio"):
        v = rep.comm_volume[ph]
        assert 0 <= v["self"] <= v["total"]
        assert rep.internode_volume[ph] <= v["total"]
    assert rep.solve_ms > 0
