"""Unit + property tests for the Batch Post-Balancing algorithms (paper S5.1)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.balancing import (
    brute_force_oracle,
    flatten_instance_lengths,
    post_balance,
    post_balance_conv,
    post_balance_nopad,
    post_balance_pad,
    post_balance_quad,
)
from repro.core.cost_model import CostModel, batch_length, transformer_cost_coeffs
from repro.core.rearrangement import identity_rearrangement


def _mk_lengths(rng, d, lo=1, hi=100, per=4):
    return [rng.integers(lo, hi, size=rng.integers(1, per + 1)) for _ in range(d)]


# ----------------------------------------------------------------------
# Cost model.
# ----------------------------------------------------------------------
def test_batch_length_eq1():
    assert batch_length([3, 5, 2], padding=True) == 3 * 5
    assert batch_length([3, 5, 2], padding=False) == 10
    assert batch_length([], padding=True) == 0


def test_cost_model_variants():
    cm_lin = CostModel(alpha=1.0, beta=0.0)
    assert cm_lin.cost([2, 3]) == 5.0
    cm_quad = CostModel(alpha=1.0, beta=0.5)
    assert cm_quad.cost([2, 3]) == 5.0 + 0.5 * 13
    cm_pad = CostModel(alpha=1.0, beta=0.5, padding=True)
    # L = 2*3=6; f = 6 + 0.5*36/2 = 15
    assert cm_pad.cost([2, 3]) == 15.0
    cm_conv = CostModel(alpha=1.0, beta=0.5, conv_attention=True)
    # f = 5 + 0.5*2*9 = 14
    assert cm_conv.cost([2, 3]) == 14.0


def test_transformer_coeffs_ssm_has_no_quadratic_term():
    a, b = transformer_cost_coeffs(1024, 4096, 24, ssm=True)
    assert b == 0.0
    a2, b2 = transformer_cost_coeffs(1024, 4096, 24)
    assert b2 > 0.0


# ----------------------------------------------------------------------
# Permutation invariants: every algorithm must output a true rearrangement
# (each input example appears exactly once) -- the consequence-invariance
# precondition of S3.3.
# ----------------------------------------------------------------------
ALGOS = {
    "nopad": post_balance_nopad,
    "pad": post_balance_pad,
    "quad": post_balance_quad,
    "conv": post_balance_conv,
}


@pytest.mark.parametrize("name", sorted(ALGOS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_algorithms_are_permutations(name, seed):
    rng = np.random.default_rng(seed)
    d = 8
    lens = _mk_lengths(rng, d, per=6)
    items = flatten_instance_lengths(lens)
    pi = ALGOS[name](items, d)
    got = sorted(zip(pi.orig_inst.tolist(), pi.orig_slot.tolist()))
    want = sorted((i, j) for i, j, _ in items)
    assert got == want
    # Destination slots are contiguous per destination batch.
    for i in range(d):
        slots = sorted(pi.dst_slot[pi.dst_inst == i].tolist())
        assert slots == list(range(len(slots)))
    # Lengths preserved.
    assert sorted(pi.lengths.tolist()) == sorted(l for _, _, l in items)


@given(
    st.lists(
        st.lists(st.integers(1, 50), min_size=1, max_size=5), min_size=2, max_size=6
    )
)
@settings(max_examples=40, deadline=None)
def test_property_nopad_never_worse_than_identity(lens_py):
    """Post-balancing can only reduce the max batch token sum."""
    d = len(lens_py)
    lens = [np.array(x) for x in lens_py]
    cm = CostModel(alpha=1.0, beta=0.0)
    ident = identity_rearrangement(lens, d)
    pi = post_balance(lens, d, cm)
    max_before = max(cm.cost(l) for l in ident.dest_lengths())
    max_after = max(cm.cost(l) for l in pi.dest_lengths())
    assert max_after <= max_before + 1e-9


@given(
    st.lists(
        st.lists(st.integers(1, 30), min_size=1, max_size=4), min_size=2, max_size=4
    )
)
@settings(max_examples=30, deadline=None)
def test_property_lpt_within_4_3_of_oracle(lens_py):
    """Alg 1 is a 4/3-approximation of the makespan objective."""
    d = len(lens_py)
    lens = [np.array(x) for x in lens_py]
    n = sum(len(x) for x in lens_py)
    if n > 10:
        return
    cm = CostModel(alpha=1.0, beta=0.0)
    pi = post_balance(lens, d, cm)
    got = max(cm.cost(l) for l in pi.dest_lengths())
    opt = brute_force_oracle(lens, d, cm)
    assert got <= 4.0 / 3.0 * opt + 1e-9


def test_pad_algorithm_minimizes_padded_batch_length():
    rng = np.random.default_rng(7)
    d = 4
    lens = _mk_lengths(rng, d, lo=5, hi=200, per=8)
    cm = CostModel(alpha=1.0, beta=0.0, padding=True)
    ident = identity_rearrangement(lens, d)
    pi = post_balance(lens, d, cm)
    before = max(batch_length(l, True) for l in ident.dest_lengths())
    after = max(batch_length(l, True) for l in pi.dest_lengths() if l.size)
    assert after <= before
    # Binary search returns <= d non-empty batches.
    assert sum(1 for l in pi.dest_lengths() if l.size) <= d


def test_pad_algorithm_is_optimal_for_its_packing_family():
    # For equal lengths, the padded objective is n/d * l exactly.
    d = 4
    lens = [np.full(5, 7) for _ in range(d)]
    cm = CostModel(padding=True)
    pi = post_balance(lens, d, cm)
    after = max(batch_length(l, True) for l in pi.dest_lengths() if l.size)
    assert after == 5 * 7


def test_quad_beats_nopad_on_quadratic_objective():
    """Alg 3 should (weakly) beat Alg 1 on f = L + lam*sum(l^2) for a
    distribution with heavy tails, which is its design target."""
    rng = np.random.default_rng(3)
    d = 8
    lens = [
        np.concatenate([rng.integers(1, 10, size=6), rng.integers(200, 400, size=1)])
        for _ in range(d)
    ]
    cm = CostModel(alpha=1.0, beta=0.01)
    pi1 = post_balance(lens, d, cm, algorithm="nopad")
    pi3 = post_balance(lens, d, cm, algorithm="quad")
    m1 = max(cm.cost(l) for l in pi1.dest_lengths())
    m3 = max(cm.cost(l) for l in pi3.dest_lengths())
    assert m3 <= m1 * 1.05  # never meaningfully worse


def test_conv_algorithm_handles_conv_objective():
    rng = np.random.default_rng(5)
    d = 4
    lens = _mk_lengths(rng, d, lo=10, hi=500, per=8)
    cm = CostModel(alpha=1.0, beta=0.001, conv_attention=True)
    ident = identity_rearrangement(lens, d)
    pi = post_balance(lens, d, cm)
    assert max(cm.cost(l) for l in pi.dest_lengths()) <= max(
        cm.cost(l) for l in ident.dest_lengths()
    )


def test_policy_dispatch():
    lens = [np.array([3, 4]), np.array([5])]
    assert post_balance(lens, 2, CostModel(padding=True)).n == 3
    assert post_balance(lens, 2, CostModel(beta=0.5)).n == 3
    assert post_balance(lens, 2, CostModel(conv_attention=True, beta=0.1)).n == 3
    assert post_balance(lens, 2, CostModel()).n == 3
    with pytest.raises(ValueError):
        post_balance(lens, 2, CostModel(), algorithm="bogus")


def test_empty_and_degenerate():
    cm = CostModel()
    pi = post_balance([np.array([], dtype=int), np.array([], dtype=int)], 2, cm)
    assert pi.n == 0
    pi = post_balance([np.array([5])], 1, cm)
    assert pi.n == 1 and pi.dest_lengths()[0].tolist() == [5]
