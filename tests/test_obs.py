"""Observability plane tests: quantile sketch rank error, registry
semantics, OpenMetrics exposition, canonical ledger formulas, flight
recorder crash safety, alert routing, unified timeline, kernel hooks.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap
import types

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.obs import (AlertBridge, FlightRecorder, GapWaterfall,
                       MetricsRegistry, QuantileSketch, StepLedger,
                       build_timeline, get_registry, goodput_fraction,
                       phase_imbalance, read_flight_record,
                       render_openmetrics, set_registry, simulated_mfu,
                       straggler_overhead, write_openmetrics)

# ----------------------------------------------------------------------
# Quantile sketch: GK rank-error guarantee on adversarial streams.
# ----------------------------------------------------------------------
QS = (0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0)


def _assert_rank_error(data, eps=0.005, qs=QS):
    """The sketch answer's true rank must be within eps*n (+1 slack for
    the discrete ceil) of the target rank -- checked against the exact
    sorted stream, which is what np.quantile also reads off."""
    sk = QuantileSketch(eps=eps)
    sk.extend(data)
    xs = np.sort(np.asarray(data, dtype=np.float64))
    n = len(xs)
    for q in qs:
        v = sk.quantile(q)
        target = max(1, int(np.ceil(q * n)))
        # 1-based rank interval of v in the stream.
        rank_lo = int(np.searchsorted(xs, v, side="left")) + 1
        rank_hi = int(np.searchsorted(xs, v, side="right"))
        margin = eps * n + 1
        assert rank_lo - margin <= target <= rank_hi + margin, (
            f"q={q}: answer {v} has rank [{rank_lo}, {rank_hi}], "
            f"target {target}, margin {margin:.1f} (n={n})")


@pytest.mark.parametrize("stream", [
    "ascending", "descending", "constant", "normal", "heavy_tail",
    "few_distinct", "alternating",
])
def test_sketch_rank_error_adversarial(stream):
    n = 20_000
    rng = np.random.default_rng(0)
    data = {
        "ascending": np.arange(n, dtype=float),
        "descending": np.arange(n, dtype=float)[::-1],
        "constant": np.full(n, 7.0),
        "normal": rng.normal(size=n),
        "heavy_tail": rng.lognormal(mean=0.0, sigma=3.0, size=n),
        "few_distinct": rng.choice([1.0, 2.0, 5.0], size=n),
        "alternating": np.where(np.arange(n) % 2 == 0, 1e-6, 1e6),
    }[stream]
    _assert_rank_error(data)


def test_sketch_memory_sublinear():
    sk = QuantileSketch(eps=0.01)
    sk.extend(np.random.default_rng(1).normal(size=50_000))
    sk.quantile(0.5)  # force drain
    # GK keeps O((1/eps) log(eps n)) tuples -- far below n.
    assert len(sk._tuples) < 2_000


def test_sketch_edge_cases():
    sk = QuantileSketch()
    assert np.isnan(sk.quantile(0.5))
    sk.add(3.0)
    assert sk.quantile(0.0) == 3.0 and sk.quantile(1.0) == 3.0
    with pytest.raises(ValueError):
        sk.quantile(1.5)
    with pytest.raises(ValueError):
        QuantileSketch(eps=0.7)


def test_sketch_state_roundtrip():
    sk = QuantileSketch(eps=0.01)
    sk.extend(np.random.default_rng(2).uniform(size=5_000))
    clone = QuantileSketch.from_state_dict(
        json.loads(json.dumps(sk.state_dict())))
    for q in QS:
        assert clone.quantile(q) == sk.quantile(q)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=-1e9, max_value=1e9), min_size=1,
                max_size=400))
def test_sketch_rank_error_property(xs):
    _assert_rank_error(xs, eps=0.01, qs=(0.5, 0.95))


def test_sketch_quantiles_monotone():
    sk = QuantileSketch()
    sk.extend(np.random.default_rng(3).exponential(size=10_000))
    vs = sk.quantiles(sorted(QS))
    assert vs == sorted(vs)


# ----------------------------------------------------------------------
# Registry semantics.
# ----------------------------------------------------------------------
def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("reqs", "requests", labels=("phase",))
    c.inc(phase="llm")
    c.inc(2.0, phase="llm")
    c.inc(phase="vision")
    assert c.labels(phase="llm").value == 3.0
    assert c.labels(phase="vision").value == 1.0
    with pytest.raises(ValueError):
        c.labels(phase="llm").inc(-1.0)
    with pytest.raises(ValueError):
        c.labels(shard="0")  # wrong label name

    g = reg.gauge("temp")
    g.set(4.0)
    g.labels().add(1.0)
    assert g.labels().value == 5.0

    h = reg.histogram("lat_ms", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    pairs = h.labels().bucket_counts()
    assert pairs[-1][0] == float("inf") and pairs[-1][1] == 4
    cums = [c for _, c in pairs]
    assert cums == sorted(cums)  # cumulative => monotone
    assert h.labels().mean() == pytest.approx(138.875)


def test_registry_reregistration_semantics():
    reg = MetricsRegistry()
    a = reg.counter("x", labels=("k",))
    assert reg.counter("x", labels=("k",)) is a  # idempotent
    with pytest.raises(ValueError):
        reg.gauge("x")  # kind conflict
    with pytest.raises(ValueError):
        reg.counter("x", labels=("other",))  # label conflict


def test_snapshot_counters_flat_naming():
    reg = MetricsRegistry()
    reg.counter("kernel_hits", labels=("kernel",)).inc(kernel="flash")
    reg.counter("steps").inc(5)
    reg.gauge("mfu").set(0.4)  # gauges excluded
    snap = reg.snapshot_counters()
    assert snap == {"kernel_hits{kernel=flash}": 1.0, "steps": 5.0}
    assert reg.snapshot_counters(prefix="kernel_") == {
        "kernel_hits{kernel=flash}": 1.0}


def test_default_registry_swap():
    prev = get_registry()
    mine = MetricsRegistry()
    try:
        assert set_registry(mine) is prev
        assert get_registry() is mine
    finally:
        set_registry(prev)


# ----------------------------------------------------------------------
# OpenMetrics exposition.
# ----------------------------------------------------------------------
def test_render_openmetrics_format():
    reg = MetricsRegistry()
    reg.counter("train_steps", "steps so far").inc(3)
    reg.gauge("mfu", "model flops util").set(0.416)
    h = reg.histogram("step_ms", "step wall", labels=("phase",),
                      buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v, phase="llm")
    text = render_openmetrics(reg)
    assert text.endswith("# EOF\n")
    assert "# TYPE train_steps_total counter" in text
    assert "train_steps_total 3" in text  # counters get _total
    assert "mfu 0.416" in text
    assert 'step_ms_bucket{phase="llm",le="1"} 1' in text
    assert 'step_ms_bucket{phase="llm",le="10"} 2' in text
    assert 'step_ms_bucket{phase="llm",le="+Inf"} 3' in text
    assert 'step_ms_count{phase="llm"} 3' in text
    for suffix in ("p50", "p95", "p99"):
        assert f"step_ms_{suffix}" in text
    # Every non-comment line is "name{labels} value".
    for line in text.splitlines():
        if line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        assert name and value not in ("",)
        float(value)  # parses


def test_render_openmetrics_label_escaping():
    reg = MetricsRegistry()
    reg.counter("c", labels=("k",)).inc(k='a"b\\c')
    assert '{k="a\\"b\\\\c"}' in render_openmetrics(reg)


def test_write_openmetrics_atomic(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("g").set(1.0)
    path = str(tmp_path / "metrics.prom")
    write_openmetrics(path, reg)
    assert open(path).read().endswith("# EOF\n")
    assert not os.path.exists(path + ".tmp")  # tmp replaced, not left


# ----------------------------------------------------------------------
# Canonical ledger formulas.
# ----------------------------------------------------------------------
def _fake_report(phase_costs, *, solve_ms=None, exposed_ms=0.0,
                 replanned=False):
    return types.SimpleNamespace(
        phase_costs={k: np.asarray(v, dtype=np.float64)
                     for k, v in phase_costs.items()},
        phase_solve_ms=solve_ms or {k: 1.0 for k in phase_costs},
        exposed_ms=exposed_ms, replanned=replanned, coeff_version=-1)


def test_simulated_mfu_matches_old_benchmark_proxy():
    """The ledger formula must equal the proxy `benchmarks/common.py`
    computed inline before the dedup (sum of means / sum of maxes)."""
    rng = np.random.default_rng(4)
    costs = {p: rng.uniform(1.0, 10.0, size=8) for p in
             ("llm", "vision", "audio")}
    old_proxy = (sum(float(np.mean(c)) for c in costs.values())
                 / sum(float(np.max(c)) for c in costs.values()))
    assert simulated_mfu(costs) == pytest.approx(old_proxy, rel=1e-12)
    assert straggler_overhead(costs) == pytest.approx(1.0 - old_proxy)


def test_simulated_mfu_on_real_orchestrator_report():
    """Same equality on a genuine plan (not synthetic cost dicts)."""
    from repro.configs import get_config
    from repro.core.orchestrator import MLLMGlobalOrchestrator
    from repro.data.synthetic import TaskMix, sample_examples

    cfg = get_config("mllm_10b").smoke()
    rng = np.random.default_rng(5)
    examples = [sample_examples(rng, 3, TaskMix(), ("vision", "audio"))
                for _ in range(4)]
    orch = MLLMGlobalOrchestrator(cfg, 4, vocab=512)
    caps = orch.default_capacities(examples, margin=3.0)
    _, report = orch.plan_and_pack(examples, caps, rng)
    old_proxy = (sum(float(np.mean(c)) for c in report.phase_costs.values())
                 / sum(float(np.max(c)) for c in report.phase_costs.values()))
    assert simulated_mfu(report.phase_costs) == pytest.approx(old_proxy)
    assert 0.0 < simulated_mfu(report.phase_costs) <= 1.0


def test_formula_edge_cases():
    assert simulated_mfu({}) == 1.0
    assert simulated_mfu({"llm": []}) == 1.0
    assert phase_imbalance([5.0, 5.0, 5.0]) == 0.0
    assert phase_imbalance([1.0, 3.0]) == pytest.approx(0.5)
    assert phase_imbalance([]) == 0.0
    # goodput: exposed host latency discounts the MFU.
    assert goodput_fraction(100.0, 0.0, 0.8) == pytest.approx(0.8)
    assert goodput_fraction(100.0, 25.0, 0.8) == pytest.approx(0.6)
    assert goodput_fraction(100.0, 1e9, 0.8) == 0.0  # clamped
    assert goodput_fraction(0.0, 5.0, 0.8) == 0.8  # no wall measured


def test_step_ledger_records_series_and_alerts():
    reg = MetricsRegistry()
    led = StepLedger(d=4, registry=reg)
    rep = _fake_report({"llm": [2.0, 2.0, 2.0, 4.0],
                        "vision": [1.0, 1.0, 1.0, 1.0]},
                       exposed_ms=5.0)
    events = led.record_step(0, report=rep, step_ms=50.0,
                             metrics={"loss": 2.5, "tokens": 128.0})
    assert events == []
    # replan + MoE drop spike both alert on the next step.
    rep2 = _fake_report({"llm": [2.0, 2.0, 2.0, 4.0]}, replanned=True)
    events = led.record_step(1, report=rep2, step_ms=50.0,
                             metrics={"moe_dropped_frac": 0.2})
    kinds = sorted(e["alert"] for e in events)
    assert kinds == ["moe_drop_spike", "stale_plan_replanned"]
    # below-threshold drop fraction stays quiet
    assert led.record_step(2, metrics={"moe_dropped_frac": 0.01}) == []

    assert reg.get("train_steps").labels().value == 3.0
    assert reg.get("train_tokens").labels().value == 128.0
    mfu = reg.get("train_mfu_simulated").labels().value
    assert 0.0 < mfu < 1.0
    assert reg.get("train_metric").labels(name="loss").value == 2.5
    # per-phase imbalance series tracked for the timeline
    assert [s for s, _ in led.series["mfu_simulated"]] == [0, 1]
    assert led.series["imbalance_llm"][0][1] == pytest.approx(
        4.0 / 2.5 - 1.0)
    assert led.step_ts_ms[1] == pytest.approx(100.0)
    s = led.summary()
    assert s["steps"] == 3 and s["tokens"] == 128.0
    assert s["step_ms_p50"] == pytest.approx(50.0)


def test_step_ledger_hw_mfu():
    cfg = types.SimpleNamespace(active_param_count=lambda: 1e9)
    led = StepLedger(cfg, d=2, registry=MetricsRegistry(), peak_flops=1e12,
                     chips=2)
    led.record_step(0, step_ms=3000.0, metrics={"tokens": 100.0})
    # 6e9 flops/token * 100 tokens / (3 s * 1e12 * 2 chips)
    assert led.series["mfu_hw"][0][1] == pytest.approx(0.1)


# ----------------------------------------------------------------------
# Flight recorder: crash safety.
# ----------------------------------------------------------------------
def test_flight_recorder_roundtrip(tmp_path):
    path = str(tmp_path / "flight.jsonl")
    with FlightRecorder(path, meta={"arch": "mllm_10b"},
                        flush_every=100) as rec:
        for i in range(5):
            rec.record("step", step=i)
    events = read_flight_record(path)
    assert [e["kind"] for e in events] == ["meta"] + ["step"] * 5
    assert events[0]["arch"] == "mllm_10b"
    assert all("ts" in e for e in events)


def test_flight_recorder_torn_tail_tolerated(tmp_path):
    path = str(tmp_path / "flight.jsonl")
    rec = FlightRecorder(path, meta={})
    rec.record("step", step=0)
    rec.flush()
    # Crash mid-write of the next buffer: a torn final line on disk.
    with open(path, "a") as f:
        f.write('{"kind": "step", "st')
    events = read_flight_record(path)
    assert [e["kind"] for e in events] == ["meta", "step"]


def test_flight_recorder_mid_file_corruption_raises(tmp_path):
    path = str(tmp_path / "flight.jsonl")
    rec = FlightRecorder(path, meta={})
    rec.record("step", step=0)
    rec.flush()
    with open(path, "a") as f:
        f.write("GARBAGE NOT JSON\n")
    rec.record("step", step=1)
    rec.flush()
    with pytest.raises(ValueError, match="corrupt flight record"):
        read_flight_record(path)


def test_flight_recorder_survives_sigkill(tmp_path):
    """Kill a recording process mid-step: the record must be valid JSONL
    up to the last explicit flush (ISSUE acceptance semantics)."""
    path = str(tmp_path / "flight.jsonl")
    child = textwrap.dedent(f"""
        import os, signal
        from repro.obs import FlightRecorder
        rec = FlightRecorder({path!r}, meta={{"run": "crashy"}},
                             flush_every=1000)
        for i in range(10):
            rec.record("step", step=i)
        rec.flush()
        for i in range(10, 15):          # never flushed
            rec.record("step", step=i)
        os.kill(os.getpid(), signal.SIGKILL)
    """)
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == -signal.SIGKILL
    events = read_flight_record(path)
    assert [e["kind"] for e in events] == ["meta"] + ["step"] * 10
    assert [e["step"] for e in events[1:]] == list(range(10))


# ----------------------------------------------------------------------
# Alert routing.
# ----------------------------------------------------------------------
def test_alert_bridge_routes_all_signal_shapes(tmp_path):
    reg = MetricsRegistry()
    path = str(tmp_path / "flight.jsonl")
    with FlightRecorder(path, meta={}) as rec:
        bridge = AlertBridge(rec, reg)
        bridge.on_drift({"llm": True, "vision": False}, step=7)
        bridge.on_checkpoint_fallback("/ckpt/step_4.corrupt", restored_step=2)
        bridge.on_preemptions(2, step=8)   # below storm threshold
        bridge.on_preemptions(3, step=9)   # storm
        bridge.on_ledger_events([{"alert": "moe_drop_spike", "step": 10,
                                  "moe_dropped_frac": 0.2}])
    events = [e for e in read_flight_record(path) if e["kind"] == "alert"]
    assert [e["alert"] for e in events] == [
        "cost_model_drift", "checkpoint_corruption_fallback",
        "preemption_storm", "moe_drop_spike"]
    assert events[0]["phase"] == "llm" and events[0]["step"] == 7
    snap = reg.snapshot_counters(prefix="alerts")
    assert snap["alerts{alert=cost_model_drift}"] == 1.0
    assert "alerts{alert=preemption_storm}" in snap


# ----------------------------------------------------------------------
# Unified timeline.
# ----------------------------------------------------------------------
def test_build_timeline_merges_sources():
    from repro.serving.engine.engine import StepTiming

    led = StepLedger(d=2, registry=MetricsRegistry())
    led.record_step(0, report=_fake_report({"llm": [1.0, 2.0]}),
                    step_ms=10.0)
    led.record_step(1, report=_fake_report({"llm": [1.0, 1.0]}),
                    step_ms=10.0)
    timings = [StepTiming(step=0, schedule_ms=0.5, prefill_ms=3.0,
                          decode_ms=1.0, n_prefill_seqs=2,
                          prefill_tokens=64, n_decode_seqs=1),
               StepTiming(step=1, schedule_ms=0.4, prefill_ms=0.0,
                          decode_ms=1.2, n_prefill_seqs=0,
                          prefill_tokens=0, n_decode_seqs=3)]
    doc = build_timeline(step_timings=timings, ledger=led,
                         series={"extra": [(0, 1.0)]})
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    counters = [e for e in evs if e["ph"] == "C"]
    metas = [e for e in evs if e["ph"] == "M"]
    # engine spans live in the engine pid block, back to back in time
    assert {e["pid"] for e in spans} == {1000}
    decode0 = next(e for e in spans if e["name"] == "decode"
                   and e["args"]["step"] == 0)
    sched1 = next(e for e in spans if e["name"] == "schedule"
                  and e["args"]["step"] == 1)
    assert sched1["ts"] == pytest.approx(decode0["ts"] + decode0["dur"])
    # counter tracks: ledger series + caller extras on the counter pid,
    # timestamped by the ledger's cumulative wall clock
    names = {e["name"] for e in counters}
    assert {"mfu_simulated", "imbalance_llm", "extra"} <= names
    assert all(e["pid"] == 9000 for e in counters)
    mfu_pts = sorted(e["ts"] for e in counters
                     if e["name"] == "mfu_simulated")
    assert mfu_pts == [10.0 * 1e3, 20.0 * 1e3]
    assert any(e["args"]["name"] == "engine:replica0" for e in metas)


def test_timeline_includes_orchestrator_trace_spans():
    from repro.telemetry.trace import PhaseSample, TraceBuffer

    buf = TraceBuffer()
    buf.add(PhaseSample.from_lengths("llm", [4, 8], 2.0, kind="plan"))
    buf.add(PhaseSample.from_lengths("vision", [2], 1.0, kind="exec"))
    doc = build_timeline(trace_buffer=buf)
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert {e["name"] for e in spans} >= {"llm/plan", "vision/exec"}


def test_ledger_flags_inconsistent_clocks():
    """exposed_ms > step_ms means the host and step clocks disagree;
    the ledger must surface that as an alert event, not clamp silently."""
    led = StepLedger(d=2, registry=MetricsRegistry())
    rep = _fake_report({"llm": [1.0, 1.0]}, exposed_ms=25.0)
    events = led.record_step(0, report=rep, step_ms=10.0)
    bad = [e for e in events if e["alert"] == "measurement_inconsistent"]
    assert len(bad) == 1
    assert bad[0]["exposed_ms"] == 25.0 and bad[0]["step_ms"] == 10.0
    # the clamp still applies to the goodput gauge itself
    assert 0.0 <= led.series["goodput_frac"][-1][1] <= 1.0


def test_timeline_checkpoint_track_and_waterfall_counters():
    from repro.checkpoint import CheckpointOp

    ops = [CheckpointOp(kind="save", step=4, start_s=100.0, wall_ms=30.0),
           CheckpointOp(kind="restore", step=4, start_s=102.0, wall_ms=12.0)]
    wf = GapWaterfall(registry=MetricsRegistry())
    wf.observe(0, phase_costs={"llm": [1.0, 2.0]}, step_ms=5.0)
    doc = build_timeline(checkpoint_ops=ops, waterfall=wf)
    evs = doc["traceEvents"]
    spans = [e for e in evs if e.get("ph") == "X"]
    # checkpoint ops render on their own pid, at real relative offsets
    save = next(e for e in spans if e["name"] == "save@step4")
    restore = next(e for e in spans if e["name"] == "restore@step4")
    assert save["pid"] == restore["pid"] == 8000
    assert save["ts"] == 0.0 and restore["ts"] == pytest.approx(2e6)
    assert save["dur"] == pytest.approx(30e3)
    metas = [e for e in evs if e.get("ph") == "M"]
    assert any(e["args"]["name"] == "checkpoint" for e in metas)
    # waterfall series join the counter pid under a waterfall_ prefix
    counters = {e["name"] for e in evs if e.get("ph") == "C"}
    assert {"waterfall_gap", "waterfall_imbalance_llm"} <= counters


def test_step_timing_carries_preemption_fields():
    from repro.serving.engine.engine import StepTiming

    t = StepTiming(step=0, schedule_ms=0.1, prefill_ms=0.0, decode_ms=0.2,
                   n_prefill_seqs=0, prefill_tokens=0, n_decode_seqs=1)
    assert t.n_preempted == 0 and t.recompute_tokens == 0  # defaults
    t2 = StepTiming(step=1, schedule_ms=0.1, prefill_ms=0.0, decode_ms=0.2,
                    n_prefill_seqs=0, prefill_tokens=0, n_decode_seqs=1,
                    n_preempted=2, recompute_tokens=96)
    assert t2.n_preempted == 2 and t2.recompute_tokens == 96


# ----------------------------------------------------------------------
# Kernel hooks.
# ----------------------------------------------------------------------
def test_autotune_resolve_counts_outcomes(tmp_path, monkeypatch):
    from repro.kernels import autotune

    prev = set_registry(MetricsRegistry())
    try:
        monkeypatch.delenv("REPRO_KERNEL_BLOCKS", raising=False)
        cache = str(tmp_path / "cache.json")
        autotune.resolve("flash", {"seq": 128}, (128, 128),
                         cache_path=cache)                    # miss
        autotune.resolve("flash", {"seq": 128}, (128, 128),
                         enabled=False, cache_path=cache)     # disabled
        monkeypatch.setenv("REPRO_KERNEL_BLOCKS", "flash=256x128")
        assert autotune.resolve("flash", {"seq": 128}, (128, 128),
                                cache_path=cache) == (256, 128)  # override
        snap = get_registry().snapshot_counters(prefix="kernel_")
        assert snap["kernel_autotune_resolves{kernel=flash,outcome=miss}"] == 1
        assert snap["kernel_autotune_resolves{kernel=flash,outcome=disabled}"] == 1
        assert snap["kernel_autotune_resolves{kernel=flash,outcome=override}"] == 1
    finally:
        set_registry(prev)


def test_tile_skip_fraction_matches_live_tiles():
    from repro.kernels.flash_attention import (count_live_tiles,
                                               tile_skip_fraction)

    # two streams of 32, causal: upper-triangle KV tiles are skipped
    seg = np.repeat([1, 2], 32)[None, :]
    pos = np.concatenate([np.arange(32), np.arange(32)])[None, :]
    kw = dict(block_q=16, block_kv=16, causal=True, window=None)
    frac = tile_skip_fraction(seg, seg, pos, pos, **kw)
    visited, total = count_live_tiles(seg, seg, pos, pos, **kw)
    assert frac == pytest.approx(1.0 - visited / total)
    assert 0.0 < frac < 1.0  # causal + cross-segment => real skips


def test_group_tile_skip_fraction():
    from repro.kernels.grouped_gemm import group_tile_skip_fraction

    assert group_tile_skip_fraction([0, 0, 0], block_m=4) == 0.0
    # 16 rows over 4 m-tiles x 3 experts = 12 grid cells; expert 0 owns
    # tiles {0,1}, expert 2 owns {2,3}, the empty expert owns none.
    assert group_tile_skip_fraction([8, 0, 8], block_m=4) == pytest.approx(
        1.0 - 4.0 / 12.0)
    # perfectly aligned groups touch exactly one tile column each
    assert group_tile_skip_fraction([8, 8], block_m=4) == pytest.approx(0.5)
