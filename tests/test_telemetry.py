"""Telemetry & online cost-model calibration tests (ISSUE 4).

Covers: trace ring buffer + Chrome-trace export, NNLS nonnegativity,
planted-coefficient recovery (property test), convergence from a
3x-miscalibrated prior, CUSUM drift detection (fires on a step-change,
quiet on stationary noise), the end-to-end orchestrator acceptance bar
(calibrated imbalance within 5% of oracle on identical token streams),
and the serving-side breakdown + weight calibration."""
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core.cost_model import (
    CostModel,
    ServingCostModel,
    encoder_cost_model,
    length_features,
    llm_cost_model,
    serving_cost_model,
)
from repro.core.dispatcher import BatchPostBalancingDispatcher
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.synthetic import TaskMix, sample_examples
from repro.telemetry import (
    AdaptiveCostModel,
    AdaptiveOrchestration,
    AdaptiveServingCostModel,
    DriftDetector,
    PhaseCalibrator,
    PhaseSample,
    RecursiveFit,
    ServingCalibrator,
    TraceBuffer,
    nnls_fit,
)


def _varied_features(rng, n, *, padding=False, lo=16, hi=2048):
    """Identifiable design: batch size AND length scale vary across
    rows, so the linear and quadratic columns decorrelate."""
    rows = []
    for _ in range(n):
        b = int(rng.integers(2, 48))
        top = int(rng.integers(lo + 1, hi))
        rows.append(length_features(rng.integers(lo, top + 1, size=b), padding))
    return np.stack(rows)


# ----------------------------------------------------------------------
# Feature basis.
# ----------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_features_consistent_with_cost(seed):
    rng = np.random.default_rng(seed)
    lens = rng.integers(1, 500, size=rng.integers(1, 40))
    for cm in (CostModel(alpha=0.7, beta=3e-3),
               CostModel(alpha=0.7, beta=3e-3, padding=True),
               CostModel(alpha=0.7, beta=3e-3, conv_attention=True)):
        f = cm.feature_vector(lens)
        assert np.isclose(float(cm.cost_from_features(f)), cm.cost(lens))
    ids = rng.integers(0, 4, size=lens.size)
    cm = CostModel(alpha=1.0, beta=1e-3, padding=True)
    F = cm.segment_features(lens.astype(float), ids, 4)
    np.testing.assert_allclose(cm.cost_from_features(F),
                               cm.segment_costs(lens.astype(float), ids, 4))


def test_dispatch_plan_carries_features():
    rng = np.random.default_rng(3)
    cm = CostModel(alpha=1.0, beta=1e-3)
    disp = BatchPostBalancingDispatcher(4, cm)
    plan = disp.plan([rng.integers(1, 200, size=8) for _ in range(4)])
    assert plan.features.shape == (4, 4)
    np.testing.assert_allclose(cm.cost_from_features(plan.features), plan.costs)


# ----------------------------------------------------------------------
# Trace buffer.
# ----------------------------------------------------------------------
def test_trace_ring_evicts_oldest():
    buf = TraceBuffer(capacity=8)
    for i in range(20):
        buf.add(PhaseSample.from_lengths("llm", [i + 1], 1.0, step=i))
    assert len(buf) == 8 and buf.dropped == 12
    steps = [s.step for s in buf.samples()]
    assert steps == list(range(12, 20))  # oldest-first, newest kept


def test_trace_filters_and_design_matrix():
    buf = TraceBuffer()
    buf.add(PhaseSample.from_lengths("llm", [5, 6], 2.0, step=0))
    buf.add(PhaseSample.from_lengths("vision", [7], 3.0, step=0))
    buf.add(PhaseSample("llm", 0, 1, np.zeros(4), 0.5, kind="plan"))
    X, y = buf.design_matrix("llm")  # exec only
    assert X.shape == (1, 4) and y.tolist() == [2.0]
    assert buf.phases() == ["llm", "vision"]
    assert len(buf.samples(kind="plan")) == 1


def test_chrome_trace_export(tmp_path):
    buf = TraceBuffer()
    for step in range(3):
        for shard in range(2):
            buf.add(PhaseSample.from_lengths(
                "llm", [10 * (step + 1)], 1.5, shard=shard, step=step))
    out = tmp_path / "trace.json"
    buf.export_chrome_trace(out)
    doc = json.loads(out.read_text())
    events = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(events) == 6
    assert all(e["dur"] == 1500.0 for e in events)  # ms -> us
    # back-to-back layout per (phase, shard) track
    per_track = [e["ts"] for e in events if e["tid"] == 0]
    assert per_track == [0.0, 1500.0, 3000.0]
    assert any(e.get("ph") == "M" for e in doc["traceEvents"])


# ----------------------------------------------------------------------
# NNLS fitting.
# ----------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_nnls_never_negative(seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(rng.integers(3, 30), 3)) * [1.0, 100.0, 1e4]
    y = rng.normal(size=X.shape[0]) - 5.0  # adversarial: negative targets
    c = nnls_fit(X, y, ridge=1e-3, prior=[0.5, 0.0, 0.0])
    assert (c >= 0).all()


def test_nnls_zero_samples_returns_prior():
    c = nnls_fit(np.zeros((0, 2)), np.zeros(0), ridge=1e-3, prior=[2.0, 3.0])
    assert c.tolist() == [2.0, 3.0]


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fit_recovers_planted_coeffs(seed):
    rng = np.random.default_rng(seed)
    alpha = float(rng.uniform(0.2, 3.0))
    beta = float(rng.uniform(1e-4, 5e-3))
    truth = CostModel(alpha=alpha, beta=beta)
    cal = PhaseCalibrator(truth.with_coeffs(1.0, 1e-3), min_samples=12)
    X = _varied_features(rng, 120)
    y = truth.cost_from_features(X) * (1 + rng.normal(0, 0.02, size=len(X)))
    cal.observe(X, y)
    est = cal.estimate
    assert cal.calibrated
    assert est.alpha == pytest.approx(alpha, rel=0.15)
    assert est.beta == pytest.approx(beta, rel=0.3)
    # lam (the only thing balancing consumes) is recovered tightly
    assert cal.cost_model().lam == pytest.approx(beta / alpha, rel=0.3)


def test_convergence_from_3x_miscalibrated_prior_within_k_samples():
    rng = np.random.default_rng(7)
    truth = CostModel(alpha=1.0, beta=8e-4)
    prior = truth.with_coeffs(1.0, 3 * 8e-4)
    adapt = AdaptiveCostModel(prior, phase="llm")
    K = 48
    for step in range(K):
        F = _varied_features(rng, 4)
        t = truth.cost_from_features(F) * (1 + rng.normal(0, 0.03, size=4))
        adapt.observe(F, t, step=step)
    assert adapt.calibrated
    assert adapt.calibrator.n_observed <= 4 * K
    assert adapt.current().lam == pytest.approx(truth.lam, rel=0.2)
    assert adapt.version >= 1  # swap-in bumped the plan version


def test_ssm_phase_calibrates_to_zero_beta():
    # A truly linear phase (SSM: beta = 0) must reach confidence with
    # beta pinned at the NNLS boundary, not be stuck "uncertain".
    rng = np.random.default_rng(11)
    truth = CostModel(alpha=2.0, beta=0.0)
    cal = PhaseCalibrator(truth.with_coeffs(1.0, 1e-3))
    X = _varied_features(rng, 100)
    cal.observe(X, truth.cost_from_features(X)
                * (1 + rng.normal(0, 0.02, size=100)))
    assert cal.calibrated
    # lam collapses to ~0 (>= 10x below the prior's 5e-4): the quad
    # term's residual ridge pull has negligible balancing impact.
    assert cal.cost_model().lam < 5e-5
    assert cal.estimate.alpha == pytest.approx(2.0, rel=0.1)


def test_recursive_fit_tracks_planted_slope():
    rng = np.random.default_rng(5)
    rls = RecursiveFit(2, prior=[1.0, 0.0], ridge=1e-2)
    for _ in range(300):
        x = np.array([rng.uniform(10, 1000), rng.uniform(1e3, 1e6)])
        y = 0.5 * x[0] + 2e-3 * x[1] + rng.normal(0, 1.0)
        rls.update(x, y)
    c = rls.coeffs
    assert (c >= 0).all()
    assert c[0] == pytest.approx(0.5, rel=0.2)
    assert c[1] == pytest.approx(2e-3, rel=0.2)


# ----------------------------------------------------------------------
# Drift detection.
# ----------------------------------------------------------------------
def test_cusum_quiet_on_stationary_noise():
    rng = np.random.default_rng(0)
    det = DriftDetector()
    fired = sum(det.update(r) for r in rng.normal(0, 0.05, size=5000))
    assert fired == 0 and det.events == 0


def test_cusum_fires_on_step_change():
    rng = np.random.default_rng(1)
    det = DriftDetector()
    for r in rng.normal(0, 0.05, size=200):
        assert not det.update(r)
    fired = False
    for r in rng.normal(0.5, 0.05, size=100):  # 10-sigma mean shift
        if det.update(r):
            fired = True
            break
    assert fired and det.events == 1


def test_calibrator_drift_recovers_new_regime():
    rng = np.random.default_rng(9)
    regime_a = CostModel(alpha=1.0, beta=5e-4)
    regime_b = CostModel(alpha=1.0, beta=2.5e-3)  # resolution-shift analog
    adapt = AdaptiveCostModel(regime_a.with_coeffs(1.0, 1e-3), phase="llm")

    def feed(truth, steps, start):
        drifts = 0
        for step in range(start, start + steps):
            F = _varied_features(rng, 4)
            t = truth.cost_from_features(F) * (1 + rng.normal(0, 0.03, size=4))
            drifts += bool(adapt.observe(F, t, step=step))
        return drifts

    assert feed(regime_a, 40, 0) == 0  # converging on A is not drift
    assert adapt.calibrated
    assert adapt.current().lam == pytest.approx(regime_a.lam, rel=0.2)
    v = adapt.version
    assert feed(regime_b, 60, 40) >= 1  # step-change flagged
    assert adapt.drift_events >= 1
    assert adapt.version > v
    assert adapt.current().lam == pytest.approx(regime_b.lam, rel=0.25)


# ----------------------------------------------------------------------
# End-to-end orchestrator acceptance (ISSUE 4 bar).
# ----------------------------------------------------------------------
def _stream_fingerprint(batch):
    """Order-invariant fingerprint of the packed token payload: the
    multiset of per-example (segment-sorted) token tuples."""
    tokens, seg = batch["tokens"], batch.get("llm_seg", batch.get("seg"))
    per_ex = {}
    text_seg = batch.get("llm_seg")
    if text_seg is not None:
        # multimodal layout: text tokens live in their own stream, keyed
        # by destination slots into the llm stream
        dst = batch["text_dst"]
        for i in range(tokens.shape[0]):
            live = dst[i] < text_seg.shape[1]
            sids = text_seg[i][dst[i][live]]
            for s in np.unique(sids):
                per_ex[int(s)] = tuple(tokens[i][live][sids == s].tolist())
    else:
        for i in range(tokens.shape[0]):
            for s in np.unique(seg[i]):
                if s > 0:
                    per_ex[int(s)] = tuple(tokens[i][seg[i] == s].tolist())
    return per_ex


def test_adaptive_orchestrator_end_to_end_matches_oracle():
    """From a 3x-miscalibrated prior, calibrated post-balanced max-cost
    imbalance lands within 5% of the oracle-coefficient run, on
    identical token streams (calibration changes only the plan)."""
    cfg = get_config("mllm_10b")
    d, per, steps = 4, 16, 30
    lam_true = {"llm": 8e-4, "vision": 1.5e-3, "audio": 4e-4}
    oracle = {"llm": llm_cost_model(cfg).with_coeffs(1.0, lam_true["llm"])}
    for e in cfg.encoders:
        oracle[e.name] = encoder_cost_model(e).with_coeffs(
            1.0, lam_true[e.name])
    prior = {k: m.with_coeffs(1.0, m.beta * 3) for k, m in oracle.items()}

    orch_oracle = MLLMGlobalOrchestrator(cfg, d, vocab=512)
    orch_oracle.llm_dispatcher.cost_model = oracle["llm"]
    for n, disp in orch_oracle.enc_dispatchers.items():
        disp.cost_model = oracle[n]
    orch_adapt = MLLMGlobalOrchestrator(
        cfg, d, vocab=512, adaptive=AdaptiveOrchestration(priors=prior))

    noise = np.random.default_rng(0)

    def imbalance(plans):
        mx = mn = 0.0
        for ph, F in plans.features.items():
            c = oracle[ph].cost_from_features(F)
            mx += float(c.max())
            mn += float(c.mean())
        return mx / mn

    imb_a, imb_o = [], []
    for step in range(steps):
        examples = [
            sample_examples(np.random.default_rng(100 * step + i), per,
                            TaskMix(), ("vision", "audio"))
            for i in range(d)
        ]
        plans_o = orch_oracle.plan_phases(examples)
        plans_a = orch_adapt.plan_phases(examples)
        imb_o.append(imbalance(plans_o))
        imb_a.append(imbalance(plans_a))
        times = {ph: oracle[ph].cost_from_features(F)
                 * (1 + noise.normal(0, 0.03, size=d))
                 for ph, F in plans_a.features.items()}
        orch_adapt.observe_phase_times(times, plans=plans_a, step=step)
    half = steps // 2
    cal, orc = np.mean(imb_a[half:]), np.mean(imb_o[half:])
    assert orch_adapt.adaptive.calibrated
    assert cal <= 1.05 * orc, (cal, orc)

    # Identical tokens/streams: pack one batch under both plans and
    # compare the per-example payload multisets.
    examples = [
        sample_examples(np.random.default_rng(9000 + i), per, TaskMix(),
                        ("vision", "audio"))
        for i in range(d)
    ]
    caps = orch_oracle.default_capacities(examples, margin=3.0)
    rng = np.random.default_rng(1)
    batch_o, _ = orch_oracle.plan_and_pack(examples, caps, rng)
    batch_a, rep_a = orch_adapt.plan_and_pack(examples, caps, rng)
    assert _stream_fingerprint(batch_o) == _stream_fingerprint(batch_a)
    assert rep_a.coeff_version >= 0


def test_stale_plan_ahead_replans_on_coefficient_swap():
    cfg = get_config("olmo_1b")
    truth = CostModel(alpha=1.0, beta=8e-4)
    prior = truth.with_coeffs(1.0, 3 * 8e-4)
    orch = MLLMGlobalOrchestrator(
        cfg, 4, vocab=512,
        adaptive=AdaptiveOrchestration(priors={"llm": prior}))
    rng = np.random.default_rng(2)
    examples = [
        sample_examples(np.random.default_rng(i), 8, TaskMix(), ())
        for i in range(4)
    ]
    caps = orch.default_capacities(examples, margin=3.0)
    plans = orch.plan_phases(examples, caps)
    assert plans.coeff_version == 0
    # Calibration swaps coefficients in while the plan sits in flight.
    adapt = orch.adaptive.models["llm"]
    noise = np.random.default_rng(3)
    step = 0
    while not adapt.calibrated:
        F = _varied_features(noise, 4)
        adapt.observe(F, truth.cost_from_features(F)
                      * (1 + noise.normal(0, 0.02, size=4)), step=step)
        step += 1
        assert step < 200, "calibration did not converge"
    assert orch.adaptive.version != plans.coeff_version
    _, report = orch.plan_and_pack(examples, caps, rng, plans)
    assert report.replanned and orch.replans == 1
    assert report.coeff_version == orch.adaptive.version
    # A fresh plan is up to date and is NOT re-planned.
    plans2 = orch.plan_phases(examples, caps)
    _, report2 = orch.plan_and_pack(examples, caps, rng, plans2)
    assert not report2.replanned and orch.replans == 1


def test_observe_requires_adaptive_and_exactly_one_source():
    cfg = get_config("olmo_1b")
    orch = MLLMGlobalOrchestrator(cfg, 2, vocab=512)
    with pytest.raises(ValueError):
        orch.observe_phase_times({"llm": 1.0}, report=None, plans=None)
    orch2 = MLLMGlobalOrchestrator(
        cfg, 2, vocab=512,
        adaptive=AdaptiveOrchestration(priors={"llm": CostModel()}))
    with pytest.raises(ValueError):
        orch2.observe_phase_times({"llm": 1.0})


# ----------------------------------------------------------------------
# Serving-side calibration.
# ----------------------------------------------------------------------
def test_serving_calibrator_recovers_weights_and_decode_cost():
    rng = np.random.default_rng(4)
    c_text, c_vis, c_aud, c_dec = 0.01, 0.04, 0.025, 0.004
    cal = ServingCalibrator(("vision", "audio"))
    for _ in range(60):
        nt = int(rng.integers(10, 500))
        nv = int(rng.integers(0, 300))
        na = int(rng.integers(0, 200))
        t = (c_text * nt + c_vis * nv + c_aud * na) * (1 + rng.normal(0, 0.02))
        cal.observe_prefill({"text": nt, "vision": nv, "audio": na}, t)
        b = int(rng.integers(1, 16))
        cal.observe_decode(b, c_dec * b * (1 + rng.normal(0, 0.02)))
    assert cal.calibrated
    w = cal.weights()
    assert w["vision"] == pytest.approx(c_vis / c_text, rel=0.15)
    assert w["audio"] == pytest.approx(c_aud / c_text, rel=0.15)
    assert cal.decode_cost() == pytest.approx(c_dec / c_text, rel=0.15)


def test_adaptive_serving_cost_model_swaps_weights():
    prior = ServingCostModel(CostModel(alpha=1.0, beta=1e-4),
                             modality_weights={"vision": 2.0, "audio": 1.5})
    adapt = AdaptiveServingCostModel(prior)
    # Before calibration: the prior answers.
    assert adapt.weighted_length(10, {"vision": 4}) == 10 + 2.0 * 4
    rng = np.random.default_rng(8)
    c_text, c_vis, c_aud = 0.01, 0.05, 0.012
    for step in range(60):
        nt, nv, na = (int(rng.integers(10, 400)), int(rng.integers(0, 250)),
                      int(rng.integers(0, 150)))
        t = (c_text * nt + c_vis * nv + c_aud * na) * (1 + rng.normal(0, 0.02))
        adapt.observe_prefill({"text": nt, "vision": nv, "audio": na}, t,
                              step=step)
    assert adapt.calibrated and adapt.version >= 1
    assert adapt.modality_weights["vision"] == pytest.approx(5.0, rel=0.2)
    assert adapt.modality_weights["audio"] == pytest.approx(1.2, rel=0.25)
    # Admission maths flow through the calibrated weights.
    wl = adapt.weighted_length(100, {"vision": 10})
    assert wl == pytest.approx(100 + adapt.modality_weights["vision"] * 10)
    # decode_cost untouched without decode samples.
    assert adapt.decode_cost == prior.decode_cost
    s = adapt.summary()
    assert s["calibrated"] and s["prior_weights"]["vision"] == 2.0


def test_serving_cost_model_helper_shared():
    # Satellite: one shared derivation helper for training + serving.
    from repro.serving.engine.scheduler import serving_cost_model as via_sched
    cfg = get_config("llava_next_mistral_7b")
    a = via_sched(cfg)
    b = serving_cost_model(cfg)
    assert a == b
    assert set(a.modality_weights) == {e.name for e in cfg.encoders}
