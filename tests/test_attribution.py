"""Attribution-engine tests: the MFU-gap waterfall's component algebra
and closure check, the online anomaly detectors, and the triage
correlator (including the flight-record and CLI paths).
"""
import json

import numpy as np
import pytest

from repro.obs import (AnomalyMonitor, FlightRecorder, GapWaterfall,
                       MetricsRegistry, SeriesDetector, read_flight_record,
                       render_text, triage, triage_flight)
from repro.obs.triage import main as triage_main


class FakeReport:
    def __init__(self, phase_costs, exposed_ms=0.0):
        self.phase_costs = {k: np.asarray(v, dtype=np.float64)
                            for k, v in phase_costs.items()}
        self.exposed_ms = exposed_ms


SCALE = 0.05  # ms per cost unit used to synthesize step times


def _observe_steady(wf, steps, *, costs=None, **kw):
    costs = costs or {"vision": [10.0, 10.0], "llm": [40.0, 40.0]}
    sum_max = sum(max(v) for v in costs.values())
    last = None
    for it in range(steps):
        last = wf.observe(it, report=FakeReport(costs),
                          step_ms=sum_max * SCALE, **kw)
    return last


# ----------------------------------------------------------------------
# Waterfall algebra.
# ----------------------------------------------------------------------
def test_waterfall_balanced_step_has_zero_gap_and_closes():
    wf = GapWaterfall(registry=MetricsRegistry())
    last = _observe_steady(wf, 6)
    assert last.gap == pytest.approx(0.0, abs=1e-9)
    assert last.goodput == pytest.approx(1.0)
    for v in last.components.values():
        assert v == pytest.approx(0.0, abs=1e-9)
    c = wf.closure()
    assert c["steps"] == 3  # warmup skipped
    assert c["max_closure_err"] == pytest.approx(0.0, abs=1e-9)


def test_waterfall_imbalance_split_per_phase():
    wf = GapWaterfall(registry=MetricsRegistry())
    _observe_steady(wf, 4)  # calibrate scale on balanced steps
    costs = {"vision": [10.0, 30.0], "llm": [40.0, 40.0]}
    step_ms = (30.0 + 40.0) * SCALE
    last = wf.observe(10, report=FakeReport(costs), step_ms=step_ms)
    # vision straggler: (max - mean) * scale / T = 10 * .05 / 3.5
    assert last.components["imbalance_vision"] == pytest.approx(
        10.0 * SCALE / step_ms, rel=1e-6)
    assert last.components["imbalance_llm"] == pytest.approx(0.0, abs=1e-9)
    # additive closure: gap == sum(components) + unattributed
    total = sum(last.components.values()) + last.unattributed
    assert last.gap == pytest.approx(total, abs=1e-9)


def test_waterfall_host_components_and_waste():
    wf = GapWaterfall(registry=MetricsRegistry())
    _observe_steady(wf, 4)
    costs = {"llm": [40.0, 40.0]}
    step_ms = 40.0 * SCALE + 1.0 + 0.5  # compute + exposed + ckpt
    last = wf.observe(11, report=FakeReport(costs, exposed_ms=1.0),
                      step_ms=step_ms, ckpt_ms=0.5, dead_tile_frac=0.1,
                      metrics={"moe_dropped_frac": 0.05},
                      recompute_frac=0.02)
    assert last.components["exposed_dispatch"] == pytest.approx(
        1.0 / step_ms)
    assert last.components["checkpoint_stall"] == pytest.approx(
        0.5 / step_ms)
    useful_raw = 40.0 * last.scale_ms_per_cost / step_ms
    assert last.components["kernel_dead_tiles"] == pytest.approx(
        0.1 * useful_raw)
    assert last.components["moe_drop"] == pytest.approx(0.05 * useful_raw)
    assert last.components["preempt_recompute"] == pytest.approx(
        0.02 * useful_raw)
    assert last.goodput == pytest.approx(useful_raw * (1 - 0.1 - 0.05 - 0.02))


def test_waterfall_drift_lands_in_unattributed():
    """Step time moves without the cost vectors moving -> the scale
    learned on earlier steps cannot explain the step, and the residual
    (not some named component) absorbs it.  This is what makes the
    closure check catch cost-model drift."""
    wf = GapWaterfall(registry=MetricsRegistry())
    _observe_steady(wf, 6)
    costs = {"vision": [10.0, 10.0], "llm": [40.0, 40.0]}
    last = wf.observe(20, report=FakeReport(costs),
                      step_ms=50.0 * SCALE * 2.0)  # 2x slower, same costs
    assert last.unattributed == pytest.approx(0.5, abs=0.05)
    assert last.closure_err > 0.2
    for name, v in last.components.items():
        assert abs(v) < 0.05, (name, v)


def test_waterfall_warmup_closure_is_zero_and_gauges_publish():
    reg = MetricsRegistry()
    wf = GapWaterfall(registry=reg, warmup=3)
    wf.observe(0, report=FakeReport({"llm": [1.0, 3.0]}), step_ms=7.0)
    assert wf.history[0].closure_err == 0.0
    assert reg.get("mfu_gap").labels().value == wf.history[0].gap
    comp = reg.get("mfu_gap_component")
    got = {labels["component"]: child.value
           for labels, child in comp.children()}
    assert "imbalance_llm" in got and "unattributed" in got
    assert reg.get("mfu_gap_closure_err").labels().value == 0.0


def test_waterfall_rejects_nonpositive_step():
    wf = GapWaterfall(registry=MetricsRegistry())
    with pytest.raises(ValueError, match="step_ms"):
        wf.observe(0, phase_costs={"llm": [1.0]}, step_ms=0.0)


def test_waterfall_series_and_summary():
    wf = GapWaterfall(registry=MetricsRegistry())
    _observe_steady(wf, 5)
    assert [s for s, _ in wf.series["gap"]] == list(range(5))
    summ = wf.summary()
    assert summ["gap"] == pytest.approx(0.0, abs=1e-9)
    assert "component_imbalance_llm" in summ
    assert summ["steps"] == 2  # closure() fields merged in


# ----------------------------------------------------------------------
# Anomaly detectors.
# ----------------------------------------------------------------------
def _feed(det, values, start=0):
    out = []
    for i, v in enumerate(values):
        a = det.update(start + i, v, name="s")
        if a is not None:
            out.append(a)
    return out


def test_detector_quiet_on_stationary_noise():
    rng = np.random.default_rng(0)
    det = SeriesDetector()
    anomalies = _feed(det, 0.3 + 0.002 * rng.standard_normal(200))
    assert anomalies == []


def test_detector_spike_then_return():
    det = SeriesDetector()
    base = [0.3] * 20
    anomalies = _feed(det, base + [0.9] + [0.3] * 10)
    kinds = [a.kind for a in anomalies]
    assert kinds == ["spike"]
    assert anomalies[0].step == 20
    assert anomalies[0].direction == 1


def test_detector_level_shift_alerts_once_then_rebaselines():
    det = SeriesDetector()
    anomalies = _feed(det, [0.3] * 20 + [0.6] * 40)
    kinds = [a.kind for a in anomalies]
    assert kinds.count("level_shift") == 1
    shift = next(a for a in anomalies if a.kind == "level_shift")
    # fires after shift_run consecutive out-of-band points
    assert 20 <= shift.step <= 20 + det.shift_run
    assert shift.baseline == pytest.approx(0.3, abs=0.05)


def test_detector_trend():
    # Ramp slow enough that the Huber-tracked center + adaptive scale
    # keep each point inside the shift band, but fast enough that the
    # fast EWMA sits > trend_z above baseline for trend_run steps.
    det = SeriesDetector()
    ramp = [0.3 + 0.004 * i for i in range(1, 81)]
    anomalies = _feed(det, [0.3] * 20 + ramp)
    kinds = [a.kind for a in anomalies]
    assert "trend" in kinds, kinds
    assert "level_shift" not in kinds  # too gradual for the band


def test_monitor_cursor_include_and_registry():
    reg = MetricsRegistry()
    rec = []

    class Sink:
        def on_anomaly(self, a):
            rec.append(a)

    mon = AnomalyMonitor(alerts=Sink(), registry=reg, include=("gap",))
    series = {"gap": [(i, 0.3) for i in range(30)],
              "ignored_series": [(i, 99.0 if i == 25 else 0.0)
                                 for i in range(30)]}
    mon.poll(series)
    series["gap"].extend([(30 + i, 0.9) for i in range(10)])
    mon.poll(series)  # cursor: only the new points are consumed
    kinds = [a.kind for a in mon.anomalies]
    assert "level_shift" in kinds
    assert all(a.series == "gap" for a in mon.anomalies)
    assert rec == mon.anomalies  # routed to the alert sink
    fam = reg.get("anomalies")
    total = sum(child.value for _, child in fam.children())
    assert total == len(mon.anomalies) >= 1


def test_monitor_update_path_matches_poll():
    mon = AnomalyMonitor(alerts=None, registry=MetricsRegistry())
    for i in range(30):
        mon.update(i, {"gap": 0.3})
    for i in range(10):
        mon.update(30 + i, {"gap": 0.9})
    assert any(a.kind == "level_shift" for a in mon.anomalies)


# ----------------------------------------------------------------------
# Triage.
# ----------------------------------------------------------------------
def _faulted_waterfall(component, *, magnitude=0.25, steps=30, fault=15,
                       extra=None):
    """Synthesize waterfall dicts with one component stepping up."""
    rng = np.random.default_rng(1)
    out = []
    for i in range(steps):
        comps = {"imbalance_llm": 0.01, "imbalance_vision": 0.005,
                 "exposed_dispatch": 0.01, "checkpoint_stall": 0.0,
                 "kernel_dead_tiles": 0.02, "moe_drop": 0.0,
                 "preempt_recompute": 0.0}
        comps = {k: v + 0.001 * rng.standard_normal() for k, v in
                 comps.items()}
        unattr = 0.002 * rng.standard_normal()
        if i >= fault:
            if component == "unattributed":
                unattr += magnitude
            else:
                comps[component] += magnitude
        gap = sum(comps.values()) + unattr
        out.append({"step": i, "step_ms": 10.0, "gap": gap,
                    "goodput": 1.0 - gap, "components": comps,
                    "unattributed": unattr,
                    "closure_err": abs(unattr) / max(gap, 0.02),
                    "scale_ms_per_cost": 0.05})
    return out


def _anoms_for(series, fault, kind="level_shift"):
    return [{"series": series, "step": fault + 2, "kind": kind,
             "value": 0.3, "baseline": 0.01, "score": 8.0,
             "direction": "up"}]


@pytest.mark.parametrize("component,cause", [
    ("imbalance_llm", "straggler_llm"),
    ("imbalance_vision", "straggler_vision"),
    ("exposed_dispatch", "dispatcher_exposed"),
    ("checkpoint_stall", "checkpoint_stall"),
    ("kernel_dead_tiles", "kernel_dead_tiles"),
    ("moe_drop", "moe_drop_spike"),
    ("preempt_recompute", "preemption_storm"),
])
def test_triage_roots_each_component(component, cause):
    steps = _faulted_waterfall(component)
    rep = triage(steps, anomalies=_anoms_for(component, 15))
    assert rep["causes"], rep
    assert rep["causes"][0]["cause"] == cause
    assert rep["causes"][0]["rank"] == 1
    assert rep["fault_step"] == 17  # earliest sustained anomaly
    assert rep["gap_delta"] == pytest.approx(0.25, abs=0.05)


def test_triage_drift_renames_unattributed_with_alert():
    steps = _faulted_waterfall("unattributed")
    alerts = [{"alert": "cost_model_drift", "step": 16}]
    rep = triage(steps, anomalies=_anoms_for("unattributed", 15),
                 alerts=alerts)
    assert rep["causes"][0]["cause"] == "cost_model_drift"
    assert "cost_model_drift" in rep["causes"][0]["anomaly_kinds"] or \
        rep["causes"][0]["evidence"]


def test_triage_alert_corroboration_breaks_ties():
    steps = _faulted_waterfall("exposed_dispatch", magnitude=0.1)
    # equal-magnitude bump on a second component
    for d in steps:
        if d["step"] >= 15:
            d["components"]["moe_drop"] += 0.1
            d["gap"] += 0.1
    alerts = [{"alert": "stale_plan_replanned", "step": 16}]
    rep = triage(steps, anomalies=_anoms_for("exposed_dispatch", 15),
                 alerts=alerts)
    assert rep["causes"][0]["cause"] == "dispatcher_exposed"


def test_triage_healthy_run_reports_nothing():
    steps = _faulted_waterfall("imbalance_llm", magnitude=0.0)
    rep = triage(steps)
    assert rep["causes"] == []
    assert rep["fault_step"] is None or rep["gap_delta"] < 0.01


def test_triage_empty_history():
    rep = triage([])
    assert rep["causes"] == [] and rep["fault_step"] is None


def test_render_text_smoke():
    steps = _faulted_waterfall("imbalance_llm")
    rep = triage(steps, anomalies=_anoms_for("imbalance_llm", 15),
                 meta={"arch": "olmo_1b"})
    text = render_text(rep)
    assert "straggler_llm" in text
    assert "#1" in text or "1." in text


# ----------------------------------------------------------------------
# Flight-record round trip + CLI.
# ----------------------------------------------------------------------
def _write_flight(tmp_path):
    path = tmp_path / "flight.jsonl"
    rec = FlightRecorder(path, meta={"run": "t"})
    for d in _faulted_waterfall("imbalance_llm"):
        rec.record("waterfall", **d)
    # Anomalies land in the flight record as AlertBridge "anomaly_<kind>"
    # alert events; triage_flight splits them back out.
    rec.record("alert", alert="anomaly_level_shift", series="imbalance_llm",
               step=17, score=8.0, direction=1)
    rec.record("alert", alert="stale_plan_replanned", step=16)
    rec.close()
    return path


def test_triage_flight_round_trip(tmp_path):
    path = _write_flight(tmp_path)
    rep = triage_flight(read_flight_record(path))
    assert rep["causes"][0]["cause"] == "straggler_llm"
    assert rep["n_anomalies"] == 1 and rep["n_alerts"] == 1


def test_triage_cli_on_flight_file_and_dir(tmp_path, capsys):
    path = _write_flight(tmp_path)
    out_json = tmp_path / "report.json"
    triage_main([str(path), "--json", str(out_json)])
    text = capsys.readouterr().out
    assert "straggler_llm" in text
    rep = json.loads(out_json.read_text())
    assert rep["causes"][0]["cause"] == "straggler_llm"
    # directory form: resolves <dir>/flight.jsonl
    triage_main([str(tmp_path)])
    assert "straggler_llm" in capsys.readouterr().out
