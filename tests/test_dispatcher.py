"""Dispatcher-level tests (paper S5 plumbing)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cost_model import CostModel
from repro.core.dispatcher import BatchPostBalancingDispatcher


def _lens(rng, d, per=5, hi=200):
    return [rng.integers(1, hi, size=rng.integers(1, per + 1)) for _ in range(d)]


def test_plan_fields():
    rng = np.random.default_rng(0)
    disp = BatchPostBalancingDispatcher(8, CostModel())
    plan = disp.plan(_lens(rng, 8))
    assert plan.d == 8
    assert plan.token_capacity % 128 == 0
    assert plan.token_capacity >= max(l.sum() for l in plan.dest_lengths)
    assert 0 < plan.utilization <= 1
    assert plan.solve_ms >= 0
    assert plan.costs.shape == (8,)


def test_balance_false_is_identity():
    rng = np.random.default_rng(1)
    lens = _lens(rng, 4)
    disp = BatchPostBalancingDispatcher(4, CostModel(), balance=False)
    plan = disp.plan(lens)
    for i, l in enumerate(lens):
        assert plan.dest_lengths[i].tolist() == list(l)


def test_balanced_capacity_not_larger_than_identity():
    """The TPU payoff: balancing shrinks the static per-shard capacity."""
    rng = np.random.default_rng(2)
    lens = _lens(rng, 8, per=8, hi=500)
    cap_bal = BatchPostBalancingDispatcher(8, CostModel()).plan(lens).token_capacity
    cap_id = BatchPostBalancingDispatcher(8, CostModel(), balance=False).plan(
        lens).token_capacity
    assert cap_bal <= cap_id


def test_padded_capacity_semantics():
    disp = BatchPostBalancingDispatcher(2, CostModel(padding=True), pad_to=8)
    plan = disp.plan([np.array([10, 3]), np.array([7])])
    # Padded phase capacity covers rows * max_len per shard.
    for l in plan.dest_lengths:
        if l.size:
            assert plan.token_capacity >= l.size * l.max()


def test_nodewise_integration():
    rng = np.random.default_rng(3)
    disp = BatchPostBalancingDispatcher(8, CostModel(), instances_per_node=4)
    plan = disp.plan(_lens(rng, 8))
    disp0 = BatchPostBalancingDispatcher(8, CostModel())
    plan0 = disp0.plan(_lens(np.random.default_rng(3), 8))
    assert plan.pi.internode_volume(4).max() <= plan0.pi.internode_volume(4).max()


@given(st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_property_utilization_improves_or_ties(seed):
    rng = np.random.default_rng(seed)
    lens = _lens(rng, 6, per=6, hi=300)
    cm = CostModel(beta=1e-4)
    u_bal = BatchPostBalancingDispatcher(6, cm).plan(lens).utilization
    u_id = BatchPostBalancingDispatcher(6, cm, balance=False).plan(lens).utilization
    assert u_bal >= u_id - 1e-9
