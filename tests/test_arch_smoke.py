"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures (+ the paper's 3 MLLMs):
instantiate the REDUCED variant of the same family (<=2 layers,
d_model<=512, <=4 experts), run one forward/train step on CPU through
the full orchestrator pipeline, assert output shapes + finite losses
(no NaNs); run one decode step where the family supports decoding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.synthetic import Example
from repro.serving.serve_step import init_cache, make_serve_step
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def _tiny_examples(cfg, rng, d=2, per=3):
    """Small examples matching the arch's modalities."""
    out = []
    for i in range(d):
        insts = []
        for j in range(per):
            text = int(rng.integers(8, 40))
            vis = aud = 0
            order = ("text",)
            names = [e.name for e in cfg.encoders]
            if "vision" in names and (j % 2 == 0 or cfg.family == "vlm"):
                vis = int(rng.integers(4, 24)) * max(
                    e.downsample for e in cfg.encoders if e.name == "vision"
                )
                order = ("vision", "text")
            if "audio" in names and (cfg.family == "audio" or j % 2 == 1):
                aud = int(rng.integers(8, 48)) * max(
                    e.downsample for e in cfg.encoders if e.name == "audio"
                )
                order = ("audio", "text") if vis == 0 else ("vision", "audio", "text")
            insts.append(Example("smoke", text, vis, aud, order))
        out.append(insts)
    return out


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    rng = np.random.default_rng(0)
    d = 2
    orch = MLLMGlobalOrchestrator(cfg, d, vocab=cfg.vocab_size)
    examples = _tiny_examples(cfg, rng, d=d)
    caps = orch.default_capacities(examples, margin=2.0)
    batch_np, report = orch.plan_and_pack(examples, caps, rng)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}

    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    # mesh=None -> the exchange runs as a global gather with identical
    # semantics (true multi-device path covered by subprocess tests).
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), mesh=None)
    params2, opt2, metrics = jax.jit(step)(params, opt_state, batch)

    assert jnp.isfinite(metrics["loss"]), f"{arch}: loss not finite"
    assert jnp.isfinite(metrics["grad_norm"])
    assert metrics["tokens"] > 0
    # Params changed and kept shapes.
    flat_a = jax.tree_util.tree_leaves(params)
    flat_b = jax.tree_util.tree_leaves(params2)
    assert all(a.shape == b.shape for a, b in zip(flat_a, flat_b))
    changed = any(
        not jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))
        for a, b in zip(flat_a, flat_b)
    )
    assert changed, f"{arch}: no parameter changed"
    # Balancing report sanity.
    assert 0 < report.phase_utilization["llm"] <= 1.0


DECODE_ARCHS = [a for a in ARCHITECTURES if a not in ()]


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    B, S = 2, 64
    params, _ = init_train_state(cfg, jax.random.PRNGKey(1))
    cache = init_cache(cfg, B, S)
    if cfg.family == "audio":
        # Fill cross-attention memory with a fake encoded segment.
        cache["cross_seg"] = cache["cross_seg"].at[:, :8].set(1)
    serve = jax.jit(make_serve_step(cfg))
    tokens = jnp.ones((B, 1), jnp.int32)
    nxt, logits, cache = serve(params, tokens, cache, jnp.int32(3))
    assert nxt.shape == (B, 1)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch}: decode logits not finite"
    # Second step consumes the updated cache.
    nxt2, logits2, _ = serve(params, nxt, cache, jnp.int32(4))
    assert jnp.isfinite(logits2).all()
