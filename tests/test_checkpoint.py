"""Checkpoint subsystem tests (ISSUE 5).

Acceptance invariants:
  * save at step k, resume at the SAME DP degree -> the continued loss
    trajectory is bitwise identical to an uninterrupted run (>= 3 steps,
    mllm_10b);
  * elastic restore (DP 4 -> 2 and 2 -> 4) matches within numerical
    tolerance, with post-balancing re-solved for the new shard count --
    including, in pipeline mode (pp > 1), the per-stage microbatch
    split (docs/pipeline.md);
  * crash consistency: a kill mid-save (``.tmp`` litter) or a truncated
    leaf shard never corrupts a restore -- the manager falls back to the
    last complete checkpoint and flags the damaged one;
  * serving ``Engine.snapshot()/restore()`` and ``MultiReplicaEngine.
    handoff`` preserve greedy output streams exactly (KV pages are
    recomputed through the preemption-recompute path).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError,
    CheckpointManager,
    DataCursor,
    ElasticResumeError,
    TrainState,
    elastic_cursor,
    load_pytree,
    meta_to_spec,
    restore_train_state,
    save_pytree,
    save_train_state,
)
from repro.configs import EngineConfig, get_config
from repro.core.cost_model import CostModel
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.pipeline import PrefetchingLoader
from repro.data.synthetic import Example
from repro.models.model import init_params
from repro.serving.engine import Engine, MultiReplicaEngine, Request
from repro.telemetry import AdaptiveOrchestration
from repro.telemetry.calibrate import PhaseCalibrator
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (
    check_opt_state,
    init_train_state,
    make_train_step,
)


# ----------------------------------------------------------------------
# Store: roundtrip, atomicity, retention, corruption fallback.
# ----------------------------------------------------------------------
def _demo_tree():
    return {
        "params": {
            "w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "layers": [np.ones((2, 2), np.float64), np.zeros(3, np.int32)],
            "pair": (np.full(2, 7, np.int64), np.float32(1.5)),
        },
        "step": np.int32(3),
    }


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_store_roundtrip_structure_dtypes_specs(tmp_path):
    from jax.sharding import PartitionSpec as P

    tree = _demo_tree()
    specs = {"params": {"w": P("data", None)}}
    path = save_pytree(str(tmp_path / "ck"), tree, specs=specs,
                       extras={"cursor": {"seed": 7}}, meta={"step": 3})
    out, manifest = load_pytree(path)
    _assert_tree_equal(tree, out)
    # structure kinds survive (tuple stays tuple, list stays list)
    assert isinstance(out["params"]["pair"], tuple)
    assert isinstance(out["params"]["layers"], list)
    assert manifest["extras"]["cursor"]["seed"] == 7
    rows = {r["path"]: r for r in manifest["leaves"]}
    assert rows["params/w"]["spec"] == ["data", None]
    assert rows["params/layers/0"]["spec"] is None
    for r in rows.values():  # content hashes recorded per shard
        assert len(r["sha256"]) == 64


def test_store_bfloat16_leaves(tmp_path):
    import ml_dtypes

    tree = {"w": np.arange(8, dtype=ml_dtypes.bfloat16).reshape(2, 4)}
    path = save_pytree(str(tmp_path / "ck"), tree)
    out, _ = load_pytree(path)
    assert out["w"].dtype == ml_dtypes.bfloat16
    assert np.array_equal(out["w"].astype(np.float32),
                          tree["w"].astype(np.float32))


def test_atomic_commit_leaves_no_tmp(tmp_path):
    save_pytree(str(tmp_path / "ck"), _demo_tree())
    assert sorted(os.listdir(tmp_path)) == ["ck"]


def test_manager_retention_keep_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _demo_tree())
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_crash_mid_save_tmp_litter_is_ignored_and_collected(tmp_path):
    """Kill mid-save: an uncommitted ``.tmp`` directory must neither be
    restored nor block the next save."""
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    mgr.save(1, _demo_tree())
    # simulate a writer that died before the atomic rename
    litter = tmp_path / "step_000002.tmp"
    litter.mkdir()
    (litter / "leaf_00000_w.npy").write_bytes(b"partial garbage")
    assert mgr.steps() == [1]
    tree, manifest = mgr.restore_latest()
    assert manifest["step"] == 1
    mgr.save(3, _demo_tree())  # next save collects the litter
    assert not litter.exists()


def test_truncated_leaf_falls_back_and_flags(tmp_path):
    """Crash-consistency satellite: restore falls back to the last
    complete checkpoint and flags the corrupt one."""
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    mgr.save(1, _demo_tree())
    mgr.save(2, _demo_tree())
    newest = mgr.step_path(2)
    shard = next(f for f in sorted(os.listdir(newest)) if f.endswith(".npy"))
    with open(os.path.join(newest, shard), "r+b") as f:
        f.truncate(8)  # torn write
    tree, manifest = mgr.restore_latest()
    assert manifest["step"] == 1  # fell back
    flagged = mgr.corrupt_paths()
    assert len(flagged) == 1 and flagged[0].endswith("step_000002.corrupt")
    assert mgr.steps() == [1]  # the flagged one no longer restorable


def test_direct_load_of_truncated_checkpoint_raises(tmp_path):
    path = save_pytree(str(tmp_path / "ck"), _demo_tree())
    shard = next(f for f in sorted(os.listdir(path)) if f.endswith(".npy"))
    with open(os.path.join(path, shard), "r+b") as f:
        f.truncate(4)
    with pytest.raises(CheckpointCorruptError):
        load_pytree(path)


def test_missing_manifest_is_corrupt(tmp_path):
    path = save_pytree(str(tmp_path / "ck"), _demo_tree())
    os.remove(os.path.join(path, "manifest.json"))
    with pytest.raises(CheckpointCorruptError):
        load_pytree(path)


def test_restore_latest_none_when_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.restore_latest() is None
    assert restore_train_state(mgr) is None


# ----------------------------------------------------------------------
# Train-state contract + cursor.
# ----------------------------------------------------------------------
def test_check_opt_state_contract():
    params = {"w": jnp.ones((2, 3)), "b": jnp.zeros(3)}
    good = {"mu": jax.tree_util.tree_map(jnp.zeros_like, params),
            "nu": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.int32(0)}
    check_opt_state(params, good)
    with pytest.raises(ValueError, match="keys"):
        check_opt_state(params, {"mu": good["mu"]})
    with pytest.raises(ValueError, match="structure"):
        check_opt_state(params, {**good, "mu": {"w": good["mu"]["w"]}})
    with pytest.raises(ValueError, match="shape"):
        check_opt_state(params, {**good, "nu": {
            "w": jnp.zeros((9, 9)), "b": jnp.zeros(3)}})
    with pytest.raises(ValueError, match="scalar"):
        check_opt_state(params, {**good, "step": jnp.zeros(4)})


def test_elastic_cursor_resplit_and_errors():
    c = DataCursor(seed=1, batch_index=5, examples_per_instance=2, d=4)
    e = elastic_cursor(c, 2)
    assert (e.d, e.examples_per_instance) == (2, 4)
    assert e.total_examples == c.total_examples
    assert e.batch_index == 5 and e.seed == 1
    assert elastic_cursor(c, 4) is c
    with pytest.raises(ElasticResumeError):
        elastic_cursor(c, 3)  # 8 examples don't split across 3
    with pytest.raises(ElasticResumeError):
        elastic_cursor(c, 0)


def test_reshard_pytree_matches_manifest_paths(tmp_path):
    """Resharding must be applied to the tree AS SAVED: manifest leaf
    paths carry the full prefix ('params/w'), so the specs only attach
    when the restored root tree is resharded, not a subtree."""
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint import reshard_pytree

    mesh = jax.make_mesh((1,), ("data",))
    tree = {"params": {"w": np.ones((4, 2), np.float32)},
            "opt_state": {"mu": {"w": np.zeros((4, 2), np.float32)}}}
    specs = {"params": {"w": P("data", None)},
             "opt_state": {"mu": {"w": P("data", None)}}}
    path = save_pytree(str(tmp_path / "ck"), tree, specs=specs)
    out, manifest = load_pytree(path)
    resharded = reshard_pytree(out, manifest, mesh)
    assert resharded["params"]["w"].sharding.spec == P("data")
    assert resharded["opt_state"]["mu"]["w"].sharding.spec == P("data")


def test_repeat_corruption_flags_do_not_collide(tmp_path):
    """A step can be re-saved after its corrupt predecessor was flagged;
    a second flag of the same step must not abort the fallback walk."""

    def corrupt_newest(mgr):
        newest = mgr.step_path(mgr.latest_step())
        shard = next(f for f in sorted(os.listdir(newest))
                     if f.endswith(".npy"))
        with open(os.path.join(newest, shard), "r+b") as f:
            f.truncate(8)

    mgr = CheckpointManager(str(tmp_path), keep_last=4)
    mgr.save(1, _demo_tree())
    mgr.save(2, _demo_tree())
    corrupt_newest(mgr)
    _, manifest = mgr.restore_latest()
    assert manifest["step"] == 1
    mgr.save(2, _demo_tree())  # re-save the flagged step...
    corrupt_newest(mgr)  # ...and corrupt it again
    _, manifest = mgr.restore_latest()  # must not raise OSError
    assert manifest["step"] == 1
    assert len(mgr.corrupt_paths()) == 2


def test_meta_to_spec_divisibility_fallback():
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    # axis present + divisible -> kept; unknown axis -> dropped
    assert meta_to_spec(["data", None], (4, 2), mesh) == P("data")
    assert meta_to_spec(["model"], (4,), mesh) == P()
    assert meta_to_spec(None, (4,), mesh) == P()
    assert meta_to_spec(["data"], (3,), jax.make_mesh((1,), ("data",))) == P("data")


# ----------------------------------------------------------------------
# Data pipeline: deterministic replay from the cursor.
# ----------------------------------------------------------------------
def _small_sampler(rng, per):
    out = []
    for _ in range(per):
        text = int(rng.integers(16, 64))
        vis = int(rng.integers(1, 3)) * 16
        aud = int(rng.integers(16, 32))
        out.append(Example("mix", text, vis, aud, ("vision", "audio", "text")))
    return out


def _mk_loader(cfg, d, per, *, start=0, seed=11, pp=1):
    orch = MLLMGlobalOrchestrator(cfg, d, vocab=cfg.vocab_size, pp=pp)
    probe = [_small_sampler(np.random.default_rng(s), per) for s in range(d)]
    caps = orch.default_capacities(probe, margin=4.0)
    loader = PrefetchingLoader(orch, caps, examples_per_instance=per,
                               seed=seed, sampler=_small_sampler,
                               start_index=start)
    return loader, orch


def test_loader_replay_from_cursor_is_bitwise():
    cfg = get_config("mllm_10b").smoke()
    la, _ = _mk_loader(cfg, 2, 3)
    full = [next(la)[0] for _ in range(4)]
    cursor_after_2 = None
    la.close()
    lb, _ = _mk_loader(cfg, 2, 3)
    for _ in range(2):
        next(lb)
    cursor_after_2 = lb.cursor
    lb.close()
    assert cursor_after_2 == 2
    lc, _ = _mk_loader(cfg, 2, 3, start=cursor_after_2)
    resumed = [next(lc)[0] for _ in range(2)]
    lc.close()
    for a, b in zip(full[2:], resumed):
        assert set(a) == set(b)
        for k in a:
            assert np.array_equal(a[k], b[k]), k


def test_loader_global_stream_invariant_under_dp_resplit():
    """The same (seed, index) yields the same global example multiset
    whether split 2x6 or 4x3 -- the elastic-resume data invariant."""
    cfg = get_config("mllm_10b").smoke()
    la, _ = _mk_loader(cfg, 2, 6)
    lb, _ = _mk_loader(cfg, 4, 3)
    ba = next(la)[0]
    bb = next(lb)[0]
    la.close()
    lb.close()

    def seg_sizes(batch):
        seg = batch["llm_seg"]
        return sorted(np.bincount(seg[seg > 0]).tolist())

    assert seg_sizes(ba) == seg_sizes(bb)


def test_elastic_resume_resolves_pipeline_for_new_dp():
    """Elastic resume x pipeline mode (docs/pipeline.md): a pp>1 run
    resumed onto a different DP degree must re-solve the per-stage
    post-balancing for the new world size -- the 1F1B plan is a pure
    function of the post-balanced shard, never checkpoint state."""
    cfg = get_config("mllm_10b").smoke()
    pp = 2
    # "Before": d=4, consume two batches, note the cursor.
    la, _ = _mk_loader(cfg, 4, 3, pp=pp)
    for _ in range(2):
        _, rep_a, _ = next(la)
    cursor = la.cursor
    la.close()
    assert rep_a.pipeline is not None and rep_a.pipeline.d == 4
    assert rep_a.pipeline.micro_costs.shape == (4, 2 * pp)

    # Elastic "after": same global batch (4x3 -> 2x6), new DP degree.
    c = DataCursor(seed=11, batch_index=cursor, examples_per_instance=3, d=4)
    ec = elastic_cursor(c, 2)
    assert (ec.d, ec.examples_per_instance) == (2, 6)
    lb, orch_b = _mk_loader(cfg, ec.d, ec.examples_per_instance,
                            start=ec.batch_index, pp=pp)
    _, rep_b, _ = next(lb)
    lb.close()

    p = rep_b.pipeline
    assert p is not None and p.d == 2 and p.pp == pp
    # Per-stage post-balancing re-solved at the new world size: the LPT
    # microbatch split exists per new rank and its cost matrix covers
    # every (rank, microbatch) cell.
    assert len(p.micro_assign) == 2
    assert p.micro_costs.shape == (2, 2 * pp)
    assert np.all(p.micro_costs > 0)
    # The rebuilt dispatcher prices per-stage loads for the new world
    # size: its plans carry outer(stage_fractions, costs) -> (pp, new_d).
    assert orch_b.llm_dispatcher.stage_fractions is not None
    assert orch_b.llm_dispatcher.stage_fractions.shape == (pp,)
    assert np.allclose(p.stage_fractions, orch_b.llm_dispatcher.stage_fractions)
    # Same schedule closure identity as an un-resumed plan.
    total = p.stage_busy.sum(axis=1) + p.stage_idle.sum(axis=1)
    assert np.allclose(total, p.pp * p.rank_total)


# ----------------------------------------------------------------------
# Telemetry calibrator state survives a restart.
# ----------------------------------------------------------------------
def _feed(cal, rng, n, alpha=2.0, beta=0.01):
    for _ in range(n):
        lens = rng.integers(10, 200, size=8)
        f = CostModel().feature_vector(lens)
        t = alpha * f[0] + beta * f[2] + rng.normal(0, 0.1)
        cal.observe(f, max(t, 0.1))


def test_phase_calibrator_state_roundtrip():
    rng = np.random.default_rng(0)
    a = PhaseCalibrator(CostModel(alpha=1.0, beta=0.0))
    _feed(a, rng, 40)
    b = PhaseCalibrator(CostModel(alpha=1.0, beta=0.0))
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    assert b.calibrated == a.calibrated
    assert b.n_observed == a.n_observed
    ca, cb = a.cost_model(), b.cost_model()
    assert (ca.alpha, ca.beta) == (cb.alpha, cb.beta)
    # continued observation behaves identically
    rng_a, rng_b = np.random.default_rng(1), np.random.default_rng(1)
    _feed(a, rng_a, 8)
    _feed(b, rng_b, 8)
    ca, cb = a.cost_model(), b.cost_model()
    assert (ca.alpha, ca.beta) == (cb.alpha, cb.beta)


def test_adaptive_orchestration_state_roundtrip():
    cfg = get_config("mllm_10b").smoke()
    rng = np.random.default_rng(0)
    a = AdaptiveOrchestration(cfg)
    for phase, m in a.models.items():
        _feed(m.calibrator, rng, 30)
    snap = json.loads(json.dumps(a.state_dict()))
    b = AdaptiveOrchestration(cfg)
    b.load_state_dict(snap)
    assert b.version == a.version or b.version >= 0
    for phase in a.models:
        ma, mb = a.cost_model(phase), b.cost_model(phase)
        assert (ma.alpha, ma.beta) == (mb.alpha, mb.beta)
        assert a.models[phase].calibrator.calibrated == \
            b.models[phase].calibrator.calibrated


# ----------------------------------------------------------------------
# Acceptance: bitwise resume + elastic restore on mllm_10b.
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_train_resume_bitwise_and_elastic(tmp_path):
    cfg = get_config("mllm_10b").smoke()
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))

    def run(d, per, steps, params=None, opt=None, start=0):
        if params is None:
            params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
        loader, orch = _mk_loader(cfg, d, per, start=start)
        losses, reports = [], []
        try:
            for _ in range(start, steps):
                batch_np, report, _ = next(loader)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                params, opt, m = step_fn(params, opt, batch)
                losses.append(float(m["loss"]))
                reports.append(report)
        finally:
            loader.close()
        return losses, params, opt, reports

    # Uninterrupted reference at DP 4.
    full, _, _, _ = run(4, 2, 5)
    # Interrupted: 2 steps, checkpoint, restore, continue 3 more.
    prefix, p2, o2, _ = run(4, 2, 2)
    assert prefix == full[:2]
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    save_train_state(mgr, TrainState(
        params=jax.device_get(p2), opt_state=jax.device_get(o2), step=2,
        cursor=DataCursor(seed=11, batch_index=2,
                          examples_per_instance=2, d=4)))
    st, _ = restore_train_state(mgr)
    assert st.step == 2 and st.cursor.d == 4
    cont, _, _, _ = run(st.cursor.d, st.cursor.examples_per_instance, 5,
                        params=st.params, opt=st.opt_state, start=st.step)
    # >= 3 steps bitwise identical to the uninterrupted trajectory.
    assert len(cont) == 3
    assert cont == full[2:]

    # Elastic restore DP 4 -> 2: same trajectory within tolerance,
    # post-balancing re-solved for the new shard count.
    ec = elastic_cursor(st.cursor, 2)
    el, p_el, o_el, reps = run(ec.d, ec.examples_per_instance, 5,
                               params=st.params, opt=st.opt_state,
                               start=st.step)
    assert all(r.phase_costs["llm"].shape == (2,) for r in reps)
    np.testing.assert_allclose(el, full[2:], rtol=2e-3)

    # Elastic back up DP 2 -> 4 from a checkpoint written at DP 2.
    save_train_state(mgr, TrainState(
        params=jax.device_get(p_el), opt_state=jax.device_get(o_el), step=5,
        cursor=DataCursor(seed=11, batch_index=5,
                          examples_per_instance=4, d=2)))
    st2, _ = restore_train_state(mgr)
    ec2 = elastic_cursor(st2.cursor, 4)
    assert (ec2.d, ec2.examples_per_instance) == (4, 2)
    el2, _, _, reps2 = run(ec2.d, ec2.examples_per_instance, 7,
                           params=st2.params, opt=st2.opt_state, start=5)
    assert all(r.phase_costs["llm"].shape == (4,) for r in reps2)
    # Continue the DP-4 reference two more steps for comparison.
    full7, _, _, _ = run(4, 2, 7)
    np.testing.assert_allclose(el2, full7[5:], rtol=5e-3)


# ----------------------------------------------------------------------
# Serving engine snapshot / restore / handoff.
# ----------------------------------------------------------------------
def _serve_setup(n_requests=5, seed=0):
    cfg = get_config("olmo_1b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(block_size=16, num_blocks=17, max_num_seqs=3,
                        token_budget=64, max_model_len=64,
                        prefill_pad=16, decode_pad=2)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        L = int(rng.integers(3, 24))
        reqs.append(Request(
            req_id=i,
            prompt=rng.integers(1, cfg.vocab_size, L).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 7)),
            arrival_step=i // 2))
    return cfg, ecfg, params, reqs


def _streams(engine_like):
    if isinstance(engine_like, MultiReplicaEngine):
        reqs = [r for e in engine_like.engines for r in e.requests]
    else:
        reqs = engine_like.requests
    return {r.req_id: list(r.output_tokens) for r in reqs}


def test_engine_snapshot_restore_streams_exact():
    cfg, ecfg, params, reqs = _serve_setup()
    # Reference: run to completion uninterrupted.
    ref = Engine(cfg, ecfg, params)
    ref.run([Request.from_state_dict(r.to_state_dict()) for r in reqs],
            max_steps=300)
    # Interrupted: a few steps, snapshot, restore into a fresh engine.
    a = Engine(cfg, ecfg, params)
    pending = sorted(reqs, key=lambda r: (r.arrival_step, r.req_id))
    for _ in range(4):
        while pending and pending[0].arrival_step <= a.n_steps:
            a.submit(pending.pop(0))
        a.step()
    snap = json.loads(json.dumps(a.snapshot()))  # JSON-able end to end
    b = Engine(cfg, ecfg, params)
    b.restore(snap)
    assert b.n_steps == a.n_steps
    assert len(b.step_timings) == len(a.step_timings)
    # KV pool starts empty: pages are recomputed, not copied.
    assert b.pool.occupancy == 0.0
    while pending or b.has_work:
        while pending and pending[0].arrival_step <= b.n_steps:
            b.submit(pending.pop(0))
        b.step()
        assert b.n_steps < 300
    b.pool.check()
    assert _streams(b) == _streams(ref)


def test_multi_replica_handoff_streams_exact():
    cfg, ecfg, params, reqs = _serve_setup(n_requests=6, seed=1)
    ecfg2 = EngineConfig(**{**ecfg.__dict__, "replicas": 2})

    def clone():
        return [Request.from_state_dict(r.to_state_dict()) for r in reqs]

    ref = MultiReplicaEngine(cfg, ecfg2, params)
    ref.run(clone(), max_steps=300)

    m = MultiReplicaEngine(cfg, ecfg2, params)
    pending = sorted(clone(), key=lambda r: (r.arrival_step, r.req_id))
    clock = 0
    for _ in range(3):
        burst = []
        while pending and pending[0].arrival_step <= clock:
            burst.append(pending.pop(0))
        if burst:
            m.submit_batch(burst)
        m.step()
        clock += 1
    # Replica 0 drains; its in-flight work moves through the shared
    # snapshot/restore + preemption-recompute path.
    moved = m.handoff(0, 1)
    assert not m.engines[0].waiting and not m.engines[0].running
    assert m.engines[0].pool.occupancy == 0.0
    while pending or m.has_work:
        burst = []
        while pending and pending[0].arrival_step <= clock:
            burst.append(pending.pop(0))
        if burst:
            # post-handoff arrivals go to the surviving replica
            for r in burst:
                m.engines[1].submit(r)
        m.step()
        clock += 1
        assert clock < 300
    assert moved >= 0
    for e in m.engines:
        e.pool.check()
    assert _streams(m) == _streams(ref)


def test_handoff_routes_through_preempt_transition():
    """The handoff path must use the state machine's preempt transition
    (shared with scheduler eviction), not ad-hoc field surgery."""
    cfg, ecfg, params, reqs = _serve_setup(n_requests=3, seed=2)
    a = Engine(cfg, ecfg, params)
    for r in reqs:
        r.arrival_step = 0
        a.submit(r)
    for _ in range(3):
        a.step()
    decoding = [s.request for s in a.running]
    before = {r.req_id: r.n_preemptions for r in decoding}
    moved = a.export_unfinished()
    moved_ids = {d["req_id"] for d in moved}
    for r in decoding:
        assert r.req_id in moved_ids
        assert r.n_preemptions == before[r.req_id] + 1
