"""Plan-ahead / overlapped dispatcher pipeline tests (paper S6)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import CostModel
from repro.core.dispatcher import BatchPostBalancingDispatcher
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.pipeline import PrefetchingLoader
from repro.data.synthetic import sample_examples


def _lens(rng, d, per=5, hi=200):
    return [rng.integers(1, hi, size=rng.integers(1, per + 1)) for _ in range(d)]


# ----------------------------------------------------------------------
# Dispatcher plan-ahead worker.
# ----------------------------------------------------------------------
def test_submit_matches_sync_plan():
    rng = np.random.default_rng(0)
    disp = BatchPostBalancingDispatcher(8, CostModel(beta=1e-4))
    lens = _lens(rng, 8)
    sync = disp.plan(lens)
    ticket = disp.submit(lens)
    asyn = ticket.result(timeout=30)
    assert ticket.done()
    np.testing.assert_allclose(asyn.costs, sync.costs)
    assert asyn.token_capacity == sync.token_capacity
    for a, b in zip(asyn.dest_lengths, sync.dest_lengths):
        assert a.tolist() == b.tolist()
    disp.close()


def test_submit_pipelines_multiple_steps():
    rng = np.random.default_rng(1)
    disp = BatchPostBalancingDispatcher(4, CostModel())
    batches = [_lens(rng, 4) for _ in range(5)]
    tickets = [disp.submit(b) for b in batches]  # > queue_depth submissions
    for b, t in zip(batches, tickets):
        plan = t.result(timeout=30)
        assert plan.max_cost == disp.plan(b).max_cost
    disp.close()


def test_submit_propagates_errors():
    disp = BatchPostBalancingDispatcher(2, CostModel(), algorithm="bogus")
    ticket = disp.submit([np.array([3, 1]), np.array([2])])
    with pytest.raises(ValueError):
        ticket.result(timeout=30)
    disp.close()


def test_dispatcher_backend_python_available():
    rng = np.random.default_rng(2)
    lens = _lens(rng, 4)
    vec = BatchPostBalancingDispatcher(4, CostModel()).plan(lens)
    ref = BatchPostBalancingDispatcher(4, CostModel(), backend="python").plan(lens)
    assert vec.max_cost == ref.max_cost
    assert vec.token_capacity == ref.token_capacity


# ----------------------------------------------------------------------
# Orchestrator plan_phases / plan_ahead.
# ----------------------------------------------------------------------
def _setup_orch(**kw):
    cfg = get_config("mllm_10b").smoke()
    rng = np.random.default_rng(4)
    d = 4
    examples = [sample_examples(rng, 4) for _ in range(d)]
    orch = MLLMGlobalOrchestrator(cfg, d, vocab=128, **kw)
    caps = orch.default_capacities(examples, margin=3.0)
    return orch, examples, caps


def test_precomputed_plans_give_identical_batch():
    orch, examples, caps = _setup_orch()
    rng = np.random.default_rng(0)
    batch_direct, rep_direct = orch.plan_and_pack(examples, caps, rng)
    plans = orch.plan_phases(examples, caps)
    batch_planned, rep_planned = orch.plan_and_pack(examples, caps, rng, plans)
    assert set(batch_direct) == set(batch_planned)
    for k in batch_direct:
        np.testing.assert_array_equal(batch_direct[k], batch_planned[k])
    assert rep_planned.overlapped and not rep_direct.overlapped
    assert rep_planned.phase_max_cost == rep_direct.phase_max_cost


def test_plan_ahead_handle():
    orch, examples, caps = _setup_orch()
    handle = orch.plan_ahead(examples, caps)
    plans, exposed_ms = handle.result(timeout=60)
    assert handle.done()
    assert exposed_ms >= 0
    rng = np.random.default_rng(0)
    batch, report = orch.plan_and_pack(examples, caps, rng, plans,
                                       exposed_ms=exposed_ms)
    assert report.overlapped
    assert report.exposed_ms == exposed_ms
    # Per-phase host timing surfaced for every phase + composition.
    assert set(report.phase_solve_ms) == {"llm", "vision", "audio", "compose"}
    assert all(v >= 0 for v in report.phase_solve_ms.values())


def test_concurrent_dispatch_matches_sequential():
    orch_c, examples, caps = _setup_orch(concurrent_dispatch=True)
    orch_s, _, _ = _setup_orch(concurrent_dispatch=False)
    plans_c = orch_c.plan_phases(examples, caps)
    plans_s = orch_s.plan_phases(examples, caps)
    np.testing.assert_allclose(plans_c.llm_plan.costs, plans_s.llm_plan.costs)
    for name in plans_s.enc_plans:
        np.testing.assert_allclose(plans_c.enc_plans[name].costs,
                                   plans_s.enc_plans[name].costs)


# ----------------------------------------------------------------------
# Pipeline overlap accounting.
# ----------------------------------------------------------------------
@pytest.mark.parametrize("plan_ahead", [False, True])
def test_loader_overlap_stats(plan_ahead):
    orch, examples, caps = _setup_orch()
    loader = PrefetchingLoader(orch, caps, examples_per_instance=3, seed=7,
                               plan_ahead=plan_ahead)
    try:
        reports = [next(loader)[1] for _ in range(3)]
    finally:
        loader.close()
    stats = loader.overlap_stats()
    assert stats["batches"] >= 3
    assert stats["mean_solve_ms"] > 0
    assert stats["mean_exposed_ms"] >= 0
    for rep in reports:
        assert rep.overlapped == plan_ahead
        assert rep.solve_ms > 0
        if plan_ahead:
            # Exposed latency can never exceed what a blocking solve
            # would have cost (it is the residual of the same wait).
            assert rep.exposed_ms >= 0
