"""Tests for the Node-wise Rearrangement Algorithm (paper S5.2.2, Alg 3)."""
import numpy as np
import pytest

from repro.core.balancing import post_balance
from repro.core.cost_model import CostModel
from repro.core.nodewise import (
    assign_within_node,
    internode_objective,
    node_cost_matrix,
    nodewise_rearrange,
    solve_greedy,
    solve_ilp,
)


def _random_pi(seed, d=8, per=6):
    rng = np.random.default_rng(seed)
    lens = [rng.integers(10, 200, size=per) for _ in range(d)]
    return post_balance(lens, d, CostModel())


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_nodewise_reduces_internode_volume(seed):
    pi = _random_pi(seed)
    c = 4
    before = pi.internode_volume(c).max()
    pi2 = nodewise_rearrange(pi, c)
    after = pi2.internode_volume(c).max()
    assert after <= before


def test_nodewise_preserves_batch_contents():
    pi = _random_pi(5)
    pi2 = nodewise_rearrange(pi, 4)
    lens_a = sorted(tuple(sorted(x.tolist())) for x in pi.dest_lengths())
    lens_b = sorted(tuple(sorted(x.tolist())) for x in pi2.dest_lengths())
    assert lens_a == lens_b  # objective-invariant permutation only


def test_ilp_matches_or_beats_greedy():
    pi = _random_pi(7, d=8)
    V = node_cost_matrix(pi)
    c = 4
    a_ilp = solve_ilp(V, c)
    a_greedy = solve_greedy(V, c)
    assert a_ilp is not None
    assert internode_objective(V, a_ilp, c) <= internode_objective(V, a_greedy, c)


def test_ilp_feasibility():
    pi = _random_pi(9, d=8)
    V = node_cost_matrix(pi)
    a = solve_ilp(V, 2)
    assert a is not None
    for g in range(4):
        assert (a == g).sum() == 2


def test_ilp_on_obvious_instance():
    # Two nodes of 2; traffic is block-diagonal to batches (0,1) from
    # node 0 and (2,3) from node 1 -> perfect assignment has zero cost.
    V = np.zeros((4, 4), dtype=np.int64)
    V[0, 0] = V[1, 1] = V[2, 2] = V[3, 3] = 100
    a = solve_ilp(V, 2)
    assert a is not None
    assert internode_objective(V, a, 2) == 0


def test_within_node_assignment_maximizes_self_traffic():
    V = np.zeros((4, 4), dtype=np.int64)
    # batch 0 gets most volume from inst 1, batch 1 from inst 0.
    V[1, 0], V[0, 1], V[2, 2], V[3, 3] = 50, 40, 30, 20
    batch_to_node = np.array([0, 0, 1, 1])
    perm = assign_within_node(V, batch_to_node, 2)
    assert perm[0] == 1 and perm[1] == 0  # self-traffic 90 > swapped 0
    assert perm[2] == 2 and perm[3] == 3


def test_single_node_is_noop():
    pi = _random_pi(11, d=4)
    pi2 = nodewise_rearrange(pi, 4)
    assert (pi2.dst_inst == pi.dst_inst).all()


def test_greedy_handles_large_d():
    pi = _random_pi(13, d=32, per=4)
    pi2 = nodewise_rearrange(pi, 8, method="greedy")
    assert pi2.internode_volume(8).max() <= pi.internode_volume(8).max()


def test_d_not_divisible_raises():
    pi = _random_pi(15, d=6)
    with pytest.raises(ValueError):
        nodewise_rearrange(pi, 4)
