"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle
(interpret=True executes the kernel body on CPU -- the same path the
model-level ``flash_interpret`` backend selects)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import count_live_tiles, live_tile_mask
from repro.kernels.grouped_gemm import count_live_group_tiles
from repro.kernels.ops import (
    flash_attention_op,
    grouped_matmul_op,
    selective_scan_op,
)
from repro.kernels.ref import flash_attention_ref, selective_scan_ref
from repro.models.ssm import mamba1_block, mamba1_scan, mamba2_block


def _segs(rng, B, T, n_seg):
    """Random packed segment layout with a padded tail."""
    seg = np.zeros((B, T), np.int32)
    pos = np.zeros((B, T), np.int32)
    for b in range(B):
        cuts = np.sort(rng.choice(np.arange(1, T), size=n_seg - 1, replace=False))
        bounds = np.concatenate([[0], cuts, [T - rng.integers(0, T // 4)]])
        for s in range(len(bounds) - 1):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            if hi <= lo:
                continue
            seg[b, lo:hi] = s + 1
            pos[b, lo:hi] = np.arange(hi - lo)
    return jnp.asarray(seg), jnp.asarray(pos)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Tq,Tkv,D,causal,window",
    [
        (1, 2, 128, 128, 64, True, None),
        (2, 2, 256, 256, 64, True, None),
        (1, 4, 128, 128, 128, True, 64),     # sliding window
        (1, 2, 128, 256, 64, False, None),   # cross-attn shape
        (2, 1, 384, 384, 32, True, None),    # 3 kv blocks
    ],
)
def test_flash_attention_matches_ref(B, H, Tq, Tkv, D, causal, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, Tq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, H, Tkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, H, Tkv, D)), dtype)
    q_seg, q_pos = _segs(rng, B, Tq, 3)
    if Tq == Tkv:
        kv_seg, kv_pos = q_seg, q_pos
    else:
        kv_seg, kv_pos = _segs(rng, B, Tkv, 3)
    got = flash_attention_op(q, k, v, q_seg, kv_seg, q_pos, kv_pos,
                             causal=causal, window=window, interpret=True)
    want = flash_attention_ref(q, k, v, q_seg, kv_seg, q_pos, kv_pos,
                               causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_padding_rows_zero():
    rng = np.random.default_rng(1)
    B, H, T, D = 1, 2, 128, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg = jnp.zeros((B, T), jnp.int32)  # all padding
    pos = jnp.zeros((B, T), jnp.int32)
    out = flash_attention_op(q, q, q, seg, seg, pos, pos, interpret=True)
    assert np.allclose(np.asarray(out), 0.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "T,di,N,block_d,chunk",
    [
        (128, 128, 16, 128, 64),
        (256, 256, 16, 128, 64),
        (64, 128, 8, 64, 32),
        (192, 384, 4, 128, 64),
    ],
)
def test_selective_scan_matches_ref(T, di, N, block_d, chunk, dtype):
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.normal(size=(T, di)), dtype)
    delta = jnp.asarray(np.abs(rng.normal(0.05, 0.02, size=(T, di))), dtype)
    A = jnp.asarray(-np.abs(rng.normal(1.0, 0.3, size=(di, N))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(T, N)), dtype)
    C = jnp.asarray(rng.normal(size=(T, N)), dtype)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    seg = np.ones(T, np.int32)
    seg[T // 2 :] = 2  # two packed segments: state must reset
    seg[-8:] = 0  # padded tail
    seg = jnp.asarray(seg)
    got = selective_scan_op(u, delta, A, B, C, D, seg,
                            block_d=block_d, chunk=chunk, interpret=True)
    want = selective_scan_ref(u, delta, A, B, C, D, seg)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_selective_scan_segment_reset_isolates_examples():
    """Output of segment 2 must be identical whether or not segment 1
    precedes it in the stream (consequence-invariance at kernel level)."""
    rng = np.random.default_rng(3)
    T, di, N = 128, 128, 8
    u = jnp.asarray(rng.normal(size=(T, di)), jnp.float32)
    delta = jnp.asarray(np.abs(rng.normal(0.05, 0.02, size=(T, di))), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(1.0, 0.3, size=(di, N))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(T, N)), jnp.float32)
    D = jnp.zeros((di,), jnp.float32)
    half = T // 2
    seg = jnp.asarray(np.r_[np.ones(half), 2 * np.ones(half)].astype(np.int32))
    y_packed = selective_scan_op(u, delta, A, B, C, D, seg, block_d=64,
                                 chunk=32, interpret=True)
    y_alone = selective_scan_op(u[half:], delta[half:], A, B[half:], C[half:],
                                D, seg[half:], block_d=64, chunk=32,
                                interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_packed[half:]), np.asarray(y_alone), atol=1e-5, rtol=1e-5
    )


@pytest.mark.parametrize(
    "causal,window",
    [(True, None), (False, None), (True, 64)],
)
def test_flash_attention_vjp_matches_ref_autodiff(causal, window):
    """jax.grad through the Pallas custom VJP (dq/dk/dv kernels) must
    match autodiff through the dense oracle to fp32 tolerance."""
    rng = np.random.default_rng(7)
    B, H, T, D = 2, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg, pos = _segs(rng, B, T, 4)

    def make_loss(fn):
        def loss(q, k, v):
            o = fn(q, k, v, seg, seg, pos, pos, causal=causal, window=window)
            return jnp.sum(jnp.sin(o.astype(jnp.float32)))
        return jax.grad(loss, argnums=(0, 1, 2))

    flash_fn = lambda *a, **kw: flash_attention_op(*a, interpret=True, **kw)
    got = make_loss(flash_fn)(q, k, v)
    want = make_loss(flash_attention_ref)(q, k, v)
    for name, g, w in zip("qkv", got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=2e-5, rtol=2e-5,
            err_msg=f"d{name} mismatch (causal={causal} window={window})")


def test_flash_attention_block_skip_parity():
    """Block-skipping is a pure FLOP optimization: outputs and gradients
    must be bit-identical with it on or off."""
    rng = np.random.default_rng(8)
    B, H, T, D = 1, 2, 384, 32
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg, pos = _segs(rng, B, T, 5)

    def run(block_skip):
        def loss(x):
            o = flash_attention_op(x, x, x, seg, seg, pos, pos,
                                   interpret=True, block_skip=block_skip)
            return jnp.sum(o * o)
        out = flash_attention_op(q, q, q, seg, seg, pos, pos,
                                 interpret=True, block_skip=block_skip)
        return out, jax.grad(loss)(q)

    out_on, g_on = run(True)
    out_off, g_off = run(False)
    np.testing.assert_array_equal(np.asarray(out_on), np.asarray(out_off))
    np.testing.assert_array_equal(np.asarray(g_on), np.asarray(g_off))


def test_flash_block_skip_visits_fewer_tiles():
    """A multi-segment packed stream must skip KV tiles: segment-range
    disjointness + the causal frontier prune most of the grid."""
    T, blk = 1024, 128
    seg = np.repeat(np.arange(1, 9), T // 8).astype(np.int32)[None]
    pos = np.tile(np.arange(T // 8), 8).astype(np.int32)[None]
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    visited, total = count_live_tiles(seg, seg, pos, pos, block_q=blk,
                                      block_kv=blk, causal=True, window=None)
    assert visited < total, (visited, total)
    # Segments align with tiles here, so only the diagonal survives.
    assert visited == T // blk
    live = live_tile_mask(seg, seg, pos, pos, block_q=blk, block_kv=blk,
                          causal=True, window=None)
    np.testing.assert_array_equal(np.asarray(live[0]), np.eye(T // blk, dtype=bool))


def test_flash_fully_padded_tail_tiles_skipped_and_zero():
    rng = np.random.default_rng(9)
    B, H, T, D = 1, 1, 256, 32
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg = np.zeros((B, T), np.int32)
    pos = np.zeros((B, T), np.int32)
    seg[0, :100] = 1
    pos[0, :100] = np.arange(100)
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    out = flash_attention_op(q, q, q, seg, seg, pos, pos, interpret=True)
    assert np.allclose(np.asarray(out[0, 0, 100:]), 0.0)
    visited, total = count_live_tiles(seg, seg, pos, pos, block_q=128,
                                      block_kv=128, causal=True, window=None)
    assert (visited, total) == (1, 4)  # only the (q0, k0) tile is live


# ----------------------------------------------------------------------
# Grouped GEMM (MoE expert dispatch).
# ----------------------------------------------------------------------
def _group_layout(rng, M, E, *, empty=(), pad=0):
    """Random per-expert row counts summing to M - pad, with the experts
    in ``empty`` forced to zero rows.  Returns (sizes [E], offsets [E+1])."""
    live = [e for e in range(E) if e not in empty]
    sizes = np.zeros(E, np.int64)
    remaining = M - pad
    for e in live[:-1]:
        sizes[e] = rng.integers(0, remaining + 1)
        remaining -= sizes[e]
    sizes[live[-1]] = remaining
    offs = np.concatenate([[0], np.cumsum(sizes)])
    return sizes, jnp.asarray(offs, jnp.int32)


def _grouped_oracle(x, w, offsets):
    """Dense per-row gather oracle: row s uses w[expert-of-s]; padding
    rows (s >= offsets[E]) produce zeros."""
    M = x.shape[0]
    E = w.shape[0]
    rows = jnp.arange(M)
    eid = jnp.searchsorted(offsets[1:], rows, side="right")  # [M] in [0, E]
    live = (eid < E) & (rows < offsets[E])
    w_row = w[jnp.minimum(eid, E - 1)]  # [M, K, N]
    out = jnp.einsum("mk,mkn->mn", x.astype(jnp.float32),
                     w_row.astype(jnp.float32))
    return jnp.where(live[:, None], out, 0.0).astype(x.dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,K,N,E,bm,bn,empty,pad",
    [
        (256, 64, 128, 4, 128, 128, (), 0),
        (256, 64, 128, 4, 64, 64, (1,), 37),    # empty expert + padding tail
        (384, 32, 96, 8, 128, 32, (0, 5), 10),  # first expert empty
        (128, 48, 64, 2, 128, 64, (), 0),       # single m-tile
    ],
)
def test_grouped_matmul_matches_oracle(M, K, N, E, bm, bn, empty, pad, dtype):
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.normal(size=(M, K)), dtype)
    w = jnp.asarray(rng.normal(size=(E, K, N)), dtype)
    _, offs = _group_layout(rng, M, E, empty=empty, pad=pad)
    got = grouped_matmul_op(x, w, offs, block_m=bm, block_n=bn, interpret=True)
    want = _grouped_oracle(x, w, offs)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_grouped_matmul_vjp_matches_oracle_autodiff():
    """dx (transposed-gmm kernel) and dw (tgmm kernel) must match
    autodiff through the dense gather oracle, including zero gradients
    for empty experts and padding rows."""
    rng = np.random.default_rng(11)
    M, K, N, E = 256, 64, 96, 4
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, K, N)), jnp.float32)
    sizes, offs = _group_layout(rng, M, E, empty=(2,), pad=21)

    def make_loss(fn):
        def loss(x, w):
            o = fn(x, w, offs)
            return jnp.sum(jnp.sin(o.astype(jnp.float32)))
        return jax.grad(loss, argnums=(0, 1))

    kernel_fn = lambda x, w, o: grouped_matmul_op(
        x, w, o, block_m=64, block_n=32, interpret=True)
    (dx, dw) = make_loss(kernel_fn)(x, w)
    (dx_ref, dw_ref) = make_loss(_grouped_oracle)(x, w)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               atol=2e-5, rtol=2e-5, err_msg="dx")
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               atol=2e-4, rtol=2e-4, err_msg="dw")
    # Empty expert and padding rows get exactly zero gradient.
    assert np.all(np.asarray(dw)[2] == 0.0)
    assert np.all(np.asarray(dx)[int(offs[E]):] == 0.0)


def test_count_live_group_tiles_accounting():
    # Sizes [100, 0, 28, 128] with bm=64: expert 0 spans tiles {0,1},
    # expert 1 is empty, expert 2 spans tile {1}, expert 3 tiles {2,3}.
    assert count_live_group_tiles([100, 0, 28, 128], 64) == 5
    # Balanced tile-aligned groups: exactly one tile each.
    assert count_live_group_tiles([64, 64, 64, 64], 64) == 4
    # Dense sweep would be n_m * E = 4 * 4 = 16 in both cases.


# ----------------------------------------------------------------------
# Selective-scan custom VJP (satellite: gradient + segment-reset
# coverage for the training-grade kernel).
# ----------------------------------------------------------------------
def _scan_inputs(rng, T, di, N, *, n_pad=8):
    u = jnp.asarray(rng.normal(size=(T, di)), jnp.float32)
    delta = jnp.asarray(np.abs(rng.normal(0.05, 0.02, size=(T, di))), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(1.0, 0.3, size=(di, N))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(T, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    seg = np.ones(T, np.int32)
    seg[T // 3:] = 2  # packed multi-segment stream (state resets inside
    seg[2 * T // 3:] = 3  # chunks, not only at chunk boundaries)
    if n_pad:
        seg[-n_pad:] = 0  # padded tail rows
    return u, delta, A, B, C, D, jnp.asarray(seg)


@pytest.mark.parametrize(
    "T,di,N,block_d,chunk",
    [
        (128, 128, 8, 64, 32),
        (128, 64, 8, 64, 128),   # single chunk covering all of T
        (96, 48, 4, 16, 8),      # edge divisors: tiny blocks, T%chunk==0
        (64, 32, 8, 32, 64),     # single chunk == T, single d-block pair
    ],
)
def test_selective_scan_vjp_matches_scan_autodiff(T, di, N, block_d, chunk):
    """jax.grad through the kernel's chunk-checkpointed custom VJP must
    match autodiff through the lax.scan reference for every input, on a
    packed multi-segment stream with a seg==0 padded tail."""
    rng = np.random.default_rng(12)
    u, delta, A, B, C, D, seg = _scan_inputs(rng, T, di, N)

    def kernel_loss(u, delta, A, B, C, D):
        y = selective_scan_op(u, delta, A, B, C, D, seg,
                              block_d=block_d, chunk=chunk, interpret=True)
        return jnp.sum(jnp.sin(y))

    def ref_loss(u, delta, A, B, C, D):
        y, _ = mamba1_scan(u, delta, A, B, C, D, seg, backend="scan")
        return jnp.sum(jnp.sin(y))

    got = jax.grad(kernel_loss, argnums=tuple(range(6)))(u, delta, A, B, C, D)
    want = jax.grad(ref_loss, argnums=tuple(range(6)))(u, delta, A, B, C, D)
    for name, g, w in zip(["du", "ddelta", "dA", "dB", "dC", "dD"], got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=5e-5, rtol=5e-5,
            err_msg=f"{name} mismatch (block_d={block_d} chunk={chunk})")


def test_selective_scan_padding_rows_isolated_grad():
    """seg==0 rows reset the state every step, so a padding-row input
    can only reach its own row's output: with the loss masked to valid
    rows, du/ddelta/dB/dC on the padded tail are exactly zero."""
    rng = np.random.default_rng(13)
    T, di, N = 64, 32, 4
    u, delta, A, B, C, D, seg = _scan_inputs(rng, T, di, N, n_pad=16)
    valid = (np.asarray(seg) > 0)[:, None]

    def loss(u, delta, B, C):
        y = selective_scan_op(u, delta, A, B, C, D, seg,
                              block_d=16, chunk=16, interpret=True)
        return jnp.sum(jnp.where(valid, y * y, 0.0))

    du, ddt, dB, dC = jax.grad(loss, argnums=(0, 1, 2, 3))(u, delta, B, C)
    for name, g in [("du", du), ("ddelta", ddt), ("dB", dB), ("dC", dC)]:
        assert np.all(np.asarray(g)[-16:] == 0.0), name
        assert np.any(np.asarray(g)[:-16] != 0.0), name


def test_selective_scan_final_state_matches_scan_backend():
    rng = np.random.default_rng(14)
    T, di, N = 128, 64, 8
    u, delta, A, B, C, D, seg = _scan_inputs(rng, T, di, N, n_pad=0)
    y_k, hf_k = selective_scan_op(u, delta, A, B, C, D, seg, block_d=32,
                                  chunk=32, interpret=True, return_state=True)
    # chunk must divide T for the scan oracle: its chunk padding runs
    # keep=False steps that zero the carried state.
    y_s, hf_s = mamba1_scan(u, delta, A, B, C, D, seg, backend="scan",
                            chunk=64)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_s),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf_k), np.asarray(hf_s),
                               atol=1e-4, rtol=1e-4)


def _batch_segs(rng, B, T):
    seg = np.zeros((B, T), np.int32)
    for b in range(B):
        cut = int(rng.integers(T // 4, 3 * T // 4))
        tail = int(rng.integers(0, T // 8))
        seg[b, :cut] = 1
        seg[b, cut:T - tail] = 2
    return jnp.asarray(seg)


def test_mamba1_block_backend_parity():
    """Full mamba1 block (proj + conv + scan + gate), pallas vs scan
    backend: forward and input gradient must agree."""
    rng = np.random.default_rng(15)
    Bt, T, d, di, N, K, dt_rank = 2, 64, 32, 64, 8, 4, 2
    p = {
        "in_proj": jnp.asarray(rng.normal(0, 0.1, size=(d, 2 * di)), jnp.float32),
        "conv_w": jnp.asarray(rng.normal(0, 0.3, size=(K, di)), jnp.float32),
        "x_proj": jnp.asarray(rng.normal(0, 0.1, size=(di, dt_rank + 2 * N)), jnp.float32),
        "dt_proj": jnp.asarray(rng.normal(0, 0.1, size=(dt_rank, di)), jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jnp.asarray(rng.normal(0, 0.1, size=(di, d)), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(Bt, T, d)), jnp.float32)
    seg = _batch_segs(rng, Bt, T)

    def run(backend):
        def loss(x):
            y = mamba1_block(p, x, seg, ssm_state=N, backend=backend,
                             block_d=32, chunk=32)
            return jnp.sum(jnp.sin(y)), y
        (l, y), g = jax.value_and_grad(loss, has_aux=True)(x)
        return y, g

    y_p, g_p = run("pallas")
    y_s, g_s = run("scan")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_s),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_s),
                               atol=2e-5, rtol=2e-5)


def test_mamba2_block_backend_parity():
    """Mamba-2 maps onto the mamba-1 kernel by broadcasting per-head
    scalars over the head dim; block outputs and grads must agree."""
    rng = np.random.default_rng(16)
    Bt, T, d, di, N, K, P = 2, 64, 32, 64, 8, 4, 16
    H = di // P
    p = {
        "in_proj": jnp.asarray(
            rng.normal(0, 0.1, size=(d, 2 * di + 2 * N + H)), jnp.float32),
        "conv_w": jnp.asarray(rng.normal(0, 0.3, size=(K, di)), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": jnp.asarray(rng.normal(0, 0.1, size=(di, d)), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(Bt, T, d)), jnp.float32)
    seg = _batch_segs(rng, Bt, T)

    def run(backend):
        def loss(x):
            y = mamba2_block(p, x, seg, ssm_state=N, headdim=P,
                             backend=backend, block_d=32, chunk=32)
            return jnp.sum(jnp.sin(y)), y
        (l, y), g = jax.value_and_grad(loss, has_aux=True)(x)
        return y, g

    y_p, g_p = run("pallas")
    y_s, g_s = run("scan")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_s),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_s),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_segment_isolation():
    """Cross-segment attention must be exactly zero: perturbing segment 1
    cannot change segment 2's outputs."""
    rng = np.random.default_rng(4)
    B, H, T, D = 1, 2, 256, 64
    half = T // 2
    seg = np.r_[np.ones(half), 2 * np.ones(half)].astype(np.int32)[None]
    pos = np.r_[np.arange(half), np.arange(half)].astype(np.int32)[None]
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    q = rng.normal(size=(B, H, T, D)).astype(np.float32)
    q2 = q.copy()
    q2[:, :, :half] += 1.0  # perturb segment 1 only
    outs = []
    for qq in (q, q2):
        qq = jnp.asarray(qq)
        outs.append(np.asarray(
            flash_attention_op(qq, qq, qq, seg, seg, pos, pos, interpret=True)
        ))
    np.testing.assert_allclose(outs[0][:, :, half:], outs[1][:, :, half:],
                               atol=1e-5)
    assert not np.allclose(outs[0][:, :, :half], outs[1][:, :, :half])
