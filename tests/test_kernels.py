"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle
(interpret=True executes the kernel body on CPU -- the same path the
model-level ``flash_interpret`` backend selects)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import count_live_tiles, live_tile_mask
from repro.kernels.ops import flash_attention_op, selective_scan_op
from repro.kernels.ref import flash_attention_ref, selective_scan_ref


def _segs(rng, B, T, n_seg):
    """Random packed segment layout with a padded tail."""
    seg = np.zeros((B, T), np.int32)
    pos = np.zeros((B, T), np.int32)
    for b in range(B):
        cuts = np.sort(rng.choice(np.arange(1, T), size=n_seg - 1, replace=False))
        bounds = np.concatenate([[0], cuts, [T - rng.integers(0, T // 4)]])
        for s in range(len(bounds) - 1):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            if hi <= lo:
                continue
            seg[b, lo:hi] = s + 1
            pos[b, lo:hi] = np.arange(hi - lo)
    return jnp.asarray(seg), jnp.asarray(pos)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Tq,Tkv,D,causal,window",
    [
        (1, 2, 128, 128, 64, True, None),
        (2, 2, 256, 256, 64, True, None),
        (1, 4, 128, 128, 128, True, 64),     # sliding window
        (1, 2, 128, 256, 64, False, None),   # cross-attn shape
        (2, 1, 384, 384, 32, True, None),    # 3 kv blocks
    ],
)
def test_flash_attention_matches_ref(B, H, Tq, Tkv, D, causal, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, Tq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, H, Tkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, H, Tkv, D)), dtype)
    q_seg, q_pos = _segs(rng, B, Tq, 3)
    if Tq == Tkv:
        kv_seg, kv_pos = q_seg, q_pos
    else:
        kv_seg, kv_pos = _segs(rng, B, Tkv, 3)
    got = flash_attention_op(q, k, v, q_seg, kv_seg, q_pos, kv_pos,
                             causal=causal, window=window, interpret=True)
    want = flash_attention_ref(q, k, v, q_seg, kv_seg, q_pos, kv_pos,
                               causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_padding_rows_zero():
    rng = np.random.default_rng(1)
    B, H, T, D = 1, 2, 128, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg = jnp.zeros((B, T), jnp.int32)  # all padding
    pos = jnp.zeros((B, T), jnp.int32)
    out = flash_attention_op(q, q, q, seg, seg, pos, pos, interpret=True)
    assert np.allclose(np.asarray(out), 0.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "T,di,N,block_d,chunk",
    [
        (128, 128, 16, 128, 64),
        (256, 256, 16, 128, 64),
        (64, 128, 8, 64, 32),
        (192, 384, 4, 128, 64),
    ],
)
def test_selective_scan_matches_ref(T, di, N, block_d, chunk, dtype):
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.normal(size=(T, di)), dtype)
    delta = jnp.asarray(np.abs(rng.normal(0.05, 0.02, size=(T, di))), dtype)
    A = jnp.asarray(-np.abs(rng.normal(1.0, 0.3, size=(di, N))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(T, N)), dtype)
    C = jnp.asarray(rng.normal(size=(T, N)), dtype)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    seg = np.ones(T, np.int32)
    seg[T // 2 :] = 2  # two packed segments: state must reset
    seg[-8:] = 0  # padded tail
    seg = jnp.asarray(seg)
    got = selective_scan_op(u, delta, A, B, C, D, seg,
                            block_d=block_d, chunk=chunk, interpret=True)
    want = selective_scan_ref(u, delta, A, B, C, D, seg)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_selective_scan_segment_reset_isolates_examples():
    """Output of segment 2 must be identical whether or not segment 1
    precedes it in the stream (consequence-invariance at kernel level)."""
    rng = np.random.default_rng(3)
    T, di, N = 128, 128, 8
    u = jnp.asarray(rng.normal(size=(T, di)), jnp.float32)
    delta = jnp.asarray(np.abs(rng.normal(0.05, 0.02, size=(T, di))), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(1.0, 0.3, size=(di, N))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(T, N)), jnp.float32)
    D = jnp.zeros((di,), jnp.float32)
    half = T // 2
    seg = jnp.asarray(np.r_[np.ones(half), 2 * np.ones(half)].astype(np.int32))
    y_packed = selective_scan_op(u, delta, A, B, C, D, seg, block_d=64,
                                 chunk=32, interpret=True)
    y_alone = selective_scan_op(u[half:], delta[half:], A, B[half:], C[half:],
                                D, seg[half:], block_d=64, chunk=32,
                                interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_packed[half:]), np.asarray(y_alone), atol=1e-5, rtol=1e-5
    )


@pytest.mark.parametrize(
    "causal,window",
    [(True, None), (False, None), (True, 64)],
)
def test_flash_attention_vjp_matches_ref_autodiff(causal, window):
    """jax.grad through the Pallas custom VJP (dq/dk/dv kernels) must
    match autodiff through the dense oracle to fp32 tolerance."""
    rng = np.random.default_rng(7)
    B, H, T, D = 2, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg, pos = _segs(rng, B, T, 4)

    def make_loss(fn):
        def loss(q, k, v):
            o = fn(q, k, v, seg, seg, pos, pos, causal=causal, window=window)
            return jnp.sum(jnp.sin(o.astype(jnp.float32)))
        return jax.grad(loss, argnums=(0, 1, 2))

    flash_fn = lambda *a, **kw: flash_attention_op(*a, interpret=True, **kw)
    got = make_loss(flash_fn)(q, k, v)
    want = make_loss(flash_attention_ref)(q, k, v)
    for name, g, w in zip("qkv", got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=2e-5, rtol=2e-5,
            err_msg=f"d{name} mismatch (causal={causal} window={window})")


def test_flash_attention_block_skip_parity():
    """Block-skipping is a pure FLOP optimization: outputs and gradients
    must be bit-identical with it on or off."""
    rng = np.random.default_rng(8)
    B, H, T, D = 1, 2, 384, 32
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg, pos = _segs(rng, B, T, 5)

    def run(block_skip):
        def loss(x):
            o = flash_attention_op(x, x, x, seg, seg, pos, pos,
                                   interpret=True, block_skip=block_skip)
            return jnp.sum(o * o)
        out = flash_attention_op(q, q, q, seg, seg, pos, pos,
                                 interpret=True, block_skip=block_skip)
        return out, jax.grad(loss)(q)

    out_on, g_on = run(True)
    out_off, g_off = run(False)
    np.testing.assert_array_equal(np.asarray(out_on), np.asarray(out_off))
    np.testing.assert_array_equal(np.asarray(g_on), np.asarray(g_off))


def test_flash_block_skip_visits_fewer_tiles():
    """A multi-segment packed stream must skip KV tiles: segment-range
    disjointness + the causal frontier prune most of the grid."""
    T, blk = 1024, 128
    seg = np.repeat(np.arange(1, 9), T // 8).astype(np.int32)[None]
    pos = np.tile(np.arange(T // 8), 8).astype(np.int32)[None]
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    visited, total = count_live_tiles(seg, seg, pos, pos, block_q=blk,
                                      block_kv=blk, causal=True, window=None)
    assert visited < total, (visited, total)
    # Segments align with tiles here, so only the diagonal survives.
    assert visited == T // blk
    live = live_tile_mask(seg, seg, pos, pos, block_q=blk, block_kv=blk,
                          causal=True, window=None)
    np.testing.assert_array_equal(np.asarray(live[0]), np.eye(T // blk, dtype=bool))


def test_flash_fully_padded_tail_tiles_skipped_and_zero():
    rng = np.random.default_rng(9)
    B, H, T, D = 1, 1, 256, 32
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
    seg = np.zeros((B, T), np.int32)
    pos = np.zeros((B, T), np.int32)
    seg[0, :100] = 1
    pos[0, :100] = np.arange(100)
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    out = flash_attention_op(q, q, q, seg, seg, pos, pos, interpret=True)
    assert np.allclose(np.asarray(out[0, 0, 100:]), 0.0)
    visited, total = count_live_tiles(seg, seg, pos, pos, block_q=128,
                                      block_kv=128, causal=True, window=None)
    assert (visited, total) == (1, 4)  # only the (q0, k0) tile is live


def test_flash_attention_segment_isolation():
    """Cross-segment attention must be exactly zero: perturbing segment 1
    cannot change segment 2's outputs."""
    rng = np.random.default_rng(4)
    B, H, T, D = 1, 2, 256, 64
    half = T // 2
    seg = np.r_[np.ones(half), 2 * np.ones(half)].astype(np.int32)[None]
    pos = np.r_[np.arange(half), np.arange(half)].astype(np.int32)[None]
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    q = rng.normal(size=(B, H, T, D)).astype(np.float32)
    q2 = q.copy()
    q2[:, :, :half] += 1.0  # perturb segment 1 only
    outs = []
    for qq in (q, q2):
        qq = jnp.asarray(qq)
        outs.append(np.asarray(
            flash_attention_op(qq, qq, qq, seg, seg, pos, pos, interpret=True)
        ))
    np.testing.assert_allclose(outs[0][:, :, half:], outs[1][:, :, half:],
                               atol=1e-5)
    assert not np.allclose(outs[0][:, :, :half], outs[1][:, :, :half])
