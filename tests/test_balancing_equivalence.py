"""Backend equivalence: the vectorized engine vs the python reference.

Guarantees under test (see core/balancing_vec.py):
  * pad / conv: identical batch *contents* item for item,
  * nopad / quad: identical multiset of batch costs (the load evolution
    matches the heap's exactly; only index tie-breaks may differ),
  * all four: identical max-cost objective, never worse than the python
    path, and within the approximation guarantee of the brute-force
    oracle on tiny instances,
  * the batched objective evaluator agrees with the scalar cost model.
"""
import zlib

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.balancing import (
    brute_force_oracle,
    flatten_instance_lengths,
    post_balance,
    post_balance_conv,
    post_balance_nopad,
    post_balance_pad,
    post_balance_quad,
    select_algorithm,
)
from repro.core.cost_model import CostModel

ALGOS = ("nopad", "pad", "quad", "conv")

COST_MODELS = {
    "nopad": CostModel(alpha=1.0, beta=0.0),
    "pad": CostModel(alpha=1.0, beta=1e-3, padding=True),
    "quad": CostModel(alpha=1.0, beta=1e-2),
    "conv": CostModel(alpha=1.0, beta=1e-3, conv_attention=True),
}


def _run(algo, lens, d, backend):
    cm = COST_MODELS[algo]
    return post_balance(lens, d, cm, algorithm=algo, backend=backend), cm


def _batch_contents(pi):
    return sorted(tuple(l.tolist()) for l in pi.dest_lengths())


def _cost_multiset(pi, cm):
    return sorted(round(cm.cost(l), 6) for l in pi.dest_lengths())


def _check_equivalence(lens, d):
    for algo in ALGOS:
        py, cm = _run(algo, lens, d, "python")
        vec, _ = _run(algo, lens, d, "vectorized")
        if algo in ("pad", "conv"):
            assert _batch_contents(py) == _batch_contents(vec), algo
        assert _cost_multiset(py, cm) == _cost_multiset(vec, cm), algo
        # Max-cost objective identical (the acceptance criterion).
        mp = max(cm.cost(l) for l in py.dest_lengths())
        mv = max(cm.cost(l) for l in vec.dest_lengths())
        assert mv <= mp + 1e-9 * max(mp, 1.0), algo
        # Vectorized output is a true rearrangement.
        items = flatten_instance_lengths(lens)
        got = sorted(zip(vec.orig_inst.tolist(), vec.orig_slot.tolist()))
        assert got == sorted((i, j) for i, j, _ in items), algo
        for i in range(d):
            slots = sorted(vec.dst_slot[vec.dst_inst == i].tolist())
            assert slots == list(range(len(slots))), algo


@given(
    st.lists(
        st.lists(st.integers(1, 60), min_size=0, max_size=6),
        min_size=1, max_size=8,
    )
)
@settings(max_examples=60, deadline=None)
def test_property_backends_equivalent(lens_py):
    d = len(lens_py)
    lens = [np.array(x, dtype=np.int64) for x in lens_py]
    _check_equivalence(lens, d)


@given(
    st.lists(
        st.lists(st.integers(1, 25), min_size=1, max_size=3),
        min_size=2, max_size=3,
    )
)
@settings(max_examples=25, deadline=None)
def test_property_vectorized_within_oracle_bounds(lens_py):
    """On tiny instances both backends obey the approximation guarantees
    vs the exact oracle: >= OPT always, and Alg 1 <= 4/3 OPT."""
    d = len(lens_py)
    lens = [np.array(x, dtype=np.int64) for x in lens_py]
    if sum(len(x) for x in lens_py) > 8:
        return
    for algo in ALGOS:
        cm = COST_MODELS[algo]
        opt = brute_force_oracle(lens, d, cm)
        for backend in ("python", "vectorized"):
            pi = post_balance(lens, d, cm, algorithm=algo, backend=backend)
            got = max(cm.cost(l) for l in pi.dest_lengths())
            assert got >= opt - 1e-9
            if algo == "nopad":
                assert got <= 4.0 / 3.0 * opt + 1e-9


@pytest.mark.parametrize("dist", ["uniform", "lognormal", "constant",
                                  "powers", "with_zeros"])
@pytest.mark.parametrize("d", [1, 5, 32])
def test_fixed_distributions_equivalent(dist, d):
    # crc32, not hash(): str hashing is salted per process, and these
    # draws must be reproducible across runs.
    rng = np.random.default_rng(zlib.crc32(f"{dist}/{d}".encode()))
    per = 40
    draw = {
        "uniform": lambda: rng.integers(1, 300, per),
        "lognormal": lambda: rng.lognormal(4, 1.1, per).astype(np.int64) + 1,
        "constant": lambda: np.full(per, 17, dtype=np.int64),
        "powers": lambda: (2 ** rng.integers(0, 10, per)).astype(np.int64),
        "with_zeros": lambda: rng.integers(0, 4, per),
    }[dist]
    lens = [draw() for _ in range(d)]
    _check_equivalence(lens, d)


def test_direct_function_backends():
    rng = np.random.default_rng(3)
    items = flatten_instance_lengths([rng.integers(1, 90, 7) for _ in range(6)])
    for fn, kw in ((post_balance_nopad, {}), (post_balance_pad, {}),
                   (post_balance_quad, {"lam": 0.05}), (post_balance_conv, {})):
        py = fn(items, 6, **kw)
        vec = fn(items, 6, backend="vectorized", **kw)
        assert sorted(py.lengths.tolist()) == sorted(vec.lengths.tolist())


def test_quad_tolerance_method_retained():
    """The paper-faithful tolerance comparator is still available."""
    rng = np.random.default_rng(9)
    items = flatten_instance_lengths([rng.integers(1, 50, 6) for _ in range(4)])
    pi = post_balance_quad(items, 4, lam=0.02, method="tolerance")
    assert pi.n == len(items)
    with pytest.raises(ValueError):
        post_balance_quad(items, 4, method="bogus")


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        post_balance([np.array([1, 2])], 1, CostModel(), backend="cuda")


def test_select_algorithm_policy():
    assert select_algorithm(CostModel(conv_attention=True, beta=0.1), 10) == "conv"
    assert select_algorithm(CostModel(padding=True), 10) == "pad"
    assert select_algorithm(CostModel(alpha=1.0, beta=0.01), 100) == "quad"
    assert select_algorithm(CostModel(alpha=1.0, beta=1e-6), 100) == "nopad"


# ----------------------------------------------------------------------
# Batched objective evaluator.
# ----------------------------------------------------------------------
@pytest.mark.parametrize("cm", list(COST_MODELS.values()),
                         ids=list(COST_MODELS))
def test_segment_costs_matches_scalar(cm):
    rng = np.random.default_rng(11)
    d = 5
    lengths = rng.integers(0, 40, 30)
    ids = rng.integers(0, d, 30)
    got = cm.segment_costs(lengths, ids, d)
    want = np.array([cm.cost(lengths[ids == i]) for i in range(d)])
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_assignment_costs_matches_scalar():
    rng = np.random.default_rng(12)
    cm = CostModel(alpha=1.0, beta=0.01, padding=True)
    lengths = rng.integers(1, 20, 6)
    assigns = rng.integers(0, 3, size=(8, 6))
    got = cm.assignment_costs(lengths, assigns, 3)
    for r in range(8):
        want = [cm.cost(lengths[assigns[r] == i]) for i in range(3)]
        np.testing.assert_allclose(got[r], want, rtol=1e-12)


def test_oracle_known_case():
    # lengths {4, 3, 3, 2} over d=2, linear cost: OPT = 6 (4+2 | 3+3).
    lens = [np.array([4, 3]), np.array([3, 2])]
    assert brute_force_oracle(lens, 2, CostModel()) == 6.0


def test_oracle_guards():
    with pytest.raises(ValueError):
        brute_force_oracle([np.arange(1, 14)], 2, CostModel())
    assert brute_force_oracle([np.array([], dtype=int)], 2, CostModel()) == 0.0
