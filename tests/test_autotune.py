"""Autotuner contract: cache roundtrip, resolve precedence (env
override > cache > default), prediction-pruned measurement sweeps, and
the roofline predictors' block sensitivity."""
import json

import pytest

from repro.kernels import autotune
from repro.launch.roofline import get_hw


@pytest.fixture
def cache(tmp_path):
    return str(tmp_path / "tune.json")


def test_cache_key_is_order_insensitive():
    a = autotune.cache_key("flash", {"Tq": 128, "D": 64})
    b = autotune.cache_key("flash", {"D": 64, "Tq": 128})
    assert a == b == "flash|D=64|Tq=128"


def test_autotune_picks_fastest_and_caches(cache):
    times = {(32, 32): 5.0, (64, 64): 1.0, (128, 128): 3.0}
    calls = []

    def run_fn(blocks):
        calls.append(blocks)
        # Simulated kernel: no sleeping needed, measurement keys off the
        # perf counter so equal walltimes tie-break by candidate order --
        # instead inject distinct fake durations via a busy wait.
        import time
        t0 = time.perf_counter()
        while (time.perf_counter() - t0) * 1e3 < times[blocks] / 10:
            pass

    res = autotune.autotune("flash", {"Tq": 128}, list(times), run_fn,
                            repeat=1, cache_path=cache)
    assert res["blocks"] == (64, 64)
    assert res["cached"] is False
    assert all(b in calls for b in times)

    # Second call: served from cache, run_fn untouched.
    calls.clear()
    res2 = autotune.autotune("flash", {"Tq": 128}, list(times), run_fn,
                             repeat=1, cache_path=cache)
    assert res2["blocks"] == (64, 64)
    assert res2["cached"] is True
    assert calls == []


def test_autotune_prunes_predicted_losers(cache):
    ran = []
    preds = {(32, 32): 1.0, (64, 64): 1.1, (128, 128): 50.0}

    res = autotune.autotune(
        "scan", {"T": 64}, list(preds), ran.append,
        predict_fn=lambda b: preds[b], prune=4.0, repeat=1,
        cache_path=cache, use_cache=False)
    assert (128, 128) not in ran  # predicted 50x off: never measured
    assert (32, 32) in ran and (64, 64) in ran
    # Pruned candidate still appears in the record, unmeasured.
    by_blocks = {tuple(c["blocks"]): c for c in res["candidates"]}
    assert by_blocks[(128, 128)]["measured_ms"] is None


def test_autotune_no_measurable_candidates_raises(cache):
    with pytest.raises(ValueError):
        autotune.autotune("scan", {"T": 64}, [], lambda b: None,
                          cache_path=cache, use_cache=False)


def test_resolve_precedence(cache, monkeypatch):
    key = {"Tq": 128, "D": 64}
    default = (128, 128)
    # 1. Nothing cached: default.
    assert autotune.resolve("flash", key, default, cache_path=cache) == default
    # 2. Cached winner beats default...
    autotune.autotune("flash", key, [(64, 32)], lambda b: None, repeat=1,
                      cache_path=cache)
    assert autotune.resolve("flash", key, default, cache_path=cache) == (64, 32)
    # ...but only when enabled.
    assert autotune.resolve("flash", key, default, enabled=False,
                            cache_path=cache) == default
    # 3. Env override beats everything, including enabled=False.
    monkeypatch.setenv("REPRO_KERNEL_BLOCKS", "scan=16x8,flash=256x128")
    assert autotune.resolve("flash", key, default, cache_path=cache) == (256, 128)
    assert autotune.resolve("flash", key, default, enabled=False,
                            cache_path=cache) == (256, 128)
    assert autotune.resolve("scan", key, default, cache_path=cache) == (16, 8)
    # Kernels not named in the override are unaffected.
    assert autotune.resolve("grouped", key, default, cache_path=cache) == default


def test_corrupt_cache_is_ignored(cache):
    with open(cache, "w") as f:
        f.write("{not json")
    assert autotune.resolve("flash", {"T": 1}, (8, 8), cache_path=cache) == (8, 8)
    # And autotune can still write a fresh cache over it.
    autotune.autotune("flash", {"T": 1}, [(4, 4)], lambda b: None, repeat=1,
                      cache_path=cache)
    with open(cache) as f:
        data = json.load(f)
    assert data[autotune.cache_key("flash", {"T": 1})]["blocks"] == [4, 4]


def test_candidate_enumerators_respect_divisibility():
    for bq, bk in autotune.flash_candidates(384, 256):
        assert 384 % bq == 0 and 256 % bk == 0
    for bd, ct in autotune.scan_candidates(192, 96):
        assert 96 % bd == 0 and 192 % ct == 0
    for bm, bn in autotune.grouped_candidates(256, 96):
        assert 256 % bm == 0 and 96 % bn == 0
    assert (128, 64) in autotune.flash_candidates(128, 64)


def test_predictors_penalize_tiny_blocks():
    """Same FLOPs, more grid steps: the step-overhead term must make an
    explosion of tiny tiles strictly slower in every predictor."""
    hw = get_hw("v5e")
    assert autotune.predict_scan((16, 16), T=4096, di=4096, N=16, hw=hw) > \
        autotune.predict_scan((128, 256), T=4096, di=4096, N=16, hw=hw)
    assert autotune.predict_flash(
        (32, 32), heads=8, Tq=4096, Tkv=4096, D=128, hw=hw) > \
        autotune.predict_flash(
            (256, 256), heads=8, Tq=4096, Tkv=4096, D=128, hw=hw)
    assert autotune.predict_grouped(
        (32, 32), M=4096, K=4096, N=4096, E=8, hw=hw) > \
        autotune.predict_grouped(
            (256, 256), M=4096, K=4096, N=4096, E=8, hw=hw)


def test_predict_grouped_rewards_tile_skip():
    """Fewer live tiles (balanced routing over many experts) must
    predict faster than a dense sweep at the same shape."""
    hw = get_hw("v5e")
    dense = autotune.predict_grouped((128, 128), M=4096, K=512, N=512, E=8,
                                     live_tiles=4096 // 128 * 8, hw=hw)
    skip = autotune.predict_grouped((128, 128), M=4096, K=512, N=512, E=8,
                                    hw=hw)  # default: n_m + E - 1 live
    assert skip < dense
