"""Miniature dry-run (subprocess, 8 host devices): param/batch/cache
shardings + lower + compile + roofline extraction, single- and
multi-pod-style meshes, across families.  The production 512-device
dry-run (launch/dryrun.py) runs the same machinery at full scale."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_dryrun_small_all_families():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src") + ":" + str(REPO)
    res = subprocess.run(
        [sys.executable, str(REPO / "tests/helpers/dryrun_small_check.py")],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"
    assert res.stdout.count("ok ") >= 18  # 9 (arch, kind) pairs x 2 meshes
