"""Cross-rank aggregation tests: GK sketch merge rank-error bound,
registry merge == union stream, serialization round-trips, the strict
OpenMetrics parser's rejection surface, and the live /metrics server.
"""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.obs import (MetricsRegistry, MetricsServer, QuantileSketch,
                       aggregate_registries, merge_sketches,
                       parse_openmetrics, registry_from_state_dict,
                       registry_state_dict, render_openmetrics,
                       validate_openmetrics)

QS = (0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0)


# ----------------------------------------------------------------------
# GK sketch merge: the mergeable-summaries rank-error bound.
# ----------------------------------------------------------------------
def _assert_rank_error(sk, data, eps, qs=QS):
    """Every quantile answer's true rank lies within eps*n (+1 discrete
    slack) of the target rank over the UNION stream."""
    xs = np.sort(np.asarray(data, dtype=np.float64))
    n = len(xs)
    assert sk.n == n, f"merged n {sk.n} != union n {n}"
    for q in qs:
        v = sk.quantile(q)
        target = max(1, int(np.ceil(q * n)))
        rank_lo = int(np.searchsorted(xs, v, side="left")) + 1
        rank_hi = int(np.searchsorted(xs, v, side="right"))
        margin = eps * n + 1
        assert rank_lo - margin <= target <= rank_hi + margin, (
            f"q={q}: answer {v} has rank [{rank_lo}, {rank_hi}], "
            f"target {target}, margin {margin:.1f} (n={n})")


def _merged(a_data, b_data, eps_a=0.005, eps_b=0.005):
    a, b = QuantileSketch(eps=eps_a), QuantileSketch(eps=eps_b)
    a.extend(a_data)
    b.extend(b_data)
    return merge_sketches(a, b)


@pytest.mark.parametrize("split", [
    "sorted_halves", "interleaved", "disjoint_ranges", "skewed_sizes",
    "identical", "heavy_tail_vs_normal",
])
def test_merge_rank_error_adversarial_splits(split):
    n = 10_000
    rng = np.random.default_rng(0)
    base = rng.normal(size=2 * n)
    a, b = {
        # Each side sees a *sorted* half: worst case for per-sketch
        # tuple placement.
        "sorted_halves": (np.sort(base)[:n], np.sort(base)[n:]),
        "interleaved": (np.sort(base)[0::2], np.sort(base)[1::2]),
        "disjoint_ranges": (rng.uniform(0, 1, n), rng.uniform(100, 101, n)),
        "skewed_sizes": (base[:40], base[40:]),
        "identical": (np.full(n, 3.0), np.full(n, 3.0)),
        "heavy_tail_vs_normal": (rng.lognormal(0, 3, n), rng.normal(size=n)),
    }[split]
    merged = _merged(a, b)
    _assert_rank_error(merged, np.concatenate([a, b]), eps=0.005)


def test_merge_preserves_max_eps():
    rng = np.random.default_rng(1)
    a, b = rng.normal(size=5_000), rng.uniform(-4, 4, 5_000)
    merged = _merged(a, b, eps_a=0.002, eps_b=0.02)
    assert merged.eps == 0.02
    _assert_rank_error(merged, np.concatenate([a, b]), eps=0.02)


def test_merge_empty_and_singleton():
    empty = merge_sketches(QuantileSketch(eps=0.01), QuantileSketch(eps=0.02))
    assert empty.n == 0 and empty.eps == 0.02
    one = QuantileSketch()
    one.add(5.0)
    m = merge_sketches(one, QuantileSketch())
    assert m.n == 1 and m.quantile(0.5) == 5.0
    m = merge_sketches(QuantileSketch(), one)
    assert m.n == 1 and m.quantile(0.5) == 5.0


def test_merge_is_reusable_and_chains():
    """Merging merged sketches (tree reduction over ranks) still meets
    the bound -- the shape an aggregator over many ranks produces."""
    rng = np.random.default_rng(2)
    parts = [rng.normal(loc=i, size=2_000) for i in range(4)]
    sks = []
    for p in parts:
        sk = QuantileSketch(eps=0.01)
        sk.extend(p)
        sks.append(sk)
    merged = merge_sketches(merge_sketches(sks[0], sks[1]),
                            merge_sketches(sks[2], sks[3]))
    _assert_rank_error(merged, np.concatenate(parts), eps=0.01)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=1,
                max_size=300),
       st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=0,
                max_size=300))
def test_merge_rank_error_property(xs, ys):
    merged = _merged(xs, ys, eps_a=0.01, eps_b=0.01)
    _assert_rank_error(merged, list(xs) + list(ys), eps=0.01,
                       qs=(0.25, 0.5, 0.95))


@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=2,
                max_size=400),
       st.integers(min_value=0, max_value=400))
def test_merge_split_point_property(xs, cut):
    """Any split point of one stream merges back to the union bound."""
    cut = min(cut, len(xs))
    merged = _merged(xs[:cut], xs[cut:], eps_a=0.01, eps_b=0.01)
    _assert_rank_error(merged, xs, eps=0.01, qs=(0.5, 0.9))


# ----------------------------------------------------------------------
# Registry aggregation == recording the union stream.
# ----------------------------------------------------------------------
def _rank_reg(rank, values):
    reg = MetricsRegistry()
    reg.counter("events", "e", labels=("shard",)).inc(
        10.0 * (rank + 1), shard=str(rank))
    reg.counter("events", "e", labels=("shard",)).inc(1.0, shard="all")
    reg.gauge("util", "u").set(0.5 + 0.1 * rank)
    h = reg.histogram("lat_ms", "l", buckets=(1.0, 10.0, 100.0, float("inf")))
    for v in values:
        h.observe(float(v))
    return reg


def test_aggregate_counters_and_histograms_equal_union():
    rng = np.random.default_rng(3)
    streams = [rng.exponential(scale=20.0, size=500) for _ in range(3)]
    regs = [_rank_reg(r, streams[r]) for r in range(3)]
    agg = aggregate_registries(regs)

    # Counters: per-labelset sum; the shared "all" labelset sums across
    # ranks while per-rank labelsets pass through.
    fam = agg.get("events")
    got = {tuple(labels.items()): child.value for labels, child in
           fam.children()}
    assert got[(("shard", "all"),)] == 3.0
    assert got[(("shard", "0"),)] == 10.0
    assert got[(("shard", "2"),)] == 30.0

    # Histograms: bucket counts, _sum and _count equal one registry fed
    # the union stream; quantiles within the sketch bound.
    union = np.concatenate(streams)
    ref = MetricsRegistry()
    rh = ref.histogram("lat_ms", "l", buckets=(1.0, 10.0, 100.0, float("inf")))
    for v in union:
        rh.observe(float(v))
    got_h = agg.get("lat_ms").labels()
    ref_child = ref.get("lat_ms").labels()
    assert got_h.bucket_counts() == ref_child.bucket_counts()
    assert got_h.count == len(union)
    assert got_h.sum == pytest.approx(float(union.sum()))
    xs = np.sort(union)
    for q in (0.5, 0.95):
        v = got_h.quantile(q)
        target = max(1, int(np.ceil(q * len(xs))))
        lo = int(np.searchsorted(xs, v, "left")) + 1
        hi = int(np.searchsorted(xs, v, "right"))
        margin = 0.005 * len(xs) + 1
        assert lo - margin <= target <= hi + margin


def test_aggregate_gauge_modes():
    regs = []
    for v in (1.0, 2.0, 4.0):
        reg = MetricsRegistry()
        reg.gauge("util", "u").set(v)
        regs.append(reg)
    mean = aggregate_registries(regs, gauge_mode="mean")
    assert mean.get("util").labels().value == pytest.approx(7.0 / 3.0)
    total = aggregate_registries(regs, gauge_mode="sum")
    assert total.get("util").labels().value == 7.0
    last = aggregate_registries(regs, gauge_mode="last")
    assert last.get("util").labels().value == 4.0
    with pytest.raises(ValueError, match="gauge_mode"):
        aggregate_registries(regs, gauge_mode="max")


def test_aggregate_gauge_mean_divides_by_contributors():
    """A gauge present on only 2 of 3 ranks means over 2, not 3."""
    regs = [MetricsRegistry() for _ in range(3)]
    regs[0].gauge("partial", "p").set(1.0)
    regs[1].gauge("partial", "p").set(3.0)
    agg = aggregate_registries(regs, gauge_mode="mean")
    assert agg.get("partial").labels().value == 2.0


def test_aggregate_rejects_mismatched_buckets():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.histogram("h", "h", buckets=(1.0, float("inf"))).labels().observe(0.5)
    b.histogram("h", "h", buckets=(2.0, float("inf"))).labels().observe(0.5)
    with pytest.raises(ValueError, match="bucket layouts differ"):
        aggregate_registries([a, b])


def test_registry_state_dict_json_roundtrip():
    reg = MetricsRegistry()
    reg.counter("c", "c", labels=("k",)).inc(5.0, k="x")
    reg.gauge("g", "g").set(-1.5)
    h = reg.histogram("h", "h", buckets=(1.0, 5.0, float("inf")))
    for v in np.random.default_rng(4).uniform(0, 10, 300):
        h.labels().observe(float(v))
    clone = registry_from_state_dict(
        json.loads(json.dumps(registry_state_dict(reg))))
    assert render_openmetrics(clone) == render_openmetrics(reg)
    # And the clone merges like the original (sketch survived).
    agg = aggregate_registries([reg, clone])
    assert agg.get("c").labels(k="x").value == 10.0
    assert agg.get("h").labels().count == 600


# ----------------------------------------------------------------------
# Strict OpenMetrics parsing.
# ----------------------------------------------------------------------
def test_parser_accepts_rendered_registry():
    reg = MetricsRegistry()
    reg.counter("req", "r", labels=("code",)).inc(3.0, code="200")
    reg.gauge("temp", "t").set(-3.5)
    h = reg.histogram("lat", "l", buckets=(0.1, 1.0, float("inf")))
    for v in (0.05, 0.5, 2.0):
        h.labels().observe(v)
    samples = parse_openmetrics(render_openmetrics(reg))
    assert samples['req_total{code="200"}'] == 3.0
    assert samples["lat_count{}"] == 3.0
    assert samples['lat_bucket{le="+Inf"}'] == 3.0


@pytest.mark.parametrize("text,match", [
    ("a 1\na 2\n# EOF\n", "duplicate series"),
    ('h_bucket{le="5"} 1\nh_bucket{le="1"} 2\n# EOF\n', "out of order"),
    ('h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\n# EOF\n', "decreases"),
    ('h_bucket{le="1"} 1\n# EOF\n', "no \\+Inf bucket"),
    ('h_bucket{le="+Inf"} 3\nh_count 4\n# EOF\n', "!= _count"),
    ("reqs_total -1\n# EOF\n", "invalid value"),
    ("a 1\n", "missing # EOF"),
    ("garbage line here\n# EOF\n", "unparsable|malformed"),
    ("# EOF\nafter 1\n", "after # EOF"),
    ("# TYPE x wrong\n# EOF\n", "malformed TYPE"),
    ('bad{label="x"extra} 1\n# EOF\n', "malformed labels"),
])
def test_parser_rejections(text, match):
    with pytest.raises(ValueError, match=match):
        parse_openmetrics(text)


def test_validate_counter_monotonicity_across_scrapes():
    first = parse_openmetrics("steps_total 5\n# EOF\n")
    validate_openmetrics("steps_total 7\n# EOF\n", previous=first)
    validate_openmetrics("steps_total 5\n# EOF\n", previous=first)  # equal ok
    with pytest.raises(ValueError, match="went backwards"):
        validate_openmetrics("steps_total 4\n# EOF\n", previous=first)


# ----------------------------------------------------------------------
# Live HTTP exporter.
# ----------------------------------------------------------------------
def _get(url):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.status, resp.read().decode()


def test_metrics_server_serves_aggregated_view():
    regs = [MetricsRegistry() for _ in range(2)]
    for i, reg in enumerate(regs):
        reg.counter("steps", "s").inc(float(i + 1))
        reg.gauge("mfu", "m").set(0.8)
    report = {"fault_step": 7, "causes": [{"cause": "straggler_llm"}]}
    with MetricsServer(lambda: aggregate_registries(regs),
                       triage_provider=lambda: report) as srv:
        status, body = _get(srv.url + "/metrics")
        assert status == 200
        first = validate_openmetrics(body)
        assert first["steps_total{}"] == 3.0
        assert first["mfu{}"] == 0.8
        # Counters move; the next scrape must stay monotone.
        regs[0].get("steps").inc(5.0)
        _, body2 = _get(srv.url + "/metrics")
        second = validate_openmetrics(body2, previous=first)
        assert second["steps_total{}"] == 8.0
        status, triage_body = _get(srv.url + "/triage")
        assert status == 200
        assert json.loads(triage_body)["causes"][0]["cause"] == "straggler_llm"
        status, _ = _get(srv.url + "/healthz")
        assert status == 200
        with pytest.raises(urllib.error.HTTPError):
            _get(srv.url + "/nope")


def test_metrics_server_no_triage_provider_404s():
    reg = MetricsRegistry()
    with MetricsServer(lambda: reg) as srv:
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/triage")
        assert e.value.code == 404


def test_metrics_server_render_error_is_500_not_crash():
    def bad():
        raise RuntimeError("boom")

    with MetricsServer(bad) as srv:
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/metrics")
        assert e.value.code == 500
        # The server thread survived the error.
        status, _ = _get(srv.url + "/healthz")
        assert status == 200
