"""Optimizer + training-loop tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)


def test_adamw_converges_quadratic():
    """AdamW minimizes a simple quadratic."""
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 4)), jnp.float32)
    params = {"w": jnp.zeros((4, 4))}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(params, grads, state, cfg)

    for _ in range(200):
        params, state, m = step(params, state)
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_grad_clipping():
    params = {"w": jnp.ones((8,))}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    grads = {"w": jnp.full((8,), 100.0)}
    _, _, m = adamw_update(params, grads, state, cfg)
    assert float(m["grad_norm"]) > 100
    # With lr=0 params unchanged (clip itself must not mutate params).


def test_weight_decay_on_matrices_only():
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0)
    grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    new, _, _ = adamw_update(params, grads, state, cfg)
    assert float(new["w"].max()) < 1.0  # decayed
    assert float(jnp.abs(new["b"] - 1.0).max()) < 1e-6  # not decayed


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_property_cosine_schedule_bounds(step):
    lr = float(cosine_schedule(step, peak_lr=1e-3, warmup=100, total=10_000))
    assert 0.0 <= lr <= 1e-3 + 1e-9


def test_cosine_schedule_shape():
    warm = float(cosine_schedule(50, peak_lr=1.0, warmup=100, total=1000))
    peak = float(cosine_schedule(100, peak_lr=1.0, warmup=100, total=1000))
    end = float(cosine_schedule(1000, peak_lr=1.0, warmup=100, total=1000))
    assert warm == pytest.approx(0.5)
    assert peak == pytest.approx(1.0)
    assert end == pytest.approx(0.1)  # floor


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))


def test_loss_decreases_tiny_model():
    """A few steps on a fixed batch must reduce the loss."""
    from repro.configs import get_config
    from repro.core.orchestrator import MLLMGlobalOrchestrator
    from repro.data.synthetic import Example
    from repro.training.train_step import init_train_state, make_train_step

    cfg = get_config("olmo_1b").smoke()
    rng = np.random.default_rng(0)
    orch = MLLMGlobalOrchestrator(cfg, 2, vocab=cfg.vocab_size)
    examples = [[Example("t", 48, 0, 0, ("text",)) for _ in range(3)]
                for _ in range(2)]
    caps = orch.default_capacities(examples, margin=2.0)
    batch_np, _ = orch.plan_and_pack(examples, caps, rng)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=5e-3)))
    losses = []
    for _ in range(6):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
