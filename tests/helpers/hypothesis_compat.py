"""Deterministic fallback for `hypothesis` when it is not installed.

The test suite's property tests use a small slice of the Hypothesis API
(`given`, `settings`, `strategies.integers/lists/permutations`).  In
offline environments without the package, :func:`install` registers this
module as ``hypothesis`` / ``hypothesis.strategies`` in ``sys.modules``
*before collection* (see ``tests/conftest.py``), so the same test code
runs against fixed-seed random examples instead:

  - every ``@given`` test runs ``max_examples`` draws (from the
    ``@settings`` decorator, default 20),
  - the RNG is seeded from the test's qualified name, so runs are
    deterministic and failures reproducible,
  - no shrinking -- the failing drawn arguments are attached to the
    assertion message instead.

With real Hypothesis installed (e.g. in CI), this module is inert.
"""
from __future__ import annotations

import sys
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "install", "HealthCheck"]


class Strategy:
    """Base class: a strategy draws a value from an np.random.Generator."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, fn):
        return Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred, _tries: int = 100):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too strict for compat shim")

        return Strategy(draw)


def integers(min_value: int = 0, max_value: int = 1 << 31) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> Strategy:
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> Strategy:
    seq = list(seq)
    return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elements: Strategy, *, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(size)]

    return Strategy(draw)


def permutations(values) -> Strategy:
    values = list(values)

    def draw(rng):
        out = list(values)
        rng.shuffle(out)
        return out

    return Strategy(draw)


def tuples(*strats: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s.draw(rng) for s in strats))


class HealthCheck:
    """Placeholder mirroring hypothesis.HealthCheck members."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


def settings(max_examples: int = 20, deadline=None, **_kw):
    """Decorator recording run parameters for the `given` wrapper."""

    def deco(fn):
        fn._compat_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*strats: Strategy, **kw_strats: Strategy):
    """Deterministic replacement for hypothesis.given."""

    def deco(fn):
        conf = getattr(fn, "_compat_settings", {"max_examples": 20})

        def wrapper(*args, **kwargs):
            seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
            rng = np.random.default_rng(seed)
            for example in range(conf["max_examples"]):
                drawn = [s.draw(rng) for s in strats]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except AssertionError as e:
                    raise AssertionError(
                        f"{e}\n[hypothesis_compat] falsifying example "
                        f"#{example}: args={drawn!r} kwargs={drawn_kw!r}"
                    ) from e

        # Copy identity WITHOUT functools.wraps: __wrapped__ would make
        # pytest resolve the original signature and treat the drawn
        # parameters as fixtures.
        for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper

    return deco


def install() -> None:
    """Register this module as `hypothesis` (+`.strategies`) if absent."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "permutations", "tuples"):
        setattr(st, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = HealthCheck
    hyp.__version__ = "0.0-compat"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
