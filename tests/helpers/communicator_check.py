"""Multi-device check for the Node-wise All-to-All Communicator.

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(set by the pytest wrapper).  Exits non-zero on any mismatch.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.balancing import post_balance
from repro.core.communicator import apply_comm_plan, build_comm_plan, plan_to_device
from repro.core.cost_model import CostModel
from repro.core.nodewise import nodewise_rearrange


def reference_exchange(pi, x_global, cap_in, cap_out, feat):
    """Pure numpy oracle: place each example's tokens at its destination."""
    from repro.core.communicator import _layout

    d = pi.d
    lengths = pi.lengths
    src_starts, _ = _layout(pi.src_inst, pi.src_slot, lengths, d)
    dst_starts, _ = _layout(pi.dst_inst, pi.dst_slot, lengths, d)
    out = np.zeros((d * cap_out,) + feat, x_global.dtype)
    for k in range(pi.n):
        l = int(lengths[k])
        s0 = int(pi.src_inst[k]) * cap_in + int(src_starts[k])
        t0 = int(pi.dst_inst[k]) * cap_out + int(dst_starts[k])
        out[t0 : t0 + l] = x_global[s0 : s0 + l]
    return out


def run_case(mesh, dp_axes, d, seed, mode, nodewise=False):
    rng = np.random.default_rng(seed)
    lens = [rng.integers(1, 40, size=rng.integers(1, 6)) for _ in range(d)]
    pi = post_balance(lens, d, CostModel())
    if nodewise:
        pi = nodewise_rearrange(pi, 2)
    cap_in = int(max(l.sum() for l in lens))
    cap_out = int(max(l.sum() for l in pi.dest_lengths()) or 1)
    feat = (4,)
    x = rng.normal(size=(d * cap_in,) + feat).astype(np.float32)
    # Zero out the pad region of each source shard so the oracle matches.
    from repro.core.communicator import _layout

    _, totals = _layout(pi.src_inst, pi.src_slot, pi.lengths, d)
    for i in range(d):
        x[i * cap_in + int(totals[i]) : (i + 1) * cap_in] = 0

    plan = build_comm_plan(pi, cap_in, cap_out)
    arrays = plan_to_device(plan)
    sharding = NamedSharding(mesh, P(dp_axes))
    xg = jax.device_put(jnp.asarray(x), sharding)
    arrays = {
        k: jax.device_put(v, NamedSharding(mesh, P(dp_axes)))
        for k, v in arrays.items()
    }

    fn = jax.jit(
        lambda xx, aa: apply_comm_plan(xx, aa, mesh, dp_axes, mode=mode),
    )
    got = np.asarray(fn(xg, arrays))
    want = reference_exchange(pi, x, cap_in, cap_out, feat)
    if not np.allclose(got, want, atol=1e-6):
        bad = np.argwhere(~np.isclose(got, want, atol=1e-6))
        print(f"FAIL mode={mode} d={d} seed={seed} nodewise={nodewise} "
              f"mismatches={len(bad)} first={bad[:5]}")
        return False
    print(f"ok mode={mode} d={d} seed={seed} nodewise={nodewise}")
    return True


def check_ragged_lowers(mesh, dp_axes, d, seed):
    """ragged_all_to_all does not execute on XLA:CPU; assert it traces
    and lowers (the TPU-target path)."""
    if not hasattr(jax.lax, "ragged_all_to_all"):
        print(f"skip ragged lowering: jax {jax.__version__} lacks "
              "jax.lax.ragged_all_to_all")
        return True
    rng = np.random.default_rng(seed)
    lens = [rng.integers(1, 40, size=3) for _ in range(d)]
    pi = post_balance(lens, d, CostModel())
    cap_in = int(max(l.sum() for l in lens))
    cap_out = int(max(l.sum() for l in pi.dest_lengths()))
    plan = build_comm_plan(pi, cap_in, cap_out)
    arrays = plan_to_device(plan)
    x = jnp.zeros((d * cap_in, 4), jnp.float32)
    lowered = jax.jit(
        lambda xx, aa: apply_comm_plan(xx, aa, mesh, dp_axes, mode="ragged")
    ).lower(x, arrays)
    txt = lowered.as_text()
    assert "ragged" in txt or "ragged-all-to-all" in txt, "no ragged op in HLO"
    print("ok ragged lowering contains ragged-all-to-all")
    return True


def main():
    n_dev = len(jax.devices())
    assert n_dev == 8, f"expected 8 host devices, got {n_dev}"
    ok = True
    # Flat DP mesh.
    mesh = jax.make_mesh((8,), ("data",))
    for mode in ("a2a", "allgather", "gather"):
        for seed in (0, 1, 2):
            ok &= run_case(mesh, ("data",), 8, seed, mode)
    ok &= run_case(mesh, ("data",), 8, 3, "a2a", nodewise=True)
    ok &= check_ragged_lowers(mesh, ("data",), 8, 5)
    # Multi-pod style mesh: DP spans ("pod", "data").
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    for mode in ("a2a", "gather"):
        ok &= run_case(mesh2, ("pod", "data"), 8, 4, mode)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
