"""Miniature dry-run: the full launch machinery (param/batch/cache
shardings, jit lower+compile, roofline extraction) on an 8-device host
mesh with smoke configs.  Validates what the production 512-device
dry-run does, cheaply, inside pytest."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax

from repro.configs import get_config
from repro.launch.roofline import collective_bytes
from repro.sharding.specs import (
    batch_specs,
    cache_sharding_specs,
    opt_state_specs,
    param_specs,
    to_shardings,
)


def tiny_specs(cfg, kind, dp):
    """input_specs at reduced sizes for smoke configs."""
    import jax.numpy as jnp

    i32, bf16 = jnp.int32, jnp.bfloat16
    S, cap = dp, 256
    if kind == "train":
        if cfg.encoders and cfg.family != "audio":
            specs = {
                "tokens": jax.ShapeDtypeStruct((S, cap // 2), i32),
                "text_dst": jax.ShapeDtypeStruct((S, cap // 2), i32),
                "llm_seg": jax.ShapeDtypeStruct((S, cap), i32),
                "llm_pos": jax.ShapeDtypeStruct((S, cap), i32),
                "llm_labels": jax.ShapeDtypeStruct((S, cap), i32),
            }
            for e in cfg.encoders:
                ce = 128 * e.downsample
                co = ce // e.downsample
                chunk = max(co // S, 8)
                specs.update({
                    f"enc_{e.name}_embeds": jax.ShapeDtypeStruct((S, ce, e.embed_dim), bf16),
                    f"enc_{e.name}_seg": jax.ShapeDtypeStruct((S, ce), i32),
                    f"enc_{e.name}_pos": jax.ShapeDtypeStruct((S, ce), i32),
                    f"enc_{e.name}_dst": jax.ShapeDtypeStruct((S, co), i32),
                    f"enc_{e.name}_plan_pre_gather_dense": jax.ShapeDtypeStruct((S, S * chunk), i32),
                    f"enc_{e.name}_plan_post_gather_dense": jax.ShapeDtypeStruct((S, co), i32),
                    f"enc_{e.name}_plan_post_mask": jax.ShapeDtypeStruct((S, co), jax.numpy.bool_),
                    f"enc_{e.name}_plan_global_gather": jax.ShapeDtypeStruct((S, co), i32),
                })
            return specs
        if cfg.family == "audio":
            e = cfg.encoders[0]
            ce = 128
            return {
                "tokens": jax.ShapeDtypeStruct((S, cap), i32),
                "labels": jax.ShapeDtypeStruct((S, cap), i32),
                "seg": jax.ShapeDtypeStruct((S, cap), i32),
                "pos": jax.ShapeDtypeStruct((S, cap), i32),
                f"enc_{e.name}_embeds": jax.ShapeDtypeStruct((S, ce, e.embed_dim), bf16),
                f"enc_{e.name}_seg": jax.ShapeDtypeStruct((S, ce), i32),
                f"enc_{e.name}_pos": jax.ShapeDtypeStruct((S, ce), i32),
                f"enc_{e.name}_seg_out": jax.ShapeDtypeStruct((S, ce), i32),
                f"enc_{e.name}_pos_out": jax.ShapeDtypeStruct((S, ce), i32),
                f"enc_{e.name}_plan_pre_gather_dense": jax.ShapeDtypeStruct((S, S * max(ce // S, 8)), i32),
                f"enc_{e.name}_plan_post_gather_dense": jax.ShapeDtypeStruct((S, ce), i32),
                f"enc_{e.name}_plan_post_mask": jax.ShapeDtypeStruct((S, ce), jax.numpy.bool_),
                f"enc_{e.name}_plan_global_gather": jax.ShapeDtypeStruct((S, ce), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((S, cap), i32),
            "labels": jax.ShapeDtypeStruct((S, cap), i32),
            "seg": jax.ShapeDtypeStruct((S, cap), i32),
            "pos": jax.ShapeDtypeStruct((S, cap), i32),
        }
    # decode
    from repro.configs.registry import cache_specs

    return {
        "tokens": jax.ShapeDtypeStruct((8, 1), i32),
        "t": jax.ShapeDtypeStruct((), i32),
        "cache": cache_specs(cfg, 8, 64),
    }


def run(arch, kind, multi_pod):
    from repro.models.model import init_params
    from repro.serving.serve_step import make_serve_step
    from repro.training.optimizer import adamw_init
    from repro.training.train_step import make_train_step

    cfg = get_config(arch).smoke()
    if multi_pod:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        dp_axes = ("pod", "data")
    else:
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        dp_axes = ("data",)
    dp = 4
    specs = tiny_specs(cfg, kind, dp)
    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_specs = param_specs(cfg, params_shape, mesh)

    with mesh:
        if kind == "train":
            opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
            fn = make_train_step(cfg, mesh=mesh, dp_axes=dp_axes)
            in_sh = (p_specs, opt_state_specs(p_specs), batch_specs(specs, dp_axes))
            args = (params_shape, opt_shape, specs)
        else:
            fn = make_serve_step(cfg)
            c_specs = cache_sharding_specs(cfg, specs["cache"], dp_axes, mesh)
            in_sh = (p_specs, jax.sharding.PartitionSpec(dp_axes), c_specs,
                     jax.sharding.PartitionSpec())
            args = (params_shape, specs["tokens"], specs["cache"], specs["t"])
        lowered = jax.jit(fn, in_shardings=to_shardings(in_sh, mesh)).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax<0.5 returns [dict]
            cost = cost[0] if cost else {}
        coll = collective_bytes(compiled.as_text())
    assert cost.get("flops", 0) > 0
    assert mem.temp_size_in_bytes >= 0
    print(f"ok {arch} {kind} multi_pod={multi_pod} flops={cost['flops']:.2e} "
          f"coll={coll['total']:.2e}")
    return True


def main():
    assert len(jax.devices()) == 8
    ok = True
    for arch, kinds in (
        ("qwen3_8b", ("train", "decode")),
        ("grok_1_314b", ("train",)),
        ("falcon_mamba_7b", ("train", "decode")),
        ("zamba2_2_7b", ("decode",)),
        ("llava_next_mistral_7b", ("train",)),
        ("whisper_large_v3", ("train", "decode")),
    ):
        for kind in kinds:
            for mp in (False, True):
                ok &= run(arch, kind, mp)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
