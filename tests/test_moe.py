"""MoE dispatch semantics: grouped vs dense backend parity, capacity /
drop accounting, the load-balance loss contract, and the measured-load
expert-to-shard planner."""
import heapq

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import expert_shard_plan, moe_ffn, router_load_balance_loss


def _moe_inputs(rng, B, T, d, f, E, *, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(size=(B, T, d)), dtype)
    router_w = jnp.asarray(rng.normal(0, 0.5, size=(d, E)), jnp.float32)
    w_gate = jnp.asarray(rng.normal(0, 0.1, size=(E, d, f)), dtype)
    w_up = jnp.asarray(rng.normal(0, 0.1, size=(E, d, f)), dtype)
    w_down = jnp.asarray(rng.normal(0, 0.1, size=(E, f, d)), dtype)
    return x, router_w, w_gate, w_up, w_down


@pytest.mark.parametrize("top_k,with_valid", [(1, False), (2, True), (4, True)])
def test_grouped_matches_dense_when_nothing_drops(top_k, with_valid):
    """With capacity high enough that dense drops nothing, the two
    backends compute the same function -- outputs and weight/input
    gradients must agree."""
    rng = np.random.default_rng(0)
    B, T, d, f, E = 2, 32, 16, 32, 4
    x, router_w, w_gate, w_up, w_down = _moe_inputs(rng, B, T, d, f, E)
    valid = None
    if with_valid:
        v = np.ones((B, T), bool)
        v[:, -5:] = False
        valid = jnp.asarray(v)

    def run(backend):
        def loss(x, w_gate, w_up, w_down):
            out, aux = moe_ffn(
                x, router_w, w_gate, w_up, w_down, top_k=top_k,
                capacity_factor=float(E),  # capacity == n*k: cannot drop
                valid=valid, backend=backend, block_m=32, block_n=16)
            return jnp.sum(jnp.sin(out)), (out, aux)
        (l, (out, aux)), grads = jax.value_and_grad(
            loss, argnums=(0, 1, 2, 3), has_aux=True)(x, w_gate, w_up, w_down)
        return out, aux, grads

    out_g, aux_g, grads_g = run("grouped")
    out_d, aux_d, grads_d = run("dense")
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_d),
                               atol=1e-5, rtol=1e-5)
    assert float(aux_d["dropped_frac"]) == 0.0
    assert float(aux_g["dropped_frac"]) == 0.0
    np.testing.assert_allclose(float(aux_g["lb_loss"]), float(aux_d["lb_loss"]))
    np.testing.assert_allclose(np.asarray(aux_g["expert_load"]),
                               np.asarray(aux_d["expert_load"]))
    for name, gg, gd in zip(("dx", "dw_gate", "dw_up", "dw_down"),
                            grads_g, grads_d):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(gd),
                                   atol=2e-4, rtol=2e-4, err_msg=name)


def test_high_capacity_factor_means_zero_drops():
    """The docstring contract: capacity_factor sized to the worst case
    (all assignments on one expert) guarantees dropped_frac == 0."""
    rng = np.random.default_rng(1)
    B, T, d, f, E = 2, 16, 8, 16, 4
    x, router_w, w_gate, w_up, w_down = _moe_inputs(rng, B, T, d, f, E)
    # Bias the router hard toward expert 0 to stress the buffer.
    router_w = router_w.at[:, 0].add(10.0)
    _, aux = moe_ffn(x, router_w, w_gate, w_up, w_down, top_k=2,
                     capacity_factor=float(E), backend="dense")
    assert float(aux["dropped_frac"]) == 0.0


def test_tight_capacity_drops_and_grouped_does_not():
    rng = np.random.default_rng(2)
    B, T, d, f, E = 2, 16, 8, 16, 4
    x, router_w, w_gate, w_up, w_down = _moe_inputs(rng, B, T, d, f, E)
    router_w = router_w.at[:, 0].add(10.0)  # skewed routing
    _, aux_d = moe_ffn(x, router_w, w_gate, w_up, w_down, top_k=2,
                       capacity_factor=0.5, backend="dense")
    assert float(aux_d["dropped_frac"]) > 0.0
    out_g, aux_g = moe_ffn(x, router_w, w_gate, w_up, w_down, top_k=2,
                           capacity_factor=0.5, backend="grouped",
                           block_m=16, block_n=16)
    assert float(aux_g["dropped_frac"]) == 0.0
    # Drop-free reference: dense with unconstrained capacity.
    out_ref, _ = moe_ffn(x, router_w, w_gate, w_up, w_down, top_k=2,
                         capacity_factor=float(E), backend="dense")
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_ref),
                               atol=1e-5, rtol=1e-5)


def test_padding_tokens_output_zero_and_use_no_capacity():
    rng = np.random.default_rng(3)
    B, T, d, f, E = 1, 16, 8, 16, 4
    x, router_w, w_gate, w_up, w_down = _moe_inputs(rng, B, T, d, f, E)
    v = np.ones((B, T), bool)
    v[:, T // 2:] = False
    valid = jnp.asarray(v)
    for backend in ("dense", "grouped"):
        out, aux = moe_ffn(x, router_w, w_gate, w_up, w_down, top_k=2,
                           valid=valid, backend=backend,
                           block_m=16, block_n=16)
        assert np.allclose(np.asarray(out)[0, T // 2:], 0.0), backend
        # expert_load counts only valid assignments.
        np.testing.assert_allclose(float(np.asarray(aux["expert_load"]).sum()),
                                   1.0, rtol=1e-6)


@pytest.mark.parametrize("k", [1, 2, 4])
def test_lb_loss_balanced_uniform_is_exactly_one(k):
    """Regression pin: uniform router probs + perfectly uniform slot
    usage give exactly 1.0 for ANY top-k (the loss counts all k slots
    normalized by k, not just the top-1 choice)."""
    E = 8
    n = 64
    probs = jnp.full((n, E), 1.0 / E)
    # Round-robin assignment: every expert fills n*k/E slots.
    gate_ids = jnp.asarray(
        (np.arange(n * k).reshape(n, k) % E).astype(np.int32))
    loss = router_load_balance_loss(probs, gate_ids, E, top_k=k)
    assert float(loss) == 1.0


def test_lb_loss_counts_all_topk_slots():
    """A router whose 2nd choices all pile onto its favorite expert is
    imbalanced even when the top-1 choices are uniform: the all-slots
    loss must see it, while a top-1-only view scores it as balanced."""
    E, n = 4, 64
    p = np.full((n, E), 0.5 / (E - 1))
    p[:, 0] = 0.5                         # router leans toward expert 0
    probs = jnp.asarray(p)
    top1 = np.arange(n) % E               # uniform first choices
    second = np.full(n, 0)                # all second choices -> expert 0
    second[top1 == 0] = 1                 # keep slots distinct per token
    gate_ids = jnp.asarray(np.stack([top1, second], 1).astype(np.int32))
    loss_all = router_load_balance_loss(probs, gate_ids, E)
    loss_top1 = router_load_balance_loss(probs, gate_ids[:, :1], E)
    # Top-1 slots alone look uniform; counting both slots exposes the
    # pile-up on the favored expert.
    np.testing.assert_allclose(float(loss_top1), 1.0, rtol=1e-6)
    assert float(loss_all) > 1.0 + 1e-2


def test_lb_loss_validates_topk():
    probs = jnp.full((4, 2), 0.5)
    ids = jnp.zeros((4, 2), jnp.int32)
    with pytest.raises(ValueError):
        router_load_balance_loss(probs, ids, 2, top_k=3)


def test_expert_shard_plan_matches_heap_lpt():
    """The chunked-exact LPT planner must reproduce the textbook heap
    LPT greedy (same assignment on distinct loads, same shard loads)."""
    rng = np.random.default_rng(4)
    E, S = 40, 8
    loads = rng.random(E)
    assignment, shard_loads = expert_shard_plan(loads, S)

    heap = [(0.0, s) for s in range(S)]
    heapq.heapify(heap)
    want = np.empty(E, np.int64)
    for e in np.argsort(-loads, kind="stable"):
        load, s = heapq.heappop(heap)
        want[e] = s
        heapq.heappush(heap, (load + loads[e], s))
    np.testing.assert_array_equal(assignment, want)
    ref_loads = np.zeros(S)
    np.add.at(ref_loads, want, loads)
    np.testing.assert_allclose(np.sort(shard_loads), np.sort(ref_loads),
                               rtol=1e-12)
    assert shard_loads.max() / loads.sum() * S < 1.35  # balanced-ish


def test_expert_shard_plan_validates():
    with pytest.raises(ValueError):
        expert_shard_plan(np.ones((2, 2)), 2)
    with pytest.raises(ValueError):
        expert_shard_plan(np.ones(4), 0)
