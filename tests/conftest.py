"""Test-suite bootstrap.

When `hypothesis` is unavailable (offline container), install the
deterministic fallback shim BEFORE collection so the property-test
modules import cleanly; with the real package installed this is a no-op.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))  # tests/ for `tests.*` imports
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    from helpers import hypothesis_compat

    hypothesis_compat.install()
