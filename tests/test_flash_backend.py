"""Unified attention-backend tests: the Pallas ``flash_interpret``
backend vs the dense reference, forward AND custom VJP, over packed
layouts produced by ``pack_stream`` / ``pack_padded_stream`` (ragged
segments, fully-padded tails, causal, sliding window, align > 1), plus
the end-to-end packed-batch loss gradient (acceptance criterion: no
dense-mask fallback anywhere in the grad path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.packing import pack_padded_stream, pack_stream
from repro.kernels.flash_attention import count_live_tiles
from repro.models.attention import attention, windowed_variant

FLASH = "flash_interpret"


def _packed_layout(rng, cap, *, align=1, padded_row=None):
    """Random ragged per-shard lengths -> (seg, pos) [1, cap] arrays with
    a padded tail (lengths never fill cap)."""
    lens = []
    budget = int(cap * 0.8)
    while budget > 4:
        l = int(rng.integers(3, max(4, budget // 2) + 1))
        l = min(l, budget)
        lens.append(l)
        budget -= l + (align - l % align) % align
    if padded_row is not None:
        n_rows = cap // padded_row
        lens = [rng.integers(3, padded_row + 1, size=n_rows).astype(np.int64)]
        seg, pos, _ = pack_padded_stream(lens, cap, padded_row)
    else:
        lens = [np.asarray(lens, np.int64)]
        seg, pos, _ = pack_stream(lens, cap, align=align)
    return jnp.asarray(seg), jnp.asarray(pos)


def _qkv(rng, T, H, Hkv, D):
    q = jnp.asarray(rng.normal(size=(1, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, T, Hkv, D)), jnp.float32)
    return q, k, v


def _assert_fwd_and_vjp_match(q, k, v, seg, pos, *, causal, window,
                              block=32, tol=2e-5):
    kw = dict(q_seg=seg, kv_seg=seg, q_pos=pos, kv_pos=pos, causal=causal,
              window=window, block_q=block, block_kv=block)
    ref = attention(q, k, v, backend="reference", **kw)
    fla = attention(q, k, v, backend=FLASH, **kw)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(fla),
                               atol=tol, rtol=tol)

    def loss(backend):
        def f(q, k, v):
            o = attention(q, k, v, backend=backend, **kw)
            return jnp.sum(jnp.sin(o))
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    for name, g_ref, g_fla in zip("qkv", loss("reference"), loss(FLASH)):
        np.testing.assert_allclose(
            np.asarray(g_ref), np.asarray(g_fla), atol=tol, rtol=tol,
            err_msg=f"d{name} (causal={causal}, window={window})")


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_property_flash_matches_reference_packed(seed):
    rng = np.random.default_rng(seed)
    T = 96
    seg, pos = _packed_layout(rng, T)
    q, k, v = _qkv(rng, T, 2, 2, 16)
    _assert_fwd_and_vjp_match(q, k, v, seg, pos, causal=True, window=None)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=3, deadline=None)
def test_property_flash_sliding_window_and_gqa(seed):
    rng = np.random.default_rng(seed)
    T = 96
    seg, pos = _packed_layout(rng, T)
    q, k, v = _qkv(rng, T, 4, 2, 16)
    _assert_fwd_and_vjp_match(q, k, v, seg, pos, causal=True, window=11)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=3, deadline=None)
def test_property_flash_bidirectional_aligned_starts(seed):
    """align > 1 (connector downsample) leaves seg-0 holes BETWEEN
    segments, not just a tail; non-causal covers the encoder stacks."""
    rng = np.random.default_rng(seed)
    T = 96
    seg, pos = _packed_layout(rng, T, align=4)
    q, k, v = _qkv(rng, T, 2, 2, 16)
    _assert_fwd_and_vjp_match(q, k, v, seg, pos, causal=False, window=None)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=3, deadline=None)
def test_property_flash_padded_rows(seed):
    """pack_padded_stream rows (audio phases, paper S8): fixed-stride
    example rows with per-row padding."""
    rng = np.random.default_rng(seed)
    T = 128
    seg, pos = _packed_layout(rng, T, padded_row=32)
    q, k, v = _qkv(rng, T, 2, 2, 16)
    _assert_fwd_and_vjp_match(q, k, v, seg, pos, causal=True, window=None)


def test_flash_fully_padded_stream_zero_grads():
    rng = np.random.default_rng(3)
    T = 64
    seg = jnp.zeros((1, T), jnp.int32)
    pos = jnp.zeros((1, T), jnp.int32)
    q, k, v = _qkv(rng, T, 2, 2, 16)

    def f(q, k, v):
        o = attention(q, k, v, q_seg=seg, kv_seg=seg, q_pos=pos, kv_pos=pos,
                      backend=FLASH, block_q=32, block_kv=32)
        return jnp.sum(o * o)

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert np.allclose(np.asarray(g), 0.0)


def test_windowed_flash_variant_matches_reference():
    """The window-chunked wrapper composes with the Pallas backend."""
    rng = np.random.default_rng(4)
    T, W = 96, 16
    lens = [np.asarray([13, 16, 9, 16, 11, 8], np.int64)]
    seg, pos, _ = pack_stream(lens, T)
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    q, _, _ = _qkv(rng, T, 2, 2, 16)
    assert windowed_variant(FLASH) == "windowed_flash_interpret"
    kw = dict(q_seg=seg, kv_seg=seg, q_pos=pos, kv_pos=pos, chunk_w=W,
              block_q=16, block_kv=16)
    ref = attention(q, q, q, backend="reference", **kw)
    win = attention(q, q, q, backend=windowed_variant(FLASH), **kw)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(win),
                               atol=2e-5, rtol=2e-5)


def test_packed_stream_skips_tiles_vs_dense_grid():
    """Acceptance: block-skipping visits strictly fewer KV tiles than the
    dense grid on a multi-segment packed stream."""
    cap = 512
    lens = [np.asarray([70, 90, 50, 64, 80, 60], np.int64)]
    seg, pos, _ = pack_stream(lens, cap)
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    visited, total = count_live_tiles(seg, seg, pos, pos, block_q=64,
                                      block_kv=64, causal=True, window=None)
    assert 0 < visited < total, (visited, total)


def test_loss_grad_through_flash_backend_matches_reference():
    """Acceptance: jax.grad of the packed-batch loss runs through the
    Pallas flash path (custom VJP, no dense-mask fallback) and matches
    the reference backend to fp32 tolerance."""
    from repro.configs import get_config
    from repro.core.orchestrator import MLLMGlobalOrchestrator
    from repro.data.synthetic import Example
    from repro.training.train_step import init_train_state, make_loss_fn

    cfg = get_config("olmo_1b").smoke()
    rng = np.random.default_rng(0)
    orch = MLLMGlobalOrchestrator(cfg, 2, vocab=cfg.vocab_size)
    examples = [[Example("t", int(l), 0, 0, ("text",)) for l in (40, 25, 33)]
                for _ in range(2)]
    caps = orch.default_capacities(examples, margin=2.0)
    batch_np, _ = orch.plan_and_pack(examples, caps, rng)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32), params)
    cfg = dataclasses.replace(cfg, dtype="float32")

    def grads(backend):
        loss_fn = make_loss_fn(cfg, attention_backend=backend)
        (_, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return metrics, g

    m_ref, g_ref = grads("reference")
    m_fla, g_fla = grads("flash_interpret")
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_fla["loss"]),
                               atol=1e-5, rtol=1e-5)
    flat_ref, _ = jax.tree_util.tree_flatten(g_ref)
    flat_fla, _ = jax.tree_util.tree_flatten(g_fla)
    for a, b in zip(flat_ref, flat_fla):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("backend", ["flash", "flash_interpret"])
def test_decode_backend_resolution(backend):
    from repro.configs import get_config

    cfg = dataclasses.replace(get_config("olmo_1b").smoke(),
                              attention_impl=backend)
    assert cfg.decode_backend == backend
    assert get_config("olmo_1b").smoke().decode_backend == "reference"


def test_decode_step_flash_matches_reference():
    """One serve step through the flash decode path equals the dense row."""
    from repro.configs import get_config
    from repro.serving.serve_step import init_cache, make_serve_step
    from repro.training.train_step import init_train_state

    cfg = get_config("olmo_1b").smoke()
    params, _ = init_train_state(cfg, jax.random.PRNGKey(1))
    B, S = 2, 64
    outs = {}
    for backend in ("reference", "flash_interpret"):
        cache = init_cache(cfg, B, S)
        serve = jax.jit(make_serve_step(cfg, attention_backend=backend))
        _, logits, _ = serve(params, jnp.ones((B, 1), jnp.int32), cache,
                             jnp.int32(3))
        outs[backend] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["reference"], outs["flash_interpret"],
                               atol=2e-2, rtol=2e-2)
