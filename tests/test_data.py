"""Data pipeline tests: synthetic incoherence, packing invariants,
prefetch + dispatcher overlap."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.packing import pack_padded_stream, pack_stream
from repro.data.pipeline import PrefetchingLoader
from repro.data.synthetic import (
    modality_ratio_stats,
    sample_examples,
)


def test_incoherence_exists():
    """Fig. 3 premise: modality ratios vary substantially across examples."""
    rng = np.random.default_rng(0)
    ex = sample_examples(rng, 3000)
    stats = modality_ratio_stats(ex, {"vision": 1, "audio": 2})
    for mod in ("vision", "audio"):
        assert stats[mod].std() > 0.1, f"{mod} ratio not incoherent"
        assert (stats[mod] == 0).any()  # some examples lack the modality


def test_asr_correlation_vs_sqa():
    """ASR text len correlates with audio; SQA does not (paper S3.1)."""
    rng = np.random.default_rng(1)
    ex = sample_examples(rng, 6000)
    asr = [(e.audio_meta, e.text_len) for e in ex if e.task == "asr"]
    sqa = [(e.audio_meta, e.text_len) for e in ex if e.task == "sqa"]
    c_asr = np.corrcoef(*zip(*asr))[0, 1]
    c_sqa = np.corrcoef(*zip(*sqa))[0, 1]
    assert c_asr > 0.8
    assert abs(c_sqa) < 0.25


def test_modality_filter():
    rng = np.random.default_rng(2)
    ex = sample_examples(rng, 200, modalities=("vision",))
    assert all(e.audio_meta == 0 for e in ex)


@given(st.lists(st.lists(st.integers(1, 20), min_size=0, max_size=5),
                min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_property_pack_stream_invariants(lens_py):
    lens = [np.array(x, np.int64) for x in lens_py]
    total = sum(int(l.sum()) for l in lens)
    cap = max(total, 1) + 8
    seg, pos, starts = pack_stream(lens, cap)
    # Token conservation; positions restart per segment.
    assert int((seg > 0).sum()) == total
    for i, l in enumerate(lens):
        for j, ln in enumerate(l):
            s0 = int(starts[i][j])
            assert (pos[i, s0 : s0 + ln] == np.arange(ln)).all()
            assert (seg[i, s0 : s0 + ln] == seg[i, s0]).all()


def test_pack_stream_alignment():
    lens = [np.array([3, 5])]
    seg, pos, starts = pack_stream(lens, 32, align=4)
    assert starts[0][0] == 0 and starts[0][1] == 4  # 3 rounded up to 4


def test_pack_padded_rows():
    lens = [np.array([3, 5])]
    seg, pos, starts = pack_padded_stream(lens, 16, 8)
    assert starts[0].tolist() == [0, 8]
    assert (seg[0, 3:8] == 0).all()  # padding inside row
    with pytest.raises(ValueError):
        pack_padded_stream([np.array([9])], 16, 8)  # len > row


def test_pack_overflow_raises():
    with pytest.raises(ValueError):
        pack_stream([np.array([10, 10])], 12)


def test_prefetching_loader_overlap():
    cfg = get_config("llava_next_mistral_7b").smoke()
    orch = MLLMGlobalOrchestrator(cfg, 2, vocab=64)
    rng = np.random.default_rng(0)
    probe = [sample_examples(rng, 3, modalities=("vision",)) for _ in range(2)]
    caps = orch.default_capacities(probe, margin=4.0)
    loader = PrefetchingLoader(orch, caps, examples_per_instance=3,
                               modalities=("vision",), depth=2)
    try:
        seen = 0
        for batch, report, ms in loader:
            assert "tokens" in batch and "llm_seg" in batch
            assert report.solve_ms >= 0
            seen += 1
            if seen >= 3:
                break
        stats = loader.overlap_stats()
        assert stats["batches"] >= 3
        assert stats["mean_solve_ms"] > 0
    finally:
        loader.close()
