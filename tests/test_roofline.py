"""Roofline extraction tests: HLO collective parsing + term analysis +
the named hardware presets."""
import pytest

from repro.launch.roofline import HW, HW_PRESETS, analyze, collective_bytes, get_hw

HLO_SAMPLE = """
HloModule jit_step

fused_computation {
  ...
}

ENTRY main {
  %p0 = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[256,4096]{1,0} all-gather(bf16[16,4096]{1,0} %p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %rs = bf16[8,128]{1,0} reduce-scatter(bf16[128,128]{1,0} %y), dimensions={0}
  %a2a = bf16[32,64]{1,0} all-to-all(bf16[32,64]{1,0} %z), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(f32[4,4]{1,0} %w), source_target_pairs={{0,1}}
  ROOT %r = (bf16[2,2]{1,0}) tuple(%q)
}
"""


def test_collective_bytes_parses_each_kind():
    b = collective_bytes(HLO_SAMPLE)
    assert b["all-gather"] == 256 * 4096 * 2
    assert b["all-reduce"] == 1024 * 4
    assert b["reduce-scatter"] == 8 * 128 * 2
    assert b["all-to-all"] == 32 * 64 * 2
    assert b["collective-permute"] == 4 * 4 * 4
    assert b["total"] == sum(
        b[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute", "ragged-all-to-all")
    )


def test_collective_bytes_ragged_not_double_counted():
    txt = "%r = bf16[64,8]{1,0} ragged-all-to-all(bf16[64,8]{1,0} %x, s32[4]{0} %o)"
    b = collective_bytes(txt)
    assert b["ragged-all-to-all"] == 64 * 8 * 2
    assert b["all-to-all"] == 0


def test_collective_bytes_ignores_plain_ops():
    txt = "%d = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)"
    assert collective_bytes(txt)["total"] == 0


def test_analyze_terms_and_dominance():
    hw = HW(peak_flops=100.0, hbm_bw=10.0, ici_bw=1.0, chips=2)
    rep = analyze(
        arch="x", shape="y", mesh_name="m",
        cost={"flops": 1000.0, "bytes accessed": 50.0},
        hlo_text="%ar = f32[25]{0} all-reduce(f32[25]{0} %x)",
        memory={}, model_flops_global=800.0, hw=hw,
    )
    assert rep.compute_s == pytest.approx(10.0)
    assert rep.memory_s == pytest.approx(5.0)
    assert rep.collective_s == pytest.approx(100.0)
    assert rep.dominant == "collective"
    assert rep.useful_ratio == pytest.approx(800.0 / 2000.0)


def test_analyze_zero_flops_safe():
    rep = analyze(arch="x", shape="y", mesh_name="m",
                  cost={"flops": 0.0, "bytes accessed": 0.0}, hlo_text="",
                  memory={}, model_flops_global=1.0)
    assert rep.useful_ratio == 0.0


def test_get_hw_presets(monkeypatch):
    monkeypatch.delenv("REPRO_HW", raising=False)
    assert get_hw().name == "v5e"  # historical default
    for name, hw in HW_PRESETS.items():
        got = get_hw(name)
        assert got.name == name and got.peak_flops == hw.peak_flops
    # chips override rides along without mutating the preset.
    assert get_hw("v4", chips=64).chips == 64
    assert get_hw("v4").chips == HW_PRESETS["v4"].chips  # preset untouched


def test_get_hw_env_and_errors(monkeypatch):
    monkeypatch.setenv("REPRO_HW", "v5p")
    assert get_hw().name == "v5p"
    # Explicit argument beats the env var.
    assert get_hw("v6e").name == "v6e"
    with pytest.raises(ValueError):
        get_hw("tpu9000")
