"""Pipeline-parallel 1F1B schedule + encoder bubble-fill tests.

Covers the planning stack end to end (docs/pipeline.md): stage
partitioning, LPT microbatch split, the event-driven 1F1B simulator's
dependency/bubble invariants, EDF + cross-iteration encoder fill
bounds, the exact per-rank closure identity the waterfall relies on,
the staged-config headline gates (fill fraction, MFU uplift), and the
observability fan-out (waterfall components, ledger series, Perfetto
stage lanes, pp mesh/sharding).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import (encoder_cost_model, llm_cost_model,
                                   phase_flops_per_unit)
from repro.core.dispatcher import BatchPostBalancingDispatcher
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.core.pipeline import (BWD_RATIO, _idle_windows, _simulate_1f1b,
                                 plan_pipeline, split_microbatches)
from repro.data.synthetic import TaskMix, sample_examples
from repro.launch.mesh import (dp_shards_of, make_production_mesh,
                               pp_stages_of)
from repro.obs.decompose import GapWaterfall
from repro.obs.ledger import StepLedger
from repro.obs.registry import MetricsRegistry
from repro.obs.timeline import build_timeline
from repro.sharding.specs import stage_partition

EPS = 1e-9


def _cfg():
    return get_config("mllm_84b")


def _plan(d=4, per=64, pp=4, m=16, seed=0, bubble_fill=True, enc_scale=1.0):
    """A staged plan over synthetic post-balanced lengths."""
    cfg = _cfg()
    rng = np.random.default_rng(seed)
    model = llm_cost_model(cfg)
    dest = [rng.integers(200, 2000, size=per).astype(np.float64)
            for _ in range(d)]
    # Per-rank encoder cost vectors in their OWN units, roughly balanced
    # (the dispatchers have already run).
    enc = {e.name: enc_scale * rng.uniform(0.95, 1.05, size=d)
           * 4_000_000.0 for e in cfg.encoders}
    return plan_pipeline(cfg, model, dest, enc, pp=pp, n_micro=m,
                         bubble_fill=bubble_fill)


# ----------------------------------------------------------------------
# stage_partition
# ----------------------------------------------------------------------
def test_stage_partition_uniform():
    assert stage_partition(80, 4) == (20, 20, 20, 20)
    # Uneven: extra layers land on the EARLY stages.
    assert stage_partition(10, 4) == (3, 3, 2, 2)
    assert stage_partition(7, 1) == (7,)
    assert sum(stage_partition(45, 6)) == 45


def test_stage_partition_weighted_beats_uniform():
    # Heavy head: a cost-aware split must not exceed the uniform split's
    # max stage cost, and here it must strictly improve.
    costs = np.array([8.0, 8.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0])
    part = stage_partition(8, 4, costs)
    assert sum(part) == 8 and len(part) == 4 and min(part) >= 1
    bounds = np.cumsum((0,) + part)
    maxc = max(costs[a:b].sum() for a, b in zip(bounds[:-1], bounds[1:]))
    uni = max(costs[i:i + 2].sum() for i in range(0, 8, 2))
    assert maxc <= uni
    assert maxc == 8.0  # optimal: isolate each heavy layer


def test_stage_partition_errors():
    with pytest.raises(ValueError):
        stage_partition(4, 0)
    with pytest.raises(ValueError):
        stage_partition(4, 5)
    with pytest.raises(ValueError):
        stage_partition(4, 2, np.ones(3))


# ----------------------------------------------------------------------
# split_microbatches
# ----------------------------------------------------------------------
def test_split_microbatches_partitions_everything():
    model = llm_cost_model(_cfg())
    lengths = np.array([100.0, 900.0, 300.0, 500.0, 700.0, 110.0, 250.0])
    assign, costs = split_microbatches(lengths, 3, model)
    assert assign.shape == (7,) and set(assign) <= {0, 1, 2}
    w = model.alpha * lengths + model.beta * lengths**2
    assert np.isclose(costs.sum(), w.sum())
    for i in range(3):
        assert np.isclose(costs[i], w[assign == i].sum())


def test_split_microbatches_balances():
    model = llm_cost_model(_cfg())
    rng = np.random.default_rng(3)
    lengths = rng.integers(100, 2000, size=64).astype(np.float64)
    _, costs = split_microbatches(lengths, 8, model)
    w = model.alpha * lengths + model.beta * lengths**2
    # LPT guarantee: max bin <= mean + max single item.
    assert costs.max() <= w.sum() / 8 + w.max() + EPS
    _, empty = split_microbatches(np.array([]), 4, model)
    assert empty.sum() == 0


# ----------------------------------------------------------------------
# 1F1B simulator
# ----------------------------------------------------------------------
def _check_dependencies(fwd, bwd, f_s, f_e, b_s, b_e):
    pp, m = fwd.shape
    for s in range(pp):
        for i in range(m):
            assert np.isclose(f_e[s, i] - f_s[s, i], fwd[s, i])
            assert np.isclose(b_e[s, i] - b_s[s, i], bwd[s, i])
            if s > 0:
                assert f_s[s, i] >= f_e[s - 1, i] - EPS
            if s < pp - 1:
                assert b_s[s, i] >= b_e[s + 1, i] - EPS
            assert b_s[s, i] >= f_e[s, i] - EPS
        # No two ops overlap on one stage's device.
        spans = sorted(list(zip(f_s[s], f_e[s])) + list(zip(b_s[s], b_e[s])))
        for (a0, b0), (a1, _) in zip(spans, spans[1:]):
            assert a1 >= b0 - EPS


def test_1f1b_dependencies_random_costs():
    rng = np.random.default_rng(7)
    fwd = rng.uniform(1.0, 3.0, size=(4, 8))
    bwd = 2.0 * fwd
    f_s, f_e, b_s, b_e, makespan = _simulate_1f1b(fwd, bwd)
    _check_dependencies(fwd, bwd, f_s, f_e, b_s, b_e)
    assert makespan >= fwd.sum(axis=1).max() + bwd.sum(axis=1).max() - EPS
    assert np.isclose(makespan, max(f_e.max(), b_e.max()))


def test_1f1b_uniform_bubble_identity():
    # Equal stage times f, b: total bubble = pp*(pp-1)*(f+b) exactly.
    pp, m, f, b = 4, 8, 1.0, 2.0
    fwd = np.full((pp, m), f)
    bwd = np.full((pp, m), b)
    f_s, f_e, b_s, b_e, makespan = _simulate_1f1b(fwd, bwd)
    assert np.isclose(makespan, (m + pp - 1) * (f + b))
    busy = fwd.sum() + bwd.sum()
    assert np.isclose(pp * makespan - busy, pp * (pp - 1) * (f + b))
    windows = _idle_windows(f_s, f_e, b_s, b_e, makespan)
    idle = [sum(w1 - w0 for w0, w1 in ws) for ws in windows]
    assert np.isclose(sum(idle), pp * makespan - busy)
    # Stage 0 never waits in the uniform case; last stage idles most at
    # the start (deepest warm-up), plus its cool-down mirror.
    assert idle[0] <= idle[-1] + EPS


# ----------------------------------------------------------------------
# bubble fill: dependency bounds on the emitted events
# ----------------------------------------------------------------------
def test_fill_respects_dependency_bounds():
    plan = _plan(d=2, per=48, pp=4, m=8, seed=1)
    ev = plan.events
    assert ev, "critical-rank events must be kept by default"
    f0_start = {e.micro: e.start for e in ev if e.kind == "F" and e.stage == 0}
    b0_end = {e.micro: e.end for e in ev if e.kind == "B" and e.stage == 0}
    kinds = {e.kind for e in ev}
    assert kinds >= {"F", "B"}
    for e in ev:
        assert e.end >= e.start - EPS
        if e.kind == "encF" and e.micro >= 0:
            # Encoder forward for micro i must finish before F(0, i).
            assert e.end <= f0_start[e.micro] + 1e-6
        if e.kind == "encB" and e.micro >= 0:
            # Encoder backward for micro i releases at end of B(0, i).
            assert e.start >= b0_end[e.micro] - 1e-6
    # Per stage, all spans (LLM + encoder fill) are mutually disjoint.
    for s in range(plan.pp):
        spans = sorted((e.start, e.end) for e in ev if e.stage == s)
        for (a0, b0), (a1, _) in zip(spans, spans[1:]):
            assert a1 >= b0 - 1e-6


def test_closure_identity_exact():
    # useful + sum_s idle_s == pp * rank_total, per rank, by construction
    # -- this is what makes the waterfall's pipeline algebra close.
    for fill in (True, False):
        plan = _plan(d=3, per=32, pp=4, m=8, seed=2, bubble_fill=fill)
        lhs = plan.stage_busy.sum(axis=1) + plan.stage_idle.sum(axis=1)
        assert np.allclose(lhs, plan.pp * plan.rank_total)
        assert np.allclose(plan.stage_busy.sum(axis=1), plan.useful)
        assert (plan.stage_idle >= -1e-6).all()


def test_fill_conservation_and_uplift():
    fill = _plan(d=4, per=64, pp=4, m=16, seed=3)
    nofill = _plan(d=4, per=64, pp=4, m=16, seed=3, bubble_fill=False)
    # Identical work on both sides of the comparison.
    assert np.allclose(fill.useful, nofill.useful)
    assert np.allclose(fill.makespan_1f1b, nofill.makespan_1f1b)
    # No-fill runs the whole encoder as prologue+epilogue.
    assert np.allclose(nofill.rank_total, nofill.rank_total_nofill)
    assert nofill.filled.sum() == 0.0
    # Fill can only help, and never places more than the bubble holds.
    assert (fill.rank_total <= nofill.rank_total + 1e-6).all()
    assert fill.filled.sum() <= fill.bubble_total.sum() + 1e-6
    assert 0.0 <= fill.fill_fraction <= 1.0 + 1e-9
    assert fill.mfu_uplift >= 0.0


def test_staged_config_headline_gates():
    """The benchmark's acceptance gates, on the library entrypoint."""
    cfg = _cfg()
    rng = np.random.default_rng(0)
    model = llm_cost_model(cfg)
    d = 4
    dest = [rng.integers(200, 2000, size=64).astype(np.float64)
            for _ in range(d)]
    # Realistic encoder load: per-rank encoder cost from its own f(S).
    enc = {}
    for e in cfg.encoders:
        em = encoder_cost_model(e)
        ls = rng.integers(256, 1500, size=(d, 48)).astype(np.float64)
        enc[e.name] = (em.alpha * ls + em.beta * ls**2).sum(axis=1)
    plan = plan_pipeline(cfg, model, dest, enc, pp=4, n_micro=16)
    assert plan.fill_fraction >= 0.5
    assert plan.mfu_uplift > 0.0
    assert plan.projected_mfu > plan.projected_mfu_nofill
    assert plan.partition == (20, 20, 20, 20)
    d_ = plan.to_dict()
    assert d_["fill_fraction"] == plan.fill_fraction
    assert d_["pp"] == 4 and d_["n_micro"] == 16


def test_plan_pipeline_validation():
    cfg = _cfg()
    model = llm_cost_model(cfg)
    with pytest.raises(ValueError):
        plan_pipeline(cfg, model, [np.ones(4)], {}, pp=1)
    # No encoders: pure 1F1B, zero fill, uplift 0.
    plan = plan_pipeline(cfg, model, [np.full(8, 500.0)], {}, pp=2, n_micro=4)
    assert plan.filled.sum() == 0.0
    assert np.isclose(plan.mfu_uplift, 0.0)
    # n_micro defaults to 2*pp.
    plan = plan_pipeline(cfg, model, [np.full(8, 500.0)], pp=4)
    assert plan.n_micro == 8


# ----------------------------------------------------------------------
# cost units: encoder costs rescaled onto the LLM unit
# ----------------------------------------------------------------------
def test_phase_flops_per_unit():
    cfg = _cfg()
    flops = phase_flops_per_unit(cfg)
    assert set(flops) == {"llm"} | {e.name for e in cfg.encoders}
    assert all(v > 0 for v in flops.values())
    # The 84B backbone dwarfs the encoders per cost unit.
    assert flops["llm"] > flops["vision"]
    assert flops["llm"] > flops["audio"]


# ----------------------------------------------------------------------
# dispatcher: per-stage post-balanced loads
# ----------------------------------------------------------------------
def test_dispatcher_stage_costs():
    cfg = _cfg()
    model = llm_cost_model(cfg)
    frac = np.asarray(stage_partition(cfg.n_layers, 4), np.float64)
    frac /= frac.sum()
    rng = np.random.default_rng(5)
    lengths = [rng.integers(100, 2000, size=32) for _ in range(4)]
    disp = BatchPostBalancingDispatcher(4, model, stage_fractions=frac)
    plan = disp.plan(lengths)
    assert plan.stage_costs.shape == (4, 4)
    # Stage loads decompose the per-rank cost exactly.
    assert np.allclose(plan.stage_costs.sum(axis=0), plan.costs)
    assert np.allclose(plan.stage_costs, np.outer(frac, plan.costs))
    # Without stage_fractions the matrix is empty (pp = 1 runs).
    plain = BatchPostBalancingDispatcher(4, model).plan(lengths)
    assert plain.stage_costs.size == 0


# ----------------------------------------------------------------------
# orchestrator integration (plan-only)
# ----------------------------------------------------------------------
def test_orchestrator_pipeline_mode():
    cfg = _cfg()
    d = 4
    rng = np.random.default_rng(11)
    examples = [sample_examples(rng, 16, TaskMix(), ("vision", "audio"))
                for _ in range(d)]
    orch = MLLMGlobalOrchestrator(cfg, d, pp=4, microbatches=8, vocab=512)
    assert orch.stage_fractions is not None
    plans = orch.plan_phases(examples)
    plan = plans.pipeline
    assert plan is not None and plan.pp == 4 and plan.d == d
    assert plan.n_micro == 8
    assert "pipeline" in plans.phase_solve_ms
    # The LLM dispatcher carries the per-stage decomposition too.
    assert plans.llm_plan.stage_costs.shape == (4, d)
    # pp=1 (default config) keeps the legacy path: no pipeline plan.
    plain = MLLMGlobalOrchestrator(cfg, d, vocab=512).plan_phases(examples)
    assert plain.pipeline is None


def test_orchestrator_staged_config_knobs():
    from repro.configs.mllm_84b import STAGED_CONFIG
    assert STAGED_CONFIG.pp_stages == 4
    assert STAGED_CONFIG.pp_microbatches == 16
    assert STAGED_CONFIG.pp_bubble_fill
    assert _cfg().pp_stages == 1  # default config unchanged
    d = 2
    rng = np.random.default_rng(13)
    examples = [sample_examples(rng, 8, TaskMix(), ("vision",))
                for _ in range(d)]
    # Config knobs flow through when the ctor args are omitted.
    orch = MLLMGlobalOrchestrator(STAGED_CONFIG, d, vocab=512)
    assert orch.pp == 4 and orch.microbatches == 16
    plans = orch.plan_phases(examples)
    assert plans.pipeline is not None and plans.pipeline.n_micro == 16


# ----------------------------------------------------------------------
# mesh + sharding
# ----------------------------------------------------------------------
def test_mesh_pp_validation():
    with pytest.raises(ValueError):
        make_production_mesh(pp=3)  # must divide the 16-wide data axis
    with pytest.raises(ValueError):
        make_production_mesh(pp=0)


def test_mesh_pp_axes_abstract():
    from jax.sharding import AbstractMesh
    mesh = AbstractMesh((("pp", 4), ("data", 4), ("model", 16)))
    assert pp_stages_of(mesh) == 4
    assert dp_shards_of(mesh) == 4  # pp is NOT a DP axis
    flat = AbstractMesh((("data", 16), ("model", 16)))
    assert pp_stages_of(flat) == 1
    assert dp_shards_of(flat) == 16


def test_param_specs_pp_shards_layer_dim():
    import jax
    from jax.sharding import AbstractMesh, PartitionSpec as P

    from repro.models.model import init_params
    from repro.sharding.specs import param_specs

    cfg = _cfg().smoke()  # n_layers=2 -> divisible by pp=2
    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    mesh = AbstractMesh((("pp", 2), ("data", 2), ("model", 2)))
    specs = param_specs(cfg, params_shape, mesh)

    def leaves(tree, stacked=False):
        if isinstance(tree, dict):
            for k, v in tree.items():
                yield from leaves(v, stacked or k in ("layers", "enc_layers"))
        else:
            yield stacked, tree

    saw_pp = False
    for stacked, spec in leaves(specs):
        parts = tuple(spec)
        if stacked and parts and parts[0] == "pp":
            saw_pp = True
        if not stacked:
            assert "pp" not in parts  # only stacked layer dims shard on pp
    assert saw_pp
    # pp=1 mesh: unchanged legacy specs (no pp axis anywhere).
    flat = AbstractMesh((("data", 2), ("model", 2)))
    for _, spec in leaves(param_specs(cfg, params_shape, flat)):
        assert "pp" not in tuple(spec)
    assert isinstance(P(), P)  # silence unused-import pedantry


# ----------------------------------------------------------------------
# observability fan-out
# ----------------------------------------------------------------------
def test_waterfall_pipeline_mode_closure():
    # Pure-LLM pipeline (no encoder fill): the 1F1B bubbles are a large,
    # honest gap, so relative closure is a meaningful check -- the
    # near-zero-gap regime is floored by GAP_FLOOR instead.
    plan = _plan(d=4, per=64, pp=4, m=8, seed=4, enc_scale=0.0)
    wf = GapWaterfall(registry=MetricsRegistry())
    crit = float(plan.rank_total.max())
    true_scale = 0.004  # ms per cost unit
    rng = np.random.default_rng(6)
    last = None
    for step in range(12):
        step_ms = crit * true_scale * (1.0 + rng.normal(0, 0.005)) + 2.0
        last = wf.observe(step, step_ms=step_ms, exposed_ms=2.0,
                          pipeline=plan)
    comps = last.components
    assert last.gap > 0.2  # bubbles dominate: the gap is real
    for k in range(plan.pp):
        assert f"pipeline_bubble_s{k}" in comps
        assert comps[f"pipeline_bubble_s{k}"] >= -1e-9
    assert "imbalance_llm" in comps and comps["imbalance_llm"] >= -1e-9
    # Out-of-sample closure: the named components explain the gap.
    assert wf.closure()["max_closure_err"] <= 0.05
    # The plan rides along on the report automatically.
    rep = type("R", (), {"phase_costs": {}, "exposed_ms": 0.0,
                         "pipeline": plan})()
    w2 = GapWaterfall(registry=MetricsRegistry())
    out = w2.observe(0, report=rep, step_ms=crit * true_scale)
    assert "pipeline_bubble_s0" in out.components


def test_ledger_record_pipeline():
    plan = _plan(d=2, per=32, pp=4, m=8, seed=8)
    ledger = StepLedger(d=2, registry=MetricsRegistry())
    ledger.record_pipeline(0, plan)
    ledger.record_pipeline(1, plan)
    for s in range(plan.pp):
        series = ledger.series[f"pipeline_bubble_s{s}"]
        assert len(series) == 2
        assert 0.0 <= series[0][1] <= 1.0
    assert ledger.series["pipeline_fill_fraction"][0][1] == pytest.approx(
        plan.fill_fraction)
    assert ledger.series["pipeline_mfu_uplift"][0][1] == pytest.approx(
        plan.mfu_uplift)


def test_timeline_pipeline_lanes():
    plan = _plan(d=2, per=32, pp=4, m=8, seed=9)
    doc = build_timeline(pipeline=plan)
    ev = doc["traceEvents"]
    lanes = [e for e in ev if e.get("ph") == "M"
             and e["name"] == "thread_name" and e["pid"] == 7000]
    assert len(lanes) == plan.pp
    assert lanes[0]["args"]["name"].startswith("stage0 (")
    spans = [e for e in ev if e.get("ph") == "X" and e["pid"] == 7000]
    assert spans and all(e["dur"] >= 0 for e in spans)
    cats = {e["cat"] for e in spans}
    assert cats >= {"fwd", "bwd"}
    assert "enc_fill" in cats  # encoder chunks render in the bubbles
    procs = [e for e in ev if e.get("ph") == "M" and e["name"] == "process_name"
             and e["pid"] == 7000]
    assert procs[0]["args"]["name"] == f"pipeline:rank{plan.critical_rank}"
