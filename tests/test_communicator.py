"""Communicator tests.

Plan-construction tests run in-process (host-only numpy); the actual
multi-device exchange (ragged all-to-all under shard_map on 8 fake host
devices) runs in a subprocess because the device count must be fixed
before JAX initializes.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.balancing import post_balance
from repro.core.communicator import build_comm_plan, plan_to_device
from repro.core.cost_model import CostModel

REPO = Path(__file__).resolve().parent.parent


def _plan(seed=0, d=4):
    rng = np.random.default_rng(seed)
    lens = [rng.integers(1, 30, size=rng.integers(1, 5)) for _ in range(d)]
    pi = post_balance(lens, d, CostModel())
    cap_in = int(max(l.sum() for l in lens))
    cap_out = int(max(l.sum() for l in pi.dest_lengths()) or 1)
    return pi, build_comm_plan(pi, cap_in, cap_out)


def test_plan_shapes_and_conservation():
    pi, plan = _plan()
    d = plan.d
    assert plan.send_sizes.shape == (d, d)
    # Token conservation: everything sent is received.
    assert plan.send_sizes.sum() == pi.lengths.sum()
    assert (plan.recv_sizes.T == plan.send_sizes).all()
    # Per-destination received tokens == destination batch tokens.
    dest_tokens = np.array([l.sum() for l in pi.dest_lengths()])
    assert (plan.recv_sizes.sum(axis=1) == dest_tokens).all()
    # post_mask count matches.
    assert plan.post_mask.sum() == pi.lengths.sum()


def test_plan_offsets_are_contiguous():
    _, plan = _plan(seed=1)
    d = plan.d
    for s in range(d):
        off = 0
        for t in range(d):
            assert plan.input_offsets[s, t] == off
            off += plan.send_sizes[s, t]
    for t in range(d):
        off = 0
        for s in range(d):
            assert plan.output_offsets[s, t] == off
            off += plan.send_sizes[s, t]


def test_plan_rejects_small_capacity():
    rng = np.random.default_rng(2)
    lens = [rng.integers(10, 30, size=4) for _ in range(4)]
    pi = post_balance(lens, 4, CostModel())
    with pytest.raises(ValueError):
        build_comm_plan(pi, 8, 10_000)
    with pytest.raises(ValueError):
        build_comm_plan(pi, 10_000, 8)


def test_comm_bytes_accounting():
    _, plan = _plan(seed=3)
    b = plan.comm_bytes(bytes_per_token=2)
    assert b["ragged"] <= b["a2a_dense"] <= b["allgather"]
    # Eq. 3 vs 4 structure: allgather is (d-1) * cap * d tokens.
    assert b["allgather"] == plan.d * (plan.d - 1) * plan.cap_in * 2


def test_plan_to_device_keys():
    _, plan = _plan(seed=4)
    arrays = plan_to_device(plan)
    assert set(arrays) == {
        "pre_gather", "input_offsets", "send_sizes", "output_offsets",
        "recv_sizes", "post_gather", "post_mask", "global_gather",
        "pre_gather_dense", "post_gather_dense",
    }
    d = plan.d
    assert arrays["pre_gather_dense"].shape == (d, d * plan.chunk_cap)


@pytest.mark.slow
def test_multidevice_exchange_subprocess():
    """End-to-end 8-device ragged-all-to-all vs numpy oracle."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    res = subprocess.run(
        [sys.executable, str(REPO / "tests/helpers/communicator_check.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
