"""Paged KV pool tests: allocator invariants (never double-books a
block across alloc/free/defrag) and paged-decode exactness (block-table
gather decode == dense-cache decode, bitwise)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, paged_cache_specs
from repro.models.model import init_params
from repro.serving.engine import NULL_BLOCK, PagedKVPool, PoolExhausted
from repro.serving.serve_step import init_cache, make_prefill_step, make_serve_step
from repro.utils import zeros_like_specs

# Acceptance matrix: plain dense, GQA (distinct kv heads + qk_norm), and
# sliding-window attention (ring cache).
PARITY_ARCHS = ["olmo_1b", "qwen3_8b", "h2o_danube_3_4b"]


def _smoke(arch):
    return get_config(arch).smoke()


# ----------------------------------------------------------------------
# Allocator invariants.
# ----------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 5), st.integers(1, 4)),
        min_size=1, max_size=40,
    ),
    num_blocks=st.integers(4, 24),
)
def test_pool_never_double_books(ops, num_blocks):
    """Random alloc/free/defrag interleavings: free + allocated always
    partition the usable id range, and no block has two owners."""
    cfg = _smoke("olmo_1b")
    pool = PagedKVPool(cfg, num_blocks=num_blocks, block_size=4)
    for op, sid, n in ops:
        if op == 0:
            try:
                got = pool.alloc(sid, n)
                assert len(got) == n
                assert NULL_BLOCK not in got
            except PoolExhausted:
                assert pool.num_free < n
        elif op == 1:
            freed = pool.free(sid)
            assert sid not in pool.owners()
            assert all(b != NULL_BLOCK for b in freed)
        else:
            mapping = pool.defrag()
            # After compaction the allocated ids are exactly 1..used.
            assert sorted(mapping.values()) == list(range(1, pool.num_used + 1))
        pool.check()
        assert pool.num_free + pool.num_used == pool.usable_blocks


def test_alloc_exhaustion_and_ensure():
    cfg = _smoke("olmo_1b")
    pool = PagedKVPool(cfg, num_blocks=5, block_size=8)
    pool.alloc(0, 3)
    with pytest.raises(PoolExhausted):
        pool.alloc(1, 2)
    assert pool.table(0) == [1, 2, 3]  # lowest ids first, deterministic
    assert pool.ensure(0, 24) == []  # 3 blocks already cover 24 slots
    assert pool.ensure(0, 25) == [4]
    assert pool.blocks_short(0, 32) == 0
    pool.free(0)
    assert pool.num_free == pool.usable_blocks
    pool.check()


def test_table_array_pads_with_null():
    cfg = _smoke("olmo_1b")
    pool = PagedKVPool(cfg, num_blocks=9, block_size=8)
    pool.alloc(7, 2)
    pool.alloc(9, 3)
    bt = pool.table_array([9, 7], width=4)
    assert bt.shape == (2, 4)
    assert bt[0].tolist() == pool.table(9) + [NULL_BLOCK]
    assert bt[1].tolist() == pool.table(7) + [NULL_BLOCK, NULL_BLOCK]
    with pytest.raises(ValueError):
        pool.table_array([9], width=2)


def test_defrag_moves_content_and_rewrites_tables():
    """Block content must follow the compaction mapping and freed slots
    must come back as null (zero) content."""
    cfg = _smoke("olmo_1b")
    pool = PagedKVPool(cfg, num_blocks=10, block_size=4)
    pool.alloc(0, 2)
    pool.alloc(1, 2)
    pool.alloc(2, 2)
    # Stamp each allocated block's kv_pos with its owner-specific value.
    marks = {}
    for sid in (0, 1, 2):
        for b in pool.table(sid):
            pool.cache["kv_pos"] = pool.cache["kv_pos"].at[b].set(100 + b)
            marks[b] = 100 + b
    pool.free(1)  # holes at the freed ids
    before = {sid: list(pool.table(sid)) for sid in (0, 2)}
    mapping = pool.defrag()
    pool.check()
    assert sorted(mapping.values()) == [1, 2, 3, 4]
    for sid in (0, 2):
        assert pool.table(sid) == [mapping[b] for b in before[sid]]
        for old, new in zip(before[sid], pool.table(sid)):
            np.testing.assert_array_equal(
                np.asarray(pool.cache["kv_pos"][new]), marks[old])
    # Free ids are one contiguous high range with zeroed seg content.
    free = sorted(set(range(1, pool.num_blocks)) - set(mapping.values()))
    assert free == list(range(5, 10))
    np.testing.assert_array_equal(
        np.asarray(pool.cache["kv_seg"][np.array(free)]), 0)


def test_free_zeroes_segment_marks():
    """A recycled block must not leak stale kv_seg into its next owner
    (stale k/v is masked to an exact zero; stale seg would unmask it)."""
    cfg = _smoke("olmo_1b")
    pool = PagedKVPool(cfg, num_blocks=4, block_size=4)
    pool.alloc(0, 2)
    pool.cache["kv_seg"] = pool.cache["kv_seg"].at[np.array(pool.table(0))].set(1)
    freed = pool.free(0)
    np.testing.assert_array_equal(
        np.asarray(pool.cache["kv_seg"][np.array(freed)]), 0)


# ----------------------------------------------------------------------
# Paged decode exactness.
# ----------------------------------------------------------------------
def _shuffled_pool(cfg, B, W, bs, seed=0):
    """Pool + deliberately shuffled (non-contiguous) block tables."""
    pool = zeros_like_specs(paged_cache_specs(cfg, 1 + B * W, bs))
    rng = np.random.default_rng(seed)
    ids = rng.permutation(np.arange(1, 1 + B * W)).reshape(B, W)
    return pool, jnp.asarray(ids, jnp.int32)


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_paged_decode_matches_dense_bitwise(arch):
    """Gather-based block-table decode == dense-cache decode, bitwise,
    including past the sliding-window ring wrap."""
    cfg = _smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, bs = 3, 16
    S = 64  # == the smoke sliding window for h2o_danube
    W = S // bs
    cache = init_cache(cfg, B, S)
    pool, bt = _shuffled_pool(cfg, B, W, bs)
    serve = jax.jit(make_serve_step(cfg))
    pserve = jax.jit(make_serve_step(cfg, paged=True))
    tok_d = tok_p = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 1,
                                       cfg.vocab_size)
    n_steps = 80 if cfg.sliding_window else 40  # wrap the ring if windowed
    for t in range(n_steps):
        tok_d, ld, cache = serve(params, tok_d, cache, jnp.int32(t))
        tok_p, lp, pool = pserve(params, tok_p, pool, bt,
                                 jnp.full((B,), t, jnp.int32))
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp),
                                      err_msg=f"{arch} step {t}")
    # The gathered pool cache must equal the dense cache, bitwise.
    for name in ("k", "v"):
        gathered = np.asarray(pool[name])[:, np.asarray(bt)].reshape(
            np.asarray(cache[name]).shape)
        np.testing.assert_array_equal(gathered, np.asarray(cache[name]))
    for name in ("kv_pos", "kv_seg"):
        gathered = np.asarray(pool[name])[np.asarray(bt)].reshape(B, S)
        np.testing.assert_array_equal(gathered, np.asarray(cache[name]))


def test_paged_inactive_rows_drop_writes():
    """Rows with t < 0 must leave the pool untouched and not disturb
    active rows."""
    cfg = _smoke("olmo_1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, bs, W = 2, 8, 2
    pool, bt = _shuffled_pool(cfg, B, W, bs)
    pserve = jax.jit(make_serve_step(cfg, paged=True))
    tok = jnp.ones((B, 1), jnp.int32)
    # Row 1 inactive: t = -1.
    _, logits, pool2 = pserve(params, tok, pool, bt,
                              jnp.array([0, -1], jnp.int32))
    seg = np.asarray(pool2["kv_seg"])
    assert seg[np.asarray(bt)[0, 0], 0] == 1  # row 0 wrote slot 0
    np.testing.assert_array_equal(seg[np.asarray(bt)[1]], 0)  # row 1 did not
    assert bool(np.isfinite(np.asarray(logits)).all())


def test_prefill_scan_matches_tokenwise_serve():
    """The chunked prefill scan == feeding the prompt token by token
    through the paged serve step (same pool, same tables)."""
    cfg = _smoke("qwen3_8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, bs, W = 2, 16, 3
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, 10), 1,
                                 cfg.vocab_size)
    lengths = jnp.array([10, 6], jnp.int32)
    pool_a, bt = _shuffled_pool(cfg, B, W, bs)
    prefill = jax.jit(make_prefill_step(cfg))
    first_a, last_a, pool_a = prefill(params, prompts, lengths, pool_a, bt)

    pool_b = zeros_like_specs(paged_cache_specs(cfg, 1 + B * W, bs))
    pserve = jax.jit(make_serve_step(cfg, paged=True))
    last_b = np.zeros(np.asarray(last_a).shape, np.float32)
    for p in range(10):
        t = jnp.where(p < lengths, p, -1).astype(jnp.int32)
        _, logits, pool_b = pserve(params, prompts[:, p : p + 1], pool_b, bt, t)
        sel = (p == np.asarray(lengths) - 1)
        last_b[sel] = np.asarray(logits)[sel]
    np.testing.assert_array_equal(np.asarray(last_a), last_b)
    for name in ("k", "v", "kv_pos", "kv_seg"):
        np.testing.assert_array_equal(np.asarray(pool_a[name]),
                                      np.asarray(pool_b[name]))


def test_defrag_mid_decode_stays_exact():
    """free + defrag between steps must not change a surviving
    sequence's continuation (vs the dense path)."""
    cfg = _smoke("olmo_1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    bs, W = 8, 4
    S = bs * W
    pool = PagedKVPool(cfg, num_blocks=1 + 3 * W, block_size=bs)
    pool.alloc(0, W)
    pool.alloc(1, W)
    pool.alloc(2, W)
    pserve = jax.jit(make_serve_step(cfg, paged=True))
    dense = jax.jit(make_serve_step(cfg))
    cache = init_cache(cfg, 1, S)  # dense reference for seq 1 alone

    tok3 = jnp.array([[3], [7], [11]], jnp.int32)
    tok1 = jnp.array([[7]], jnp.int32)
    bt = jnp.asarray(pool.table_array([0, 1, 2], W))
    for t in range(6):
        tok3, _, pool.cache = pserve(params, tok3, pool.cache, bt,
                                     jnp.full((3,), t, jnp.int32))
        tok1, l1, cache = dense(params, tok1, cache, jnp.int32(t))
    # Drop seqs 0 and 2 and compact; seq 1's blocks move.
    pool.free(0)
    pool.free(2)
    old_table = pool.table(1)
    pool.defrag()
    pool.check()
    assert pool.table(1) != old_table  # actually moved
    bt = jnp.asarray(pool.table_array([1], W))
    tok3 = tok3[1:2]
    for t in range(6, 14):
        tok3, lp, pool.cache = pserve(params, tok3, pool.cache, bt,
                                      jnp.full((1,), t, jnp.int32))
        tok1, l1, cache = dense(params, tok1, cache, jnp.int32(t))
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(l1))


def test_paged_cache_specs_rejects_stateful_families():
    with pytest.raises(ValueError):
        paged_cache_specs(_smoke("falcon_mamba_7b"), 8, 16)
    with pytest.raises(ValueError):
        paged_cache_specs(_smoke("zamba2_2_7b"), 8, 16)
