"""Docs stay in sync with the code (the CI ``docs`` job).

Two contracts, no network access:

* every internal markdown link in README.md + docs/*.md resolves — the
  relative path exists, and a ``#anchor`` matches a GitHub-slugged
  heading in the target file;
* every command quoted in a ``sh``/``bash`` code fence is runnable in
  shape: the ``python -m <module>`` / ``python <script>.py`` target
  exists, and every ``--flag`` passed to it appears in that file's
  argparse ``add_argument`` calls.  Docs promising flags that were
  renamed or removed is exactly the rot this test exists to catch.
"""
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted([REPO / "README.md", *(REPO / "docs").glob("*.md")])

assert DOC_FILES, "no markdown docs found"

# ---------------------------------------------------------------------
# Markdown parsing helpers
# ---------------------------------------------------------------------

_FENCE_RE = re.compile(r"^```(\w*)\s*$")
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")


def _fences(text: str) -> list[tuple[str, str]]:
    """All code fences as (info-string, body) tuples."""
    out, lang, buf = [], None, []
    for line in text.splitlines():
        m = _FENCE_RE.match(line)
        if m and lang is None:
            lang, buf = m.group(1), []
        elif m:
            out.append((lang, "\n".join(buf)))
            lang = None
        elif lang is not None:
            buf.append(line)
    return out


def _outside_fences(text: str) -> str:
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE_RE.match(line):
            in_fence = not in_fence
        elif not in_fence:
            out.append(line)
    return "\n".join(out)


def _github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip code ticks + punctuation, lowercase,
    spaces to hyphens."""
    s = heading.strip().lower().replace("`", "")
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors(path: Path) -> set[str]:
    return {
        _github_slug(m.group(2))
        for m in map(_HEADING_RE.match, _outside_fences(path.read_text()).splitlines())
        if m
    }


def _commands(text: str) -> list[str]:
    """Shell commands from sh/bash fences, continuations joined,
    comments stripped."""
    cmds = []
    for lang, body in _fences(text):
        if lang not in ("sh", "bash", "shell", "console"):
            continue
        joined = re.sub(r"\\\n\s*", " ", body)
        for line in joined.splitlines():
            line = re.sub(r"(^|\s)#.*$", "", line).strip()
            if line:
                cmds.append(line)
    return cmds


def _module_source(cmd: str) -> Path | None:
    """Source file a doc-quoted python command executes, if it names
    one inside the repo (``python -m repro.x.y`` / ``python path.py``)."""
    m = re.search(r"python3?\s+-m\s+([\w.]+)", cmd)
    if m:
        mod = m.group(1)
        if mod == "pytest":
            return None
        root = "src" if mod.split(".")[0] == "repro" else "."
        p = REPO / root / (mod.replace(".", "/") + ".py")
        q = REPO / root / mod.replace(".", "/") / "__main__.py"
        return p if p.exists() or not q.exists() else q
    m = re.search(r"python3?\s+([\w./-]+\.py)", cmd)
    if m:
        return REPO / m.group(1)
    return None


def _flags(cmd: str) -> list[str]:
    # Tolerate [--optional] notation and trailing punctuation.
    return [
        t.strip("[],;:")
        for t in cmd.replace("[", " ").replace("]", " ").split()
        if t.startswith("--")
    ]


# ---------------------------------------------------------------------
# Internal links
# ---------------------------------------------------------------------

def _links():
    for doc in DOC_FILES:
        for m in _LINK_RE.finditer(doc.read_text()):
            yield doc, m.group(1)


@pytest.mark.parametrize(
    "doc,target",
    [pytest.param(d, t, id=f"{d.name}:{t}") for d, t in _links()],
)
def test_internal_links_resolve(doc, target):
    if target.startswith(("http://", "https://", "mailto:")):
        pytest.skip("external link (not checked: no network in CI)")
    path_part, _, anchor = target.partition("#")
    dest = (doc.parent / path_part).resolve() if path_part else doc
    assert dest.exists(), f"{doc.name}: broken link target {target!r}"
    if anchor:
        assert dest.suffix == ".md", f"{doc.name}: anchor on non-markdown {target!r}"
        slugs = _anchors(dest)
        assert anchor in slugs, (
            f"{doc.name}: anchor #{anchor} not in {dest.name} "
            f"(headings: {sorted(slugs)})"
        )


def test_readme_links_every_docs_page():
    readme = (REPO / "README.md").read_text()
    for page in sorted((REPO / "docs").glob("*.md")):
        assert f"docs/{page.name}" in readme, (
            f"docs/{page.name} is not linked from the README index"
        )


def test_readme_is_a_short_index():
    # The deep content lives in docs/; the README stays a quickstart.
    n = len((REPO / "README.md").read_text().splitlines())
    assert n < 150, f"README.md has {n} lines; keep it <150 and move detail to docs/"


# ---------------------------------------------------------------------
# Quoted commands and flags exist
# ---------------------------------------------------------------------

def _quoted_commands():
    for doc in DOC_FILES:
        for cmd in _commands(doc.read_text()):
            src = _module_source(cmd)
            if src is not None:
                yield doc, cmd, src


CASES = list(_quoted_commands())


def test_docs_quote_commands_at_all():
    # The extractor going blind (fence syntax drift, regex rot) must
    # fail loudly rather than silently passing an empty parametrize.
    assert len(CASES) >= 15, f"only {len(CASES)} commands extracted from docs"
    assert any("repro.launch.train" in c for _, c, _ in CASES)
    assert any("pipeline_bubbles" in c for _, c, _ in CASES)


@pytest.mark.parametrize(
    "doc,cmd,src",
    [pytest.param(d, c, s, id=f"{d.name}:{c[:60]}") for d, c, s in CASES],
)
def test_quoted_command_targets_and_flags_exist(doc, cmd, src):
    assert src.exists(), f"{doc.name} quotes {cmd!r} but {src} does not exist"
    text = src.read_text()
    for flag in _flags(cmd):
        pat = re.compile(r"add_argument\(\s*['\"]" + re.escape(flag) + r"['\"]")
        assert pat.search(text), (
            f"{doc.name} quotes flag {flag} for {cmd.split()[0]}... "
            f"but {src.relative_to(REPO)} defines no such argument"
        )
