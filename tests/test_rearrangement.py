"""Tests for Rearrangement representation, inverse, composition (paper S6)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.balancing import post_balance
from repro.core.cost_model import CostModel
from repro.core.rearrangement import Rearrangement, compose, identity_rearrangement


def _pi_from_perm(perm, lengths):
    """A simple d=len(perm) rearrangement sending example i (1 per inst) to perm[i]."""
    d = len(perm)
    batches = [[] for _ in range(d)]
    for i, p in enumerate(perm):
        batches[p].append((i, 0, lengths[i]))
    return Rearrangement.from_batches(batches, d)


def test_identity():
    lens = [np.array([3, 4]), np.array([5])]
    pi = identity_rearrangement(lens, 2)
    assert pi.self_volume() == 12
    V = pi.comm_matrix()
    assert V[0, 0] == 7 and V[1, 1] == 5 and V[0, 1] == 0


def test_inverse_roundtrip():
    rng = np.random.default_rng(0)
    lens = [rng.integers(1, 50, size=4) for _ in range(6)]
    pi = post_balance(lens, 6, CostModel())
    inv = pi.inverse()
    # inverse sends each payload back: src of inv == dst of pi.
    assert (inv.src_inst == pi.dst_inst).all()
    assert (inv.dst_inst == pi.src_inst).all()


def test_compose_direct_path():
    """compose(pi_m, pi_e) must equal 'undo pi_e then apply pi_m'."""
    lengths = [10, 20, 30, 40]
    pi_e = _pi_from_perm([2, 3, 0, 1], lengths)
    pi_m = _pi_from_perm([1, 0, 3, 2], lengths)
    comp = compose(pi_m, pi_e)
    # Example i currently lives at pi_e dst; composed src must match.
    for k in range(comp.n):
        oi = int(comp.orig_inst[k])
        e = int(np.where(pi_e.orig_inst == oi)[0][0])
        m = int(np.where(pi_m.orig_inst == oi)[0][0])
        assert comp.src_inst[k] == pi_e.dst_inst[e]
        assert comp.dst_inst[k] == pi_m.dst_inst[m]


def test_compose_halves_volume_vs_two_hops():
    """Rearrangement Composition (paper S6) merges two all-to-alls into one:
    composed volume <= inverse-volume + forward-volume."""
    rng = np.random.default_rng(1)
    d = 8
    enc_lens = [rng.integers(10, 100, size=5) for _ in range(d)]
    pi_e = post_balance(enc_lens, d, CostModel())
    # The backbone plan balances a different metric (interleaved length):
    llm_lens = [l + rng.integers(1, 50, size=l.shape) for l in enc_lens]
    pi_m = post_balance(llm_lens, d, CostModel(beta=1e-4), algorithm="quad")
    # Composition must still track the *encoder* payload lengths.
    comp = compose(pi_m, pi_e)
    assert sorted(comp.lengths.tolist()) == sorted(pi_e.lengths.tolist())
    two_hop = pi_e.inverse().comm_matrix().sum() + pi_e.lengths.sum()
    one_hop = comp.comm_matrix().sum()
    assert one_hop <= two_hop


def test_permute_destinations_objective_invariant():
    rng = np.random.default_rng(2)
    d = 4
    lens = [rng.integers(1, 40, size=3) for _ in range(d)]
    cm = CostModel()
    pi = post_balance(lens, d, cm)
    before = sorted(cm.cost(l) for l in pi.dest_lengths())
    perm = np.array([2, 0, 3, 1])
    pi2 = pi.permute_destinations(perm)
    after = sorted(cm.cost(l) for l in pi2.dest_lengths())
    assert np.allclose(before, after)
    with pytest.raises(ValueError):
        pi.permute_destinations(np.array([0, 0, 1, 2]))


@given(st.permutations(list(range(6))))
@settings(max_examples=20, deadline=None)
def test_property_compose_with_self_inverse_is_src_stationary(perm):
    lengths = list(range(10, 70, 10))
    pi = _pi_from_perm(list(perm), lengths)
    comp = compose(pi, pi)  # pi o pi^{-1} = identity motion
    assert (comp.src_inst == comp.dst_inst).all()
    assert comp.comm_matrix().trace() == sum(lengths)


def test_internode_volume_accounting():
    # 4 instances, 2 per node; everything sent cross-node.
    pi = _pi_from_perm([2, 3, 0, 1], [10, 10, 10, 10])
    v = pi.internode_volume(2)
    assert v.tolist() == [10, 10, 10, 10]
    # Identity: zero inter-node.
    pi_id = _pi_from_perm([0, 1, 2, 3], [10, 10, 10, 10])
    assert pi_id.internode_volume(2).sum() == 0


def test_compose_rejects_mismatched_examples():
    pi_a = _pi_from_perm([1, 0], [5, 6])
    batches = [[(0, 0, 5)], [(1, 1, 6)]]  # slot mismatch
    pi_b = Rearrangement.from_batches(batches, 2)
    with pytest.raises(KeyError):
        compose(pi_a, pi_b)
