"""Small shared numeric helpers."""
from __future__ import annotations

__all__ = ["round_up", "zeros_like_specs"]


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return -(-x // m) * m


def zeros_like_specs(tree):
    """Zero-initialized arrays for a pytree of ``jax.ShapeDtypeStruct``.

    Shared by the dense decode cache (``serving.serve_step.init_cache``)
    and the paged KV pool (``serving.engine.kv_pool``), which both
    materialize ``registry`` cache specs.
    """
    import jax
    import jax.numpy as jnp

    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
