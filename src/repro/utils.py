"""Small shared numeric helpers."""
from __future__ import annotations

__all__ = ["round_up"]


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return -(-x // m) * m
