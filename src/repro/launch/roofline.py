"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):
  compute_s    = HLO_FLOPs / peak_FLOPs          (per chip)
  memory_s     = HLO_bytes / HBM_bw              (per chip)
  collective_s = collective_bytes / link_bw      (per chip)

``cost_analysis()`` supplies FLOPs / bytes (per device under SPMD).
Collective bytes are NOT in cost_analysis: we parse the compiled HLO and
sum the RESULT buffer sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute / ragged-all-to-all op
(per-device module => per-device bytes).

Hardware model: named presets in ``HW_PRESETS`` (defaults to TPU v5e --
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI; DCI between pods is
slower; collectives that cross the 'pod' axis are reported separately
via their replica-group parse when available).  ``get_hw`` resolves a
preset by name or from the ``REPRO_HW`` env var, so roofline and
autotuner predictions aren't silently v5e numbers on other targets.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Any

from repro.obs.ledger import projected_mfu, useful_flops_ratio

__all__ = ["HW", "HW_PRESETS", "get_hw", "RooflineReport",
           "collective_bytes", "analyze"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/s
    ici_bw: float = 50e9  # B/s/link
    chips: int = 256
    name: str = "v5e"


# Public per-chip specs (bf16 peak, HBM bandwidth, per-link ICI).
HW_PRESETS: dict[str, HW] = {
    "v4": HW(peak_flops=275e12, hbm_bw=1228e9, ici_bw=50e9, name="v4"),
    "v5e": HW(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9, name="v5e"),
    "v5p": HW(peak_flops=459e12, hbm_bw=2765e9, ici_bw=100e9, name="v5p"),
    "v6e": HW(peak_flops=918e12, hbm_bw=1640e9, ici_bw=100e9, name="v6e"),
}


def get_hw(name: str | None = None, *, chips: int | None = None) -> HW:
    """Resolve a hardware preset: explicit ``name`` > ``REPRO_HW`` env
    var > "v5e".  ``chips`` overrides the preset's chip count (e.g. from
    the actual mesh)."""
    name = name or os.environ.get("REPRO_HW") or "v5e"
    try:
        hw = HW_PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown HW preset {name!r}; choose from {sorted(HW_PRESETS)}"
        ) from None
    if chips is not None:
        hw = dataclasses.replace(hw, chips=chips)
    return hw


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    """Sum byte sizes of every typed shape in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per collective kind, summed RESULT bytes (per-device module)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", line)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVES:
            # Match the opcode, not substrings of other ops
            # (all-to-all also matches ragged-all-to-all: order matters).
            if re.search(rf"\)\s*{kind}\(", rhs) or re.search(rf"^\(?.*?\s{kind}\(", rhs):
                if kind == "all-to-all" and "ragged-all-to-all" in rhs:
                    continue
                # Result type = everything before the opcode token.
                result_txt = rhs.split(f" {kind}(")[0]
                out[kind] += _shape_bytes(result_txt)
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)
    memory_per_device: dict[str, Any]
    # Roofline-projected MFU (ledger canonical formula): useful_ratio
    # discounted by the compute fraction of the serial roofline sum.
    mfu_projected: float = 0.0

    def row(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    cost: dict[str, Any],
    hlo_text: str,
    memory: dict[str, Any],
    model_flops_global: float,
    hw: HW = HW(),
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    collective_s = coll["total"] / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    # Canonical formula lives in the obs ledger (single source of truth
    # with the training-loop accounting).
    useful = useful_flops_ratio(model_flops_global, flops, hw.chips)
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        coll_bytes_per_chip=float(coll["total"]),
        coll_breakdown=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_global=model_flops_global,
        useful_ratio=useful,
        memory_per_device=memory,
        mfu_projected=projected_mfu(useful, compute_s, memory_s, collective_s),
    )
