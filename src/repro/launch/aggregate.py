"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.launch.aggregate experiments/dryrun
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def gb(x):
    return f"{x / 2**30:.2f}" if x is not None else "-"


def load(out_dir: Path):
    rows = []
    for f in sorted(out_dir.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def roofline_table(rows) -> str:
    lines = [
        "| arch | shape | kind | compute | memory | collective | dominant | "
        "HBM GiB (args+tmp) | useful ratio | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != "16x16":
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - | "
                f"skipped: {r['reason']} |")
            continue
        if r["status"] == "FAILED":
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - | "
                f"FAILED: {r['error'][:80]} |")
            continue
        mem = r["memory_per_device"]
        hbm = (mem.get("argument_size") or 0) + (mem.get("temp_size") or 0)
        note = "fits" if hbm < 16 * 2**30 else "OVER 16G HBM"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{hbm / 2**30:.2f} | {r['useful_ratio']:.3f} | {note} |")
    return "\n".join(lines)


def multipod_table(rows) -> str:
    lines = ["| arch | shape | 2x16x16 status | compile_s |",
             "|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != "2x16x16":
            continue
        st = r["status"]
        extra = r.get("compile_s", "-") if st == "ok" else r.get(
            "reason", r.get("error", ""))[:60]
        lines.append(f"| {r['arch']} | {r['shape']} | {st} | {extra} |")
    return "\n".join(lines)


def summarize(rows) -> str:
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skipped")
    bad = sum(1 for r in rows if r["status"] == "FAILED")
    return f"{ok} ok / {sk} skipped / {bad} failed of {len(rows)}"


def main():
    out = Path(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    rows = load(out)
    print("## Roofline (single pod 16x16, per chip)\n")
    print(roofline_table(rows))
    print("\n## Multi-pod (2x16x16) compile check\n")
    print(multipod_table(rows))
    print(f"\nTotals: {summarize(rows)}")


if __name__ == "__main__":
    main()
