"""Production mesh construction (dry-run contract).

``make_production_mesh`` is a FUNCTION, not a module constant, so
importing this module never touches JAX device state.  The dry-run
entrypoint (launch/dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import; everything else sees the real device count.

Single pod (TPU v5e-256): mesh (16, 16) over ("data", "model").
Two pods (512 chips):      mesh (2, 16, 16) over ("pod", "data", "model").

DP shards for the Batch Post-Balancing problem = product of the
("pod","data") axes; the node-wise ILP groups them by pod (ICI vs DCI =
the paper's NVLink vs InfiniBand split).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes_of", "dp_shards_of"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_shards_of(mesh) -> int:
    n = 1
    for a in dp_axes_of(mesh):
        n *= mesh.shape[a]
    return n
