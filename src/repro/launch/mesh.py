"""Production mesh construction (dry-run contract).

``make_production_mesh`` is a FUNCTION, not a module constant, so
importing this module never touches JAX device state.  The dry-run
entrypoint (launch/dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import; everything else sees the real device count.

Single pod (TPU v5e-256): mesh (16, 16) over ("data", "model").
Two pods (512 chips):      mesh (2, 16, 16) over ("pod", "data", "model").
Pipelined (pp > 1):        the data axis splits into ("pp", "data") --
                           e.g. pp=4: (4, 4, 16) over ("pp", "data",
                           "model") -- so each DP shard spans pp stage
                           groups (see docs/pipeline.md).

DP shards for the Batch Post-Balancing problem = product of the
("pod","data") axes; the node-wise ILP groups them by pod (ICI vs DCI =
the paper's NVLink vs InfiniBand split).  The ``pp`` axis is NOT a DP
axis: every stage of one pipeline sees the same post-balanced shard.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes_of", "dp_shards_of",
           "pp_stages_of"]


def make_production_mesh(*, multi_pod: bool = False, pp: int = 1):
    if pp < 1 or 16 % pp:
        raise ValueError(f"pp must divide the 16-wide data axis, got {pp}")
    if pp == 1:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    else:
        shape = (2, pp, 16 // pp, 16) if multi_pod else (pp, 16 // pp, 16)
        axes = (("pod", "pp", "data", "model") if multi_pod
                else ("pp", "data", "model"))
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_shards_of(mesh) -> int:
    n = 1
    for a in dp_axes_of(mesh):
        n *= mesh.shape[a]
    return n


def pp_stages_of(mesh) -> int:
    return mesh.shape.get("pp", 1) if "pp" in mesh.axis_names else 1
