"""Production training launcher.

Builds the mesh, sharded train state and post-balanced data pipeline for
any registered architecture and runs the training loop.  On the CPU
container this runs reduced configs (``--smoke``); on a real TPU slice
the same entrypoint runs the full configs under the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --smoke \
        --steps 20 --d 4

Pipeline mode: ``--pp N`` (N > 1) partitions the LLM backbone into N
stages and plans a 1F1B microbatch schedule with encoder bubble-fill
per step (docs/pipeline.md); the ledger gains per-stage bubble series,
the waterfall switches to its ``pipeline_bubble_s{k}`` components, and
the Perfetto timeline gets one lane per stage.

Observability: ``--metrics-dir DIR`` turns on the unified metrics plane
(:mod:`repro.obs`): an OpenMetrics textfile (``metrics.prom``,
atomically rewritten every ``--metrics-every`` steps), a crash-safe
JSONL flight recorder (``flight.jsonl``) carrying run metadata and
structured alert events (cost-model drift, checkpoint corruption
fallbacks, MoE drop spikes, stale-plan re-plans), and one merged
Perfetto timeline (``timeline.json``) with orchestrator spans,
checkpoint save/restore spans and MFU/goodput/imbalance counter
tracks.  On top of the recording plane sits the attribution plane: a
per-step MFU-gap waterfall (:class:`repro.obs.GapWaterfall`, recorded
as ``waterfall`` flight events), online anomaly detection over every
ledger/waterfall series (:class:`repro.obs.AnomalyMonitor`), and an
end-of-run ranked root-cause report (``triage.json`` +
``python -m repro.obs.triage <metrics-dir>``).

``--serve-metrics PORT`` serves the registry live at
``http://127.0.0.1:PORT/metrics`` (OpenMetrics) with the current triage
report at ``/triage`` (JSON); ``--serve-metrics-linger SEC`` keeps the
server up after the loop finishes so scrapers (the nightly CI curl)
can take a final sample.  The bound address is written to
``<metrics-dir>/server.json``.

Fault injection handles (each implies the plane it exercises):
``--inject-drift N`` triples the observed step time from step N on
(fires the CUSUM cost-model-drift alert); ``--inject-straggler N``
inflates shard 0's LLM-phase cost 1.6x from step N on (fires the
``imbalance_llm`` waterfall component and the ``straggler_llm`` triage
root cause); ``--inject-drop-spike N`` reports a 20% MoE drop fraction
from step N on (fires the drop-spike alert and the ``moe_drop``
component).

Fault tolerance: ``--ckpt-dir DIR --ckpt-every N`` snapshots the full
:class:`~repro.checkpoint.TrainState` (params, optimizer state, data
cursor, calibrator state) atomically every N steps with keep-last-K
retention; ``--resume`` restores the newest complete checkpoint (corrupt
ones are flagged and skipped) and continues bit-deterministically.
Resuming with a *different* ``--d`` than the checkpoint's is the elastic
path: the global batch is re-split across the new DP degree and the
Batch Post-Balancing Dispatcher re-solves assignments for the new shard
count -- no divisibility requirement between old and new world sizes.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    CheckpointManager,
    DataCursor,
    TrainState,
    elastic_cursor,
    reshard_pytree,
    restore_train_state,
    save_train_state,
)
from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.pipeline import PrefetchingLoader
from repro.data.synthetic import Example
from repro.obs import (AlertBridge, AnomalyMonitor, FlightRecorder,
                       GapWaterfall, MetricsRegistry, MetricsServer,
                       StepLedger, build_timeline, render_text,
                       set_registry, triage, write_openmetrics)
from repro.sharding.specs import opt_state_specs, param_specs, to_shardings
from repro.telemetry import AdaptiveOrchestration
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def _sampler_for(cfg):
    names = [e.name for e in cfg.encoders]

    def sampler(rng, per):
        out = []
        for _ in range(per):
            text = int(rng.integers(16, 128))
            vis = int(rng.integers(1, 4)) * 32 if "vision" in names else 0
            aud = int(rng.integers(16, 64)) if "audio" in names else 0
            if cfg.family == "audio":
                order = ("audio", "text")
            elif vis and aud:
                order = ("vision", "audio", "text")
            elif vis:
                order = ("vision", "text")
            elif aud:
                order = ("audio", "text")
            else:
                order = ("text",)
            out.append(Example("mix", text, vis, aud, order))
        return out

    return sampler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--d", type=int, default=4, help="DP instances")
    ap.add_argument("--per", type=int, default=4, help="examples/instance")
    ap.add_argument("--pp", type=int, default=None, metavar="STAGES",
                    help="pipeline-parallel stages; >1 plans a 1F1B "
                         "microbatch schedule with encoder bubble fill "
                         "per step (docs/pipeline.md; default: the "
                         "config's pp_stages)")
    ap.add_argument("--microbatches", type=int, default=None, metavar="M",
                    help="microbatches per pipeline iteration (default: "
                         "the config's pp_microbatches, or 2*pp)")
    ap.add_argument("--no-bubble-fill", action="store_true",
                    help="pp > 1 only: schedule encoder microbatches as "
                         "pipeline prologue/epilogue instead of filling "
                         "the 1F1B bubbles (the ablation baseline of "
                         "benchmarks/pipeline_bubbles.py)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0, help="data stream seed")
    ap.add_argument("--mesh", choices=["none", "host"], default="none",
                    help="'host': shard over all local devices on a "
                         "(data, model) mesh")
    ap.add_argument("--adaptive", action="store_true",
                    help="online cost-model calibration: measured step "
                         "times refit the balancing coefficients "
                         "(repro.telemetry)")
    ap.add_argument("--trace-out", default=None,
                    help="write the telemetry Chrome-trace/Perfetto JSON "
                         "here on exit (requires --adaptive)")
    ap.add_argument("--metrics-dir", default=None,
                    help="enable the obs plane: write metrics.prom, "
                         "flight.jsonl and timeline.json here")
    ap.add_argument("--metrics-every", type=int, default=10,
                    help="flush the exporters every N steps")
    ap.add_argument("--inject-drift", type=int, default=None, metavar="STEP",
                    help="fault injection: report 3x step times from STEP "
                         "on (fires the CUSUM drift alert; implies "
                         "--adaptive)")
    ap.add_argument("--inject-straggler", type=int, default=None,
                    metavar="STEP",
                    help="fault injection: inflate shard 0's LLM-phase "
                         "cost 1.6x from STEP on (fires the imbalance "
                         "waterfall component / straggler triage cause)")
    ap.add_argument("--inject-drop-spike", type=int, default=None,
                    metavar="STEP",
                    help="fault injection: report moe_dropped_frac=0.2 "
                         "from STEP on (fires the drop-spike alert and "
                         "the moe_drop waterfall component)")
    ap.add_argument("--serve-metrics", type=int, default=None, metavar="PORT",
                    help="serve live /metrics (OpenMetrics) and /triage "
                         "(JSON) on 127.0.0.1:PORT (0 picks a free port; "
                         "requires --metrics-dir; address lands in "
                         "<metrics-dir>/server.json)")
    ap.add_argument("--serve-metrics-linger", type=float, default=0.0,
                    metavar="SEC",
                    help="keep the metrics server up SEC seconds after "
                         "the loop ends (lets scrapers take a final "
                         "sample)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint root (enables checkpointing)")
    ap.add_argument("--ckpt-every", type=int, default=5,
                    help="save a checkpoint every N steps")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="retention: keep the newest K checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest complete checkpoint in "
                         "--ckpt-dir (elastic when --d differs)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    if args.inject_drift is not None and not args.adaptive:
        print("--inject-drift implies --adaptive; enabling calibration")
        args.adaptive = True
    if args.serve_metrics is not None and not args.metrics_dir:
        raise SystemExit("--serve-metrics requires --metrics-dir")

    registry = ledger = recorder = alerts = None
    waterfall = monitor = ledger_monitor = server = None
    if args.metrics_dir:
        from repro.launch.roofline import get_hw

        os.makedirs(args.metrics_dir, exist_ok=True)
        registry = MetricsRegistry()
        set_registry(registry)  # kernel hooks publish here too
        hw = get_hw()
        recorder = FlightRecorder(
            os.path.join(args.metrics_dir, "flight.jsonl"),
            meta={"arch": cfg.name, "d": args.d, "per": args.per,
                  "steps": args.steps, "adaptive": args.adaptive,
                  "hw": hw.name, "smoke": args.smoke})
        alerts = AlertBridge(recorder, registry)
        waterfall = GapWaterfall(registry=registry)
        # Two monitors because the ledger and the waterfall both track
        # an ``imbalance_<phase>`` series (ratio vs fraction-of-step):
        # one shared cursor map would silently skip one of the pair.
        monitor = AnomalyMonitor(alerts=alerts, registry=registry)
        ledger_monitor = AnomalyMonitor(alerts=alerts, registry=registry,
                                        include=("mfu_", "goodput_"))

        def triage_now() -> dict:
            return triage(
                [w.to_dict() for w in waterfall.history],
                anomalies=[a.to_dict() for a in (monitor.anomalies
                                                 + ledger_monitor.anomalies)],
                alerts=list(alerts.alerts),
                meta={"arch": cfg.name, "d": args.d})

        if args.serve_metrics is not None:
            server = MetricsServer(lambda: registry,
                                   triage_provider=triage_now,
                                   port=args.serve_metrics).start()
            with open(os.path.join(args.metrics_dir, "server.json"),
                      "w") as f:
                json.dump({"url": server.url, "port": server.port}, f)
            print(f"serving live metrics at {server.url}/metrics "
                  f"(triage at {server.url}/triage)")

    mesh = None
    dp_axes = ("data",)
    if args.mesh == "host":
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))

    manager = None
    if args.ckpt_dir:
        manager = CheckpointManager(args.ckpt_dir, keep_last=args.keep_last,
                                    metrics=registry)

    # The CLI loop feeds ONE straggler-attributed wall-clock scalar per
    # step, and shared-CPU wall times are far noisier than the per-shard
    # samples the calibrator defaults assume -- a 0.25 rel-SE
    # coefficient fit is unreachable here, which would leave the CUSUM
    # detector disarmed forever.  A coarse fit is still a usable drift
    # reference (the detector standardizes residuals against its own
    # warmup window), so loosen the confidence gate for this regime.
    adaptive = (AdaptiveOrchestration(cfg, rel_tol=1.0, min_samples=8)
                if args.adaptive else None)
    cursor = DataCursor(seed=args.seed, batch_index=0,
                        examples_per_instance=args.per, d=args.d)
    start_step = 0
    params = opt_state = None
    resumed_on_mesh = False
    if args.resume:
        if manager is None:
            raise SystemExit("--resume requires --ckpt-dir")
        found = restore_train_state(manager)
        if found is None:
            print("no restorable checkpoint found; starting fresh")
        else:
            state, manifest = found
            params, opt_state = state.params, state.opt_state
            start_step = state.step
            if args.seed != state.cursor.seed:
                print(f"warning: --seed {args.seed} ignored on resume; "
                      f"continuing the checkpoint's stream "
                      f"(seed {state.cursor.seed})")
            if (args.d == state.cursor.d
                    and args.per != state.cursor.examples_per_instance):
                print(f"warning: --per {args.per} ignored on resume; "
                      f"keeping the checkpoint's "
                      f"{state.cursor.examples_per_instance}/instance")
            cursor = state.cursor
            if args.d != cursor.d:
                old_d = cursor.d
                cursor = elastic_cursor(cursor, args.d)
                print(f"elastic resume: DP {old_d} -> {cursor.d} "
                      f"(per-instance {cursor.examples_per_instance}); "
                      f"post-balancing will re-solve for the new shard "
                      f"count")
            if mesh is not None:
                # Reshard the tree AS SAVED so leaf paths line up with
                # the manifest's spec rows ('params/...', 'opt_state/...').
                # This is the only device placement on the resume path
                # (the fresh-start device_put below is skipped).
                resharded = reshard_pytree(
                    {"params": params, "opt_state": opt_state},
                    manifest, mesh)
                params = resharded["params"]
                opt_state = resharded["opt_state"]
                resumed_on_mesh = True
            if adaptive is not None and state.calibrator is not None:
                adaptive.load_state_dict(state.calibrator)
            print(f"resumed from step {start_step} "
                  f"(cursor batch {cursor.batch_index})")

    if registry is not None:
        ledger = StepLedger(cfg, d=cursor.d, registry=registry,
                            peak_flops=hw.peak_flops, chips=cursor.d)
        if manager is not None:
            # A fallback restore leaves flagged *.corrupt litter behind;
            # surface each one as a structured alert.
            for p in sorted(glob.glob(
                    os.path.join(manager.root, "*.corrupt*"))):
                alerts.on_checkpoint_fallback(p, start_step)

    orch = MLLMGlobalOrchestrator(
        cfg, cursor.d, vocab=cfg.vocab_size, adaptive=adaptive,
        metrics=registry, pp=args.pp, microbatches=args.microbatches,
        bubble_fill=False if args.no_bubble_fill else None)
    if orch.pp > 1:
        print(f"pipeline mode: pp={orch.pp} "
              f"microbatches={orch.microbatches or 2 * orch.pp} "
              f"bubble_fill={orch.bubble_fill} (docs/pipeline.md)")
    sampler = _sampler_for(cfg)
    probe = [sampler(np.random.default_rng(s), cursor.examples_per_instance)
             for s in range(cursor.d)]
    caps = orch.default_capacities(probe, margin=3.0)
    loader = PrefetchingLoader(
        orch, caps, examples_per_instance=cursor.examples_per_instance,
        seed=cursor.seed, sampler=sampler, start_index=cursor.batch_index)

    if params is None:
        params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = make_train_step(cfg, AdamWConfig(lr=args.lr), mesh=mesh,
                              dp_axes=dp_axes)
    p_specs = None
    if mesh is not None:
        p_specs = param_specs(cfg, params, mesh)
        if not resumed_on_mesh:  # resume already placed via the manifest
            params = jax.device_put(params, to_shardings(p_specs, mesh))
    step = jax.jit(step_fn, donate_argnums=(0, 1))

    def save_ckpt(next_step: int) -> None:
        specs = None
        if p_specs is not None:
            specs = {"params": p_specs, "opt_state": opt_state_specs(p_specs)}
        state = TrainState(
            params=jax.device_get(params),
            opt_state=jax.device_get(opt_state),
            step=next_step,
            cursor=DataCursor(seed=cursor.seed, batch_index=loader.cursor,
                              examples_per_instance=cursor.examples_per_instance,
                              d=cursor.d),
            calibrator=adaptive.state_dict() if adaptive else None,
        )
        path = save_train_state(manager, state, specs=specs,
                                meta={"arch": cfg.name})
        print(f"checkpoint: step {next_step} -> {path}", flush=True)

    t0 = time.time()
    done = start_step
    pending_ckpt_ms = 0.0  # save wall charged to the NEXT step's waterfall
    last_pipeline = None  # newest PipelinePlan (pp > 1): timeline lanes
    try:
        for it in range(start_step, args.steps):
            batch_np, report, _ = next(loader)
            if (args.inject_straggler is not None
                    and it >= args.inject_straggler):
                # Fault injection: one shard's LLM phase runs 1.6x hot,
                # exactly the residual-imbalance signature the waterfall
                # attributes to imbalance_llm (triage: straggler_llm).
                costs = np.asarray(report.phase_costs["llm"],
                                   dtype=np.float64).copy()
                costs[0] *= 1.6
                report.phase_costs["llm"] = costs
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            ts = time.perf_counter()
            params, opt_state, m = step(params, opt_state, batch)
            step_ms = None
            if adaptive is not None or ledger is not None:
                # Calibration and the ledger need the device-complete
                # step time; the sync is only paid when either is on
                # (the default path keeps async dispatch overlap).
                jax.block_until_ready(m["loss"])
                step_ms = (time.perf_counter() - ts) * 1e3
                if args.inject_drift is not None and it >= args.inject_drift:
                    # Fault injection: pretend the step slowed 3x so the
                    # CUSUM detector (and the alert path behind it) fire
                    # without needing a real hardware regression.
                    step_ms *= 3.0
            if adaptive is not None and it > start_step:
                # Skip the process's first step (dominated by XLA
                # compilation -- also the first step AFTER a resume,
                # which recompiles in the fresh process).  The
                # whole-step time is attributed to the LLM backbone
                # phase -- on a CPU smoke run the encoders are
                # noise; a per-phase profiler would feed each phase.
                drift = orch.observe_phase_times({"llm": step_ms},
                                                 report=report, step=it)
                if alerts is not None:
                    alerts.on_drift(drift, step=it)
            if ledger is not None:
                host_m = {k: float(v) for k, v in m.items()
                          if np.ndim(v) == 0}
                if (args.inject_drop_spike is not None
                        and it >= args.inject_drop_spike):
                    # Fault injection: a capacity-overflow drop storm.
                    host_m["moe_dropped_frac"] = 0.2
                events = ledger.record_step(it, report=report,
                                            step_ms=step_ms, metrics=host_m)
                alerts.on_ledger_events(events)
                if report.pipeline is not None:
                    # Per-stage bubble series + fill/uplift gauges; the
                    # waterfall below picks the plan off the report and
                    # switches to its pipeline_bubble_s{k} algebra.
                    ledger.record_pipeline(it, report.pipeline)
                    last_pipeline = report.pipeline
                # The smoke path runs dense reference attention, so the
                # tile fraction the Pallas kernels would have skipped IS
                # dead compute actually paid this step -- but only for
                # the attention share of the step's FLOPs, so weight it
                # down before charging it against total useful compute.
                dead = ledger.series.get("kernel_flash_skip_frac")
                attn_share = 0.2
                if it > start_step:
                    # Skip the compile-dominated first step: its wall
                    # time would poison the waterfall's cost->ms EWMA
                    # (same reason the calibrator skips it above).
                    wf = waterfall.observe(
                        it, report=report, step_ms=step_ms, metrics=host_m,
                        ckpt_ms=pending_ckpt_ms,
                        dead_tile_frac=(dead[-1][1] * attn_share
                                        if dead else 0.0))
                    recorder.record("waterfall", **wf.to_dict())
                pending_ckpt_ms = 0.0
                monitor.poll(waterfall.series)
                ledger_monitor.poll(ledger.series)
                if (it - start_step) % max(args.metrics_every, 1) == 0:
                    ledger.record_kernel_stats(it, batch_np)
                    write_openmetrics(
                        os.path.join(args.metrics_dir, "metrics.prom"),
                        registry)
                    recorder.record("flush", step=it,
                                    **{k: v for k, v in ledger.summary().items()
                                       if isinstance(v, (int, float))})
                    recorder.flush()
            done = it + 1
            if manager is not None and args.ckpt_every > 0 \
                    and done % args.ckpt_every == 0 and done < args.steps:
                save_ckpt(done)
                pending_ckpt_ms = manager.last_op_ms
            if it % 5 == 0 or it == args.steps - 1:
                denom = max(it + 1 - start_step, 1)
                print(f"step {it:4d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.2f} "
                      f"util={report.phase_utilization['llm']:.2f} "
                      f"{(time.time()-t0)/denom:.2f}s/step", flush=True)
    finally:
        loader.close()
    if manager is not None and done > start_step:
        save_ckpt(done)
    if adaptive is not None:
        print("telemetry calibration summary:")
        print(json.dumps(adaptive.summary(), indent=1, default=str))
        print(f"stale plan-ahead re-plans: {orch.replans}")
        if args.trace_out:
            adaptive.export_chrome_trace(args.trace_out)
            print(f"wrote phase trace to {args.trace_out} "
                  f"(open in ui.perfetto.dev)")
    if ledger is not None:
        write_openmetrics(os.path.join(args.metrics_dir, "metrics.prom"),
                          registry)
        tl_path = os.path.join(args.metrics_dir, "timeline.json")
        tl = build_timeline(
            trace_buffer=adaptive.trace if adaptive is not None else None,
            ledger=ledger, waterfall=waterfall,
            checkpoint_ops=manager.ops if manager is not None else None,
            pipeline=last_pipeline)
        with open(tl_path, "w") as f:
            json.dump(tl, f)
        triage_report = triage_now()
        with open(os.path.join(args.metrics_dir, "triage.json"), "w") as f:
            json.dump(triage_report, f, indent=1, default=str)
        print(render_text(triage_report))
        summary = ledger.summary()
        summary.update({f"waterfall_{k}": v
                        for k, v in waterfall.summary().items()})
        recorder.record("summary", **{k: v for k, v in summary.items()
                                      if isinstance(v, (int, float))})
        recorder.close()
        print("observability summary:")
        print(json.dumps(summary, indent=1, default=str))
        print(f"wrote {args.metrics_dir}/metrics.prom, flight.jsonl "
              f"({recorder.events_written} events, "
              f"{len(alerts.alerts)} alerts), timeline.json "
              f"(open in ui.perfetto.dev), triage.json")
    if server is not None:
        if args.serve_metrics_linger > 0:
            print(f"metrics server lingering {args.serve_metrics_linger:g}s "
                  f"at {server.url}", flush=True)
            time.sleep(args.serve_metrics_linger)
        server.stop()
    print("training loop complete")


if __name__ == "__main__":
    main()
