"""Production training launcher.

Builds the mesh, sharded train state and post-balanced data pipeline for
any registered architecture and runs the training loop.  On the CPU
container this runs reduced configs (``--smoke``); on a real TPU slice
the same entrypoint runs the full configs under the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --smoke \
        --steps 20 --d 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.orchestrator import MLLMGlobalOrchestrator
from repro.data.pipeline import PrefetchingLoader
from repro.data.synthetic import Example
from repro.sharding.specs import batch_specs, opt_state_specs, param_specs, to_shardings
from repro.telemetry import AdaptiveOrchestration
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def _sampler_for(cfg):
    names = [e.name for e in cfg.encoders]

    def sampler(rng, per):
        out = []
        for _ in range(per):
            text = int(rng.integers(16, 128))
            vis = int(rng.integers(1, 4)) * 32 if "vision" in names else 0
            aud = int(rng.integers(16, 64)) if "audio" in names else 0
            if cfg.family == "audio":
                order = ("audio", "text")
            elif vis and aud:
                order = ("vision", "audio", "text")
            elif vis:
                order = ("vision", "text")
            elif aud:
                order = ("audio", "text")
            else:
                order = ("text",)
            out.append(Example("mix", text, vis, aud, order))
        return out

    return sampler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--d", type=int, default=4, help="DP instances")
    ap.add_argument("--per", type=int, default=4, help="examples/instance")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", choices=["none", "host"], default="none",
                    help="'host': shard over all local devices on a "
                         "(data, model) mesh")
    ap.add_argument("--adaptive", action="store_true",
                    help="online cost-model calibration: measured step "
                         "times refit the balancing coefficients "
                         "(repro.telemetry)")
    ap.add_argument("--trace-out", default=None,
                    help="write the telemetry Chrome-trace/Perfetto JSON "
                         "here on exit (requires --adaptive)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    mesh = None
    dp_axes = ("data",)
    if args.mesh == "host":
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))

    adaptive = AdaptiveOrchestration(cfg) if args.adaptive else None
    orch = MLLMGlobalOrchestrator(cfg, args.d, vocab=cfg.vocab_size,
                                  adaptive=adaptive)
    sampler = _sampler_for(cfg)
    probe = [sampler(np.random.default_rng(s), args.per) for s in range(args.d)]
    caps = orch.default_capacities(probe, margin=3.0)
    loader = PrefetchingLoader(orch, caps, examples_per_instance=args.per,
                               sampler=sampler)

    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = make_train_step(cfg, AdamWConfig(lr=args.lr), mesh=mesh,
                              dp_axes=dp_axes)
    if mesh is not None:
        p_specs = param_specs(cfg, params, mesh)
        params = jax.device_put(params, to_shardings(p_specs, mesh))
        step = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step = jax.jit(step_fn, donate_argnums=(0, 1))

    t0 = time.time()
    try:
        for it in range(args.steps):
            batch_np, report, _ = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            ts = time.perf_counter()
            params, opt_state, m = step(params, opt_state, batch)
            if adaptive is not None:
                # Calibration needs the device-complete step time; the
                # sync is only paid on the --adaptive path (the default
                # path keeps async dispatch overlap).
                jax.block_until_ready(m["loss"])
                step_ms = (time.perf_counter() - ts) * 1e3
                if it > 0:
                    # Skip step 0 (dominated by XLA compilation).  The
                    # whole-step time is attributed to the LLM backbone
                    # phase -- on a CPU smoke run the encoders are
                    # noise; a per-phase profiler would feed each phase.
                    orch.observe_phase_times({"llm": step_ms},
                                             report=report, step=it)
            if it % 5 == 0 or it == args.steps - 1:
                print(f"step {it:4d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.2f} "
                      f"util={report.phase_utilization['llm']:.2f} "
                      f"{(time.time()-t0)/(it+1):.2f}s/step", flush=True)
    finally:
        loader.close()
    if adaptive is not None:
        print("telemetry calibration summary:")
        print(json.dumps(adaptive.summary(), indent=1, default=str))
        print(f"stale plan-ahead re-plans: {orch.replans}")
        if args.trace_out:
            adaptive.export_chrome_trace(args.trace_out)
            print(f"wrote phase trace to {args.trace_out} "
                  f"(open in ui.perfetto.dev)")
    print("training loop complete")


if __name__ == "__main__":
    main()
