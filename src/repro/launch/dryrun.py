import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input shape) pair on the
production meshes -- single-pod (16,16) and multi-pod (2,16,16) -- with
ShapeDtypeStruct inputs (no allocation), records memory_analysis(),
cost_analysis() and the HLO collective schedule, and emits the roofline
terms (deliverable g).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

NOTE: the XLA_FLAGS line above MUST run before any other import (JAX
locks the device count on first init); do not set it globally.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import INPUT_SHAPES, get_config, input_specs, step_kind
from repro.configs.registry import ARCHITECTURES
from repro.launch.mesh import dp_axes_of, dp_shards_of, make_production_mesh
from repro.launch.roofline import HW, analyze, get_hw
from repro.sharding.specs import (
    batch_specs,
    cache_sharding_specs,
    opt_state_specs,
    param_specs,
    to_shardings,
)


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode counts
    one token per request."""
    n = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token per request
    else:
        tokens = shape.seq_len * shape.global_batch
        if shape.kind == "train":
            return 6.0 * n * tokens  # fwd + bwd
        return 2.0 * n * tokens
    return 2.0 * n * tokens


def build_step(cfg, shape, mesh, comm_mode="a2a"):
    """Returns (fn, example_args, in_shardings, donate) for the pair."""
    from repro.models.model import init_params
    from repro.serving.serve_step import make_serve_step
    from repro.training.optimizer import adamw_init
    from repro.training.train_step import make_prefill_step, make_train_step

    dp_axes = dp_axes_of(mesh)
    dp = dp_shards_of(mesh)
    specs = input_specs(cfg, shape.name, dp_shards=dp)
    kind = step_kind(cfg, shape)

    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0))
    )
    p_specs = param_specs(cfg, params_shape, mesh)

    if kind == "train":
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
        o_specs = opt_state_specs(p_specs)
        fn = make_train_step(cfg, mesh=mesh, dp_axes=dp_axes, comm_mode=comm_mode)
        args = (params_shape, opt_shape, specs)
        in_sh = (p_specs, o_specs, batch_specs(specs, dp_axes))
        donate = (0, 1)
    elif kind == "prefill":
        fn = make_prefill_step(cfg, mesh=mesh, dp_axes=dp_axes, comm_mode=comm_mode)
        args = (params_shape, specs)
        in_sh = (p_specs, batch_specs(specs, dp_axes))
        donate = ()
    else:  # decode
        fn = make_serve_step(cfg)
        cache = specs["cache"]
        c_specs = cache_sharding_specs(cfg, cache, dp_axes, mesh)
        B = specs["tokens"].shape[0]
        tok_spec = (
            jax.sharding.PartitionSpec(dp_axes) if B % dp == 0 and B >= dp
            else jax.sharding.PartitionSpec()
        )
        args = (params_shape, specs["tokens"], cache, specs["t"])
        in_sh = (p_specs, tok_spec, c_specs, jax.sharding.PartitionSpec())
        donate = (2,)
    return fn, args, in_sh, donate


def _compile_once(cfg, shape, mesh, comm_mode):
    fn, args, in_sh, donate = build_step(cfg, shape, mesh, comm_mode)
    with mesh:
        jitted = jax.jit(fn, in_shardings=to_shardings(in_sh, mesh),
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax<0.5 returns [dict]
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
    return mem, cost, hlo


def _stacks(cfg, kind):
    """(tag, trip_count, probe_unroll) for each layer scan in the step.
    Used for the roofline extrapolation: XLA cost_analysis prices a
    while-loop body once, so we probe with the body holding 1 and k
    layers and extrapolate linearly to the real trip count."""
    if cfg.family == "hybrid":
        trip = (cfg.shared_attn_every if kind == "decode"
                else cfg.n_layers // cfg.shared_attn_every)
    else:
        trip = cfg.n_layers
    k2 = 3 if trip % 2 else 2
    out = [("llm", trip, k2)]
    if kind != "decode" and cfg.family != "audio":
        for e in cfg.encoders:
            if e.n_layers > 0:
                out.append((e.name, e.n_layers, 3 if e.n_layers % 2 else 2))
    return out


def _probe_cfg(cfg, tag, k):
    import dataclasses as dc

    enc = tuple(
        dc.replace(e, scan_unroll=k if e.name == tag else 1) for e in cfg.encoders
    )
    return dc.replace(
        cfg,
        attention_impl="chunked_unrolled",
        scan_unroll=k if tag == "llm" else 1,
        encoders=enc,
    )


def _extract(cost, hlo):
    from repro.launch.roofline import collective_bytes

    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(hlo),
    }


def run_pair(arch: str, shape_name: str, *, multi_pod: bool, comm_mode="a2a",
             roofline: bool = True, hw: HW | None = None,
             cfg_override=None, tag_suffix: str = "") -> dict:
    cfg = cfg_override or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    kind = step_kind(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if kind is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": "sub-quadratic attention required"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    hw = hw or get_hw(chips=int(np.prod(list(mesh.shape.values()))))
    t0 = time.time()
    try:
        # Pass 1: production form (scan-over-layers) -- compile success,
        # memory_analysis, baseline HLO.
        mem, cost0, hlo0 = _compile_once(cfg, shape, mesh, comm_mode)
        t_main = time.time() - t0

        flops = bytes_ = None
        coll = None
        if roofline:
            # Pass 2..n: roofline probes with unrolled inner scans;
            # per-stack unroll 1 vs k extrapolates loop trip counts.
            _, c1, h1 = _compile_once(_probe_cfg(cfg, "llm", 1), shape, mesh, comm_mode)
            base = _extract(c1, h1)
            flops, bytes_ = base["flops"], base["bytes"]
            coll = dict(base["coll"])
            for tag, trip, k2 in _stacks(cfg, kind):
                _, c2, h2 = _compile_once(_probe_cfg(cfg, tag, k2), shape, mesh, comm_mode)
                probe = _extract(c2, h2)
                scale = (trip - 1) / (k2 - 1)
                flops += (probe["flops"] - base["flops"]) * scale
                bytes_ += (probe["bytes"] - base["bytes"]) * scale
                for key in coll:
                    coll[key] += (probe["coll"][key] - base["coll"][key]) * scale
    except Exception as e:  # noqa: BLE001 -- report, don't crash the sweep
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "FAILED", "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
    mem_d = {
        "argument_size": getattr(mem, "argument_size_in_bytes", None),
        "output_size": getattr(mem, "output_size_in_bytes", None),
        "temp_size": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
    }
    if not roofline:
        flops, bytes_ = float(cost0.get("flops", 0)), float(cost0.get("bytes accessed", 0))
        from repro.launch.roofline import collective_bytes

        coll = collective_bytes(hlo0)
    rep = analyze(
        arch=arch, shape=shape_name, mesh_name=mesh_name,
        cost={"flops": flops, "bytes accessed": bytes_},
        hlo_text="", memory=mem_d,
        model_flops_global=_model_flops(cfg, shape), hw=hw,
    )
    rep.coll_breakdown = {k: int(v) for k, v in coll.items()}
    rep.coll_bytes_per_chip = float(coll["total"])
    rep.collective_s = rep.coll_bytes_per_chip / hw.ici_bw
    terms = {"compute": rep.compute_s, "memory": rep.memory_s,
             "collective": rep.collective_s}
    rep.dominant = max(terms, key=terms.get)
    row = rep.row()
    row.update({
        "status": "ok", "kind": kind, "comm_mode": comm_mode,
        "roofline_corrected": roofline,
        "compile_s": round(time.time() - t0, 1), "main_compile_s": round(t_main, 1),
    })
    print(f"[{arch} x {shape_name} @ {mesh_name}] memory_analysis: {mem_d}")
    print(f"[{arch} x {shape_name} @ {mesh_name}] cost_analysis(corrected): "
          f"flops={flops:.3e} bytes={bytes_:.3e} coll={coll['total']:.3e}")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--comm-mode", default="a2a",
                    choices=["a2a", "ragged", "allgather", "gather"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--assigned-only", action="store_true",
                    help="only the 10 assigned archs (skip paper MLLMs)")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else [
        a for a in ARCHITECTURES if not args.assigned_only or not a.startswith("mllm")
    ]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                f = out / f"{tag}__{args.comm_mode}.json"
                if f.exists():
                    results.append(json.loads(f.read_text()))
                    print(f"cached {tag}")
                    continue
                print(f"=== {tag} (comm={args.comm_mode}) ===", flush=True)
                # Roofline probes on the single-pod mesh only (the table
                # is single-pod; multi-pod proves the pod axis shards).
                row = run_pair(arch, shape, multi_pod=mp,
                               comm_mode=args.comm_mode, roofline=not mp)
                f.write_text(json.dumps(row, indent=1, default=str))
                results.append(row)
                status = row["status"]
                extra = row.get("error", "")[:200] if status == "FAILED" else (
                    f"dominant={row.get('dominant')} compile={row.get('compile_s')}s"
                )
                print(f"--> {status} {extra}", flush=True)

    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    bad = [r for r in results if r["status"] == "FAILED"]
    print(f"\nSummary: {ok} ok, {sk} skipped, {len(bad)} failed of {len(results)}")
    for r in bad:
        print(f"  FAILED {r['arch']} x {r['shape']} @ {r['mesh']}: {r['error'][:200]}")


if __name__ == "__main__":
    main()
