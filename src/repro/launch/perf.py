import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""S-Perf hillclimb driver (EXPERIMENTS.md).

Re-lowers a chosen (arch x shape) pair with one optimization knob
changed and reports the delta on every roofline term vs the cached
baseline.  Experiments are named; each run writes
experiments/perf/<pair>__<variant>.json.

    PYTHONPATH=src python -m repro.launch.perf --exp qwen3_windowed
    PYTHONPATH=src python -m repro.launch.perf --list
"""
import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.dryrun import run_pair


def _variant(cfg, **kw):
    enc_kw = kw.pop("encoders_map", None)
    if enc_kw:
        kw["encoders"] = tuple(dataclasses.replace(e, **enc_kw) for e in cfg.encoders)
    return dataclasses.replace(cfg, **kw)


# Each experiment: (arch, shape, {variant_name: cfg_kwargs_or_run_kwargs}).
EXPERIMENTS = {
    # 1. memory-dominant dense train: window-chunked segment attention
    #    (exploits post-balancing's bounded segment length).
    "qwen3_windowed": ("qwen3_8b", "train_4k", {
        "segwin4096": dict(cfg=dict(segment_window=4096)),
        "segwin4096_bq256": dict(cfg=dict(segment_window=4096, block_q=256,
                                          block_kv=256)),
    }),
    "h2o_windowed": ("h2o_danube_3_4b", "train_4k", {
        "segwin4096": dict(cfg=dict(segment_window=4096)),
    }),
    # 2. collective-bound MoE train: buffer sharding + capacity factor.
    "grok_collective": ("grok_1_314b", "train_4k", {
        "moe_shard_buf": dict(cfg=dict(moe_shard_buffers=True)),
        "cap1.0": dict(cfg=dict(capacity_factor=1.0)),
        "moe_shard_buf_cap1.0": dict(cfg=dict(moe_shard_buffers=True,
                                              capacity_factor=1.0)),
        "segwin4096": dict(cfg=dict(segment_window=4096)),
        "combined": dict(cfg=dict(moe_shard_buffers=True, capacity_factor=1.0,
                                  segment_window=4096)),
    }),
    # 3. the paper's own technique, end to end: communicator mode on the
    #    representative multimodal arch (Fig. 12 analog in compiled HLO).
    "mllm_comm": ("mllm_10b", "train_4k", {
        "allgather": dict(run=dict(comm_mode="allgather")),
        "gather": dict(run=dict(comm_mode="gather")),
        "segwin4096": dict(cfg=dict(segment_window=4096)),
    }),
    # 4. big-model representative: windowed attention at 84B.
    "mllm84_windowed": ("mllm_84b", "train_4k", {
        "segwin4096": dict(cfg=dict(segment_window=4096)),
    }),
}


def show(row, base=None):
    if row["status"] != "ok":
        print(f"  !! {row['status']}: {row.get('error', row.get('reason'))}")
        return
    terms = {k: row[k] for k in ("compute_s", "memory_s", "collective_s")}
    line = "  " + "  ".join(f"{k[:-2]}={v:8.3f}s" for k, v in terms.items())
    line += f"  dominant={row['dominant']}  useful={row['useful_ratio']:.3f}"
    if base and base["status"] == "ok":
        deltas = []
        for k in terms:
            b = base[k]
            if b:
                deltas.append(f"{k[:-2]}:{row[k] / b:5.2f}x")
        line += "   [vs base " + " ".join(deltas) + "]"
    print(line, flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--baseline-dir", default="experiments/dryrun")
    args = ap.parse_args()
    if args.list:
        for k, (a, s, vs) in EXPERIMENTS.items():
            print(f"{k}: {a} x {s} -> {sorted(vs)}")
        return

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    todo = [args.exp] if args.exp else list(EXPERIMENTS)
    for name in todo:
        arch, shape, variants = EXPERIMENTS[name]
        print(f"=== {name}: {arch} x {shape} ===", flush=True)
        base_f = Path(args.baseline_dir) / f"{arch}__{shape}__16x16__a2a.json"
        if base_f.exists():
            base = json.loads(base_f.read_text())
        else:
            print("  (computing baseline)", flush=True)
            base = run_pair(arch, shape, multi_pod=False)
            base_f.write_text(json.dumps(base, indent=1, default=str))
        print("  baseline:")
        show(base)
        for vname, spec in variants.items():
            f = out / f"{arch}__{shape}__{vname}.json"
            if f.exists():
                row = json.loads(f.read_text())
            else:
                cfg = get_config(arch)
                if "cfg" in spec:
                    cfg = _variant(cfg, **spec["cfg"])
                run_kw = spec.get("run", {})
                row = run_pair(arch, shape, multi_pod=False, cfg_override=cfg,
                               **run_kw)
                row["variant"] = vname
                f.write_text(json.dumps(row, indent=1, default=str))
            print(f"  {vname}:")
            show(row, base)


if __name__ == "__main__":
    main()
