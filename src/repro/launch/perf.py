import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""S-Perf hillclimb driver (EXPERIMENTS.md).

Re-lowers a chosen (arch x shape) pair with one optimization knob
changed and reports the delta on every roofline term vs the cached
baseline.  Experiments are named; each run writes
experiments/perf/<pair>__<variant>.json.

    PYTHONPATH=src python -m repro.launch.perf --exp qwen3_windowed
    PYTHONPATH=src python -m repro.launch.perf --list
"""
import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import INPUT_SHAPES, get_config
from repro.core.cost_model import llm_cost_model
from repro.launch.dryrun import run_pair
from repro.telemetry import nnls_fit


def _variant(cfg, **kw):
    enc_kw = kw.pop("encoders_map", None)
    if enc_kw:
        kw["encoders"] = tuple(dataclasses.replace(e, **enc_kw) for e in cfg.encoders)
    return dataclasses.replace(cfg, **kw)


# Each experiment: (arch, shape, {variant_name: cfg_kwargs_or_run_kwargs}).
EXPERIMENTS = {
    # 1. memory-dominant dense train: window-chunked segment attention
    #    (exploits post-balancing's bounded segment length).
    "qwen3_windowed": ("qwen3_8b", "train_4k", {
        "segwin4096": dict(cfg=dict(segment_window=4096)),
        "segwin4096_bq256": dict(cfg=dict(segment_window=4096, block_q=256,
                                          block_kv=256)),
    }),
    "h2o_windowed": ("h2o_danube_3_4b", "train_4k", {
        "segwin4096": dict(cfg=dict(segment_window=4096)),
    }),
    # 2. collective-bound MoE train: buffer sharding + capacity factor.
    "grok_collective": ("grok_1_314b", "train_4k", {
        "moe_shard_buf": dict(cfg=dict(moe_shard_buffers=True)),
        "cap1.0": dict(cfg=dict(capacity_factor=1.0)),
        "moe_shard_buf_cap1.0": dict(cfg=dict(moe_shard_buffers=True,
                                              capacity_factor=1.0)),
        "segwin4096": dict(cfg=dict(segment_window=4096)),
        "combined": dict(cfg=dict(moe_shard_buffers=True, capacity_factor=1.0,
                                  segment_window=4096)),
    }),
    # 3. the paper's own technique, end to end: communicator mode on the
    #    representative multimodal arch (Fig. 12 analog in compiled HLO).
    "mllm_comm": ("mllm_10b", "train_4k", {
        "allgather": dict(run=dict(comm_mode="allgather")),
        "gather": dict(run=dict(comm_mode="gather")),
        "segwin4096": dict(cfg=dict(segment_window=4096)),
    }),
    # 4. big-model representative: windowed attention at 84B.
    "mllm84_windowed": ("mllm_84b", "train_4k", {
        "segwin4096": dict(cfg=dict(segment_window=4096)),
    }),
}


def coeff_delta(arch, baseline_dir, *, mesh="16x16", comm="a2a"):
    """Calibrated-vs-analytic cost coefficients from cached dry-runs.

    Fits (alpha, beta) of the paper's f(S) to the XLA-priced FLOPs of
    every cached shape for this arch (features: linear = tokens,
    quadratic = batch * seq^2; train rows are normalized by 3x for the
    backward pass) via the telemetry NNLS, and compares the fitted
    quadratic/linear ratio ``lam`` against ``llm_cost_model``'s analytic
    one.  A large ratio means the hand-derived coefficients mis-model
    this architecture and the balancing objective is skewed -- exactly
    what ``AdaptiveCostModel`` corrects online.  Needs >= 2 cached
    shapes to be identifiable (returns None otherwise)."""
    import numpy as np

    X, y, used = [], [], []
    for f in sorted(Path(baseline_dir).glob(f"{arch}__*__{mesh}__{comm}.json")):
        row = json.loads(f.read_text())
        if row.get("status") != "ok" or row.get("kind") not in ("train", "prefill"):
            continue
        shape = INPUT_SHAPES.get(row.get("shape"))
        flops = row.get("flops_per_chip")
        if shape is None or not flops:
            continue
        tokens = float(shape.seq_len) * shape.global_batch
        X.append([tokens, shape.global_batch * float(shape.seq_len) ** 2])
        y.append(float(flops) / (3.0 if row["kind"] == "train" else 1.0))
        used.append(shape.name)
    if len(set(used)) < 2:
        return None
    c = nnls_fit(np.asarray(X), np.asarray(y))
    if c[0] <= 0:
        return None
    lam_cal = float(c[1] / c[0])
    lam_ana = llm_cost_model(get_config(arch)).lam
    return {
        "coeff_lam_analytic": lam_ana,
        "coeff_lam_calibrated": lam_cal,
        "coeff_lam_ratio": (lam_cal / lam_ana) if lam_ana else None,
        "coeff_fit_shapes": used,
    }


def show(row, base=None):
    if row["status"] != "ok":
        print(f"  !! {row['status']}: {row.get('error', row.get('reason'))}")
        return
    terms = {k: row[k] for k in ("compute_s", "memory_s", "collective_s")}
    line = "  " + "  ".join(f"{k[:-2]}={v:8.3f}s" for k, v in terms.items())
    line += f"  dominant={row['dominant']}  useful={row['useful_ratio']:.3f}"
    # Cached rows predate the ledger-projected MFU; recompute on the fly
    # so old experiment files display it too (same canonical formula).
    mfu = row.get("mfu_projected")
    if mfu is None:
        from repro.obs.ledger import projected_mfu
        mfu = projected_mfu(row["useful_ratio"], *terms.values())
    line += f"  mfu_proj={mfu:.3f}"
    if row.get("coeff_lam_ratio") is not None:
        line += (f"  lam(cal/ana)={row['coeff_lam_ratio']:.2f}x"
                 f" [{row['coeff_lam_calibrated']:.2e} vs"
                 f" {row['coeff_lam_analytic']:.2e}]")
    if base and base["status"] == "ok":
        deltas = []
        for k in terms:
            b = base[k]
            if b:
                deltas.append(f"{k[:-2]}:{row[k] / b:5.2f}x")
        line += "   [vs base " + " ".join(deltas) + "]"
    print(line, flush=True)


def main():
    from repro.launch.roofline import HW_PRESETS

    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--baseline-dir", default="experiments/dryrun")
    ap.add_argument("--hw", default=None, choices=sorted(HW_PRESETS),
                    help="hardware preset for roofline terms (default: "
                         "$REPRO_HW or v5e)")
    args = ap.parse_args()
    if args.hw:
        # run_pair -> get_hw reads the env var; setting it here also
        # covers any nested dry-run invocations.
        os.environ["REPRO_HW"] = args.hw
    if args.list:
        for k, (a, s, vs) in EXPERIMENTS.items():
            print(f"{k}: {a} x {s} -> {sorted(vs)}")
        return

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    todo = [args.exp] if args.exp else list(EXPERIMENTS)
    for name in todo:
        arch, shape, variants = EXPERIMENTS[name]
        print(f"=== {name}: {arch} x {shape} ===", flush=True)
        base_f = Path(args.baseline_dir) / f"{arch}__{shape}__16x16__a2a.json"
        if base_f.exists():
            base = json.loads(base_f.read_text())
        else:
            print("  (computing baseline)", flush=True)
            base = run_pair(arch, shape, multi_pod=False)
            base_f.write_text(json.dumps(base, indent=1, default=str))
        # Calibrated-vs-analytic f(S) coefficients for this arch (from
        # every cached dry-run shape); a ratio far from 1x flags an
        # architecture whose balancing objective is mis-modeled.
        # Applied to cached AND fresh rows (the fit improves as more
        # dry-run shapes land), and persisted back to the files.
        coeffs = coeff_delta(arch, args.baseline_dir)
        if coeffs and coeffs != {k: base.get(k) for k in coeffs}:
            base.update(coeffs)
            base_f.write_text(json.dumps(base, indent=1, default=str))
        print("  baseline:")
        show(base)
        for vname, spec in variants.items():
            f = out / f"{arch}__{shape}__{vname}.json"
            if f.exists():
                row = json.loads(f.read_text())
            else:
                cfg = get_config(arch)
                if "cfg" in spec:
                    cfg = _variant(cfg, **spec["cfg"])
                run_kw = spec.get("run", {})
                row = run_pair(arch, shape, multi_pod=False, cfg_override=cfg,
                               **run_kw)
                row["variant"] = vname
            if coeffs and coeffs != {k: row.get(k) for k in coeffs}:
                row.update(coeffs)
                f.write_text(json.dumps(row, indent=1, default=str))
            elif not f.exists():
                f.write_text(json.dumps(row, indent=1, default=str))
            print(f"  {vname}:")
            show(row, base)


if __name__ == "__main__":
    main()
