"""Paper Table 1 MLLM-84B: 72B LLM + ViT-6B + Whisper-6B."""
import dataclasses

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="mllm-84b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    encoders=(
        EncoderConfig(name="vision", n_layers=45, d_model=3200, n_heads=25,
                      d_ff=12800, embed_dim=1176, downsample=4,
                      tokens_per_example_max=4096),  # 896/14 = 64x64
        EncoderConfig(name="audio", n_layers=48, d_model=3072, n_heads=24,
                      d_ff=12288, embed_dim=1280, downsample=4, padded=True,
                      conv_attention=True, tokens_per_example_max=1500),
    ),
    # Train on the Pallas flash path end to end (encoders + backbone +
    # decode); compiles via Mosaic on TPU, interpret mode elsewhere.
    attention_impl="flash",
    block_q=128,
    block_kv=128,
    citation="OrchMLLM Table 1 (MLLM-84B)",
)

# Pipeline-staged variant (the paper's 2560-GPU regime analogue): 80
# backbone layers over 4 stages, 16 microbatches so the 1F1B steady
# state saturates and the warm-up/cool-down bubbles can absorb the
# encoder compute (docs/pipeline.md; benchmarks/pipeline_bubbles.py).
STAGED_CONFIG = dataclasses.replace(
    CONFIG, pp_stages=4, pp_microbatches=16, pp_bubble_fill=True)
