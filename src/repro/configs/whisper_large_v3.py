"""whisper-large-v3: encoder-decoder ASR [arXiv:2212.04356].

Mel-spectrogram + conv frontend is a STUB: input_specs() provides frame
embeddings [T<=1500, 1280].  The 32-layer bidirectional encoder and the
32-layer causal decoder with cross-attention are real.  Audio batches
use PADDING (paper S8: 'audios are batched with paddings, due to the
convolution architecture') -> the audio phase uses Alg 2 and the
conv-attention cost model."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,          # decoder layers
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    encoders=(
        EncoderConfig(
            name="audio",
            n_layers=0,   # the encoder stack lives in the enc-dec model itself
            d_model=1280,
            n_heads=20,
            d_ff=5120,
            embed_dim=1280,
            downsample=1,
            padded=True,
            conv_attention=True,
            tokens_per_example_max=1500,
        ),
    ),
    citation="arXiv:2212.04356",
)
