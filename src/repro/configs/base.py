"""Config schema for every architecture in the zoo.

A model is described declaratively; ``repro.models.model.build_model``
turns a :class:`ModelConfig` into init/apply functions.  All assigned
architectures (10) plus the paper's own MLLM-10B/18B/84B (Table 1) are
expressed in this schema -- see the sibling ``<arch>.py`` modules.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["EncoderConfig", "EngineConfig", "ModelConfig", "with_attention_backend"]

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """A modality encoder submodule (paper S2.1).

    For assigned [vlm]/[audio] archs the *frontend* (ViT / mel+conv) is a
    stub -- ``input_specs()`` supplies precomputed patch/frame embeddings
    of shape [tokens, embed_dim]; the transformer below (n_layers may be
    0 for pure-stub connectors like LLaVA's) plus the MLP connector is
    real and is a balancing *phase* of its own.
    """

    name: str  # "vision" | "audio"
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    embed_dim: int  # incoming stub embedding dim
    downsample: int = 1  # paper S8: downsample before the connector
    padded: bool = False  # paper: audio batches WITH padding (conv arch)
    conv_attention: bool = False  # App. A cost model for conv-transformers
    tokens_per_example_max: int = 2048
    scan_unroll: int = 1  # roofline probes (see ModelConfig.scan_unroll)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // n_heads

    # Attention variants.
    rope_theta: float = 10_000.0
    qk_norm: bool = False  # qwen3
    sliding_window: int | None = None  # h2o-danube SWA
    nonparametric_norm: bool = False  # olmo-1b
    tie_embeddings: bool = False

    # MoE.
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # Expert dispatch backend: "dense" = legacy [E, capacity, d] buffer
    # (static shapes, drops past capacity); "grouped" = drop-free sorted
    # dispatch through the Pallas grouped-GEMM kernel
    # (kernels/grouped_gemm.py, tile-skip over empty experts).
    moe_backend: Literal["dense", "grouped"] = "dense"
    moe_block_m: int = 128
    moe_block_n: int = 128

    # SSM (mamba).
    ssm_variant: Literal["mamba1", "mamba2", None] = None
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_headdim: int = 64  # mamba2
    # Selective-scan backend: "scan" = chunked lax.scan recurrence;
    # "pallas" = the fused kernel (kernels/selective_scan.py) with its
    # chunk-checkpointed custom VJP.
    ssm_backend: Literal["scan", "pallas"] = "scan"
    ssm_block_d: int = 128
    ssm_chunk: int = 64

    # Hybrid (zamba2): a shared attention block every `shared_attn_every`
    # SSM layers, reusing ONE set of attention weights each time.
    shared_attn_every: int = 0

    # Encoder-decoder (whisper): n_layers counts DECODER layers;
    # cross-attention in every decoder layer.
    is_encoder_decoder: bool = False
    encoder_layers: int = 0

    # Multimodal encoders (paper S2.1 submodules).
    encoders: tuple[EncoderConfig, ...] = ()

    # Numerics / implementation.
    dtype: str = "bfloat16"
    # Attention backend for every attention site (encoders, LLM
    # backbone, cross attention, decode) -- see
    # repro.models.attention.ATTENTION_BACKENDS.
    #   "chunked_unrolled" = roofline mode: inner scans (attention KV
    #   blocks, xent chunks) unroll so cost_analysis counts every
    #   iteration (XLA prices a while-loop body once).
    #   "flash" = the Pallas kernel (Mosaic on TPU, interpret off-TPU);
    #   "flash_interpret" forces the interpreter (CPU validation).
    attention_impl: Literal[
        "reference", "chunked", "chunked_unrolled", "flash", "flash_interpret"
    ] = "chunked"
    block_q: int = 512
    block_kv: int = 512
    # Beyond-paper: window-chunked segment attention.  When set (to the
    # max example/segment length), self-attention over packed streams
    # computes [W x 2W] windows instead of [T x T] -- exact because
    # post-balanced segments never exceed W.  None = paper-faithful.
    segment_window: int | None = None
    # Beyond-paper: explicit sharding constraint on the MoE dispatch
    # buffers ([E, C, d] capacity dim over the model axis) -- a S-Perf
    # knob against collective-bound MoE steps.
    moe_shard_buffers: bool = False
    remat: bool = True
    # Layer-scan unroll factor; the dry-run compiles at 1 and 2 (3 for
    # hybrids) and extrapolates exact per-layer FLOPs/bytes/collectives.
    scan_unroll: int = 1
    # Consult the kernel autotune cache (kernels/autotune.py) at trace
    # time: tuned block shapes override block_q/block_kv, moe_block_*,
    # ssm_block_d/ssm_chunk when a cache entry matches the call shape.
    kernel_autotune: bool = False
    autotune_cache: str | None = None  # path; None = default location
    # Pipeline parallelism (docs/pipeline.md): number of stages the LLM
    # backbone is partitioned into (1 = DP-only), microbatches per step
    # (0 = auto: 2*pp_stages), and whether encoder microbatches are
    # scheduled into the 1F1B warm-up/cool-down bubbles.
    pp_stages: int = 1
    pp_microbatches: int = 0
    pp_bubble_fill: bool = True
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_backend(self) -> str:
        """The configured attention backend (``attention_impl`` keeps its
        historical field name for config compatibility)."""
        return self.attention_impl

    @property
    def decode_backend(self) -> str:
        """Backend for single-token decode.  The chunked scan is pure
        overhead for a 1-row query, so chunked variants decode through
        the dense reference row; flash backends pass through (the kernel
        pads the query tile)."""
        if self.attention_impl in ("flash", "flash_interpret"):
            return self.attention_impl
        return "reference"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6*N*D roofline term)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        return _param_count(self, active_only=True)

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family: <=2 layers, d_model<=256,
        <=4 experts -- runs one forward/train step on CPU."""
        enc = tuple(
            dataclasses.replace(
                e, n_layers=min(e.n_layers, 2), d_model=128, n_heads=2,
                d_ff=256, embed_dim=64, tokens_per_example_max=64,
            )
            for e in self.encoders
        )
        return dataclasses.replace(
            self,
            n_layers=2,
            encoder_layers=min(self.encoder_layers, 2),
            d_model=256 if not self.ssm_variant else 128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=None,
            d_ff=512,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_headdim=32 if self.ssm_variant == "mamba2" else self.ssm_headdim,
            ssm_state=min(self.ssm_state, 16) or self.ssm_state,
            sliding_window=64 if self.sliding_window else None,
            shared_attn_every=2 if self.shared_attn_every else 0,
            block_q=64,
            block_kv=64,
            encoders=enc,
            name=self.name + "-smoke",
        )


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs for the continuous-batching serving engine
    (:mod:`repro.serving.engine`).

    The pool is ``num_blocks`` KV blocks of ``block_size`` tokens each
    (block 0 is the reserved all-zero null block, so the usable capacity
    is ``num_blocks - 1``).  ``token_budget`` caps the modality-weighted
    work admitted per engine step: each running decode costs the serving
    cost model's ``decode_cost`` (1 by default) and each admitted
    prefill costs ``f(weighted prompt length)``.  ``max_model_len`` is
    the logical per-sequence cache length (prompt + generation must fit
    unless the model uses a sliding window, whose ring needs only
    ``sliding_window`` slots).  ``prefill_pad`` / ``decode_pad`` round
    batched shapes up so jit retraces stay bounded.
    """

    block_size: int = 16
    num_blocks: int = 129
    max_num_seqs: int = 8
    token_budget: int = 512
    max_model_len: int = 256
    replicas: int = 1
    prefill_pad: int = 32
    decode_pad: int = 4
    # Max padding overhead of a prefill sub-batch, as a fraction of its
    # useful tokens: a group is closed rather than padded past
    # useful * (1 + prefill_waste) slots.  Admitted prompts are split
    # into length-sorted groups (Algorithm 2's bounded padded batches)
    # so one long prompt cannot inflate every co-admitted short one to
    # its padded length.
    prefill_waste: float = 0.35
    balancing_backend: str = "vectorized"

    def __post_init__(self) -> None:
        if self.block_size < 1 or self.num_blocks < 2:
            raise ValueError("need block_size >= 1 and num_blocks >= 2 "
                             "(block 0 is the reserved null block)")
        if self.max_model_len % self.block_size:
            raise ValueError(
                f"max_model_len={self.max_model_len} must be a multiple of "
                f"block_size={self.block_size}")
        if self.max_num_seqs < 1 or self.replicas < 1:
            raise ValueError("need max_num_seqs >= 1 and replicas >= 1")
        if self.token_budget < 1 or self.prefill_pad < 1 or self.decode_pad < 1:
            raise ValueError("token_budget / prefill_pad / decode_pad must be >= 1")
        if self.prefill_waste < 0.0:
            raise ValueError("prefill_waste must be >= 0")

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1


def with_attention_backend(cfg: ModelConfig, backend: str | None) -> ModelConfig:
    """Copy of ``cfg`` on the given attention backend, validated eagerly
    (a typo fails here, not deep inside a jitted trace).  None = cfg
    unchanged."""
    if backend is None:
        return cfg
    from repro.models.attention import ATTENTION_BACKENDS

    if backend not in ATTENTION_BACKENDS:
        raise ValueError(f"unknown attention backend {backend!r}; "
                         f"choose from {ATTENTION_BACKENDS}")
    return dataclasses.replace(cfg, attention_impl=backend)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    total = cfg.vocab_size * d  # embed
    if not cfg.tie_embeddings:
        total += d * cfg.vocab_size  # lm head

    def attn_params() -> int:
        return d * nh * hd + 2 * d * nkv * hd + nh * hd * d

    def mlp_params() -> int:
        return 3 * d * f  # swiglu

    def mamba_params() -> int:
        di = cfg.d_inner
        n = cfg.ssm_state
        if cfg.ssm_variant == "mamba2":
            nheads = di // cfg.ssm_headdim
            return d * (2 * di + 2 * n + nheads) + di * d + di * cfg.ssm_conv
        # mamba1: in_proj 2*di, x_proj di->(dt_rank+2n), dt_proj, out_proj, A, D, conv
        dt_rank = max(1, d // 16)
        return (
            d * 2 * di + di * (dt_rank + 2 * n) + dt_rank * di + di * d
            + di * n + di + di * cfg.ssm_conv
        )

    if cfg.family in ("dense", "vlm"):
        total += cfg.n_layers * (attn_params() + mlp_params())
    elif cfg.family == "moe":
        e_count = cfg.experts_per_token if active_only else cfg.n_experts
        total += cfg.n_layers * (attn_params() + e_count * mlp_params() + d * cfg.n_experts)
    elif cfg.family == "ssm":
        total += cfg.n_layers * mamba_params()
    elif cfg.family == "hybrid":
        total += cfg.n_layers * mamba_params()
        if cfg.shared_attn_every:
            total += attn_params() + mlp_params()  # ONE shared block
    elif cfg.family == "audio":
        total += cfg.n_layers * (2 * attn_params() + mlp_params())  # dec: self+cross
        total += cfg.encoder_layers * (attn_params() + mlp_params())
    for e in cfg.encoders:
        ed, ef = e.d_model, e.d_ff
        per = 4 * ed * ed + 3 * ed * ef
        total += e.n_layers * per + e.embed_dim * ed + ed * d  # + connector
    return total
