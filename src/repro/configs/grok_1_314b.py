"""grok-1-314b: MoE 8 experts top-2, GQA kv=8 [hf:xai-org/grok-1]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    experts_per_token=2,
    # Drop-free grouped-GEMM expert dispatch (kernels/grouped_gemm.py).
    moe_backend="grouped",
    citation="hf:xai-org/grok-1",
)
