"""Architecture registry + assigned input shapes + input_specs().

``get_config(name)`` resolves ``--arch <id>``.  ``input_specs(cfg,
shape_name, mesh_info)`` builds ShapeDtypeStruct stand-ins for every
model input of the (architecture x input-shape) pair -- weak-type
correct, shardable, no device allocation (dry-run contract).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils import round_up as _round_up

__all__ = ["ARCHITECTURES", "INPUT_SHAPES", "get_config", "input_specs", "step_kind",
           "cache_specs", "paged_cache_specs"]

ARCHITECTURES = (
    "falcon_mamba_7b",
    "grok_1_314b",
    "h2o_danube_3_4b",
    "llava_next_mistral_7b",
    "qwen3_8b",
    "olmo_1b",
    "whisper_large_v3",
    "zamba2_2_7b",
    "granite_moe_3b_a800m",
    "starcoder2_15b",
    # The paper's own models (Table 1):
    "mllm_10b",
    "mllm_18b",
    "mllm_84b",
)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention (see DESIGN.md S4): SSM, hybrid,
# and native-SWA dense only.
LONG_CONTEXT_OK = {"falcon_mamba_7b", "zamba2_2_7b", "h2o_danube_3_4b"}


def get_config(name: str, *, attention_backend: str | None = None) -> ModelConfig:
    """Resolve ``--arch <id>``; ``attention_backend`` overrides the
    config's attention path (e.g. force "flash" / "reference")."""
    name = name.replace("-", "_")
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHITECTURES}")
    mod = importlib.import_module(f"repro.configs.{name}")
    from repro.configs.base import with_attention_backend

    return with_attention_backend(mod.CONFIG, attention_backend)


def step_kind(cfg: ModelConfig, shape: InputShape) -> str | None:
    """Which step this pair lowers; None = skipped (DESIGN.md S4)."""
    key = cfg.name.replace("-", "_").replace(".", "_")
    if shape.name == "long_500k" and key not in LONG_CONTEXT_OK:
        return None
    return shape.kind


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str, *, dp_shards: int) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch x input shape) pair.

    ``dp_shards`` = product of the DP mesh axes (pod*data); every leading
    dim is a multiple of it so the arrays shard cleanly.
    """
    shp = INPUT_SHAPES[shape_name]
    kind = step_kind(cfg, shp)
    if kind is None:
        raise ValueError(f"{cfg.name} skips {shape_name} (see DESIGN.md)")
    i32, f32 = jnp.int32, jnp.bfloat16

    if kind in ("train", "prefill"):
        # Per-shard packed stream capacity: global tokens / shards.
        total_tokens = shp.seq_len * shp.global_batch
        cap = max(total_tokens // dp_shards, shp.seq_len)
        S = dp_shards
        if cfg.encoders and cfg.family != "audio":
            return _mm_specs(cfg, S, cap, i32, f32)
        if cfg.family == "audio":
            return _encdec_specs(cfg, S, cap, i32, f32)
        return {
            "tokens": _sds((S, cap), i32),
            "labels": _sds((S, cap), i32),
            "seg": _sds((S, cap), i32),
            "pos": _sds((S, cap), i32),
        }

    # decode: one new token per request, KV/SSM state at seq_len.  When
    # B < dp_shards (long_500k), the cache shards over its seq/feature
    # dims instead of batch (see repro.sharding.specs).
    B = shp.global_batch
    return {
        "tokens": _sds((B, 1), i32),
        "t": _sds((), i32),
        "cache": cache_specs(cfg, B, shp.seq_len),
    }


def _mm_specs(cfg, S, cap, i32, f32):
    """VLM / paper-MLLM train batch: text + per-encoder streams + plan."""
    specs = {
        "tokens": _sds((S, cap // 2), i32),
        "text_dst": _sds((S, cap // 2), i32),
        "llm_seg": _sds((S, cap), i32),
        "llm_pos": _sds((S, cap), i32),
        "llm_labels": _sds((S, cap), i32),
    }
    for e in cfg.encoders:
        cap_e = _round_up(cap // 2, e.downsample * 128)
        cap_eo = cap_e // e.downsample
        specs.update({
            f"enc_{e.name}_embeds": _sds((S, cap_e, e.embed_dim), f32),
            f"enc_{e.name}_seg": _sds((S, cap_e), i32),
            f"enc_{e.name}_pos": _sds((S, cap_e), i32),
            f"enc_{e.name}_dst": _sds((S, cap_eo), i32),
            **_plan_specs(e.name, S, cap_eo, i32),
        })
    return specs


def _encdec_specs(cfg, S, cap, i32, f32):
    e = cfg.encoders[0]
    cap_e = _round_up(cap, e.downsample * 128)
    cap_eo = cap_e  # encoder output stream stays per-shard, same capacity
    return {
        "tokens": _sds((S, cap), i32),
        "labels": _sds((S, cap), i32),
        "seg": _sds((S, cap), i32),
        "pos": _sds((S, cap), i32),
        f"enc_{e.name}_embeds": _sds((S, cap_e, e.embed_dim), f32),
        f"enc_{e.name}_seg": _sds((S, cap_e), i32),
        f"enc_{e.name}_pos": _sds((S, cap_e), i32),
        f"enc_{e.name}_seg_out": _sds((S, cap_eo), i32),
        f"enc_{e.name}_pos_out": _sds((S, cap_eo), i32),
        **_plan_specs(e.name, S, cap_eo, i32),
    }


def _plan_specs(name, S, cap_out, i32):
    """Communicator plan arrays (dense-a2a mode) as specs.

    chunk_cap is a static capacity; we size it at cap_out//S rounded up
    (balanced plans send ~1/S of a shard's tokens to each peer)."""
    chunk = _round_up(max(cap_out // S, 8), 8)
    return {
        f"enc_{name}_plan_pre_gather_dense": _sds((S, S * chunk), i32),
        f"enc_{name}_plan_post_gather_dense": _sds((S, cap_out), i32),
        f"enc_{name}_plan_post_mask": _sds((S, cap_out), jnp.bool_),
        f"enc_{name}_plan_global_gather": _sds((S, cap_out), i32),
    }


def cache_specs(cfg: ModelConfig, B: int, seq_len: int):
    """Decode-state specs per family (full KV / SWA ring / SSM state)."""
    f32 = jnp.float32
    bf16 = jnp.bfloat16
    hd, Hkv, L = cfg.head_dim_, cfg.n_kv_heads, cfg.n_layers

    def attn_cache(n_layers, S):
        return {
            "k": _sds((n_layers, B, S, Hkv, hd), bf16),
            "v": _sds((n_layers, B, S, Hkv, hd), bf16),
            "kv_pos": _sds((B, S), jnp.int32),
            "kv_seg": _sds((B, S), jnp.int32),
        }

    if cfg.family in ("dense", "moe", "vlm"):
        S = min(seq_len, cfg.sliding_window or seq_len)
        return attn_cache(L, S)
    if cfg.family == "ssm":
        di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        return {
            "conv": _sds((L, B, K - 1, di), bf16),
            "h": _sds((L, B, di, N), f32),
        }
    if cfg.family == "hybrid":
        di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        H = di // cfg.ssm_headdim
        G = L // cfg.shared_attn_every
        c = {
            "conv": _sds((L, B, K - 1, di), bf16),
            "h": _sds((L, B, H, cfg.ssm_headdim, N), f32),
        }
        sa = attn_cache(G, seq_len)  # zamba's shared attn sees full history
        return {**c, **{f"sa_{k}": v for k, v in sa.items()}}
    if cfg.family == "audio":
        e = cfg.encoders[0]
        enc_T = e.tokens_per_example_max
        return {
            **attn_cache(L, seq_len),
            "cross_k": _sds((L, B, enc_T, Hkv, hd), bf16),
            "cross_v": _sds((L, B, enc_T, Hkv, hd), bf16),
            "cross_seg": _sds((B, enc_T), jnp.int32),
            "cross_pos": _sds((B, enc_T), jnp.int32),
        }
    raise ValueError(cfg.family)


def paged_cache_specs(cfg: ModelConfig, num_blocks: int, block_size: int):
    """Decode-state specs for the paged KV pool (serving engine).

    The attention cache of :func:`cache_specs` re-laid-out as a pool of
    fixed-size blocks shared by every sequence: k/v are
    ``[L, num_blocks, block_size, Hkv, hd]`` and kv_pos/kv_seg are
    ``[num_blocks, block_size]`` (shared across layers, exactly like the
    dense ``[B, S]`` layout).  A sequence's logical cache of S slots is
    the gather of its block table -- slot ``i`` lives at
    ``(table[i // block_size], i % block_size)``.

    Only attention-cache families page: SSM/hybrid decode state is O(1)
    per sequence (nothing to page) and audio adds per-request
    cross-attention state the pool does not model.
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"paged KV cache supports dense/moe/vlm families, not {cfg.family!r}")
    bf16 = jnp.bfloat16
    hd, Hkv, L = cfg.head_dim_, cfg.n_kv_heads, cfg.n_layers
    return {
        "k": _sds((L, num_blocks, block_size, Hkv, hd), bf16),
        "v": _sds((L, num_blocks, block_size, Hkv, hd), bf16),
        "kv_pos": _sds((num_blocks, block_size), jnp.int32),
        "kv_seg": _sds((num_blocks, block_size), jnp.int32),
    }


