"""granite-moe-3b-a800m: MoE 40 experts top-8, per-expert d_ff=512
[hf:ibm-granite/granite-3.0-3b-a800m-base].

Note: the assignment line says "MoE 40e top-8" while its bracket remark
says "32 experts"; we follow the explicit field (40 experts, top-8),
which matches the HF model card."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    experts_per_token=8,
    # 40 small experts, top-8: the imbalance-sensitive case the
    # grouped-GEMM backend exists for.
    moe_backend="grouped",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
