from repro.configs.base import EncoderConfig, EngineConfig, ModelConfig
from repro.configs.registry import (
    ARCHITECTURES,
    INPUT_SHAPES,
    cache_specs,
    get_config,
    input_specs,
    paged_cache_specs,
    step_kind,
)

__all__ = [
    "ARCHITECTURES", "INPUT_SHAPES", "EncoderConfig", "EngineConfig",
    "ModelConfig", "cache_specs", "get_config", "input_specs",
    "paged_cache_specs", "step_kind",
]
