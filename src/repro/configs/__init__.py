from repro.configs.base import EncoderConfig, ModelConfig
from repro.configs.registry import (
    ARCHITECTURES,
    INPUT_SHAPES,
    get_config,
    input_specs,
    step_kind,
)

__all__ = [
    "ARCHITECTURES", "INPUT_SHAPES", "EncoderConfig", "ModelConfig",
    "get_config", "input_specs", "step_kind",
]
