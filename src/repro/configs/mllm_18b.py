"""Paper Table 1 MLLM-18B: 14B LLM + ViT-3B + Whisper-0.6B."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="mllm-18b",
    family="vlm",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    encoders=(
        EncoderConfig(name="vision", n_layers=40, d_model=2400, n_heads=24,
                      d_ff=9600, embed_dim=1176, downsample=4,
                      tokens_per_example_max=2304),  # 672/14 = 48x48
        EncoderConfig(name="audio", n_layers=32, d_model=1280, n_heads=20,
                      d_ff=5120, embed_dim=1280, downsample=2, padded=True,
                      conv_attention=True, tokens_per_example_max=1500),
    ),
    citation="OrchMLLM Table 1 (MLLM-18B)",
)
