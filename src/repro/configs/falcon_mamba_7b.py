"""falcon-mamba-7b: attention-free Mamba-1 SSM [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,       # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm_variant="mamba1",
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    # Fused Pallas selective scan (kernels/selective_scan.py).
    ssm_backend="pallas",
    citation="arXiv:2410.05355",
)
