"""llava-next-mistral-7b: VLM, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision tower (CLIP ViT-L/14) + projector frontend is a STUB per the
assignment carve-out: input_specs() provides precomputed patch
embeddings (1024-dim); the MLP connector into the 4096-dim LLM space and
the Mistral-7B backbone are real.  AnyRes tiling makes image token
counts vary wildly per example -- exactly the Modality Composition
Incoherence case the paper targets."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1e6,
    encoders=(
        EncoderConfig(
            name="vision",
            n_layers=0,          # frontend stub: embeddings arrive projected
            d_model=1024,
            n_heads=16,
            d_ff=4096,
            embed_dim=1024,
            downsample=1,
            tokens_per_example_max=2880,  # anyres: up to 5 tiles x 576
        ),
    ),
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
