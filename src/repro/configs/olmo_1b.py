"""olmo-1b: dense with non-parametric LayerNorm [arXiv:2402.00838]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    nonparametric_norm=True,
    tie_embeddings=True,
    citation="arXiv:2402.00838",
)
