"""Paper Table 1 MLLM-10B: Qwen2-7B backbone + ViT-2B + Whisper-0.6B.

Downsample rates (paper S8): vision 1, audio 2.  Vision batched packed
(no padding, Alg 1); audio batched padded (Alg 2 + conv cost model)."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="mllm-10b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    encoders=(
        EncoderConfig(name="vision", n_layers=36, d_model=2048, n_heads=16,
                      d_ff=8192, embed_dim=1176, downsample=1,
                      tokens_per_example_max=1024),  # 448/14 = 32x32
        EncoderConfig(name="audio", n_layers=32, d_model=1280, n_heads=20,
                      d_ff=5120, embed_dim=1280, downsample=2, padded=True,
                      conv_attention=True, tokens_per_example_max=1500),
    ),
    citation="OrchMLLM Table 1 (MLLM-10B)",
)
