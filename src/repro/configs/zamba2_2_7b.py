"""zamba2-2.7b: hybrid Mamba-2 + shared attention blocks [arXiv:2411.15242].

54 Mamba-2 layers; ONE shared attention+MLP block (single weight set)
applied every 6 SSM layers -- the Zamba parameter-sharing trick."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_variant="mamba2",
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_headdim=64,
    # Mamba-2 maps onto the mamba1 Pallas kernel by head broadcast.
    ssm_backend="pallas",
    shared_attn_every=6,
    citation="arXiv:2411.15242",
)
