"""PartitionSpecs for params, optimizer state, batches and decode caches.

Strategy (mirrors the paper's FSDP setup, S7/S8, mapped to TPU):
  * params: ZeRO-3-style sharding over the ``data`` axis + tensor
    parallelism over ``model``; the ``pod`` axis REPLICATES params --
    that's the paper's hybrid-shard group (they used group size 256; our
    single-pod data*model = 256 matches), with gradient all-reduce over
    pods.
  * batch streams: leading (DP-shard) dim over (pod, data).
  * decode caches: batch dim over DP when divisible; otherwise the
    long-context case (B=1) shards the sequence / feature dims instead.

Assignment is pattern-free: for every param leaf we pick the last dim
divisible by the ``model`` axis for TP and the largest remaining dim
divisible by ``data`` for FSDP, skipping the stacked-layer leading dim.
This is deliberately generic -- per-arch hand overrides live in the
perf-iteration layer (EXPERIMENTS.md S-Perf), not here.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = [
    "param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_sharding_specs",
    "stage_partition",
    "to_shardings",
]


def stage_partition(n_layers: int, pp: int,
                    layer_costs=None) -> tuple[int, ...]:
    """Contiguous partition of ``n_layers`` into ``pp`` pipeline stages.

    Minimizes the max per-stage cost over contiguous splits (activations
    only flow between adjacent stages, so stages must be contiguous).
    ``layer_costs`` is an optional per-layer cost vector -- e.g. the
    calibrated per-layer LLM cost from the telemetry fits -- defaulting
    to uniform layers, where the split is the balanced floor/ceil one.
    Returns layers-per-stage (len ``pp``, sums to ``n_layers``); every
    stage gets at least one layer.
    """
    if pp < 1:
        raise ValueError(f"pp must be >= 1, got {pp}")
    if pp > n_layers:
        raise ValueError(f"pp={pp} exceeds n_layers={n_layers}")
    if pp == 1:
        return (n_layers,)
    if layer_costs is None:
        base, extra = divmod(n_layers, pp)
        # Heavier stages FIRST: warmup bubbles shrink toward the tail,
        # so front-loading keeps the steady-state critical path tight.
        return tuple(base + (1 if s < extra else 0) for s in range(pp))
    costs = np.asarray(layer_costs, dtype=np.float64)
    if costs.shape != (n_layers,):
        raise ValueError(f"layer_costs must have shape ({n_layers},)")
    prefix = np.concatenate([[0.0], np.cumsum(costs)])

    def feasible(cap: float) -> tuple[int, ...] | None:
        """Greedy: longest prefix per stage under ``cap``; leave enough
        layers so every remaining stage can take at least one."""
        out, lo = [], 0
        for s in range(pp):
            hi_max = n_layers - (pp - 1 - s)
            hi = int(np.searchsorted(prefix, prefix[lo] + cap, side="right")) - 1
            hi = min(max(hi, lo + 1), hi_max)
            out.append(hi - lo)
            lo = hi
        return tuple(out) if lo == n_layers else None

    # Binary search the min-max stage cost over the distinct candidates.
    lo_cap, hi_cap = float(costs.max()), float(costs.sum())
    best = feasible(hi_cap)
    for _ in range(64):
        mid = 0.5 * (lo_cap + hi_cap)
        got = feasible(mid)
        if got is not None:
            best, hi_cap = got, mid
        else:
            lo_cap = mid
    assert best is not None
    return best


def _leaf_spec(shape: tuple[int, ...], data: int, model: int,
               *, skip_dims: int = 0) -> P:
    """Generic FSDP+TP assignment with divisibility checks."""
    spec: list[Any] = [None] * len(shape)
    dims = list(range(skip_dims, len(shape)))
    # TP: last eligible dim divisible by `model` and reasonably large.
    tp_dim = None
    for d in reversed(dims):
        if model > 1 and shape[d] % model == 0 and shape[d] >= 2 * model:
            tp_dim = d
            spec[d] = "model"
            break
    # FSDP: largest remaining dim divisible by `data`.
    best, best_size = None, 0
    for d in dims:
        if d == tp_dim:
            continue
        if data > 1 and shape[d] % data == 0 and shape[d] >= data and shape[d] > best_size:
            best, best_size = d, shape[d]
    if best is not None:
        spec[best] = "data"
    return P(*spec)


def param_specs(cfg: ModelConfig, params, mesh: Mesh):
    """Specs matching the params pytree.  Stacked-layer leaves (inside
    'layers'/'enc_layers') skip their leading [L] dim for FSDP/TP; when
    the mesh carries a ``pp`` axis that dim is instead SHARDED over it --
    stage s owns its contiguous layer slice (``stage_partition``), which
    is exactly the pipeline placement expressed as a sharding."""
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)
    pp = mesh.shape.get("pp", 1)

    def walk(tree, stacked: bool):
        if isinstance(tree, dict):
            return {
                k: walk(v, stacked or k in ("layers", "enc_layers"))
                for k, v in tree.items()
            }
        spec = _leaf_spec(tree.shape, data, model, skip_dims=1 if stacked else 0)
        if stacked and pp > 1 and tree.shape[0] % pp == 0:
            spec = P("pp", *tuple(spec)[1:]) if len(spec) > 1 else P("pp")
        return spec

    return walk(params, False)


def opt_state_specs(p_specs):
    return {
        "mu": p_specs,
        "nu": p_specs,
        "step": P(),
    }


def batch_specs(batch: dict[str, Any], dp_axes: tuple[str, ...]) -> dict[str, P]:
    """All batch arrays carry the DP-shard layout on their leading dim."""
    return {k: P(dp_axes) for k in batch}


def cache_sharding_specs(cfg: ModelConfig, cache, dp_axes: tuple[str, ...],
                         mesh: Mesh):
    """Decode-cache specs; see module docstring for the B=1 fallback."""
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    model = mesh.shape.get("model", 1)
    all_axes = tuple(mesh.axis_names)

    def leaf(path: str, x) -> P:
        shape = x.shape
        if path in ("kv_pos", "kv_seg", "sa_kv_pos", "sa_kv_seg",
                    "cross_seg", "cross_pos"):
            B = shape[0]
            return P(dp_axes) if B % dp == 0 and B >= dp else P()
        if path in ("k", "v", "sa_k", "sa_v", "cross_k", "cross_v"):
            L, B, S = shape[0], shape[1], shape[2]
            if B % dp == 0 and B >= dp:
                seq_ax = "model" if S % model == 0 and S >= model else None
                return P(None, dp_axes, seq_ax, None, None)
            # Long-context: shard the sequence across everything it divides.
            if S % int(np.prod([mesh.shape[a] for a in all_axes])) == 0:
                return P(None, None, all_axes, None, None)
            return P(None, None, dp_axes if S % dp == 0 else None, None, None)
        if path == "conv":
            L, B = shape[0], shape[1]
            di = shape[-1]
            if B % dp == 0 and B >= dp:
                return P(None, dp_axes, None, "model" if di % model == 0 else None)
            return P(None, None, None, "model" if di % model == 0 else None)
        if path == "h":
            B = shape[1]
            if B % dp == 0 and B >= dp:
                if len(shape) == 4:  # mamba1 [L,B,di,N]
                    return P(None, dp_axes, "model" if shape[2] % model == 0 else None, None)
                return P(None, dp_axes, None, None, None)  # mamba2 [L,B,H,P,N]
            if len(shape) == 4:
                return P(None, None, "model" if shape[2] % model == 0 else None, None)
            return P()
        return P()

    return {k: leaf(k, v) for k, v in cache.items()}


def to_shardings(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
