"""PartitionSpecs for params, optimizer state, batches and decode caches.

Strategy (mirrors the paper's FSDP setup, S7/S8, mapped to TPU):
  * params: ZeRO-3-style sharding over the ``data`` axis + tensor
    parallelism over ``model``; the ``pod`` axis REPLICATES params --
    that's the paper's hybrid-shard group (they used group size 256; our
    single-pod data*model = 256 matches), with gradient all-reduce over
    pods.
  * batch streams: leading (DP-shard) dim over (pod, data).
  * decode caches: batch dim over DP when divisible; otherwise the
    long-context case (B=1) shards the sequence / feature dims instead.

Assignment is pattern-free: for every param leaf we pick the last dim
divisible by the ``model`` axis for TP and the largest remaining dim
divisible by ``data`` for FSDP, skipping the stacked-layer leading dim.
This is deliberately generic -- per-arch hand overrides live in the
perf-iteration layer (EXPERIMENTS.md S-Perf), not here.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = [
    "param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_sharding_specs",
    "to_shardings",
]


def _leaf_spec(shape: tuple[int, ...], data: int, model: int,
               *, skip_dims: int = 0) -> P:
    """Generic FSDP+TP assignment with divisibility checks."""
    spec: list[Any] = [None] * len(shape)
    dims = list(range(skip_dims, len(shape)))
    # TP: last eligible dim divisible by `model` and reasonably large.
    tp_dim = None
    for d in reversed(dims):
        if model > 1 and shape[d] % model == 0 and shape[d] >= 2 * model:
            tp_dim = d
            spec[d] = "model"
            break
    # FSDP: largest remaining dim divisible by `data`.
    best, best_size = None, 0
    for d in dims:
        if d == tp_dim:
            continue
        if data > 1 and shape[d] % data == 0 and shape[d] >= data and shape[d] > best_size:
            best, best_size = d, shape[d]
    if best is not None:
        spec[best] = "data"
    return P(*spec)


def param_specs(cfg: ModelConfig, params, mesh: Mesh):
    """Specs matching the params pytree.  Stacked-layer leaves (inside
    'layers'/'enc_layers') skip their leading [L] dim."""
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)

    def walk(tree, stacked: bool):
        if isinstance(tree, dict):
            return {
                k: walk(v, stacked or k in ("layers", "enc_layers"))
                for k, v in tree.items()
            }
        return _leaf_spec(tree.shape, data, model, skip_dims=1 if stacked else 0)

    return walk(params, False)


def opt_state_specs(p_specs):
    return {
        "mu": p_specs,
        "nu": p_specs,
        "step": P(),
    }


def batch_specs(batch: dict[str, Any], dp_axes: tuple[str, ...]) -> dict[str, P]:
    """All batch arrays carry the DP-shard layout on their leading dim."""
    return {k: P(dp_axes) for k in batch}


def cache_sharding_specs(cfg: ModelConfig, cache, dp_axes: tuple[str, ...],
                         mesh: Mesh):
    """Decode-cache specs; see module docstring for the B=1 fallback."""
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    model = mesh.shape.get("model", 1)
    all_axes = tuple(mesh.axis_names)

    def leaf(path: str, x) -> P:
        shape = x.shape
        if path in ("kv_pos", "kv_seg", "sa_kv_pos", "sa_kv_seg",
                    "cross_seg", "cross_pos"):
            B = shape[0]
            return P(dp_axes) if B % dp == 0 and B >= dp else P()
        if path in ("k", "v", "sa_k", "sa_v", "cross_k", "cross_v"):
            L, B, S = shape[0], shape[1], shape[2]
            if B % dp == 0 and B >= dp:
                seq_ax = "model" if S % model == 0 and S >= model else None
                return P(None, dp_axes, seq_ax, None, None)
            # Long-context: shard the sequence across everything it divides.
            if S % int(np.prod([mesh.shape[a] for a in all_axes])) == 0:
                return P(None, None, all_axes, None, None)
            return P(None, None, dp_axes if S % dp == 0 else None, None, None)
        if path == "conv":
            L, B = shape[0], shape[1]
            di = shape[-1]
            if B % dp == 0 and B >= dp:
                return P(None, dp_axes, None, "model" if di % model == 0 else None)
            return P(None, None, None, "model" if di % model == 0 else None)
        if path == "h":
            B = shape[1]
            if B % dp == 0 and B >= dp:
                if len(shape) == 4:  # mamba1 [L,B,di,N]
                    return P(None, dp_axes, "model" if shape[2] % model == 0 else None, None)
                return P(None, dp_axes, None, None, None)  # mamba2 [L,B,H,P,N]
            if len(shape) == 4:
                return P(None, None, "model" if shape[2] % model == 0 else None, None)
            return P()
        return P()

    return {k: leaf(k, v) for k, v in cache.items()}


def to_shardings(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
