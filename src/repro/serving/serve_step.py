"""Serving steps: batched decode, paged decode, and chunked prefill.

``make_serve_step(cfg)`` -> jit-able ``(params, tokens, cache, t, key=None)
-> (next_tokens, logits, cache)``.  Sampling is pluggable via
``sample_fn`` (:func:`greedy_sample` default keeps tests deterministic;
:func:`make_sample_fn` builds temperature/top-k sampling behind a PRNG
key threaded through the step).

``make_serve_step(cfg, paged=True)`` is the continuous-batching variant:
the cache is the paged KV pool (``registry.paged_cache_specs``), reads
go through a block-table gather, and ``t`` is a per-row position vector
-- see :mod:`repro.models.decode`.

``make_prefill_step(cfg)`` is the serving prefill: a ``lax.scan`` of the
paged decode step over prompt positions, so a whole batch of admitted
prompts is consumed in ONE jitted call while staying bit-identical to
feeding the prompt token by token through ``serve_step`` (which is what
makes engine output streams exactly reproduce the dense path).  It is
distinct from ``repro.training.train_step.make_prefill_step``, the
forward-only packed-stream loss path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, with_attention_backend
from repro.models.decode import decode_step
from repro.utils import zeros_like_specs

__all__ = ["make_serve_step", "make_prefill_step", "init_cache",
           "greedy_sample", "make_sample_fn"]


def greedy_sample(logits, key=None):
    """Deterministic argmax sampling ([B,V] -> [B,1] int32)."""
    del key
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]


def make_sample_fn(*, temperature: float = 1.0, top_k: int | None = None):
    """Stochastic ``sample_fn``: softmax(logits / temperature), optionally
    restricted to the ``top_k`` highest-scoring tokens.

    ``temperature == 0`` degrades to :func:`greedy_sample`; otherwise the
    returned fn REQUIRES the PRNG key the engine threads through
    serve/prefill steps (one fold per step keeps runs reproducible).
    """
    if temperature == 0.0:
        return greedy_sample
    if temperature < 0.0:
        raise ValueError(f"temperature must be >= 0, got {temperature}")

    def sample(logits, key):
        if key is None:
            raise ValueError("stochastic sample_fn needs a PRNG key "
                             "(pass key= to the serve/prefill step)")
        scaled = logits.astype(jnp.float32) / temperature
        if top_k is not None:
            kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)[:, None]

    return sample


def make_serve_step(cfg: ModelConfig, *, attention_backend: str | None = None,
                    sample_fn=None, paged: bool = False):
    """``attention_backend`` overrides ``cfg.attention_impl`` for the
    decode attention sites (resolved via ``cfg.decode_backend``);
    ``sample_fn`` defaults to greedy.

    Dense (default): ``(params, tokens [B,1], cache, t, key=None)``.
    Paged: ``(params, tokens [B,1], cache, block_tables [B,W], t [B],
    key=None)`` where ``cache`` is the pool layout and negative ``t``
    entries mark inactive (padding) rows."""
    cfg = with_attention_backend(cfg, attention_backend)
    sample_fn = sample_fn or greedy_sample

    if paged:
        def paged_serve_step(params, tokens, cache, block_tables, t, key=None):
            logits, cache = decode_step(cfg, params, tokens, cache, t,
                                        block_tables=block_tables)
            next_tokens = sample_fn(logits, key)
            return next_tokens, logits, cache

        return paged_serve_step

    def serve_step(params, tokens, cache, t, key=None):
        logits, cache = decode_step(cfg, params, tokens, cache, t)
        next_tokens = sample_fn(logits, key)
        return next_tokens, logits, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, *, attention_backend: str | None = None,
                      sample_fn=None):
    """Serving prefill on the paged cache (see module docstring).

    Returns ``prefill_step(params, prompts [B,Tp], lengths [B], cache,
    block_tables [B,W], key=None) -> (first_tokens [B,1], last_logits
    [B,V], cache)``: scans the paged decode step over positions
    0..Tp-1; row b goes inactive once ``p >= lengths[b]`` (its writes
    are dropped), and ``first_tokens`` is sampled from each row's
    logits at its own last prompt position."""
    cfg = with_attention_backend(cfg, attention_backend)
    sample_fn = sample_fn or greedy_sample

    def prefill_step(params, prompts, lengths, cache, block_tables, key=None):
        B, Tp = prompts.shape
        vocab = params["embed"].shape[0]

        def body(carry, inp):
            cache, last = carry
            p, tok = inp
            t = jnp.where(p < lengths, p, -1).astype(jnp.int32)
            logits, cache = decode_step(cfg, params, tok[:, None], cache, t,
                                        block_tables=block_tables)
            last = jnp.where((p == lengths - 1)[:, None], logits, last)
            return (cache, last), None

        init = (cache, jnp.zeros((B, vocab), jnp.float32))
        xs = (jnp.arange(Tp, dtype=jnp.int32), prompts.T)
        (cache, last_logits), _ = jax.lax.scan(body, init, xs)
        first_tokens = sample_fn(last_logits, key)
        return first_tokens, last_logits, cache

    return prefill_step


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Zero-initialized decode cache matching registry.cache_specs."""
    from repro.configs.registry import cache_specs

    return zeros_like_specs(cache_specs(cfg, batch, seq_len))
