"""Serving: batched single-token decode + cache init.

``make_serve_step(cfg)`` -> jit-able ``(params, tokens, cache, t) ->
(next_tokens, logits, cache)``; greedy sampling (argmax) keeps the step
deterministic for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, with_attention_backend
from repro.models.decode import decode_step

__all__ = ["make_serve_step", "init_cache"]


def make_serve_step(cfg: ModelConfig, *, attention_backend: str | None = None):
    """``attention_backend`` overrides ``cfg.attention_impl`` for the
    decode attention sites (resolved via ``cfg.decode_backend``)."""
    cfg = with_attention_backend(cfg, attention_backend)

    def serve_step(params, tokens, cache, t):
        logits, cache = decode_step(cfg, params, tokens, cache, t)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, logits, cache

    return serve_step


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Zero-initialized decode cache matching registry.cache_specs."""
    from repro.configs.registry import cache_specs

    specs = cache_specs(cfg, batch, seq_len)

    def zeros(tree):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), tree,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    return zeros(specs)
