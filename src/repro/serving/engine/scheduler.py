"""Post-balanced admission scheduling for the serving engine.

Each engine step the scheduler packs work into a *token budget* using
the same cost machinery as training-time Batch Post-Balancing:

  1. running DECODE sequences go first, FIFO by arrival, at
     ``decode_cost`` each (one token per step).  A sequence that needs a
     fresh KV block it cannot get triggers *preemption*: the
     youngest-arrival running sequence is evicted (blocks freed,
     recompute on re-admission) until the allocation fits -- mirroring
     vLLM's recompute preemption, oldest requests win.
  2. WAITING requests are admitted FIFO while their weighted prefill
     cost (``ServingCostModel.prefill_cost``: modality-weighted length
     through the paper's f(S)) fits the remaining budget, the pool can
     cover their prompt, and ``max_num_seqs`` is respected.  Strict
     FIFO within each cost class = no starvation: a too-expensive queue
     head *blocks* later arrivals instead of being skipped, and a head
     whose cost alone exceeds the budget is admitted on an otherwise
     idle step so it cannot livelock.

In multi-replica mode :func:`assign_replicas` post-balances a batch of
waiting requests across the N engine replicas by calling
``core.balancing.post_balance`` (vectorized backend from
``core.balancing_vec``) on the modality-weighted lengths -- the
training dispatcher reused verbatim, now minimizing the straggler
replica's admission cost.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.configs.base import EngineConfig
from repro.core.balancing import post_balance
from repro.core.cost_model import ServingCostModel, serving_cost_model
from repro.serving.engine.kv_pool import PagedKVPool
from repro.serving.engine.request import Request, SequenceState

__all__ = ["StepPlan", "Scheduler", "serving_cost_model", "assign_replicas"]


@dataclasses.dataclass
class StepPlan:
    """One engine step's scheduling decision (kept by the engine for the
    invariant tests and the report's budget accounting)."""

    step: int
    prefill: list[SequenceState]
    decode: list[SequenceState]
    admitted: list[int]  # req_ids newly WAITING->PREFILL this step
    preempted: list[int]  # req_ids evicted DECODE->WAITING this step
    budget: float
    budget_used: float

    @property
    def empty(self) -> bool:
        return not (self.prefill or self.decode)


def _fifo_key(seq: SequenceState):
    return (seq.request.arrival_step, seq.request.arrival_time,
            seq.request.req_id)


class Scheduler:
    def __init__(self, cost_model: ServingCostModel, engine_cfg: EngineConfig):
        self.cost_model = cost_model
        self.engine_cfg = engine_cfg

    # ------------------------------------------------------------------
    def request_cost(self, req: Request) -> float:
        """Weighted prefill cost of (re)computing ``req``'s context:
        generated-so-far tokens count as text (recompute prefills
        them)."""
        text = req.text_len + len(req.output_tokens)
        return self.cost_model.prefill_cost(text, req.modality_tokens)

    def prompt_blocks(self, req: Request, pool: PagedKVPool, seq_slots: int) -> int:
        """Blocks an admission must reserve: the full-prompt span,
        capped at the per-sequence ring length (windowed models wrap)."""
        span = min(req.prompt_len + len(req.output_tokens), seq_slots)
        return pool.blocks_for_slots(span)

    # ------------------------------------------------------------------
    def schedule(self, step: int, waiting: list[SequenceState],
                 running: list[SequenceState], pool: PagedKVPool,
                 *, seq_slots: int) -> StepPlan:
        """Mutates ``waiting``/``running`` and the pool's tables: admits,
        allocates, and preempts.  ``seq_slots`` is the per-sequence
        logical cache length (ring length for windowed models)."""
        budget = float(self.engine_cfg.token_budget)
        used = 0.0
        decode: list[SequenceState] = []
        prefill: list[SequenceState] = []
        admitted: list[int] = []
        preempted: list[int] = []

        # -- 1. running decodes, FIFO by arrival ------------------------
        running.sort(key=_fifo_key)
        pending = list(running)
        while pending:
            seq = pending.pop(0)
            if used + self.cost_model.decode_cost > budget and decode:
                break  # out of budget; the rest run next step
            # Ring sequences (seq_slots-bounded) never grow past their
            # table; growing sequences may need one fresh block.
            slot = seq.t % seq_slots
            need = pool.blocks_short(seq.seq_id, slot + 1)
            while need and not pool.can_alloc(need):
                victim = pending[-1] if pending else seq
                self._preempt(victim, pool, waiting, running)
                preempted.append(victim.seq_id)
                if victim is seq:
                    seq = None
                    break
                pending.pop()
            if seq is None:
                continue
            pool.ensure(seq.seq_id, slot + 1)
            decode.append(seq)
            used += self.cost_model.decode_cost

        # -- 2. waiting prefills, strict FIFO ---------------------------
        waiting.sort(key=_fifo_key)
        while waiting:
            seq = waiting[0]
            req = seq.request
            if len(running) + len(prefill) >= self.engine_cfg.max_num_seqs:
                break
            cost = self.request_cost(req)
            idle = not decode and not prefill
            if used + cost > budget and not idle:
                break  # head blocks the queue: FIFO, no skip-ahead
            n_blocks = self.prompt_blocks(req, pool, seq_slots)
            if not pool.can_alloc(n_blocks):
                break
            waiting.pop(0)
            pool.alloc(req.req_id, n_blocks)
            req.start_prefill()
            seq.reset()
            prefill.append(seq)
            admitted.append(req.req_id)
            used += cost

        running.extend(prefill)
        return StepPlan(step=step, prefill=prefill, decode=decode,
                        admitted=admitted, preempted=preempted,
                        budget=budget, budget_used=used)

    @staticmethod
    def _preempt(seq: SequenceState, pool: PagedKVPool,
                 waiting: list[SequenceState],
                 running: list[SequenceState]) -> None:
        pool.free(seq.seq_id)
        seq.request.preempt()
        seq.reset()
        running.remove(seq)
        waiting.append(seq)


def assign_replicas(
    requests: Sequence[Request],
    d: int,
    cost_model: ServingCostModel,
    *,
    backend: str = "vectorized",
) -> tuple[list[list[Request]], np.ndarray]:
    """Post-balance a batch of requests across ``d`` engine replicas.

    Items are the requests' modality-weighted lengths; the assignment is
    ``post_balance``'s rearrangement (so the max per-replica admission
    cost matches the training dispatcher's objective exactly -- the
    scheduler-invariant test checks this).  Returns the per-replica
    request lists (FIFO order restored within each) and the per-replica
    weighted-length loads."""
    if d < 1:
        raise ValueError(f"need d >= 1 replicas, got {d}")
    if not requests:
        return [[] for _ in range(d)], np.zeros(d)
    lens = np.maximum(1, np.rint(cost_model.weighted_lengths(
        [r.text_len for r in requests],
        [r.modality_tokens for r in requests])).astype(np.int64))
    re = post_balance([lens], d, cost_model.model, backend=backend)
    groups: list[list[Request]] = [[] for _ in range(d)]
    loads = np.zeros(d)
    for k in range(re.n):
        r = requests[int(re.orig_slot[k])]
        dst = int(re.dst_inst[k])
        groups[dst].append(r)
        loads[dst] += float(lens[int(re.orig_slot[k])])
    for g in groups:
        g.sort(key=lambda r: (r.arrival_step, r.arrival_time, r.req_id))
    return groups, loads
