"""Request lifecycle for the continuous-batching serving engine.

State machine (one :class:`Request` per user request):

    WAITING --admit--> PREFILL --first token--> DECODE --done--> FINISHED
                 ^                                  |
                 +----------- preempt --------------+

Preemption (pool exhaustion) frees the sequence's KV blocks and
re-queues it for *recompute*: on re-admission the prefill covers the
original prompt PLUS the tokens generated so far (teacher-forcing its
own outputs), so a greedy request regenerates exactly the same stream.

Timestamps are recorded twice: in engine steps (deterministic, what the
tests and the benchmark's simulated-cost accounting use) and in wall
seconds (what the throughput numbers use).  ``modality_tokens`` carries
the per-modality prefill token counts (post-connector LLM tokens) that
the scheduler's :class:`~repro.core.cost_model.ServingCostModel` weighs
-- the serving-side mirror of the structure the MLLM Global Orchestrator
gathers at training time (paper S7).
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

__all__ = ["RequestState", "Request", "SequenceState", "requests_from_examples"]


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` holds the flattened LLM-token prompt (all modality
    subsequences post-connector); ``modality_tokens`` records how many
    of those tokens belong to each non-text modality.
    """

    req_id: int
    prompt: np.ndarray  # [T] int32 LLM tokens
    max_new_tokens: int
    modality_tokens: dict[str, int] = dataclasses.field(default_factory=dict)
    # Wall-clock arrival, stamped by Engine.submit() (same clock domain
    # as the other *_time fields); arrival_step is the deterministic
    # scheduling clock traces are authored in.
    arrival_time: float = 0.0
    arrival_step: int = 0

    state: RequestState = RequestState.WAITING
    output_tokens: list[int] = dataclasses.field(default_factory=list)
    first_token_time: float | None = None
    first_token_step: int | None = None
    finish_time: float | None = None
    finish_step: int | None = None
    n_preemptions: int = 0
    replica: int | None = None

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, dtype=np.int32).ravel()
        if self.prompt.size == 0:
            raise ValueError(f"request {self.req_id}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.req_id}: max_new_tokens must be >= 1")

    # ------------------------------------------------------------------
    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def text_len(self) -> int:
        """Prompt tokens not accounted to any non-text modality."""
        return max(0, self.prompt_len - sum(self.modality_tokens.values()))

    @property
    def done(self) -> bool:
        return len(self.output_tokens) >= self.max_new_tokens

    def full_prompt(self) -> np.ndarray:
        """Prompt + generated-so-far: what a recompute must prefill."""
        if not self.output_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.output_tokens, np.int32)])

    # -- transitions ----------------------------------------------------
    def start_prefill(self) -> None:
        assert self.state is RequestState.WAITING, self.state
        self.state = RequestState.PREFILL

    def record_token(self, token: int, step: int, now: float) -> None:
        """Append one generated token (the first flips PREFILL->DECODE)."""
        assert self.state in (RequestState.PREFILL, RequestState.DECODE)
        self.output_tokens.append(int(token))
        if self.first_token_step is None:
            self.first_token_step = step
            self.first_token_time = now
        self.state = RequestState.DECODE

    def finish(self, step: int, now: float) -> None:
        assert self.state is RequestState.DECODE, self.state
        self.state = RequestState.FINISHED
        self.finish_step = step
        self.finish_time = now

    def preempt(self) -> None:
        assert self.state is RequestState.DECODE, self.state
        self.state = RequestState.WAITING
        self.n_preemptions += 1

    # -- serialization (engine snapshot / replica handoff) --------------
    def to_state_dict(self) -> dict:
        """JSON-able lifecycle state.  KV-cache contents are NOT part of
        a request's state: a restored in-flight request re-enters
        through the preemption-recompute path (teacher-forcing
        ``output_tokens``), which regenerates the pages exactly."""
        return {
            "req_id": self.req_id,
            "prompt": self.prompt.tolist(),
            "max_new_tokens": self.max_new_tokens,
            "modality_tokens": dict(self.modality_tokens),
            "arrival_time": self.arrival_time,
            "arrival_step": self.arrival_step,
            "state": self.state.value,
            "output_tokens": list(self.output_tokens),
            "first_token_time": self.first_token_time,
            "first_token_step": self.first_token_step,
            "finish_time": self.finish_time,
            "finish_step": self.finish_step,
            "n_preemptions": self.n_preemptions,
            "replica": self.replica,
        }

    @staticmethod
    def from_state_dict(d: dict) -> "Request":
        req = Request(
            req_id=int(d["req_id"]),
            prompt=np.asarray(d["prompt"], np.int32),
            max_new_tokens=int(d["max_new_tokens"]),
            modality_tokens=dict(d["modality_tokens"]),
            arrival_time=float(d["arrival_time"]),
            arrival_step=int(d["arrival_step"]),
        )
        req.state = RequestState(d["state"])
        req.output_tokens = [int(t) for t in d["output_tokens"]]
        req.first_token_time = d["first_token_time"]
        req.first_token_step = d["first_token_step"]
        req.finish_time = d["finish_time"]
        req.finish_step = d["finish_step"]
        req.n_preemptions = int(d["n_preemptions"])
        req.replica = d["replica"]
        return req


@dataclasses.dataclass
class SequenceState:
    """Runtime decode state of one admitted request.

    ``t`` is the next cache position to write (= tokens already in the
    KV cache); ``last_token`` feeds the next decode step.  Block
    ownership lives in the pool's table, keyed by ``request.req_id``."""

    request: Request
    t: int = 0
    last_token: int = 0

    @property
    def seq_id(self) -> int:
        return self.request.req_id

    def reset(self) -> None:
        """Back to un-prefilled (preemption recompute)."""
        self.t = 0
        self.last_token = 0


def requests_from_examples(examples, *, vocab: int, max_total_len: int,
                           rng: np.random.Generator,
                           max_new_lo: int = 4, max_new_hi: int = 48,
                           length_scale: int = 1,
                           arrival_step_fn=None) -> list[Request]:
    """Turn ``data.synthetic`` Examples into a serving request trace.

    Subsequence lengths are divided by ``length_scale`` (synthetic
    examples are sized for 4k-32k training streams; serving smoke tests
    run at a few hundred slots) and clipped so prompt + max_new fits
    ``max_total_len``.  Prompt token ids are uniform in [1, vocab);
    ``modality_tokens`` carries the scaled per-modality counts.
    ``arrival_step_fn(i)`` assigns arrival steps (default: all at 0).
    """
    ds = {"vision": 1, "audio": 1}
    reqs = []
    for i, ex in enumerate(examples):
        mt = {}
        for m in ("vision", "audio"):
            n = ex.subseq_len(m, ds)
            if n:
                mt[m] = max(1, n // length_scale)
        text = max(2, ex.text_len // length_scale)
        max_new = int(rng.integers(max_new_lo, max_new_hi + 1))
        total = text + sum(mt.values())
        cap = max_total_len - max_new
        if total > cap:  # clip text first, then modalities proportionally
            over = total - cap
            cut = min(over, text - 2)
            text -= cut
            over -= cut
            for m in list(mt):
                if over <= 0:
                    break
                cut = min(over, mt[m] - 1)
                mt[m] -= cut
                over -= cut
            total = text + sum(mt.values())
        prompt = rng.integers(1, vocab, size=total).astype(np.int32)
        step = int(arrival_step_fn(i)) if arrival_step_fn else 0
        reqs.append(Request(req_id=i, prompt=prompt, max_new_tokens=max_new,
                            modality_tokens=mt, arrival_step=step))
    return reqs
