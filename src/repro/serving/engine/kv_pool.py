"""Paged KV-cache pool: a fixed-size block allocator over the decode cache.

Device storage follows ``registry.paged_cache_specs``: per layer, the
KV cache is a pool of ``num_blocks`` blocks of ``block_size`` token
slots; a sequence's logical cache is its *block table* -- slot ``i``
lives at ``(table[i // block_size], i % block_size)``.  Decode reads
through a block-table gather (:mod:`repro.models.decode`), so the same
attention path runs on paged storage and sequences of wildly different
lengths share one physical pool with no per-sequence over-allocation.

Block 0 is the reserved NULL block: all-zero k/v with ``kv_seg == 0``.
Short block tables are padded with it, and a gather of the null block
reproduces exactly what a dense zero-initialized cache holds in
unwritten slots -- this is what makes paged decode bit-identical to the
dense path.  For the same reason ``free()`` zeroes the freed blocks'
``kv_seg`` rows: a recycled block must never leak stale segment marks
into a new owner's masked slots (stale k/v values are harmless -- the
mask multiplies them by an exact 0 -- but stale seg marks would
un-mask them).

Host-side bookkeeping (free list + tables) is plain Python; all device
mutation happens functionally through ``self.cache`` so the pool tree
can be passed into and returned from jitted steps.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.registry import paged_cache_specs
from repro.utils import zeros_like_specs

__all__ = ["PoolExhausted", "PagedKVPool", "NULL_BLOCK"]

NULL_BLOCK = 0


class PoolExhausted(RuntimeError):
    """Raised by ``alloc`` when the free list cannot cover a request --
    the engine's signal to preempt."""


class PagedKVPool:
    def __init__(self, cfg: ModelConfig, *, num_blocks: int, block_size: int):
        self.cfg = cfg
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        if self.num_blocks < 2:
            raise ValueError("need num_blocks >= 2 (block 0 is reserved)")
        self.cache = zeros_like_specs(
            paged_cache_specs(cfg, self.num_blocks, self.block_size))
        # Free list kept descending so list.pop() hands out the lowest
        # id first (deterministic allocation order for tests).
        self._free: list[int] = list(range(self.num_blocks - 1, NULL_BLOCK, -1))
        self._tables: dict[int, list[int]] = {}

    # -- capacity accounting --------------------------------------------
    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.usable_blocks - self.num_free

    @property
    def occupancy(self) -> float:
        return self.num_used / self.usable_blocks

    def table(self, seq_id: int) -> list[int]:
        return list(self._tables.get(seq_id, ()))

    def owners(self) -> list[int]:
        return list(self._tables)

    def blocks_for_slots(self, n_slots: int) -> int:
        """Blocks a table must span to cover ``n_slots`` token slots."""
        return -(-max(0, n_slots) // self.block_size)

    def blocks_short(self, seq_id: int, n_slots: int) -> int:
        """Additional blocks ``seq_id`` needs to cover ``n_slots``."""
        return max(0, self.blocks_for_slots(n_slots)
                   - len(self._tables.get(seq_id, ())))

    def can_alloc(self, n_blocks: int) -> bool:
        return n_blocks <= self.num_free

    # -- alloc / free / defrag ------------------------------------------
    def alloc(self, seq_id: int, n_blocks: int = 1) -> list[int]:
        """Append ``n_blocks`` fresh blocks to ``seq_id``'s table."""
        if n_blocks < 0:
            raise ValueError(f"n_blocks must be >= 0, got {n_blocks}")
        if n_blocks > self.num_free:
            raise PoolExhausted(
                f"seq {seq_id} needs {n_blocks} blocks, {self.num_free} free")
        blocks = [self._free.pop() for _ in range(n_blocks)]
        self._tables.setdefault(seq_id, []).extend(blocks)
        return blocks

    def ensure(self, seq_id: int, n_slots: int) -> list[int]:
        """Grow ``seq_id``'s table to cover ``n_slots`` slots."""
        return self.alloc(seq_id, self.blocks_short(seq_id, n_slots))

    def free(self, seq_id: int) -> list[int]:
        """Release ``seq_id``'s blocks (zeroing their kv_seg rows)."""
        blocks = self._tables.pop(seq_id, [])
        if blocks:
            idx = np.asarray(blocks)
            self.cache["kv_seg"] = self.cache["kv_seg"].at[idx].set(0)
            self._free.extend(blocks)
            self._free.sort(reverse=True)
        return blocks

    def defrag(self) -> dict[int, int]:
        """Compact allocated blocks to the lowest physical ids.

        Rewrites every table, permutes the device arrays to match
        (freed ids become copies of the null block, i.e. zeros), and
        rebuilds the free list as one contiguous high range.  Returns
        the ``{old_id: new_id}`` mapping.  Safe between engine steps
        only (the pool tree passed to an in-flight jitted step is
        stale afterwards)."""
        allocated: list[int] = []
        for blocks in self._tables.values():
            allocated.extend(blocks)
        mapping = {old: new for new, old in enumerate(allocated, start=1)}
        gather = np.zeros(self.num_blocks, dtype=np.int32)  # new -> old
        for old, new in mapping.items():
            gather[new] = old
        self.cache = {
            "k": self.cache["k"][:, gather],
            "v": self.cache["v"][:, gather],
            "kv_pos": self.cache["kv_pos"][gather],
            "kv_seg": self.cache["kv_seg"][gather],
        }
        self._tables = {sid: [mapping[b] for b in blocks]
                        for sid, blocks in self._tables.items()}
        self._free = list(range(self.num_blocks - 1, len(allocated), -1))
        return mapping

    # -- device-side views ----------------------------------------------
    def table_array(self, seq_ids, width: int) -> np.ndarray:
        """Block tables as a dense [B, width] int32 (null-block padded)."""
        out = np.full((len(seq_ids), width), NULL_BLOCK, dtype=np.int32)
        for i, sid in enumerate(seq_ids):
            blocks = self._tables.get(sid, ())
            if len(blocks) > width:
                raise ValueError(
                    f"seq {sid} table has {len(blocks)} blocks > width {width}")
            out[i, : len(blocks)] = blocks
        return out

    def check(self) -> None:
        """Assert allocator invariants (tests): the null block is never
        allocated, no block is double-booked, and free + allocated
        partition the usable id range."""
        seen: set[int] = set()
        for sid, blocks in self._tables.items():
            for b in blocks:
                assert b != NULL_BLOCK, f"seq {sid} owns the null block"
                assert 0 < b < self.num_blocks, f"seq {sid} owns bad id {b}"
                assert b not in seen, f"block {b} double-booked"
                seen.add(b)
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate ids in free list"
        assert not (free & seen), f"blocks both free and allocated: {free & seen}"
        assert free | seen == set(range(1, self.num_blocks)), "id leak"
