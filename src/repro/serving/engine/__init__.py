"""Continuous-batching serving engine with paged KV cache and
post-balanced admission scheduling (ISSUE 3).

    request.py    Request / SequenceState lifecycle
    kv_pool.py    PagedKVPool block allocator (alloc/free/defrag)
    scheduler.py  token-budget admission + post_balance replica assignment
    engine.py     Engine.step() loop, MultiReplicaEngine, EngineReport
"""
from repro.serving.engine.engine import (
    Engine,
    EngineReport,
    MultiReplicaEngine,
    StepTiming,
)
from repro.serving.engine.kv_pool import NULL_BLOCK, PagedKVPool, PoolExhausted
from repro.serving.engine.request import (
    Request,
    RequestState,
    SequenceState,
    requests_from_examples,
)
from repro.serving.engine.scheduler import (
    Scheduler,
    StepPlan,
    assign_replicas,
    serving_cost_model,
)

__all__ = [
    "Engine", "EngineReport", "MultiReplicaEngine", "StepTiming",
    "NULL_BLOCK", "PagedKVPool", "PoolExhausted",
    "Request", "RequestState", "SequenceState", "requests_from_examples",
    "Scheduler", "StepPlan", "assign_replicas", "serving_cost_model",
]
