"""Continuous-batching engine loop: schedule -> prefill -> decode.

``Engine.step()`` asks the :class:`~repro.serving.engine.scheduler.
Scheduler` for a :class:`StepPlan` under the token budget, runs the
admitted prompts through ONE jitted chunked-prefill call
(``serving.serve_step.make_prefill_step``), runs the running sequences
through ONE jitted paged decode call (``make_serve_step(paged=True)``),
and streams sampled tokens into each request.  Sequences join and leave
the decode batch every step (iteration-level scheduling), so a finished
request's slot is recycled immediately instead of idling until the
slowest member of a fixed batch completes.

Exactness: prefill is a scan of the very same paged decode step, and
paged reads gather bit-identical dense views (see
:mod:`repro.models.decode`), so with greedy sampling every request's
output stream is identical to running it alone through the dense-cache
``serve_step`` path -- preemption included (recompute teacher-forces
the tokens generated so far).

``EngineReport`` mirrors ``OrchestratorReport``: throughput, TTFT, ITL,
pool occupancy, budget utilization, and a padded-compute ``token_slots``
account (the deterministic cost the serving benchmark compares against
the fixed-batch baseline).

``MultiReplicaEngine`` runs N engines behind one queue, post-balancing
each arrival burst across replicas with the training dispatcher
(:func:`~repro.serving.engine.scheduler.assign_replicas`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EngineConfig, ModelConfig
from repro.core.cost_model import ServingCostModel
from repro.obs.registry import QuantileSketch
from repro.serving.engine.kv_pool import PagedKVPool
from repro.serving.engine.request import Request, RequestState, SequenceState
from repro.serving.engine.scheduler import (
    Scheduler,
    StepPlan,
    assign_replicas,
    serving_cost_model,
)
from repro.serving.serve_step import make_prefill_step, make_serve_step
from repro.utils import round_up

__all__ = ["Engine", "MultiReplicaEngine", "EngineReport", "StepTiming"]


@dataclasses.dataclass
class StepTiming:
    """One engine step's wall-time breakdown (host clock).

    ``prefill_ms`` / ``decode_ms`` cover the jitted calls (all prefill
    sub-batches of the step, resp. the one decode batch);
    ``schedule_ms`` is the scheduler's host time.  The serving
    calibrator regresses these against the step's token composition."""

    step: int
    schedule_ms: float
    prefill_ms: float
    decode_ms: float
    n_prefill_seqs: int
    prefill_tokens: int  # tokens prefilled this step (recompute included)
    n_decode_seqs: int
    # Attribution inputs for the MFU-gap waterfall (repro.obs.decompose):
    # preemptions charged to this step's schedule and the recomputed
    # (post-preemption re-prefill) share of prefill_tokens.
    n_preempted: int = 0
    recompute_tokens: int = 0

    def to_state_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_state_dict(d: dict) -> "StepTiming":
        return StepTiming(**d)


@dataclasses.dataclass
class EngineReport:
    """Per-run serving metrics (the ``OrchestratorReport`` analog)."""

    n_requests: int
    n_finished: int
    n_steps: int
    n_preemptions: int
    prompt_tokens: int  # first-time prefill tokens (== sum of prompt lens)
    recompute_tokens: int  # re-prefilled context after preemption (overhead)
    generated_tokens: int
    wall_s: float
    throughput_tok_s: float  # generated tokens / wall second
    token_slots: int  # padded (sequence, position) compute slots spent
    slot_efficiency: float  # useful tokens / token_slots
    ttft_steps_mean: float  # arrival -> first token, in engine steps
    ttft_steps_p95: float
    ttft_s_mean: float
    itl_steps_mean: float  # steps per generated token after the first
    occupancy_mean: float  # KV-pool block occupancy, sampled per step
    occupancy_max: float
    budget_util_mean: float  # budget_used / token_budget per step
    # Sketch-backed tail latencies (Greenwald-Khanna, repro.obs.registry):
    # means alone hide preemption-induced tails -- a preempted request
    # re-prefills its whole context, which shows up only at p95/p99.
    ttft_steps_p50: float = 0.0
    ttft_steps_p99: float = 0.0
    itl_steps_p50: float = 0.0
    itl_steps_p95: float = 0.0
    itl_steps_p99: float = 0.0
    # Phase-level wall-time breakdown (sums over steps; the per-step
    # rows live in ``Engine.step_timings``).  prefill_ms_mean /
    # decode_ms_mean average over the steps that RAN that phase.
    schedule_s_total: float = 0.0
    prefill_s_total: float = 0.0
    decode_s_total: float = 0.0
    prefill_steps: int = 0  # steps with at least one prefill sub-batch
    decode_steps: int = 0  # steps with a decode batch
    prefill_ms_mean: float = 0.0
    decode_ms_mean: float = 0.0

    def summary(self) -> str:
        return (
            f"requests {self.n_finished}/{self.n_requests} finished in "
            f"{self.n_steps} steps ({self.n_preemptions} preemptions)\n"
            f"tokens   {self.prompt_tokens} prompt + {self.generated_tokens} "
            f"generated (+{self.recompute_tokens} recomputed); "
            f"{self.throughput_tok_s:.1f} tok/s wall, "
            f"{self.token_slots} compute slots "
            f"({self.slot_efficiency:.1%} useful)\n"
            f"latency  TTFT {self.ttft_steps_mean:.1f} steps mean / "
            f"{self.ttft_steps_p50:.1f}/{self.ttft_steps_p95:.1f}/"
            f"{self.ttft_steps_p99:.1f} p50/p95/p99 "
            f"({self.ttft_s_mean * 1e3:.1f} ms); "
            f"ITL {self.itl_steps_mean:.2f} steps mean / "
            f"{self.itl_steps_p50:.2f}/{self.itl_steps_p95:.2f}/"
            f"{self.itl_steps_p99:.2f} p50/p95/p99\n"
            f"pool     occupancy {self.occupancy_mean:.1%} mean / "
            f"{self.occupancy_max:.1%} max; budget {self.budget_util_mean:.1%}\n"
            f"phases   prefill {self.prefill_s_total * 1e3:.1f} ms over "
            f"{self.prefill_steps} steps ({self.prefill_ms_mean:.2f} ms/step); "
            f"decode {self.decode_s_total * 1e3:.1f} ms over "
            f"{self.decode_steps} steps ({self.decode_ms_mean:.2f} ms/step)"
        )


def _sketch_quantiles(xs: Sequence[float], qs: Sequence[float]) -> list[float]:
    """Percentiles via the streaming sketch (the same estimator the live
    registry histograms use, so report numbers match scraped metrics).
    Monotone in q by construction."""
    if not len(xs):
        return [0.0] * len(qs)
    sk = QuantileSketch()
    sk.extend(float(x) for x in xs)
    return [sk.quantile(q) for q in qs]


def build_report(requests: Sequence[Request], *, n_steps: int, wall_s: float,
                 token_slots: int, prompt_tokens: int, recompute_tokens: int,
                 generated_tokens: int,
                 occupancy_samples: Sequence[float],
                 budget_fracs: Sequence[float],
                 step_timings: Sequence[StepTiming] = ()) -> EngineReport:
    finished = [r for r in requests if r.state is RequestState.FINISHED]
    ttft_steps = [r.first_token_step - r.arrival_step for r in finished
                  if r.first_token_step is not None]
    ttft_s = [r.first_token_time - r.arrival_time for r in finished
              if r.first_token_time is not None]
    itl = [(r.finish_step - r.first_token_step) / (len(r.output_tokens) - 1)
           for r in finished
           if len(r.output_tokens) > 1 and r.finish_step is not None]
    # Recomputed context is real compute but NOT useful output -- it is
    # preemption overhead and must not inflate slot_efficiency.
    useful = prompt_tokens + generated_tokens
    pf = [t for t in step_timings if t.n_prefill_seqs]
    dc = [t for t in step_timings if t.n_decode_seqs]
    ttft_p50, ttft_p95, ttft_p99 = _sketch_quantiles(
        ttft_steps, (0.5, 0.95, 0.99))
    itl_p50, itl_p95, itl_p99 = _sketch_quantiles(itl, (0.5, 0.95, 0.99))
    return EngineReport(
        n_requests=len(requests),
        n_finished=len(finished),
        n_steps=n_steps,
        n_preemptions=sum(r.n_preemptions for r in requests),
        prompt_tokens=prompt_tokens,
        recompute_tokens=recompute_tokens,
        generated_tokens=generated_tokens,
        wall_s=wall_s,
        throughput_tok_s=generated_tokens / wall_s if wall_s > 0 else 0.0,
        token_slots=token_slots,
        slot_efficiency=useful / token_slots if token_slots else 0.0,
        ttft_steps_mean=float(np.mean(ttft_steps)) if ttft_steps else 0.0,
        ttft_steps_p95=ttft_p95,
        ttft_s_mean=float(np.mean(ttft_s)) if ttft_s else 0.0,
        itl_steps_mean=float(np.mean(itl)) if itl else 0.0,
        ttft_steps_p50=ttft_p50,
        ttft_steps_p99=ttft_p99,
        itl_steps_p50=itl_p50,
        itl_steps_p95=itl_p95,
        itl_steps_p99=itl_p99,
        occupancy_mean=float(np.mean(occupancy_samples)) if len(occupancy_samples) else 0.0,
        occupancy_max=float(np.max(occupancy_samples)) if len(occupancy_samples) else 0.0,
        budget_util_mean=float(np.mean(budget_fracs)) if len(budget_fracs) else 0.0,
        schedule_s_total=sum(t.schedule_ms for t in step_timings) * 1e-3,
        prefill_s_total=sum(t.prefill_ms for t in step_timings) * 1e-3,
        decode_s_total=sum(t.decode_ms for t in step_timings) * 1e-3,
        prefill_steps=len(pf),
        decode_steps=len(dc),
        prefill_ms_mean=float(np.mean([t.prefill_ms for t in pf])) if pf else 0.0,
        decode_ms_mean=float(np.mean([t.decode_ms for t in dc])) if dc else 0.0,
    )


class Engine:
    """One continuous-batching replica over one paged KV pool."""

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig, params, *,
                 sample_fn: Callable | None = None,
                 attention_backend: str | None = None,
                 rng_key=None,
                 cost_model: ServingCostModel | None = None,
                 replica_id: int = 0,
                 jit_steps: tuple | None = None,
                 metrics=None):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"engine serves dense/moe/vlm families, not {cfg.family!r}")
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.params = params
        self.replica_id = replica_id
        # Logical per-sequence cache length: the SWA ring needs only the
        # window (but never less -- a smaller ring would silently
        # truncate attention vs the dense path); everything else must
        # hold prompt + generation.
        if cfg.sliding_window and engine_cfg.max_model_len < cfg.sliding_window:
            raise ValueError(
                f"max_model_len={engine_cfg.max_model_len} is smaller than "
                f"sliding_window={cfg.sliding_window}; the ring must cover "
                f"the full window")
        self.seq_slots = cfg.sliding_window or engine_cfg.max_model_len
        if self.seq_slots % engine_cfg.block_size:
            raise ValueError(
                f"per-sequence cache length {self.seq_slots} (sliding window "
                f"or max_model_len) must be a multiple of "
                f"block_size={engine_cfg.block_size}")
        self.table_width = self.seq_slots // engine_cfg.block_size
        self.pool = PagedKVPool(cfg, num_blocks=engine_cfg.num_blocks,
                                block_size=engine_cfg.block_size)
        self.scheduler = Scheduler(cost_model or serving_cost_model(cfg),
                                   engine_cfg)
        # ``jit_steps`` lets MultiReplicaEngine share one (prefill,
        # decode) pair of jitted callables -- and their XLA compile
        # caches -- across replicas instead of compiling per replica.
        self._prefill, self._decode = jit_steps or (
            jax.jit(make_prefill_step(
                cfg, attention_backend=attention_backend, sample_fn=sample_fn)),
            jax.jit(make_serve_step(
                cfg, attention_backend=attention_backend, sample_fn=sample_fn,
                paged=True)),
        )
        self._key = rng_key  # None = deterministic (greedy) path
        self._rng_calls = 0  # folded into the key once per jitted call
        # Shapes this replica has already run through the jitted steps:
        # the FIRST call per shape includes XLA compilation (seconds vs
        # milliseconds steady-state) and must not be fed to the serving
        # calibrator as a timing sample.
        self._warm_prefill_shapes: set[tuple[int, int]] = set()
        self._warm_decode_shapes: set[int] = set()

        self.waiting: list[SequenceState] = []
        self.running: list[SequenceState] = []
        self.requests: list[Request] = []
        self.plans: list[StepPlan] = []
        self.step_timings: list[StepTiming] = []
        self.n_steps = 0
        self.token_slots = 0
        self.prompt_tokens = 0
        self.recompute_tokens = 0
        self.generated_tokens = 0
        self.occupancy_samples: list[float] = []
        self.budget_fracs: list[float] = []
        self._wall_s = 0.0
        # Observability: an optional MetricsRegistry (repro.obs.registry)
        # receives the SLO series live -- TTFT / per-request ITL / pool
        # occupancy as replica-labeled histograms whose sketch gives the
        # same p50/p95/p99 the end-of-run EngineReport computes.
        self.metrics = metrics
        if metrics is not None:
            step_buckets = (1, 2, 4, 8, 16, 32, 64, 128, 256)
            self._h_ttft = metrics.histogram(
                "serving_ttft_steps", "arrival to first token, engine steps",
                labels=("replica",), buckets=step_buckets)
            self._h_itl = metrics.histogram(
                "serving_itl_steps", "per-request mean inter-token steps",
                labels=("replica",), buckets=step_buckets)
            self._h_occ = metrics.histogram(
                "serving_occupancy_frac", "KV-pool block occupancy per step",
                labels=("replica",),
                buckets=tuple(i / 10 for i in range(1, 11)))
            self._c_preempt = metrics.counter(
                "serving_preemptions", "sequences preempted by the scheduler",
                labels=("replica",))
            self._n_preempt_seen = 0
        else:
            self._h_ttft = self._h_itl = self._h_occ = self._c_preempt = None

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def submit(self, request: Request) -> None:
        """Queue a request (WAITING).  Prompt + generation must fit the
        logical cache unless the model's sliding window bounds reads."""
        total = request.prompt_len + request.max_new_tokens
        if self.cfg.sliding_window is None and total > self.seq_slots:
            raise ValueError(
                f"request {request.req_id}: prompt+max_new={total} exceeds "
                f"max_model_len={self.seq_slots}")
        # Reject up front what no amount of preemption could ever place
        # (a too-big head would livelock the strict-FIFO queue).
        need = self.pool.blocks_for_slots(min(total, self.seq_slots))
        if need > self.pool.usable_blocks:
            raise ValueError(
                f"request {request.req_id}: needs {need} KV blocks, pool has "
                f"{self.pool.usable_blocks} total")
        request.replica = self.replica_id
        request.arrival_time = time.perf_counter()  # wall clock domain
        self.requests.append(request)
        self.waiting.append(SequenceState(request))

    # ------------------------------------------------------------------
    def step(self) -> StepPlan:
        """One engine iteration: schedule -> batched prefill -> batched
        decode -> lifecycle bookkeeping.  Returns the step's plan."""
        t0 = time.perf_counter()
        step = self.n_steps
        pre_recompute = self.recompute_tokens
        pre_preempt = sum(r.n_preemptions for r in self.requests)
        plan = self.scheduler.schedule(step, self.waiting, self.running,
                                       self.pool, seq_slots=self.seq_slots)
        t1 = time.perf_counter()
        prefill_tokens = 0
        if plan.prefill:
            prefill_tokens = self._run_prefill(plan.prefill, step)
        t2 = time.perf_counter()
        if plan.decode:
            self._run_decode(plan.decode, step)
        t3 = time.perf_counter()
        n_preempted = sum(r.n_preemptions for r in self.requests) - pre_preempt
        self.step_timings.append(StepTiming(
            step=step,
            schedule_ms=(t1 - t0) * 1e3,
            prefill_ms=(t2 - t1) * 1e3,
            decode_ms=(t3 - t2) * 1e3,
            n_prefill_seqs=len(plan.prefill),
            prefill_tokens=prefill_tokens,
            n_decode_seqs=len(plan.decode),
            n_preempted=n_preempted,
            recompute_tokens=self.recompute_tokens - pre_recompute))
        self.n_steps += 1
        self.plans.append(plan)
        self.occupancy_samples.append(self.pool.occupancy)
        self.budget_fracs.append(plan.budget_used / plan.budget)
        self._wall_s += time.perf_counter() - t0
        if self._h_occ is not None:
            self._h_occ.observe(self.pool.occupancy, replica=self.replica_id)
            if n_preempted > 0:
                self._c_preempt.inc(n_preempted, replica=self.replica_id)
        return plan

    def _prefill_groups(self, seqs: list[SequenceState],
                        prompts: list[np.ndarray]) -> list[list[int]]:
        """Split one step's admitted prefills into low-padding
        sub-batches: sort by prompt length (descending) and cut a new
        group whenever padding the next prompt up to the group's padded
        max would cost more than ``prefill_waste`` extra slots per
        useful token (padded > useful * (1 + prefill_waste)) --
        Algorithm 2's bounded padded batches applied to the prefill
        batch dimension."""
        ecfg = self.engine_cfg
        order = sorted(range(len(seqs)), key=lambda i: -prompts[i].size)
        groups: list[list[int]] = []
        cur: list[int] = []
        tp = useful = 0
        for i in order:
            n = int(prompts[i].size)
            if not cur:
                cur, tp, useful = [i], round_up(n, ecfg.prefill_pad), n
                continue
            if (len(cur) + 1) * tp > (useful + n) * (1.0 + ecfg.prefill_waste):
                groups.append(cur)
                cur, tp, useful = [i], round_up(n, ecfg.prefill_pad), n
            else:
                cur.append(i)
                useful += n
        if cur:
            groups.append(cur)
        return groups

    def _next_key(self):
        """Fresh key per jitted call (deterministic across identical
        runs; never reused between prefill groups, decode calls, or
        replicas)."""
        if self._key is None:
            return None
        self._rng_calls += 1
        return jax.random.fold_in(
            jax.random.fold_in(self._key, self.replica_id), self._rng_calls)

    def _run_prefill(self, seqs: list[SequenceState], step: int) -> int:
        ecfg = self.engine_cfg
        observe = getattr(self.scheduler.cost_model, "observe_prefill", None)
        total_tokens = 0
        prompts = [s.request.full_prompt() for s in seqs]
        for group in self._prefill_groups(seqs, prompts):
            B = len(group)
            lens = np.array([prompts[i].size for i in group], np.int32)
            Tp = round_up(int(lens.max()), ecfg.prefill_pad)
            batch = np.zeros((B, Tp), np.int32)
            for row, i in enumerate(group):
                batch[row, : prompts[i].size] = prompts[i]
            bt = self.pool.table_array([seqs[i].seq_id for i in group],
                                       self.table_width)
            tg = time.perf_counter()
            first, _, cache = self._prefill(
                self.params, jnp.asarray(batch), jnp.asarray(lens),
                self.pool.cache, jnp.asarray(bt), self._next_key())
            self.pool.cache = cache
            first = np.asarray(first)
            now = time.perf_counter()
            total_tokens += int(lens.sum())
            warm = (B, Tp) in self._warm_prefill_shapes
            self._warm_prefill_shapes.add((B, Tp))
            if observe is not None and warm:
                # Feed the serving calibrator this sub-batch's token
                # composition (generated-so-far recompute tokens count
                # as text, matching Scheduler.request_cost).  Cold
                # shapes are skipped: their wall time is XLA compile.
                counts: dict[str, int] = {"text": 0}
                for i in group:
                    req = seqs[i].request
                    for m, n in req.modality_tokens.items():
                        counts[m] = counts.get(m, 0) + int(n)
                    counts["text"] += int(prompts[i].size
                                          - sum(req.modality_tokens.values()))
                observe(counts, (now - tg) * 1e3, step=step)
            for row, i in enumerate(group):
                # A recompute (post-preemption) re-prefills its whole
                # context; only a first admission counts as useful
                # prompt work.
                if seqs[i].request.first_token_step is None:
                    self.prompt_tokens += int(lens[row])
                else:
                    self.recompute_tokens += int(lens[row])
                seqs[i].t = int(lens[row])
                self._deliver(seqs[i], int(first[row, 0]), step, now)
            self.token_slots += B * Tp
        return total_tokens

    def _run_decode(self, seqs: list[SequenceState], step: int) -> None:
        ecfg = self.engine_cfg
        B = round_up(len(seqs), ecfg.decode_pad)
        tokens = np.zeros((B, 1), np.int32)
        t_vec = np.full(B, -1, np.int32)
        for i, seq in enumerate(seqs):
            tokens[i, 0] = seq.last_token
            t_vec[i] = seq.t
        bt = self.pool.table_array([s.seq_id for s in seqs], self.table_width)
        if B > len(seqs):
            bt = np.concatenate(
                [bt, np.zeros((B - len(seqs), self.table_width), np.int32)])
        tg = time.perf_counter()
        nxt, _, cache = self._decode(
            self.params, jnp.asarray(tokens), self.pool.cache,
            jnp.asarray(bt), jnp.asarray(t_vec), self._next_key())
        self.pool.cache = cache
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        warm = B in self._warm_decode_shapes
        self._warm_decode_shapes.add(B)
        observe = getattr(self.scheduler.cost_model, "observe_decode", None)
        if observe is not None and warm:  # cold shape = XLA compile time
            # Regress on the PADDED row count: that is what the jitted
            # call computed, so the fitted per-row cost is fill-level
            # unbiased (an active seq occupies ~1 padded row).
            observe(B, (now - tg) * 1e3, step=step)
        for i, seq in enumerate(seqs):
            seq.t += 1
            self._deliver(seq, int(nxt[i, 0]), step, now)
        self.token_slots += B

    def _deliver(self, seq: SequenceState, token: int, step: int, now: float) -> None:
        seq.last_token = token
        req = seq.request
        first = req.first_token_step is None
        req.record_token(token, step, now)
        self.generated_tokens += 1
        if first and self._h_ttft is not None:
            self._h_ttft.observe(step - req.arrival_step,
                                 replica=self.replica_id)
        if req.done:
            req.finish(step, now)
            if (self._h_itl is not None and len(req.output_tokens) > 1
                    and req.finish_step is not None):
                itl = ((req.finish_step - req.first_token_step)
                       / (len(req.output_tokens) - 1))
                self._h_itl.observe(itl, replica=self.replica_id)
            self.pool.free(seq.seq_id)
            self.running.remove(seq)

    # ------------------------------------------------------------------
    # Snapshot / restore: scheduler + request lifecycle state.  KV pages
    # are deliberately NOT serialized -- a restored in-flight sequence
    # re-enters through the SAME preemption-recompute path the scheduler
    # uses under pool pressure (full_prompt() teacher-forces the tokens
    # generated so far), so with greedy sampling the continued output
    # stream is bitwise the stream an uninterrupted engine produces.
    def snapshot(self) -> dict:
        """JSON-able engine state: every request's lifecycle, the
        scheduler queues (by req_id), counters, and per-step timings."""
        return {
            "replica_id": self.replica_id,
            "n_steps": self.n_steps,
            "token_slots": self.token_slots,
            "prompt_tokens": self.prompt_tokens,
            "recompute_tokens": self.recompute_tokens,
            "generated_tokens": self.generated_tokens,
            "occupancy_samples": [float(x) for x in self.occupancy_samples],
            "budget_fracs": [float(x) for x in self.budget_fracs],
            "wall_s": self._wall_s,
            "rng_calls": self._rng_calls,
            "requests": [r.to_state_dict() for r in self.requests],
            "waiting": [s.seq_id for s in self.waiting],
            "running": [s.seq_id for s in self.running],
            "step_timings": [t.to_state_dict() for t in self.step_timings],
            "cost_model": (self.scheduler.cost_model.state_dict()
                           if hasattr(self.scheduler.cost_model,
                                      "state_dict") else None),
        }

    def restore(self, snap: dict) -> None:
        """Rebuild a drained replica's state from :meth:`snapshot`.

        Must be called on a fresh (empty) engine.  Former RUNNING
        sequences are re-queued WAITING through the recompute path;
        their KV pages are regenerated on re-admission."""
        if self.requests or self.waiting or self.running:
            raise ValueError("restore() needs a fresh engine "
                             "(this one already has requests)")
        if int(snap["replica_id"]) != self.replica_id:
            raise ValueError(
                f"snapshot is replica {snap['replica_id']}, this engine "
                f"is replica {self.replica_id} (use export_unfinished/"
                f"admit_serialized to MOVE work between replicas)")
        self.n_steps = int(snap["n_steps"])
        self.token_slots = int(snap["token_slots"])
        self.prompt_tokens = int(snap["prompt_tokens"])
        self.recompute_tokens = int(snap["recompute_tokens"])
        self.generated_tokens = int(snap["generated_tokens"])
        self.occupancy_samples = list(snap["occupancy_samples"])
        self.budget_fracs = list(snap["budget_fracs"])
        self._wall_s = float(snap["wall_s"])
        self._rng_calls = int(snap["rng_calls"])
        self.step_timings = [StepTiming.from_state_dict(t)
                             for t in snap["step_timings"]]
        cm_state = snap.get("cost_model")
        if cm_state is not None and hasattr(self.scheduler.cost_model,
                                           "load_state_dict"):
            self.scheduler.cost_model.load_state_dict(cm_state)
        was_running = set(snap["running"])
        for d in snap["requests"]:
            self._admit_restored(Request.from_state_dict(d),
                                 recompute=d["req_id"] in was_running)

    def _admit_restored(self, req: Request, *, recompute: bool) -> None:
        """One shared admission path for snapshot restore AND replica
        handoff: an in-flight request goes through the state machine's
        preemption transition (DECODE -> WAITING recompute), exactly as
        the scheduler evicts under pool pressure."""
        req.replica = self.replica_id
        self.requests.append(req)
        if req.state is RequestState.FINISHED:
            return
        if req.state is RequestState.DECODE and recompute:
            req.preempt()
        elif req.state is not RequestState.WAITING:
            # PREFILL never survives a step boundary; normalize anything
            # unexpected to WAITING without touching preemption counts.
            req.state = RequestState.WAITING
        seq = SequenceState(req)
        seq.reset()
        self.waiting.append(seq)

    def export_unfinished(self) -> list[dict]:
        """Drain this replica: serialize and REMOVE every unfinished
        request (blocks freed), leaving finished history in place for
        reporting.  Feed the result to another replica's
        :meth:`admit_serialized` -- together they are the handoff path
        ``MultiReplicaEngine.handoff`` uses."""
        out = []
        for seq in list(self.running):
            self.pool.free(seq.seq_id)
            self.running.remove(seq)
            if seq.request.state is RequestState.DECODE:
                seq.request.preempt()  # shared recompute transition
            out.append(seq.request.to_state_dict())
            self.requests.remove(seq.request)
        for seq in list(self.waiting):
            self.waiting.remove(seq)
            out.append(seq.request.to_state_dict())
            self.requests.remove(seq.request)
        return out

    def admit_serialized(self, reqs: Sequence[dict]) -> None:
        """Admit serialized requests (from :meth:`export_unfinished` or
        an external queue) through the shared restore path."""
        for d in reqs:
            self._admit_restored(Request.from_state_dict(d),
                                 recompute=False)

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request] = (), *,
            max_steps: int = 100_000) -> EngineReport:
        """Drive to completion: submit each request when the step clock
        reaches its ``arrival_step``, then step until idle."""
        pending = sorted(requests, key=lambda r: (r.arrival_step, r.req_id))
        while pending or self.has_work:
            while pending and pending[0].arrival_step <= self.n_steps:
                self.submit(pending.pop(0))
            self.step()
            if self.n_steps >= max_steps:
                raise RuntimeError(
                    f"engine did not drain within {max_steps} steps "
                    f"({len(self.waiting)} waiting, {len(self.running)} running)")
        return self.report()

    def report(self) -> EngineReport:
        return build_report(
            self.requests, n_steps=self.n_steps, wall_s=self._wall_s,
            token_slots=self.token_slots, prompt_tokens=self.prompt_tokens,
            recompute_tokens=self.recompute_tokens,
            generated_tokens=self.generated_tokens,
            occupancy_samples=self.occupancy_samples,
            budget_fracs=self.budget_fracs,
            step_timings=self.step_timings)


class MultiReplicaEngine:
    """N engine replicas behind one post-balanced admission queue.

    Each arrival burst (requests sharing an ``arrival_step``) is
    assigned across replicas by :func:`assign_replicas` -- the paper's
    post-balancing applied to the waiting queue, minimizing the
    straggler replica's weighted admission load."""

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig, params,
                 **engine_kw):
        self.engine_cfg = engine_cfg
        self.cost_model = engine_kw.pop("cost_model", None) or serving_cost_model(cfg)
        shared = jax.jit(make_prefill_step(
            cfg, attention_backend=engine_kw.get("attention_backend"),
            sample_fn=engine_kw.get("sample_fn"))), jax.jit(make_serve_step(
            cfg, attention_backend=engine_kw.get("attention_backend"),
            sample_fn=engine_kw.get("sample_fn"), paged=True))
        self.engines = [
            Engine(cfg, engine_cfg, params, cost_model=self.cost_model,
                   replica_id=i, jit_steps=shared, **engine_kw)
            for i in range(engine_cfg.replicas)
        ]
        self.assignment_loads: list[np.ndarray] = []

    @property
    def has_work(self) -> bool:
        return any(e.has_work for e in self.engines)

    def submit_batch(self, requests: Sequence[Request]) -> np.ndarray:
        """Post-balance one burst across replicas; returns the
        per-replica weighted-length loads of this assignment."""
        groups, loads = assign_replicas(
            requests, len(self.engines), self.cost_model,
            backend=self.engine_cfg.balancing_backend)
        for engine, group in zip(self.engines, groups):
            for r in group:
                engine.submit(r)
        self.assignment_loads.append(loads)
        return loads

    def step(self) -> None:
        # Idle replicas step too: local step clocks stay in lockstep
        # with the global arrival clock (TTFT-in-steps consistency).
        for e in self.engines:
            e.step()

    # ------------------------------------------------------------------
    def handoff(self, src: int, dst: int) -> int:
        """Drain replica ``src`` and move its unfinished requests to
        ``dst`` -- the replica-failure / rolling-restart path.

        Routed entirely through ``Engine.export_unfinished`` /
        ``Engine.admit_serialized``, i.e. the same snapshot/restore and
        preemption-recompute code paths the scheduler and the unit tests
        exercise: in-flight DECODE sequences take the state machine's
        preempt transition and re-prefill their full context at ``dst``
        (KV pages are never copied between pools).  Returns how many
        requests moved."""
        if src == dst:
            raise ValueError("handoff needs distinct src/dst replicas")
        moved = self.engines[src].export_unfinished()
        self.engines[dst].admit_serialized(moved)
        return len(moved)

    def snapshot(self) -> list[dict]:
        """Per-replica ``Engine.snapshot`` list (whole-cluster state)."""
        return [e.snapshot() for e in self.engines]

    def restore(self, snaps: Sequence[dict]) -> None:
        """Restore a whole-cluster snapshot onto fresh replicas."""
        if len(snaps) != len(self.engines):
            raise ValueError(
                f"snapshot has {len(snaps)} replicas, engine has "
                f"{len(self.engines)}")
        for e, snap in zip(self.engines, snaps):
            e.restore(snap)

    def run(self, requests: Sequence[Request] = (), *,
            max_steps: int = 100_000) -> EngineReport:
        pending = sorted(requests, key=lambda r: (r.arrival_step, r.req_id))
        clock = 0
        while pending or self.has_work:
            burst = []
            while pending and pending[0].arrival_step <= clock:
                burst.append(pending.pop(0))
            if burst:
                self.submit_batch(burst)
            self.step()
            clock += 1
            if clock >= max_steps:
                raise RuntimeError(f"replicas did not drain in {max_steps} steps")
        return self.report()

    def report(self) -> EngineReport:
        requests = [r for e in self.engines for r in e.requests]
        occ = [s for e in self.engines for s in e.occupancy_samples]
        frac = [f for e in self.engines for f in e.budget_fracs]
        return build_report(
            requests,
            n_steps=max((e.n_steps for e in self.engines), default=0),
            wall_s=sum(e._wall_s for e in self.engines),
            token_slots=sum(e.token_slots for e in self.engines),
            prompt_tokens=sum(e.prompt_tokens for e in self.engines),
            recompute_tokens=sum(e.recompute_tokens for e in self.engines),
            generated_tokens=sum(e.generated_tokens for e in self.engines),
            occupancy_samples=occ, budget_fracs=frac,
            step_timings=[t for e in self.engines for t in e.step_timings])
