"""MLLM Global Orchestrator (paper S6).

Takes the per-DP-instance sampled example mini-batches and produces the
fully post-balanced device batch for one iteration:

  1. one Batch Post-Balancing Dispatcher per encoder phase (vision:
     packed / Alg 1; audio: padded / Alg 2 + conv cost model) -> Pi_Ek
  2. the global dispatcher for the LLM backbone, keyed on the
     INTERLEAVED sequence length (subsequences assembly, S6) -> Pi_M
  3. Rearrangement Composition: Pi_M o Pi_Ek^{-1} compiled into ONE
     communicator plan per encoder (halving all-to-all traffic)
  4. packed/padded stream assembly (tokens, segments, positions, labels,
     scatter indices) with static capacities

The dispatcher *computation* (steps 1-3) is pure host work with only
lengths as input, so the data pipeline overlaps it with the forward pass
via prefetching (repro.data.pipeline), exactly as S6 prescribes.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.communicator import CommPlan, build_comm_plan
from repro.core.cost_model import encoder_cost_model, llm_cost_model
from repro.core.dispatcher import BatchPostBalancingDispatcher, DispatchPlan
from repro.core.pipeline import PipelinePlan, plan_pipeline
from repro.core.rearrangement import Rearrangement, compose
from repro.sharding.specs import stage_partition
from repro.data.packing import pack_padded_stream, pack_stream
from repro.data.synthetic import Example
from repro.utils import round_up as _round_up


def _ex_rng(seed: int, sid: int, tag: str) -> np.random.Generator:
    """Per-example deterministic content: the SAME example yields the
    same tokens/embeddings wherever the rearrangement places it.  This
    is what makes consequence-invariance (paper S3.3) *testable*: loss
    and gradients must be bit-identical under any balancing choice."""
    return np.random.default_rng(abs(hash((seed, sid, tag))) % (2**63))

__all__ = [
    "Capacities",
    "PhasePlans",
    "PlanAheadHandle",
    "OrchestratorReport",
    "MLLMGlobalOrchestrator",
    "llm_cost_model",
    "encoder_cost_model",
]


@dataclasses.dataclass(frozen=True)
class Capacities:
    """Static per-shard token capacities (fixed across steps for jit).

    Post-balancing is what makes small capacities *safe*: the dispatcher
    minimizes the max per-shard cost, so the margin over the mean can be
    tight (this is the TPU static-shape payoff of the paper's idea)."""

    llm: int
    text: int
    enc_in: dict[str, int]
    enc_out: dict[str, int]
    enc_row: dict[str, int]  # padded phases: row length; 0 = packed
    chunk: dict[str, int]  # dense-a2a static per-peer chunk capacity


@dataclasses.dataclass
class OrchestratorReport:
    """Per-iteration accounting for benchmarks / EXPERIMENTS.md."""

    phase_utilization: dict[str, float]
    phase_max_cost: dict[str, float]
    phase_costs: dict[str, np.ndarray]
    comm_volume: dict[str, dict[str, int]]
    internode_volume: dict[str, int]
    solve_ms: float
    # Per-phase dispatcher host time (paper Table 2 analog), keyed by
    # phase name plus "compose" for the composition/comm-plan step.
    phase_solve_ms: dict[str, float] = dataclasses.field(default_factory=dict)
    # Plan-ahead accounting, filled by the pipeline/harness: host time
    # the consumer actually waited on this plan (~0 when the previous
    # step's forward pass hid it), and whether it was overlapped.
    exposed_ms: float = 0.0
    overlapped: bool = False
    # Telemetry: per-phase per-shard feature vectors (d, 4) -- the
    # consumer pairs them with measured phase times and feeds them back
    # through observe_phase_times -- plus the adaptive-coefficient
    # version the plans were computed under and whether a stale
    # plan-ahead plan had to be re-planned (drift / coefficient swap).
    phase_features: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    coeff_version: int = -1
    replanned: bool = False
    # Pipeline mode (pp > 1): the simulated 1F1B + bubble-fill schedule
    # for this iteration (None when DP-only).
    pipeline: PipelinePlan | None = None


@dataclasses.dataclass
class PhasePlans:
    """Steps 1-3 of an iteration: every phase's dispatch plan plus the
    composed communicator plans.  Pure host work, computable from
    lengths alone -- this is the unit plan-ahead mode overlaps with the
    previous step's forward pass."""

    llm_plan: DispatchPlan
    enc_plans: dict[str, DispatchPlan]
    pi_es: dict[str, Rearrangement]
    composed: dict[str, Rearrangement]
    comm_plans: dict[str, CommPlan]
    phase_solve_ms: dict[str, float]
    solve_ms: float
    # Adaptive-coefficient version the plans were solved under (-1 when
    # no AdaptiveOrchestration is attached); plan_and_pack re-plans when
    # the version moved on (drift / calibration swap-in) before packing.
    coeff_version: int = -1
    # Pipeline mode: 1F1B microbatch schedule + encoder bubble fill.
    pipeline: PipelinePlan | None = None

    @property
    def features(self) -> dict[str, np.ndarray]:
        """Per-phase (d, 4) feature matrices for telemetry calibration."""
        out = {"llm": self.llm_plan.features}
        for name, plan in self.enc_plans.items():
            out[name] = plan.features
        return out


class PlanAheadHandle:
    """Future-like handle for a :meth:`plan_phases` running in the
    background; ``result()`` also reports how long the caller blocked
    (the *exposed* dispatcher latency)."""

    def __init__(self, thread: "threading.Thread", box: dict) -> None:
        self._thread = thread
        self._box = box

    def done(self) -> bool:
        return not self._thread.is_alive()

    def result(self, timeout: float | None = None) -> tuple[PhasePlans, float]:
        t0 = time.perf_counter()
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("plan-ahead not finished")
        exposed_ms = (time.perf_counter() - t0) * 1e3
        if "error" in self._box:
            raise self._box["error"]
        return self._box["plans"], exposed_ms


class MLLMGlobalOrchestrator:
    def __init__(
        self,
        cfg: ModelConfig,
        d: int,
        *,
        instances_per_node: int | None = None,
        balance: bool = True,
        balance_encoders: bool = True,  # False = Pre-Balancing baseline (Fig 10)
        llm_algorithm: str | None = None,
        encoder_algorithm_override: str | None = None,  # Fig 11 rigid-algo ablation
        vocab: int | None = None,
        backend: str = "vectorized",
        concurrent_dispatch: bool = False,
        adaptive=None,
        metrics=None,
        pp: int | None = None,
        microbatches: int | None = None,
        bubble_fill: bool | None = None,
    ) -> None:
        self.cfg = cfg
        self.d = d
        # Pipeline mode (docs/pipeline.md): pp > 1 partitions the LLM
        # backbone into stages and every plan_phases() additionally
        # solves a 1F1B microbatch schedule with encoder bubble fill.
        # None falls back to the config's pp_* knobs.
        self.pp = int(pp if pp is not None else getattr(cfg, "pp_stages", 1))
        self.microbatches = int(
            microbatches if microbatches is not None
            else getattr(cfg, "pp_microbatches", 0))
        self.bubble_fill = bool(
            bubble_fill if bubble_fill is not None
            else getattr(cfg, "pp_bubble_fill", True))
        self.stage_fractions = None
        if self.pp > 1:
            part = stage_partition(cfg.n_layers, self.pp)
            self.stage_fractions = (
                np.asarray(part, np.float64) / float(cfg.n_layers))
        # Observability: an optional MetricsRegistry (repro.obs.registry)
        # receives per-phase solve-time histograms and plan/replan
        # counters.  None keeps the orchestrator dependency-free; the
        # StepLedger still gets everything via OrchestratorReport.
        self.metrics = metrics
        if metrics is not None:
            self._h_solve = metrics.histogram(
                "orch_plan_solve_ms", "dispatcher solve time per phase",
                labels=("phase",))
            self._c_plans = metrics.counter(
                "orch_plans", "phase-plan solves by mode",
                labels=("mode",))
        else:
            self._h_solve = self._c_plans = None
        self.vocab = vocab or cfg.vocab_size
        self.data_seed = 0
        self.instances_per_node = instances_per_node
        self.downsample = {e.name: e.downsample for e in cfg.encoders}
        # One dispatcher per modality runs on its own worker when
        # concurrent_dispatch is set (paper Fig. 4: per-phase dispatchers
        # are independent).
        self.concurrent_dispatch = concurrent_dispatch
        # Telemetry: an AdaptiveOrchestration (repro.telemetry.adaptive)
        # supplies each phase's cost model -- analytic prior until the
        # online fit is confident, calibrated coefficients after.  The
        # dispatchers are refreshed from it before every solve, and the
        # consumer feeds measured phase times back through
        # :meth:`observe_phase_times`.
        self.adaptive = adaptive
        self.replans = 0  # stale plan-ahead plans re-planned (drift/swap)
        self.llm_dispatcher = BatchPostBalancingDispatcher(
            d, adaptive.cost_model("llm") if adaptive else llm_cost_model(cfg),
            algorithm=llm_algorithm,
            instances_per_node=instances_per_node,
            balance=balance,
            backend=backend,
            stage_fractions=self.stage_fractions,
        )
        self.enc_dispatchers: dict[str, BatchPostBalancingDispatcher] = {}
        for e in cfg.encoders:
            self.enc_dispatchers[e.name] = BatchPostBalancingDispatcher(
                d,
                adaptive.cost_model(e.name) if adaptive
                else encoder_cost_model(e),
                algorithm=encoder_algorithm_override,
                instances_per_node=instances_per_node,
                balance=balance and balance_encoders,
                backend=backend,
            )

    # ------------------------------------------------------------------
    def default_capacities(
        self, examples_per_instance: Sequence[Sequence[Example]], *, margin: float = 1.5
    ) -> Capacities:
        """Derive static capacities from a (first) batch with headroom."""
        cfg = self.cfg
        all_ex = [ex for insts in examples_per_instance for ex in insts]
        tot_llm = sum(ex.total_len(self.downsample) for ex in all_ex)
        tot_text = sum(ex.text_len for ex in all_ex)
        # Probe plan: observed per-peer volumes size the static a2a chunk
        # (planning is cheap host work; a fixed d-based heuristic under-
        # provisions when a better-balanced plan concentrates one pair).
        probe_peer_max: dict[str, int] = {}
        if cfg.encoders and any(dd.balance for dd in self.enc_dispatchers.values()):
            probe = self.plan_phases(examples_per_instance)
            for name, comp in probe.composed.items():
                probe_peer_max[name] = int(comp.comm_matrix().max())
        llm = _round_up(int(tot_llm / self.d * margin) + 8, 128)
        text = _round_up(int(max(tot_text / self.d * margin, 1)) + 8, 128)
        enc_in, enc_out, enc_row, chunk = {}, {}, {}, {}
        for e in cfg.encoders:
            metas = [getattr(ex, f"{e.name}_meta") for ex in all_ex]
            metas = [m for m in metas if m > 0]
            if e.padded:
                # Rows must fit the largest POSSIBLE example, not just the
                # probe batch's max (static shapes across steps).
                row = _round_up(max(metas + [e.tokens_per_example_max]),
                                e.downsample * 8)
                rows_per_shard = max(1, int(np.ceil(len(metas) / self.d * margin)) + 1)
                cin = row * rows_per_shard
            else:
                row = 0
                cin = _round_up(int(max(sum(metas) / self.d * margin, 128)),
                                e.downsample * 128)
            cout = _round_up(cin // e.downsample, 128)
            enc_in[e.name], enc_out[e.name], enc_row[e.name] = cin, cout, row
            # Balanced plans send ~cout/d per peer (2x margin for skew)
            # and at least 2x the probe plan's observed peer max; one
            # example's tokens move to one peer atomically so the chunk
            # must also fit the largest example.  Unbalanced baselines
            # keep whole batches on one pair.
            max_ex_out = -(-max(metas + [e.tokens_per_example_max]) // e.downsample)
            if self.enc_dispatchers[e.name].balance:
                chunk[e.name] = _round_up(
                    max(cout * 2 // max(self.d, 1),
                        2 * probe_peer_max.get(e.name, 0), max_ex_out, 16), 8)
            else:
                chunk[e.name] = _round_up(cout, 8)
        return Capacities(llm=llm, text=text, enc_in=enc_in, enc_out=enc_out,
                          enc_row=enc_row, chunk=chunk)

    # ------------------------------------------------------------------
    def plan_phases(
        self,
        examples_per_instance: Sequence[Sequence[Example]],
        caps: Capacities | None = None,
    ) -> PhasePlans:
        """Steps 1-3: per-phase post-balancing plans + composition.

        Needs only example *lengths* -- no payloads -- so plan-ahead mode
        runs it for step k+1 while step k's forward pass is on device.
        With ``concurrent_dispatch`` every phase's solve runs on its
        dispatcher's own worker thread (NumPy releases the GIL in the
        sort/scan kernels, and one dispatcher per modality is exactly the
        paper's Fig. 4 layout).  Without ``caps`` the communicator plans
        are skipped (plan-only accounting, e.g. the overhead benchmark).
        """
        cfg = self.cfg
        t0 = time.perf_counter()
        phase_ms: dict[str, float] = {}
        coeff_version = -1
        if self.adaptive is not None:
            # Refresh every dispatcher's f(S) from the adaptive models
            # and stamp the plans with the coefficient version, so a
            # plan computed ahead under stale coefficients is detected
            # (and re-planned) at consumption time.
            coeff_version = self.adaptive.version
            self.llm_dispatcher.cost_model = self.adaptive.cost_model("llm")
            for name, disp in self.enc_dispatchers.items():
                disp.cost_model = self.adaptive.cost_model(name)

        # ---- LLM backbone plan (interleaved lengths, S6). -------------
        key = "text" if cfg.family == "audio" else "total"
        llm_lengths = [
            np.array(
                [ex.text_len if key == "text" else ex.total_len(self.downsample)
                 for ex in insts], np.int64)
            for insts in examples_per_instance
        ]
        enc_lengths = {
            e.name: [
                np.array([getattr(ex, f"{e.name}_meta") for ex in insts
                          if getattr(ex, f"{e.name}_meta") > 0], np.int64)
                for insts in examples_per_instance
            ]
            for e in cfg.encoders
        }

        enc_plans: dict[str, DispatchPlan] = {}
        if self.concurrent_dispatch and cfg.encoders:
            tickets = {
                name: self.enc_dispatchers[name].submit(lens)
                for name, lens in enc_lengths.items()
            }
            llm_plan = self.llm_dispatcher.plan(llm_lengths)
            for name, ticket in tickets.items():
                enc_plans[name] = ticket.result()
        else:
            llm_plan = self.llm_dispatcher.plan(llm_lengths)
            for name, lens in enc_lengths.items():
                enc_plans[name] = self.enc_dispatchers[name].plan(lens)
        phase_ms["llm"] = llm_plan.solve_ms
        for name, plan in enc_plans.items():
            phase_ms[name] = plan.solve_ms
        pi_m = llm_plan.pi

        # ---- Composition + communicator plans. -------------------------
        tc = time.perf_counter()
        pi_es: dict[str, Rearrangement] = {}
        composed: dict[str, Rearrangement] = {}
        comm_plans: dict[str, CommPlan] = {}
        for e in cfg.encoders:
            plan = enc_plans[e.name]
            # pi_e's orig_slot indexes the SUBSET of modality-bearing
            # examples; remap to full example slots so composition joins.
            pi_e = _remap_subset_slots(plan.pi, examples_per_instance, e.name)
            pi_es[e.name] = pi_e
            comp = compose(pi_m, pi_e)
            # Payload lengths after the connector downsample.
            comp = dataclasses.replace(
                comp, lengths=np.ceil(comp.lengths / e.downsample).astype(np.int64)
            )
            composed[e.name] = comp
            if caps is not None:
                src_starts = _encoder_out_starts(pi_e, caps.enc_row[e.name],
                                                 e.downsample)
                comm_plans[e.name] = build_comm_plan(
                    comp,
                    caps.enc_in[e.name] // e.downsample,
                    caps.enc_out[e.name],
                    src_starts=src_starts,
                    chunk_cap=caps.chunk[e.name],
                )
        phase_ms["compose"] = (time.perf_counter() - tc) * 1e3

        # ---- Pipeline schedule (pp > 1): 1F1B microbatch split over
        # the post-balanced per-rank batches + encoder bubble fill. ----
        pipeline = None
        if self.pp > 1:
            pipeline = plan_pipeline(
                cfg,
                self.llm_dispatcher.cost_model,
                llm_plan.dest_lengths,
                {name: plan.costs for name, plan in enc_plans.items()},
                pp=self.pp,
                n_micro=self.microbatches,
                bubble_fill=self.bubble_fill,
            )
            phase_ms["pipeline"] = pipeline.solve_ms

        if self.adaptive is not None:
            self.adaptive.record_plan_spans(phase_ms)
        if self._h_solve is not None:
            for name, ms in phase_ms.items():
                self._h_solve.observe(ms, phase=name)
        return PhasePlans(
            llm_plan=llm_plan,
            enc_plans=enc_plans,
            pi_es=pi_es,
            composed=composed,
            comm_plans=comm_plans,
            phase_solve_ms=phase_ms,
            solve_ms=(time.perf_counter() - t0) * 1e3,
            coeff_version=coeff_version,
            pipeline=pipeline,
        )

    def plan_ahead(
        self,
        examples_per_instance: Sequence[Sequence[Example]],
        caps: Capacities,
    ) -> PlanAheadHandle:
        """Run :meth:`plan_phases` on a background thread; the returned
        handle's ``result()`` reports the latency that was actually
        exposed to the caller."""
        box: dict = {}

        def run() -> None:
            try:
                box["plans"] = self.plan_phases(examples_per_instance, caps)
            except BaseException as e:
                box["error"] = e

        thread = threading.Thread(target=run, name="orch-plan-ahead", daemon=True)
        thread.start()
        return PlanAheadHandle(thread, box)

    # ------------------------------------------------------------------
    def plan_and_pack(
        self,
        examples_per_instance: Sequence[Sequence[Example]],
        caps: Capacities,
        rng: np.random.Generator,
        plans: PhasePlans | None = None,
        *,
        exposed_ms: float | None = None,
    ) -> tuple[dict[str, np.ndarray], OrchestratorReport]:
        cfg = self.cfg
        overlapped = plans is not None
        replanned = False
        if (plans is not None and self.adaptive is not None
                and plans.coeff_version != self.adaptive.version):
            # The coefficients moved (calibration swap-in or drift)
            # after this plan was computed ahead: the plan is still
            # *correct* (any rearrangement is), but it balances against
            # a stale f(S) -- re-plan with the current coefficients.
            # The synchronous re-solve is genuinely exposed latency, so
            # it is charged to exposed_ms and the step loses its
            # overlapped flag.
            plans = None
            replanned = True
            overlapped = False
            self.replans += 1
            if self._c_plans is not None:
                self._c_plans.inc(mode="replanned")
        if plans is None:
            t_replan = time.perf_counter()
            plans = self.plan_phases(examples_per_instance, caps)
            if replanned:
                exposed_ms = ((exposed_ms or 0.0)
                              + (time.perf_counter() - t_replan) * 1e3)
        llm_plan, enc_plans = plans.llm_plan, plans.enc_plans
        pi_m = llm_plan.pi
        pi_es, composed, comm_plans = plans.pi_es, plans.composed, plans.comm_plans
        solve_ms = plans.solve_ms

        # Global example ids (segment ids shared across phases).
        ex_id = {}
        nid = 1
        for i, insts in enumerate(examples_per_instance):
            for j, _ in enumerate(insts):
                ex_id[(i, j)] = nid
                nid += 1

        # ---- Pack device arrays. ---------------------------------------
        if cfg.family == "audio":
            batch = self._pack_encdec(examples_per_instance, ex_id, pi_m,
                                      pi_es, composed, comm_plans, caps, rng)
        elif cfg.encoders:
            batch = self._pack_multimodal(examples_per_instance, ex_id, pi_m,
                                          pi_es, composed, comm_plans, caps, rng)
        else:
            batch = self._pack_text(examples_per_instance, ex_id, pi_m, caps, rng)

        report = self._report(
            llm_plan, enc_plans, composed, solve_ms,
            phase_solve_ms=plans.phase_solve_ms,
            exposed_ms=exposed_ms if exposed_ms is not None else solve_ms,
            overlapped=overlapped,
        )
        report.phase_features = plans.features
        report.coeff_version = plans.coeff_version
        report.replanned = replanned
        report.pipeline = plans.pipeline
        if self._c_plans is not None:
            self._c_plans.inc(mode="overlapped" if overlapped else "sync")
        return batch, report

    # ------------------------------------------------------------------
    def observe_phase_times(
        self,
        times_by_phase,
        *,
        plans: PhasePlans | None = None,
        report: OrchestratorReport | None = None,
        step: int | None = None,
    ) -> dict[str, bool]:
        """Feed measured per-phase execution times back to calibration.

        ``times_by_phase[p]`` is a per-shard wall-time vector aligned
        with the phase's (d, 4) feature matrix, or a scalar synchronous
        step time (attributed to the straggler shard).  Features come
        from ``plans`` or ``report`` (whichever the caller kept).
        ``step`` defaults to the AdaptiveOrchestration's own counter.
        Returns per-phase drift flags; after a drift or a confident
        calibration swap the NEXT plan consumes the new coefficients
        (and a stale plan-ahead plan is re-planned in plan_and_pack)."""
        if self.adaptive is None:
            raise ValueError("orchestrator has no AdaptiveOrchestration "
                             "attached (pass adaptive= at construction)")
        if (plans is None) == (report is None):
            raise ValueError("pass exactly one of plans= / report=")
        features = plans.features if plans is not None else report.phase_features
        return self.adaptive.observe(features, times_by_phase, step=step)

    # ------------------------------------------------------------------
    def _pack_text(self, examples, ex_id, pi_m, caps, rng):
        dest_lengths = pi_m.dest_lengths()
        seg_ids = _dest_seg_ids(pi_m, ex_id)
        seg, pos, starts = pack_stream(dest_lengths, caps.llm, seg_ids=seg_ids)
        tokens = np.zeros(seg.shape, np.int32)
        for i in range(self.d):
            for j, l in enumerate(np.asarray(dest_lengths[i], np.int64)):
                sid = int(seg_ids[i][j])
                s0 = int(starts[i][j])
                tokens[i, s0 : s0 + l] = _ex_rng(self.data_seed, sid, "tok").integers(
                    1, self.vocab, int(l), dtype=np.int32
                )
        # Next-token labels within the same example.
        nxt_same = (np.roll(seg, -1, axis=1) == seg) & (seg > 0)
        nxt_same[:, -1] = False
        labels = np.where(nxt_same, np.roll(tokens, -1, axis=1), -1).astype(np.int32)
        return {"tokens": tokens, "labels": labels, "seg": seg, "pos": pos}

    # ------------------------------------------------------------------
    def _pack_multimodal(self, examples, ex_id, pi_m, pi_es, composed,
                         comm_plans, caps, rng):
        cfg = self.cfg
        d = self.d
        get_ex = lambda k: examples[int(pi_m.orig_inst[k])][int(pi_m.orig_slot[k])]
        order_k = np.lexsort((pi_m.dst_slot, pi_m.dst_inst))
        per_shard: list[list[int]] = [[] for _ in range(d)]
        for k in order_k:
            per_shard[int(pi_m.dst_inst[k])].append(int(k))

        llm_seg = np.zeros((d, caps.llm), np.int32)
        llm_pos = np.zeros((d, caps.llm), np.int32)
        llm_labels = np.full((d, caps.llm), -1, np.int32)
        tokens = np.zeros((d, caps.text), np.int32)
        text_dst = np.full((d, caps.text), caps.llm, np.int32)
        # pi_m entry k, modality -> llm stream slot where its subsequence starts.
        subseq_start: dict[tuple[int, str], int] = {}

        for t in range(d):
            off = 0
            toff = 0
            for k in per_shard[t]:
                ex = get_ex(k)
                sid = ex_id[(int(pi_m.orig_inst[k]), int(pi_m.orig_slot[k]))]
                L = ex.total_len(self.downsample)
                if off + L > caps.llm:
                    raise ValueError(f"llm cap {caps.llm} overflow on shard {t}")
                llm_seg[t, off : off + L] = sid
                llm_pos[t, off : off + L] = np.arange(L)

                text_parts = max(1, sum(1 for m in ex.order if m == "text"))
                tpart = ex.text_len // text_parts
                ex_tokens = _ex_rng(self.data_seed, sid, "tok").integers(
                    1, self.vocab, max(ex.text_len, 1), dtype=np.int32
                )
                is_text = np.zeros(L, bool)
                tok_at = np.zeros(L, np.int32)
                cur = off
                ti = 0
                seen_text = 0
                for m in ex.order:
                    if m == "text":
                        n_t = (ex.text_len - tpart * (text_parts - 1)
                               if seen_text == text_parts - 1 else tpart)
                        if toff + n_t > caps.text:
                            raise ValueError(f"text cap {caps.text} overflow")
                        tokens[t, toff : toff + n_t] = ex_tokens[ti : ti + n_t]
                        text_dst[t, toff : toff + n_t] = np.arange(cur, cur + n_t)
                        is_text[cur - off : cur - off + n_t] = True
                        tok_at[cur - off : cur - off + n_t] = ex_tokens[ti : ti + n_t]
                        toff += n_t
                        ti += n_t
                        seen_text += 1
                        cur += n_t
                    else:
                        subseq_start[(k, m)] = cur
                        cur += ex.subseq_len(m, self.downsample)
                nxt_text = np.roll(is_text, -1)
                nxt_text[-1] = False
                llm_labels[t, off : off + L] = np.where(
                    nxt_text, np.roll(tok_at, -1), -1
                )
                off += L

        batch = {
            "tokens": tokens,
            "text_dst": text_dst,
            "llm_seg": llm_seg,
            "llm_pos": llm_pos,
            "llm_labels": llm_labels,
        }
        # pi_m entry lookup for composed plans (keyed by orig example).
        pim_idx = {
            (int(a), int(b)): k
            for k, (a, b) in enumerate(zip(pi_m.orig_inst, pi_m.orig_slot))
        }
        for e in cfg.encoders:
            batch.update(self._pack_encoder_stream(
                e, pi_es[e.name], composed[e.name], comm_plans[e.name],
                caps, rng, ex_id, subseq_start, pim_idx,
            ))
        return batch

    def _pack_encoder_stream(self, e, pi_e, comp, comm_plan, caps, rng,
                             ex_id, subseq_start, pim_idx):
        d = self.d
        cap_in = caps.enc_in[e.name]
        row = caps.enc_row[e.name]
        dest_lengths = pi_e.dest_lengths()
        seg_ids = _dest_seg_ids(pi_e, ex_id)
        if e.padded:
            seg, pos, starts = pack_padded_stream(dest_lengths, cap_in, row,
                                                  seg_ids=seg_ids)
        else:
            seg, pos, starts = pack_stream(dest_lengths, cap_in, seg_ids=seg_ids,
                                           align=e.downsample)
        embeds = _fill_embeds(dest_lengths, starts, seg_ids, cap_in,
                              e.embed_dim, self.data_seed, e.name)

        # enc_dst: composed plan delivers tokens packed at dest (dst_starts);
        # map each token to its llm-stream slot.
        cap_out = caps.enc_out[e.name]
        enc_dst = np.full((d, cap_out), caps.llm, np.int32)
        for k in range(comp.n):
            t = int(comp.dst_inst[k])
            start = int(comm_plan.dst_starts[k])
            l = int(comp.lengths[k])
            m_entry = pim_idx[(int(comp.orig_inst[k]), int(comp.orig_slot[k]))]
            slot0 = subseq_start[(m_entry, e.name)]
            enc_dst[t, start : start + l] = np.arange(slot0, slot0 + l)
        return {
            f"enc_{e.name}_embeds": embeds,
            f"enc_{e.name}_seg": seg,
            f"enc_{e.name}_pos": pos,
            f"enc_{e.name}_dst": enc_dst,
            **_plan_arrays(e.name, comm_plan),
        }

    # ------------------------------------------------------------------
    def _pack_encdec(self, examples, ex_id, pi_m, pi_es, composed,
                     comm_plans, caps, rng):
        """Whisper-style: decoder text streams + encoder stream; the
        composed plan moves encoder OUTPUTS to the decoder's shard, where
        cross-attention pairs them by segment id."""
        e = self.cfg.encoders[0]
        base = self._pack_text(examples, ex_id, pi_m, caps, rng)
        pi_e, comp, comm_plan = pi_es[e.name], composed[e.name], comm_plans[e.name]
        cap_in = caps.enc_in[e.name]
        row = caps.enc_row[e.name]
        seg_ids = _dest_seg_ids(pi_e, ex_id)
        dest_lengths = pi_e.dest_lengths()
        seg, pos, starts = pack_padded_stream(dest_lengths, cap_in, row, seg_ids=seg_ids)
        embeds = _fill_embeds(dest_lengths, starts, seg_ids, cap_in,
                              e.embed_dim, self.data_seed, e.name)
        # Post-exchange layout at the decoder shard: packed by dst_slot.
        cap_out = caps.enc_out[e.name]
        seg_out = np.zeros((self.d, cap_out), np.int32)
        pos_out = np.zeros((self.d, cap_out), np.int32)
        for k in range(comp.n):
            t = int(comp.dst_inst[k])
            start = int(comm_plan.dst_starts[k])
            l = int(comp.lengths[k])
            sid = ex_id[(int(comp.orig_inst[k]), int(comp.orig_slot[k]))]
            seg_out[t, start : start + l] = sid
            pos_out[t, start : start + l] = np.arange(l)
        return {
            **base,
            f"enc_{e.name}_embeds": embeds,
            f"enc_{e.name}_seg": seg,
            f"enc_{e.name}_pos": pos,
            f"enc_{e.name}_seg_out": seg_out,
            f"enc_{e.name}_pos_out": pos_out,
            **_plan_arrays(e.name, comm_plan),
        }

    def _report(self, llm_plan, enc_plans, composed, solve_ms,
                phase_solve_ms=None, exposed_ms=None, overlapped=False):
        util = {"llm": llm_plan.utilization}
        maxc = {"llm": llm_plan.max_cost}
        costs = {"llm": llm_plan.costs}
        comm, inter = {}, {}
        for name, plan in enc_plans.items():
            util[name] = plan.utilization
            maxc[name] = plan.max_cost
            costs[name] = plan.costs
        for name, comp in composed.items():
            V = comp.comm_matrix()
            comm[name] = {"total": int(V.sum()), "self": int(np.trace(V))}
            if self.instances_per_node:
                inter[name] = int(comp.internode_volume(self.instances_per_node).max())
        return OrchestratorReport(
            phase_utilization=util,
            phase_max_cost=maxc,
            phase_costs=costs,
            comm_volume=comm,
            internode_volume=inter,
            solve_ms=solve_ms,
            phase_solve_ms=dict(phase_solve_ms or {}),
            exposed_ms=solve_ms if exposed_ms is None else exposed_ms,
            overlapped=overlapped,
        )


def _fill_embeds(dest_lengths, starts, seg_ids, cap_in, embed_dim, seed, tag):
    d = len(dest_lengths)
    embeds = np.zeros((d, cap_in, embed_dim), np.float32)
    for i in range(d):
        for j, l in enumerate(np.asarray(dest_lengths[i], np.int64)):
            sid = int(seg_ids[i][j])
            s0 = int(starts[i][j])
            embeds[i, s0 : s0 + l] = _ex_rng(seed, sid, tag).standard_normal(
                (int(l), embed_dim)
            ).astype(np.float32)
    return embeds


def _plan_arrays(name: str, plan: CommPlan) -> dict[str, np.ndarray]:
    return {
        f"enc_{name}_plan_pre_gather_dense": plan.pre_gather_dense,
        f"enc_{name}_plan_post_gather_dense": plan.post_gather_dense,
        f"enc_{name}_plan_post_mask": plan.post_mask,
        f"enc_{name}_plan_global_gather": plan.global_gather,
    }


def _remap_subset_slots(pi: Rearrangement, examples, modality: str) -> Rearrangement:
    """pi's orig_slot counts only modality-bearing examples per instance;
    remap to the instance's FULL example slots so composition joins."""
    mapping: dict[tuple[int, int], int] = {}
    for i, insts in enumerate(examples):
        sub = 0
        for j, ex in enumerate(insts):
            if getattr(ex, f"{modality}_meta") > 0:
                mapping[(i, sub)] = j
                sub += 1
    new_slot = np.array(
        [mapping[(int(a), int(b))] for a, b in zip(pi.orig_inst, pi.orig_slot)],
        np.int64,
    )
    return dataclasses.replace(pi, orig_slot=new_slot)


def _encoder_out_starts(pi_e: Rearrangement, row: int, ds: int) -> np.ndarray:
    """Token start of each example's CONNECTOR OUTPUT in its encoder-dest
    shard's output stream (flat, aligned with pi_e / composed entries)."""
    starts = np.zeros(pi_e.n, np.int64)
    for i in range(pi_e.d):
        sel = np.where(pi_e.dst_inst == i)[0]
        sel = sel[np.argsort(pi_e.dst_slot[sel])]
        off = 0
        for j, k in enumerate(sel):
            if row:  # padded rows: fixed stride (row already ds-aligned)
                starts[k] = j * (row // ds)
            else:
                starts[k] = off
                in_len = _round_up(int(pi_e.lengths[k]), ds)
                off += in_len // ds
    return starts


def _dest_seg_ids(pi: Rearrangement, ex_id):
    out = []
    for i in range(pi.d):
        sel = np.where(pi.dst_inst == i)[0]
        sel = sel[np.argsort(pi.dst_slot[sel])]
        out.append(np.array(
            [ex_id[(int(pi.orig_inst[k]), int(pi.orig_slot[k]))] for k in sel],
            np.int64,
        ))
    return out
