"""1F1B pipeline schedule + encoder bubble-fill (ROADMAP item 1).

The paper's headline regime (84B on 2560 GPUs) trains with pipeline
parallelism, where a 1F1B schedule leaves warm-up/cool-down *bubbles* of
``(f+b) * pp * (pp-1)`` idle device time per rank per step.  Optimus
(arxiv 2408.03505) and DIP (arxiv 2504.14145) fill those bubbles with
the MLLM's *encoder* microbatches -- compute that has no dependency on
the LLM stage being idle -- and that composes directly with Batch
Post-Balancing: the per-phase dispatchers equalize per-rank cost, this
module splits each rank's batch into microbatches (LPT, so the max
microbatch cost is minimized -- per-STAGE balancing, since stage cost =
stage_fraction * microbatch cost) and then places encoder chunks into
the simulated schedule's idle windows under real dependency bounds:

  * an encoder FORWARD chunk feeding microbatch ``i`` must END before
    ``F(0, i)`` starts (stage 0 consumes the connector outputs);
  * an encoder BACKWARD chunk for microbatch ``i`` is RELEASED by the
    end of ``B(0, i)`` (the connector grads come out of stage 0's
    backward).

Placement is earliest-deadline-first over each stage's idle windows;
chunks are divisible (an encoder microbatch is many layers).  In steady
state a second, volume-bound pass models the DIP "dual interleaved"
trick: cool-down bubbles absorb the NEXT iteration's encoder forward
(its inputs are already prefetched -- lengths-only planning runs a
step ahead) and warm-up bubbles absorb the PREVIOUS iteration's encoder
backward, so leftover chunks whose own-iteration bound cannot be met
still fill bubbles as long as per-stage volume allows.  Whatever
remains runs as a prologue (before the pipeline flush starts) or
epilogue (after the drain) -- which is exactly the *whole* encoder
cost in the no-fill baseline, so the two schedules are compared on
identical work.

Costs are abstract forward-compute units on ONE scale: LLM costs come
from the (possibly calibrated) LLM ``CostModel`` directly; encoder
phase costs are rescaled by :func:`repro.core.cost_model.
phase_flops_per_unit` ratios so a vision cost unit and an LLM cost unit
mean the same FLOPs.  Backward compute is ``bwd_ratio`` (default 2.0)
times forward.  Everything here is host-side planning over lengths --
the same dry-run contract as the dispatcher -- consumed by the
orchestrator, the gap waterfall (``pipeline_bubble_s{k}`` components),
the ledger, the Perfetto timeline, and ``benchmarks/pipeline_bubbles``.

See docs/pipeline.md for a worked schedule diagram.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import CostModel, phase_flops_per_unit
from repro.sharding.specs import stage_partition

__all__ = [
    "BWD_RATIO",
    "PipelinePlan",
    "ScheduleEvent",
    "plan_pipeline",
    "split_microbatches",
]

# Backward ≈ 2x forward FLOPs (grad wrt activations + grad wrt weights).
BWD_RATIO = 2.0


@dataclasses.dataclass(frozen=True)
class ScheduleEvent:
    """One simulated span on one stage's device (times in cost units)."""

    kind: str  # "F" | "B" | "encF" | "encB"
    stage: int
    micro: int
    start: float
    end: float


def split_microbatches(lengths: np.ndarray, n_micro: int,
                       model: CostModel) -> tuple[np.ndarray, np.ndarray]:
    """LPT split of one rank's examples into ``n_micro`` microbatches.

    Minimizing the max microbatch cost minimizes the max per-stage load
    simultaneously (stage cost = stage_fraction * microbatch cost), so
    this IS the per-stage post-balancing step.  Returns
    ``(assign, micro_costs)``: per-example microbatch index and the
    (n_micro,) cost vector.  Single-example cost is ``alpha*l +
    beta*l^2`` for every f(S) variant.
    """
    lengths = np.asarray(lengths, dtype=np.float64)
    assign = np.zeros(lengths.size, dtype=np.int64)
    costs = np.zeros(n_micro, dtype=np.float64)
    if lengths.size == 0:
        return assign, costs
    w = model.alpha * lengths + model.beta * lengths * lengths
    order = np.argsort(-w, kind="stable")
    for k in order:  # exact LPT greedy (n is small: one rank's batch)
        i = int(np.argmin(costs))
        assign[k] = i
        costs[i] += w[k]
    return assign, costs


# ----------------------------------------------------------------------
# 1F1B simulation (one DP rank).
# ----------------------------------------------------------------------
def _simulate_1f1b(fwd: np.ndarray, bwd: np.ndarray):
    """Event-driven non-interleaved 1F1B over ``fwd/bwd`` of shape
    (pp, m).  Stage s runs ``min(pp-1-s, m)`` warm-up forwards, then
    strict 1F1B alternation, then cool-down backwards.  Returns
    ``(f_start, f_end, b_start, b_end, makespan)`` each (pp, m)."""
    pp, m = fwd.shape
    f_s = np.zeros((pp, m)); f_e = np.full((pp, m), -1.0)
    b_s = np.zeros((pp, m)); b_e = np.full((pp, m), -1.0)
    ops: list[list[tuple[str, int]]] = []
    for s in range(pp):
        w = min(pp - 1 - s, m)
        seq = [("F", i) for i in range(w)]
        for i in range(w, m):
            seq += [("F", i), ("B", i - w)]
        seq += [("B", i) for i in range(max(m - w, 0), m)]
        ops.append(seq)
    ptr = [0] * pp
    clock = np.zeros(pp)
    remaining = 2 * pp * m
    while remaining:
        progressed = False
        for s in range(pp):
            while ptr[s] < len(ops[s]):
                kind, i = ops[s][ptr[s]]
                if kind == "F":
                    if s > 0 and f_e[s - 1, i] < 0:
                        break
                    dep = f_e[s - 1, i] if s > 0 else 0.0
                    t0 = max(clock[s], dep)
                    f_s[s, i], f_e[s, i] = t0, t0 + fwd[s, i]
                else:
                    if s < pp - 1 and b_e[s + 1, i] < 0:
                        break
                    dep = b_e[s + 1, i] if s < pp - 1 else 0.0
                    t0 = max(clock[s], dep, f_e[s, i])
                    b_s[s, i], b_e[s, i] = t0, t0 + bwd[s, i]
                clock[s] = max(f_e[s, i], b_e[s, i], clock[s])
                ptr[s] += 1
                remaining -= 1
                progressed = True
        if not progressed:  # pragma: no cover - schedule is acyclic
            raise RuntimeError("1F1B simulation deadlocked")
    return f_s, f_e, b_s, b_e, float(clock.max())


def _idle_windows(f_s, f_e, b_s, b_e, makespan: float) -> list[list[list[float]]]:
    """Per-stage idle windows [t0, t1] in the bare 1F1B schedule,
    including leading idle before the first op and trailing idle."""
    pp = f_s.shape[0]
    out: list[list[list[float]]] = []
    for s in range(pp):
        spans = sorted(
            [(float(a), float(b)) for a, b in zip(f_s[s], f_e[s])]
            + [(float(a), float(b)) for a, b in zip(b_s[s], b_e[s])])
        windows: list[list[float]] = []
        cur = 0.0
        for a, b in spans:
            if a > cur + 1e-12:
                windows.append([cur, a])
            cur = max(cur, b)
        if makespan > cur + 1e-12:
            windows.append([cur, makespan])
        out.append(windows)
    return out


def _edf_fill(windows: list[list[float]], sizes: np.ndarray,
              bounds: np.ndarray, *, deadline: bool, stage: int,
              kind: str, events: list[ScheduleEvent]):
    """Place divisible chunks into idle ``windows`` (mutated in place).

    ``deadline=True``: chunk i may only occupy time < ``bounds[i]``
    (encoder forward -- must finish before F(0, i)); chunks arrive in
    deadline order.  ``deadline=False``: chunk i may only occupy time
    >= ``bounds[i]`` (encoder backward -- released by B(0, i)).
    Returns (placed_total, leftover_per_chunk_sum).
    """
    placed = 0.0
    leftover = 0.0
    for i, size in enumerate(sizes):
        need = float(size)
        bound = float(bounds[i])
        for w in windows:
            if need <= 1e-12:
                break
            a, b = w
            if deadline:
                hi = min(b, bound)
                take = min(need, max(hi - a, 0.0))
                if take > 1e-12:
                    events.append(ScheduleEvent(kind, stage, i, a, a + take))
                    w[0] = a + take
            else:
                lo = max(a, bound)
                take = min(need, max(b - lo, 0.0))
                if take > 1e-12:
                    events.append(ScheduleEvent(kind, stage, i, lo, lo + take))
                    w[0] = lo + take
            need -= max(take, 0.0)
        placed += float(size) - need
        leftover += need
    return placed, leftover


def _volume_fill(windows: list[list[float]], amount: float, *, stage: int,
                 kind: str, events: list[ScheduleEvent]) -> float:
    """Steady-state cross-iteration pass: fill remaining window capacity
    with ``amount`` of adjacent-iteration encoder work (no per-chunk
    bound -- the previous iteration's backward / next iteration's
    forward are both schedulable anywhere).  Returns the placed total.
    """
    placed = 0.0
    for w in windows:
        if amount - placed <= 1e-12:
            break
        a, b = w
        take = min(amount - placed, max(b - a, 0.0))
        if take > 1e-12:
            events.append(ScheduleEvent(kind, stage, -1, a, a + take))
            w[0] = a + take
            placed += take
    return placed


# ----------------------------------------------------------------------
@dataclasses.dataclass
class PipelinePlan:
    """Per-iteration pipeline schedule plan across all DP ranks.

    All times are abstract LLM-forward cost units (the waterfall's
    online cost->ms calibration puts them on the wall clock).
    """

    pp: int
    n_micro: int
    d: int
    partition: tuple[int, ...]
    stage_fractions: np.ndarray        # (pp,)
    micro_assign: list[np.ndarray]     # per rank: example -> microbatch
    micro_costs: np.ndarray            # (d, n_micro) full-model fwd cost
    enc_cost: np.ndarray               # (d,) encoder fwd cost, LLM units
    bubble_fill: bool
    # Simulation results:
    makespan_1f1b: np.ndarray          # (d,) bare LLM pipeline makespan
    bubble_total: np.ndarray           # (d,) theoretical 1F1B bubble time
    filled: np.ndarray                 # (d,) encoder compute placed in bubbles
    stage_busy: np.ndarray             # (d, pp) useful compute per stage
    stage_idle: np.ndarray             # (d, pp) unfilled idle per stage
    rank_total: np.ndarray             # (d,) prologue + makespan + epilogue
    rank_total_nofill: np.ndarray      # (d,) same schedule, no bubble fill
    useful: np.ndarray                 # (d,) total useful compute (LLM + enc)
    solve_ms: float = 0.0
    critical_rank: int = 0
    events: list[ScheduleEvent] = dataclasses.field(default_factory=list)

    # -- headline metrics ----------------------------------------------
    @property
    def fill_fraction(self) -> float:
        """Filled fraction of the theoretical 1F1B bubble time."""
        tot = float(self.bubble_total.sum())
        return float(self.filled.sum()) / tot if tot > 0 else 0.0

    @property
    def projected_mfu(self) -> float:
        t = float(self.rank_total.max())
        return (float(self.useful.sum()) / (self.d * self.pp * t)
                if t > 0 else 0.0)

    @property
    def projected_mfu_nofill(self) -> float:
        t = float(self.rank_total_nofill.max())
        return (float(self.useful.sum()) / (self.d * self.pp * t)
                if t > 0 else 0.0)

    @property
    def mfu_uplift(self) -> float:
        return self.projected_mfu - self.projected_mfu_nofill

    def waterfall_inputs(self) -> dict:
        """The ``pipeline=`` payload for :meth:`GapWaterfall.observe`."""
        return {
            "stages": self.pp,
            "stage_bubble": self.stage_idle.mean(axis=0),
            "rank_totals": self.rank_total,
            "useful_per_device": float(self.useful.mean()) / self.pp,
            "critical_cost": float(self.rank_total.max()),
        }

    def to_dict(self) -> dict:
        return {
            "pp": self.pp,
            "n_micro": self.n_micro,
            "d": self.d,
            "partition": list(self.partition),
            "bubble_fill": self.bubble_fill,
            "fill_fraction": self.fill_fraction,
            "bubble_total": float(self.bubble_total.sum()),
            "filled": float(self.filled.sum()),
            "projected_mfu": self.projected_mfu,
            "projected_mfu_nofill": self.projected_mfu_nofill,
            "mfu_uplift": self.mfu_uplift,
            "solve_ms": self.solve_ms,
        }


def plan_pipeline(
    cfg,
    llm_model: CostModel,
    dest_lengths: Sequence[np.ndarray],
    enc_costs: Mapping[str, np.ndarray] | None = None,
    *,
    pp: int,
    n_micro: int = 0,
    bubble_fill: bool = True,
    layer_costs: np.ndarray | None = None,
    bwd_ratio: float = BWD_RATIO,
    keep_events: bool = True,
) -> PipelinePlan:
    """Build the per-iteration pipeline plan for all DP ranks.

    ``dest_lengths`` is the post-balanced per-rank LLM length layout
    (``DispatchPlan.dest_lengths``); ``enc_costs[name]`` the (d,)
    per-rank cost vector of encoder phase ``name`` in its OWN cost
    units (``DispatchPlan.costs``) -- rescaled here onto the LLM unit
    via :func:`phase_flops_per_unit`.  ``n_micro=0`` defaults to
    ``2*pp`` (enough microbatches to saturate the steady state).
    ``layer_costs`` optionally drives a cost-weighted
    :func:`stage_partition` (calibrated per-layer costs).
    """
    t0 = time.perf_counter()
    d = len(dest_lengths)
    if pp < 2:
        raise ValueError(f"plan_pipeline needs pp >= 2, got {pp}")
    n_micro = int(n_micro) or 2 * pp
    partition = stage_partition(cfg.n_layers, pp, layer_costs)
    frac = np.asarray(partition, dtype=np.float64) / float(cfg.n_layers)

    flops = phase_flops_per_unit(cfg)
    enc_costs = enc_costs or {}
    enc_fwd = np.zeros(d)
    for name, costs in enc_costs.items():
        enc_fwd += (flops[name] / flops["llm"]) * np.asarray(costs, np.float64)

    micro_assign: list[np.ndarray] = []
    micro_costs = np.zeros((d, n_micro))
    for r in range(d):
        assign, costs = split_microbatches(dest_lengths[r], n_micro, llm_model)
        micro_assign.append(assign)
        micro_costs[r] = costs

    makespan_1f1b = np.zeros(d)
    bubble_total = np.zeros(d)
    filled = np.zeros(d)
    stage_busy = np.zeros((d, pp))
    stage_idle = np.zeros((d, pp))
    rank_total = np.zeros(d)
    rank_total_nofill = np.zeros(d)
    useful = np.zeros(d)
    events_by_rank: list[list[ScheduleEvent]] = []

    for r in range(d):
        fwd = np.outer(frac, micro_costs[r])          # (pp, m)
        bwd = bwd_ratio * fwd
        f_s, f_e, b_s, b_e, makespan = _simulate_1f1b(fwd, bwd)
        makespan_1f1b[r] = makespan
        llm_busy = fwd.sum(axis=1) + bwd.sum(axis=1)  # (pp,)
        bubble_total[r] = pp * makespan - float(llm_busy.sum())
        useful[r] = float(llm_busy.sum()) + (1.0 + bwd_ratio) * enc_fwd[r]

        ev: list[ScheduleEvent] = []
        if keep_events:
            for s in range(pp):
                for i in range(n_micro):
                    if fwd[s, i] > 0:
                        ev.append(ScheduleEvent("F", s, i, f_s[s, i], f_e[s, i]))
                        ev.append(ScheduleEvent("B", s, i, b_s[s, i], b_e[s, i]))

        # Encoder work: each stage owns a 1/pp slice of the encoder
        # stack (same sharding rule as the LLM layers), one chunk per
        # microbatch.  Forward chunks are deadline-bound by F(0, i),
        # backward chunks released by B(0, i).
        enc_f_chunk = np.full(n_micro, enc_fwd[r] / (pp * n_micro))
        enc_b_chunk = bwd_ratio * enc_f_chunk
        pro = np.zeros(pp)
        epi = np.zeros(pp)
        for s in range(pp):
            if bubble_fill and enc_fwd[r] > 0:
                windows = _idle_windows(f_s[s:s + 1], f_e[s:s + 1],
                                        b_s[s:s + 1], b_e[s:s + 1],
                                        makespan)[0]
                pf, lf = _edf_fill(windows, enc_f_chunk, f_s[0],
                                   deadline=True, stage=s, kind="encF",
                                   events=ev if keep_events else [])
                pb, lb = _edf_fill(windows, enc_b_chunk, b_e[0],
                                   deadline=False, stage=s, kind="encB",
                                   events=ev if keep_events else [])
                # Steady-state cross-iteration fill: leftover backward
                # rides in the next step's warm-up bubbles, leftover
                # forward (of the next, prefetched step) in this step's
                # cool-down bubbles -- volume-bound per stage.
                xb = _volume_fill(windows, lb, stage=s, kind="encB",
                                  events=ev if keep_events else [])
                xf = _volume_fill(windows, lf, stage=s, kind="encF",
                                  events=ev if keep_events else [])
                filled[r] += pf + pb + xb + xf
                pro[s], epi[s] = lf - xf, lb - xb
            else:
                pro[s] = float(enc_f_chunk.sum())
                epi[s] = float(enc_b_chunk.sum())
        prologue, epilogue = float(pro.max()), float(epi.max())
        rank_total[r] = prologue + makespan + epilogue
        rank_total_nofill[r] = makespan + float(
            enc_f_chunk.sum() + enc_b_chunk.sum())
        stage_busy[r] = llm_busy + (1.0 + bwd_ratio) * enc_fwd[r] / pp
        stage_idle[r] = rank_total[r] - stage_busy[r]
        events_by_rank.append(ev)

    critical = int(np.argmax(rank_total)) if d else 0
    return PipelinePlan(
        pp=pp, n_micro=n_micro, d=d, partition=partition,
        stage_fractions=frac, micro_assign=micro_assign,
        micro_costs=micro_costs, enc_cost=enc_fwd,
        bubble_fill=bubble_fill, makespan_1f1b=makespan_1f1b,
        bubble_total=bubble_total, filled=filled, stage_busy=stage_busy,
        stage_idle=stage_idle, rank_total=rank_total,
        rank_total_nofill=rank_total_nofill, useful=useful,
        solve_ms=(time.perf_counter() - t0) * 1e3,
        critical_rank=critical,
        events=events_by_rank[critical] if (keep_events and d) else [],
    )
