"""Node-wise All-to-All Communicator -- device side (paper S5.2.1).

The dispatcher decides a rearrangement Pi on the host; this module moves
the actual token payloads between DP shards.  Three modes, matching the
paper's comparison (Fig. 5 / Fig. 12):

  * ``a2a``       the paper's All-to-All Batch Communicator:
                  ``shard_map`` + :func:`jax.lax.ragged_all_to_all`.
                  Per-shard traffic is O(max_i L_i), independent of d
                  (paper Eq. 4).
  * ``allgather`` the strawman: every shard gathers every mini-batch and
                  slices out its own -- O((d-1) max_i L_i) traffic
                  (paper Eq. 3).  Kept as a selectable mode so the HLO
                  collective-byte comparison in EXPERIMENTS.md reproduces
                  Fig. 12 structurally.
  * ``gather``    XLA-native: a global `jnp.take` under pjit; XLA SPMD
                  chooses the collectives.  Used as a third point in the
                  perf iteration.

Everything here works on PACKED token buffers: a global array
``[d, capacity, ...]`` sharded on its first (DP) axis; each shard holds
its examples' tokens contiguously in slot order.  Padded phases flatten
valid tokens before transport and re-pad at the destination -- i.e. the
communicator never moves padding (a TPU-friendly bonus of token-level
transport).

Portability note: ``jax.lax.ragged_all_to_all`` does not execute on
XLA:CPU (ThunkEmitter unimplemented), so the default ``a2a`` mode is a
dense ``jax.lax.all_to_all`` over per-peer chunks padded to a static
chunk capacity (host-computed max over peers).  That still lowers to a
genuine ``all-to-all`` HLO op with volume O(d * chunk_cap) per shard --
the balancing makes chunk_cap small, preserving the paper's Eq. 4
behavior -- and it runs on CPU, TPU and GPU alike.  ``mode="ragged"``
keeps the exact ragged collective for real TPU runs (traced/lowered in
tests, executed only on hardware that supports it).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# jax.shard_map landed in 0.5.x; older releases ship it as experimental.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax version
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.rearrangement import Rearrangement
from repro.utils import round_up as _round_up

__all__ = ["CommPlan", "build_comm_plan", "apply_comm_plan", "plan_to_device"]


@dataclasses.dataclass
class CommPlan:
    """Host-built static-shape plan for one payload exchange.

    All integer arrays are int32.  Shapes:
      pre_gather   [d, cap_in]   send-buffer build: dest-major token order
      input_offsets, send_sizes, output_offsets, recv_sizes  [d, d]
      post_gather  [d, cap_out]  recv-buffer -> final packed layout
      post_mask    [d, cap_out]  True on valid (non-pad) token positions
    """

    d: int
    cap_in: int
    cap_out: int
    pre_gather: np.ndarray
    input_offsets: np.ndarray
    send_sizes: np.ndarray
    output_offsets: np.ndarray
    recv_sizes: np.ndarray
    post_gather: np.ndarray
    post_mask: np.ndarray
    # Global-gather fallback: final token p of shard i comes from global
    # flat index global_gather[i, p] of the [d*cap_in] source array.
    global_gather: np.ndarray
    # Dense all_to_all emulation (CPU/TPU-portable): static per-peer chunk.
    chunk_cap: int
    pre_gather_dense: np.ndarray  # [d, d*chunk_cap]
    post_gather_dense: np.ndarray  # [d, cap_out]
    # Host-only metadata: destination packed-layout offsets per example
    # (flat, aligned with the source Rearrangement's entries).
    dst_starts: np.ndarray | None = None

    def comm_bytes(self, bytes_per_token: int) -> dict[str, int]:
        """Analytic traffic accounting (paper Eq. 3 vs 4)."""
        off_diag = self.send_sizes.copy()
        np.fill_diagonal(off_diag, 0)
        ragged = int(off_diag.sum()) * bytes_per_token
        dense = int(self.d * (self.d - 1) * self.chunk_cap) * bytes_per_token
        ag = int(self.d * (self.d - 1) * self.cap_in) * bytes_per_token
        return {"ragged": ragged, "a2a_dense": dense, "allgather": ag}


def _layout(insts: np.ndarray, slots: np.ndarray, lengths: np.ndarray, d: int):
    """Token start offset of each example in its shard's packed buffer,
    ordering examples by slot; returns (starts[n], totals[d])."""
    starts = np.zeros(len(insts), dtype=np.int64)
    totals = np.zeros(d, dtype=np.int64)
    for i in range(d):
        sel = np.where(insts == i)[0]
        sel = sel[np.argsort(slots[sel])]
        off = 0
        for k in sel:
            starts[k] = off
            off += lengths[k]
        totals[i] = off
    return starts, totals


def build_comm_plan(
    pi: Rearrangement, cap_in: int, cap_out: int, *, chunk_pad_to: int = 8,
    src_starts: np.ndarray | None = None, chunk_cap: int | None = None,
) -> CommPlan:
    """Compile a Rearrangement into static-shape transport arrays.

    ``src_starts``: explicit token offset of each example in its SOURCE
    shard buffer (flat, aligned with pi's entries).  Defaults to packed
    contiguous layout in src_slot order; the orchestrator passes explicit
    starts when the source layout has alignment gaps (downsample) or
    padded rows (audio).
    """
    d = pi.d
    n = pi.n
    lengths = pi.lengths.astype(np.int64)
    if src_starts is None:
        src_starts, src_totals = _layout(pi.src_inst, pi.src_slot, lengths, d)
        if src_totals.max(initial=0) > cap_in:
            raise ValueError(f"cap_in={cap_in} < max shard tokens {src_totals.max()}")
    else:
        src_starts = np.asarray(src_starts, dtype=np.int64)
        if n and (src_starts + lengths).max() > cap_in:
            raise ValueError(f"cap_in={cap_in} < max src end {(src_starts + lengths).max()}")
    dst_starts, dst_totals = _layout(pi.dst_inst, pi.dst_slot, lengths, d)
    if dst_totals.max(initial=0) > cap_out:
        raise ValueError(f"cap_out={cap_out} < max shard tokens {dst_totals.max()}")

    pre_gather = np.zeros((d, cap_in), dtype=np.int32)
    input_offsets = np.zeros((d, d), dtype=np.int32)
    send_sizes = np.zeros((d, d), dtype=np.int32)
    output_offsets = np.zeros((d, d), dtype=np.int32)
    recv_sizes = np.zeros((d, d), dtype=np.int32)
    post_gather = np.zeros((d, cap_out), dtype=np.int32)
    post_mask = np.zeros((d, cap_out), dtype=bool)
    global_gather = np.zeros((d, cap_out), dtype=np.int32)

    # Send side: per source shard, order examples dest-major then dst_slot.
    send_pos_of_example = np.zeros(n, dtype=np.int64)  # position in send buffer
    for s in range(d):
        ex = np.where(pi.src_inst == s)[0]
        ex = ex[np.lexsort((pi.dst_slot[ex], pi.dst_inst[ex]))]
        off = 0
        for t in range(d):
            input_offsets[s, t] = off
            for k in ex[pi.dst_inst[ex] == t]:
                send_pos_of_example[k] = off
                l = int(lengths[k])
                pre_gather[s, off : off + l] = np.arange(
                    src_starts[k], src_starts[k] + l, dtype=np.int32
                )
                off += l
            send_sizes[s, t] = off - input_offsets[s, t]

    # Recv side: source-major chunks.
    for t in range(d):
        off = 0
        for s in range(d):
            output_offsets[s, t] = off
            recv_sizes[t, s] = send_sizes[s, t]
            off += send_sizes[s, t]

    # Dense-emulation layout: per-peer chunks padded to a static capacity.
    # ``chunk_cap`` may be supplied by the caller (FIXED across steps so
    # the jitted step never recompiles); overflow raises and the data
    # pipeline resamples.
    max_send = int(send_sizes.max(initial=0))
    if chunk_cap is None:
        chunk_cap = _round_up(max(max_send, 1), chunk_pad_to)
    elif max_send > chunk_cap:
        raise ValueError(f"peer chunk {max_send} > static chunk_cap {chunk_cap}")
    pre_gather_dense = np.zeros((d, d * chunk_cap), dtype=np.int32)
    for s in range(d):
        for t in range(d):
            sz = int(send_sizes[s, t])
            src = pre_gather[s, input_offsets[s, t] : input_offsets[s, t] + sz]
            pre_gather_dense[s, t * chunk_cap : t * chunk_cap + sz] = src

    # Post gather: final packed layout per destination shard.
    post_gather_dense = np.zeros((d, cap_out), dtype=np.int32)
    for t in range(d):
        ex = np.where(pi.dst_inst == t)[0]
        ex = ex[np.argsort(pi.dst_slot[ex])]
        for k in ex:
            s = int(pi.src_inst[k])
            # position of k's tokens inside s->t chunk:
            within = send_pos_of_example[k] - input_offsets[s, t]
            recv_start = output_offsets[s, t] + within
            l = int(lengths[k])
            dst = int(dst_starts[k])
            post_gather[t, dst : dst + l] = np.arange(
                recv_start, recv_start + l, dtype=np.int32
            )
            post_gather_dense[t, dst : dst + l] = s * chunk_cap + int(within) + np.arange(
                l, dtype=np.int32
            )
            post_mask[t, dst : dst + l] = True
            global_gather[t, dst : dst + l] = s * cap_in + np.arange(
                src_starts[k], src_starts[k] + l, dtype=np.int32
            )

    return CommPlan(
        d=d,
        cap_in=cap_in,
        cap_out=cap_out,
        pre_gather=pre_gather,
        input_offsets=input_offsets,
        send_sizes=send_sizes,
        output_offsets=output_offsets,
        recv_sizes=recv_sizes,
        post_gather=post_gather,
        post_mask=post_mask,
        global_gather=global_gather,
        chunk_cap=chunk_cap,
        pre_gather_dense=pre_gather_dense,
        post_gather_dense=post_gather_dense,
        dst_starts=dst_starts,
    )


_PLAN_KEYS = (
    "pre_gather", "input_offsets", "send_sizes", "output_offsets",
    "recv_sizes", "post_gather", "post_mask", "global_gather",
    "pre_gather_dense", "post_gather_dense",
)


def plan_to_device(plan: CommPlan) -> dict[str, jnp.ndarray]:
    """The arrays the jitted step consumes (shard these on the DP axis)."""
    return {k: jnp.asarray(getattr(plan, k)) for k in _PLAN_KEYS}


def plan_shardings(dp_axes: tuple[str, ...]) -> dict[str, P]:
    """PartitionSpecs for :func:`plan_to_device` outputs."""
    return {k: P(dp_axes) for k in _PLAN_KEYS}


# ----------------------------------------------------------------------
# Device-side exchange.
# ----------------------------------------------------------------------
COMM_MODES = ("a2a", "ragged", "allgather", "gather")


def apply_comm_plan(
    x: jnp.ndarray,
    plan_arrays: dict[str, jnp.ndarray],
    mesh: Mesh,
    dp_axes: tuple[str, ...],
    *,
    mode: str = "a2a",
) -> jnp.ndarray:
    """Rearrange packed token payloads across DP shards.

    Args:
      x: global [total_shards * cap_in, ...] array (first dim sharded over
        ``dp_axes``); *token* leading dim.
      plan_arrays: from :func:`plan_to_device`; first dims sharded likewise.
      mode: "a2a" (dense all_to_all emulation, portable), "ragged"
        (paper-exact ragged_all_to_all, TPU), "allgather" (strawman,
        paper Eq. 3), "gather" (XLA-native global take).

    Returns [total_shards * cap_out, ...] global array, same sharding.
    """
    d = int(np.prod([mesh.shape[a] for a in dp_axes]))
    # post_mask is the one plan array every mode carries.
    cap_out = plan_arrays["post_mask"].shape[-1]
    feat = x.shape[1:]
    axis = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    row = P(dp_axes)

    def masked(res, mask):
        return jnp.where(mask.reshape(mask.shape + (1,) * (res.ndim - 1)), res, 0)

    if mode == "gather":
        # Global take; XLA SPMD inserts the collectives it prefers.
        idx = plan_arrays["global_gather"].reshape(-1)
        mask = plan_arrays["post_mask"].reshape(-1)
        res = jnp.take(x, idx, axis=0)
        return jnp.where(mask.reshape((-1,) + (1,) * len(feat)), res, 0)

    if mode == "allgather":
        def body(xs, gg, mask):
            allx = jax.lax.all_gather(xs, axis_name=axis, tiled=True)
            return masked(jnp.take(allx, gg[0], axis=0), mask[0])

        return _shard_map(
            body, mesh=mesh, in_specs=(row, row, row), out_specs=row
        )(x, plan_arrays["global_gather"], plan_arrays["post_mask"])

    if mode == "a2a":
        chunk_cap = plan_arrays["pre_gather_dense"].shape[-1] // d

        def body(xs, pgd, post, mask):
            send = jnp.take(xs, pgd[0], axis=0)  # [d*chunk, ...]
            send = send.reshape((d, chunk_cap) + feat)
            recv = jax.lax.all_to_all(
                send, axis_name=axis, split_axis=0, concat_axis=0
            )  # [d, chunk, ...]: entry s = chunk from source shard s
            recv = recv.reshape((d * chunk_cap,) + feat)
            return masked(jnp.take(recv, post[0], axis=0), mask[0])

        return _shard_map(
            body, mesh=mesh, in_specs=(row, row, row, row), out_specs=row
        )(x, plan_arrays["pre_gather_dense"], plan_arrays["post_gather_dense"],
          plan_arrays["post_mask"])

    if mode == "ragged":
        if not hasattr(jax.lax, "ragged_all_to_all"):
            raise NotImplementedError(
                f"mode='ragged' needs jax.lax.ragged_all_to_all "
                f"(unavailable in jax {jax.__version__}); use mode='a2a'"
            )

        def body(xs, pg, io, ss, oo, rs, post, mask):
            send = jnp.take(xs, pg[0], axis=0)
            out = jnp.zeros((cap_out,) + feat, xs.dtype)
            out = jax.lax.ragged_all_to_all(
                send, out,
                io[0].astype(jnp.int32), ss[0].astype(jnp.int32),
                oo[0].astype(jnp.int32), rs[0].astype(jnp.int32),
                axis_name=axis,
            )
            return masked(jnp.take(out, post[0], axis=0), mask[0])

        return _shard_map(
            body, mesh=mesh, in_specs=(row,) + (row,) * 7, out_specs=row
        )(
            x,
            plan_arrays["pre_gather"],
            plan_arrays["input_offsets"],
            plan_arrays["send_sizes"],
            plan_arrays["output_offsets"],
            plan_arrays["recv_sizes"],
            plan_arrays["post_gather"],
            plan_arrays["post_mask"],
        )

    raise ValueError(f"unknown communicator mode {mode!r}")
