"""Batch Post-Balancing Algorithms (paper S5.1, Alg 1-2; App. A, Alg 3-4).

All algorithms take the flat list of examples -- each identified by its
(source instance, source slot, length) -- and return ``d`` new batches
minimizing (approximately) ``max_i f(S'_i)`` for the phase's cost model.

  - :func:`post_balance_nopad`   Alg 1: LPT greedy, 4/3-approx, O(n log n)
  - :func:`post_balance_pad`     Alg 2: binary search + first-fit, O(n log nC)
  - :func:`post_balance_quad`    Alg 3: quadratic objective (beta not << alpha)
  - :func:`post_balance_conv`    Alg 4: ConvTransformer objective
  - :func:`post_balance`         policy dispatch from a :class:`CostModel`
  - :func:`brute_force_oracle`   exact minimizer for tests (tiny n, d)

Two backends implement the same algorithms:

  - ``backend="python"``     the per-item heapq loops below -- the
    readable reference path, kept for equivalence testing;
  - ``backend="vectorized"`` the chunked NumPy engine in
    :mod:`repro.core.balancing_vec`, exactly equivalent (same
    assignments, not just the same objective) and 10-100x faster at
    production sizes.  This is the default.

The returned object is a :class:`~repro.core.rearrangement.Rearrangement`.
"""
from __future__ import annotations

import heapq
from typing import Sequence

import numpy as np

from repro.core import balancing_vec as _vec
from repro.core.cost_model import CostModel
from repro.core.rearrangement import Rearrangement

__all__ = [
    "flatten_instance_lengths",
    "post_balance_nopad",
    "post_balance_pad",
    "post_balance_quad",
    "post_balance_conv",
    "post_balance",
    "select_algorithm",
    "brute_force_oracle",
    "BACKENDS",
]

BACKENDS = ("python", "vectorized")

Item = tuple[int, int, int]  # (src_inst, src_slot, length)


def flatten_instance_lengths(lengths_per_instance: Sequence[np.ndarray]) -> list[Item]:
    items: list[Item] = []
    for i, lens in enumerate(lengths_per_instance):
        for j, l in enumerate(np.asarray(lens)):
            items.append((i, j, int(l)))
    return items


def _sorted_desc(items: Sequence[Item]) -> list[Item]:
    return sorted(items, key=lambda it: -it[2])


def _sorted_asc(items: Sequence[Item]) -> list[Item]:
    return sorted(items, key=lambda it: it[2])


def _to_rearrangement(batches: list[list[Item]], d: int) -> Rearrangement:
    batches = batches + [[] for _ in range(d - len(batches))]
    return Rearrangement.from_batches(batches, d)


# ----------------------------------------------------------------------
# Algorithm 1: Post-Balancing without paddings (LPT greedy).
# ----------------------------------------------------------------------
def post_balance_nopad(items: Sequence[Item], d: int, *,
                       backend: str = "python") -> Rearrangement:
    """Paper Algorithm 1.  Sort descending, push each onto the batch with
    the smallest running token sum (priority queue).  4/3-approximation
    of the makespan objective ``min max_i L'_i``."""
    if backend == "vectorized":
        return _vec.nopad_vec(*_vec.items_to_arrays(items), d)
    heap: list[tuple[int, int]] = [(0, i) for i in range(d)]  # (sum, batch_idx)
    heapq.heapify(heap)
    batches: list[list[Item]] = [[] for _ in range(d)]
    for it in _sorted_desc(items):
        total, idx = heapq.heappop(heap)
        batches[idx].append(it)
        heapq.heappush(heap, (total + it[2], idx))
    return _to_rearrangement(batches, d)


# ----------------------------------------------------------------------
# Algorithm 2: Post-Balancing with paddings (binary search + first-fit).
# ----------------------------------------------------------------------
def _least_batches_under_bound(sorted_asc: list[Item], bound: int) -> list[list[Item]]:
    """GetLeastBatches(b): pack ascending; a batch's padded length is
    (count * running-max); open a new batch when adding would exceed the
    bound.  Ascending order makes the incoming item the running max."""
    batches: list[list[Item]] = [[]]
    for it in sorted_asc:
        if (len(batches[-1]) + 1) * it[2] > bound and batches[-1]:
            batches.append([])
        batches[-1].append(it)
    return batches


def post_balance_pad(items: Sequence[Item], d: int, *,
                     backend: str = "python") -> Rearrangement:
    """Paper Algorithm 2: binary-search the smallest padded-batch-length
    bound for which first-fit packing needs <= d batches."""
    if backend == "vectorized":
        return _vec.pad_vec(*_vec.items_to_arrays(items), d)
    if not items:
        return _to_rearrangement([], d)
    asc = _sorted_asc(items)
    n = len(asc)
    lo = asc[-1][2]  # must fit the longest sequence alone
    hi = asc[-1][2] * (n // d + 1)
    while lo < hi:
        mid = (lo + hi) // 2
        if len(_least_batches_under_bound(asc, mid)) <= d:
            hi = mid
        else:
            lo = mid + 1
    batches = _least_batches_under_bound(asc, lo)
    return _to_rearrangement(batches, d)


# ----------------------------------------------------------------------
# Algorithm 3 (App. A): tolerance-interval greedy for beta not << alpha.
# Objective: min max_i  L'_i + lambda * sum_j l'_{i,j}^2
# ----------------------------------------------------------------------
class _QuadBatch:
    __slots__ = ("idx", "lsum", "sqsum", "tol")

    def __init__(self, idx: int, tol: float):
        self.idx = idx
        self.lsum = 0
        self.sqsum = 0
        self.tol = tol

    def __lt__(self, other: "_QuadBatch") -> bool:  # paper CMP
        if abs(self.lsum - other.lsum) < self.tol:
            return self.sqsum < other.sqsum
        return self.lsum < other.lsum


def post_balance_quad(
    items: Sequence[Item],
    d: int,
    *,
    tolerance: float | None = None,
    lam: float = 0.0,
    method: str = "effective",
    backend: str = "python",
) -> Rearrangement:
    """Paper Algorithm 3 ('Post-Balancing Algorithm 3rd').

    Objective: min max_i  L'_i + lam * sum_j l'_{i,j}^2.

    ``method="effective"`` (default) is LPT greedy on the *effective
    weight* w = l + lam*l^2: assigning an item raises its batch's
    objective by exactly w, so greedy-on-resulting-cost IS plain LPT on
    w -- the clean reduction the paper's tolerance comparator
    approximates.  ``method="tolerance"`` keeps the paper-faithful heap
    CMP (balance L first, break near-ties by sum of squares);
    ``tolerance`` is its manually-set interval v, defaulting to a
    mean-length heuristic.  Passing ``tolerance`` explicitly selects
    the tolerance method (it has no meaning for the effective method).
    Only the effective method has a vectorized backend.
    """
    if tolerance is not None and method == "effective":
        method = "tolerance"
    if method == "effective":
        if backend == "vectorized":
            return _vec.quad_vec(*_vec.items_to_arrays(items), d, lam=lam)
        heap: list[tuple[float, int]] = [(0.0, i) for i in range(d)]
        heapq.heapify(heap)
        batches: list[list[Item]] = [[] for _ in range(d)]
        for it in _sorted_desc(items):
            # Precompute w so float accumulation order matches the
            # vectorized engine exactly (loads stay bit-identical).
            w = it[2] + lam * float(it[2]) ** 2
            total, idx = heapq.heappop(heap)
            batches[idx].append(it)
            heapq.heappush(heap, (total + w, idx))
        return _to_rearrangement(batches, d)
    if method != "tolerance":
        raise ValueError(f"unknown quad method {method!r}")
    if not items:
        return _to_rearrangement([], d)
    if tolerance is None:
        mean_len = float(np.mean([it[2] for it in items]))
        tolerance = max(1.0, mean_len * (0.5 if lam > 0 else 0.1))
    theap = [_QuadBatch(i, tolerance) for i in range(d)]
    heapq.heapify(theap)
    tbatches: list[list[Item]] = [[] for _ in range(d)]
    for it in _sorted_desc(items):
        top = heapq.heappop(theap)
        tbatches[top.idx].append(it)
        top.lsum += it[2]
        top.sqsum += it[2] * it[2]
        heapq.heappush(theap, top)
    return _to_rearrangement(tbatches, d)


# ----------------------------------------------------------------------
# Algorithm 4 (App. A): ConvTransformer objective.
# Objective: min max_i  L'_i + lambda * b_i * max_j(l'_{i,j})^2
# ----------------------------------------------------------------------
def post_balance_conv(items: Sequence[Item], d: int, *,
                      backend: str = "python") -> Rearrangement:
    """Paper Algorithm 4 ('Post-Balancing Algorithm 4th').

    First bound the padded term: pack descending under the bound given by
    Alg 1's objective value (so the conv-attention padded cost of each
    batch stays near the balanced linear cost), stopping once d batches
    are open; then distribute the remainder LPT-style by running sums.
    """
    if backend == "vectorized":
        return _vec.conv_vec(*_vec.items_to_arrays(items), d)
    if not items:
        return _to_rearrangement([], d)
    desc = _sorted_desc(items)
    # Bound = objective value of Algorithm 1 (max batch token sum).
    alg1 = post_balance_nopad(items, d)
    bound = max((int(l.sum()) for l in alg1.dest_lengths()), default=0)

    batches: list[list[Item]] = [[]]
    consumed = 0
    for k, it in enumerate(desc):
        cur = batches[-1]
        cur_max = cur[0][2] if cur else it[2]  # descending: first item is max
        if cur and (len(cur) + 1) * cur_max > bound:
            if len(batches) >= d:
                break
            batches.append([])
        batches[-1].append(it)
        consumed = k + 1
    batches += [[] for _ in range(d - len(batches))]

    # Remainder: LPT greedy on running sums.
    heap = [(sum(x[2] for x in b), i) for i, b in enumerate(batches)]
    heapq.heapify(heap)
    for it in desc[consumed:]:
        total, idx = heapq.heappop(heap)
        batches[idx].append(it)
        heapq.heappush(heap, (total + it[2], idx))
    return _to_rearrangement(batches, d)


# ----------------------------------------------------------------------
# Policy dispatch + exact oracle.
# ----------------------------------------------------------------------
def select_algorithm(cost_model: CostModel, lmax: int) -> str:
    """The balance policy (paper S5.1/S7 'selected according to the
    specified balance policy'):

      conv_attention -> Alg 4;  padding -> Alg 2;
      quadratic term material for the longest example
      (lambda * l_max >= 0.05) -> Alg 3;  else -> Alg 1.

    The length-aware threshold is a refinement over a fixed lambda
    cutoff: with heavy-tailed lengths, beta*l^2 of a single long example
    dominates its bin even when beta/alpha is tiny.
    """
    if cost_model.conv_attention:
        return "conv"
    if cost_model.padding:
        return "pad"
    return "quad" if cost_model.lam * lmax >= 0.05 else "nopad"


def post_balance(
    lengths_per_instance: Sequence[np.ndarray],
    d: int,
    cost_model: CostModel,
    *,
    algorithm: str | None = None,
    backend: str = "vectorized",
) -> Rearrangement:
    """Select and run the Post-Balancing algorithm for a phase.

    ``algorithm`` overrides the policy (see :func:`select_algorithm`):
    one of {"nopad", "pad", "quad", "conv"}.  ``backend`` picks the
    implementation: "vectorized" (default) or the "python" heapq
    reference.  Both produce identical rearrangements.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "vectorized":
        inst, slot, length = _vec.arrays_from_instance_lengths(lengths_per_instance)
        if algorithm is None:
            lmax = int(length.max()) if length.size else 0
            algorithm = select_algorithm(cost_model, lmax)
        if algorithm == "nopad":
            return _vec.nopad_vec(inst, slot, length, d)
        if algorithm == "pad":
            return _vec.pad_vec(inst, slot, length, d)
        if algorithm == "quad":
            return _vec.quad_vec(inst, slot, length, d, lam=cost_model.lam)
        if algorithm == "conv":
            return _vec.conv_vec(inst, slot, length, d)
        raise ValueError(f"unknown balancing algorithm {algorithm!r}")
    items = flatten_instance_lengths(lengths_per_instance)
    if algorithm is None:
        lmax = max((it[2] for it in items), default=0)
        algorithm = select_algorithm(cost_model, lmax)
    if algorithm == "nopad":
        return post_balance_nopad(items, d)
    if algorithm == "pad":
        return post_balance_pad(items, d)
    if algorithm == "quad":
        return post_balance_quad(items, d, lam=cost_model.lam)
    if algorithm == "conv":
        return post_balance_conv(items, d)
    raise ValueError(f"unknown balancing algorithm {algorithm!r}")


def brute_force_oracle(
    lengths_per_instance: Sequence[np.ndarray],
    d: int,
    cost_model: CostModel,
    *,
    chunk: int = 1 << 15,
) -> float:
    """Exact optimal max-cost via exhaustive assignment (tests only).

    Enumerates all d^n assignments in mixed-radix chunks and prices each
    chunk with the batched objective evaluator
    (:meth:`CostModel.assignment_costs`) -- one bincount per chunk
    instead of d^n * d python ``cost()`` calls.
    """
    items = flatten_instance_lengths(lengths_per_instance)
    n = len(items)
    if n > 12:
        raise ValueError("oracle is exponential; use n <= 12")
    if n == 0:
        return 0.0
    total = d**n
    if total > 10**8:
        raise ValueError(f"oracle would enumerate {total} assignments; shrink n or d")
    lens = np.array([it[2] for it in items], dtype=np.float64)
    radix = d ** np.arange(n, dtype=np.int64)
    best = np.inf
    for start in range(0, total, chunk):
        codes = np.arange(start, min(start + chunk, total), dtype=np.int64)
        assigns = (codes[:, None] // radix) % d
        costs = cost_model.assignment_costs(lens, assigns, d)
        best = min(best, float(costs.max(axis=1).min()))
    return float(best)
