"""Cost models f(S) for Batch Post-Balancing (paper Eq. 1, Eq. 2, App. A).

A *batch* here is a collection of example sequence lengths assigned to one
DP instance for one phase.  The balancing objective is

    minimize over rearrangements Pi of   max_i f(S'_i(Pi))

where ``f`` models the compute (and, proportionally, memory) cost of the
batch on its instance.  The paper gives:

  Eq. (1)  batch length   L = b * max(l)      (padding)
                          L = sum(l)          (no padding)

  Eq. (2)  transformer    f = alpha*L + beta * L^2 / b          (padding)
                          f = alpha*L + beta * sum(l_j^2)       (no padding)

  App. A   conv-transformer (padded attention, unpadded batch):
                          f = L + lambda * b * max(l)^2

``alpha`` is the per-token linear cost (MLP + projections), ``beta`` the
quadratic attention coefficient.  For an architecture with hidden size H,
FFN size F, #layers N, per-token FLOPs scale like
``alpha ~ N*(8H^2 + 4HF(+MoE top-k scaling))`` and per-pair attention
FLOPs like ``beta ~ 4*N*H`` -- so ``beta/alpha ~ 1/(2H + F)``, i.e. the
paper's beta << alpha assumption holds until sequence lengths approach
the model width.  SSM (Mamba) layers have NO quadratic term (beta = 0).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

__all__ = [
    "CostModel",
    "FEATURE_NAMES",
    "N_FEATURES",
    "ServingCostModel",
    "batch_length",
    "encoder_cost_model",
    "length_features",
    "llm_cost_model",
    "phase_flops_per_unit",
    "serving_cost_model",
    "transformer_cost_coeffs",
]

# Per-batch feature basis shared by every f(S) variant (and by the
# telemetry calibrator, which regresses measured wall times onto it):
#   x0 = L        batch length per Eq. (1) (sum packed, b*max padded)
#   x1 = L^2/b    padded quadratic term
#   x2 = sum l^2  packed quadratic term
#   x3 = b*max^2  ConvTransformer quadratic term (== x1 when padded)
# so every variant is  f = alpha*x0 + beta*x[quad_index].
FEATURE_NAMES = ("L", "L2_over_b", "sum_l2", "b_max_l2")
N_FEATURES = len(FEATURE_NAMES)


def length_features(lengths: Sequence[int] | np.ndarray,
                    padding: bool = False) -> np.ndarray:
    """The (4,) feature vector of one mini-batch."""
    arr = np.asarray(lengths, dtype=np.float64)
    if arr.size == 0:
        return np.zeros(N_FEATURES)
    b = float(arr.size)
    s = float(arr.sum())
    mx = float(arr.max())
    L = b * mx if padding else s
    return np.array([L, L * L / b, float((arr * arr).sum()), b * mx * mx])


def _segment_max(values: np.ndarray, ids: np.ndarray, n_segments: int) -> np.ndarray:
    """Max of ``values`` per segment id (empty segments -> 0)."""
    out = np.zeros(n_segments, dtype=np.float64)
    np.maximum.at(out, ids, values)
    return out


def batch_length(lengths: Sequence[int] | np.ndarray, padding: bool) -> int:
    """Paper Eq. (1): the batch length L of a mini-batch."""
    arr = np.asarray(lengths, dtype=np.int64)
    if arr.size == 0:
        return 0
    if padding:
        return int(arr.size * arr.max())
    return int(arr.sum())


@dataclasses.dataclass(frozen=True)
class CostModel:
    """f(S) for one phase.

    Attributes:
      alpha: linear per-token coefficient.
      beta: quadratic attention coefficient (0 for SSM phases).
      padding: whether the phase batches with padding (paper: audio yes,
        vision/LLM no).
      conv_attention: App. A ConvTransformer objective -- attention is
        computed on the *padded* length even though the batch is packed
        (f = L + lambda*b*max(l)^2).  Mutually exclusive with `padding`.
    """

    alpha: float = 1.0
    beta: float = 0.0
    padding: bool = False
    conv_attention: bool = False

    @property
    def lam(self) -> float:
        return self.beta / self.alpha if self.alpha else 0.0

    @property
    def quad_index(self) -> int:
        """Which feature column carries this variant's quadratic term."""
        if self.conv_attention:
            return 3
        return 1 if self.padding else 2

    def with_coeffs(self, alpha: float, beta: float) -> "CostModel":
        """Same variant (padding / conv flags), new coefficients -- the
        single injection point calibration swaps through."""
        return dataclasses.replace(self, alpha=float(alpha), beta=float(beta))

    def feature_vector(self, lengths: Sequence[int] | np.ndarray) -> np.ndarray:
        return length_features(lengths, self.padding)

    def segment_features(self, lengths: np.ndarray, batch_ids: np.ndarray,
                         d: int) -> np.ndarray:
        """Per-destination-batch feature vectors, shape (d, 4) -- the
        vectorized :func:`length_features` over a whole assignment."""
        lengths = np.asarray(lengths, dtype=np.float64)
        batch_ids = np.asarray(batch_ids)
        cnt = np.bincount(batch_ids, minlength=d).astype(np.float64)
        bsum = np.bincount(batch_ids, weights=lengths, minlength=d)
        sq = np.bincount(batch_ids, weights=lengths * lengths, minlength=d)
        bmax = _segment_max(lengths, batch_ids, d)
        L = cnt * bmax if self.padding else bsum
        safe_cnt = np.maximum(cnt, 1.0)
        return np.stack([L, L * L / safe_cnt, sq, cnt * bmax * bmax], axis=1)

    def cost_from_features(self, features: np.ndarray) -> np.ndarray:
        """f(S) from (..., 4) feature vectors; agrees with :meth:`cost`."""
        f = np.asarray(features, dtype=np.float64)
        return self.alpha * f[..., 0] + self.beta * f[..., self.quad_index]

    def cost(self, lengths: Sequence[int] | np.ndarray) -> float:
        """f(S) per paper Eq. (2) / App. A."""
        arr = np.asarray(lengths, dtype=np.float64)
        if arr.size == 0:
            return 0.0
        b = arr.size
        if self.conv_attention:
            L = float(arr.sum())
            return self.alpha * L + self.beta * b * float(arr.max()) ** 2
        if self.padding:
            L = b * float(arr.max())
            return self.alpha * L + self.beta * (L * L) / b
        L = float(arr.sum())
        return self.alpha * L + self.beta * float((arr * arr).sum())

    def costs(self, batches: Sequence[Sequence[int]]) -> np.ndarray:
        return np.array([self.cost(b) for b in batches], dtype=np.float64)

    # -- batched evaluators (vectorized balancing engine + oracle) ------
    def segment_costs(self, lengths: np.ndarray, batch_ids: np.ndarray,
                      d: int) -> np.ndarray:
        """f(S'_i) for every destination batch at once.

        ``lengths[k]`` belongs to batch ``batch_ids[k]``; returns shape
        (d,).  Agrees with :meth:`cost` per batch (empty batches cost 0).
        """
        lengths = np.asarray(lengths, dtype=np.float64)
        batch_ids = np.asarray(batch_ids)
        bsum = np.bincount(batch_ids, weights=lengths, minlength=d)
        if self.conv_attention:
            cnt = np.bincount(batch_ids, minlength=d)
            bmax = _segment_max(lengths, batch_ids, d)
            return self.alpha * bsum + self.beta * cnt * bmax * bmax
        if self.padding:
            cnt = np.bincount(batch_ids, minlength=d)
            bmax = _segment_max(lengths, batch_ids, d)
            L = cnt * bmax
            return self.alpha * L + self.beta * L * L / np.maximum(cnt, 1)
        sq = np.bincount(batch_ids, weights=lengths * lengths, minlength=d)
        return self.alpha * bsum + self.beta * sq

    def assignment_costs(self, lengths: np.ndarray,
                         assignments: np.ndarray, d: int) -> np.ndarray:
        """Per-batch costs for a whole matrix of candidate assignments.

        ``assignments`` has shape (m, n): row r assigns ``lengths[j]`` to
        batch ``assignments[r, j]``.  Returns shape (m, d).  This is the
        batched objective evaluator the brute-force oracle enumerates
        with (one bincount instead of m*d python cost() calls).
        """
        assignments = np.asarray(assignments, dtype=np.int64)
        m, n = assignments.shape
        flat_ids = (assignments + d * np.arange(m, dtype=np.int64)[:, None]).ravel()
        flat_lens = np.broadcast_to(lengths, (m, n)).ravel()
        return self.segment_costs(flat_lens, flat_ids, m * d).reshape(m, d)

    def max_cost(self, batches: Sequence[Sequence[int]]) -> float:
        c = self.costs(batches)
        return float(c.max()) if c.size else 0.0

    def utilization(self, batches: Sequence[Sequence[int]]) -> float:
        """Simulated utilization = mean(f) / max(f).

        Under synchronous DP every instance waits for the straggler, so a
        batch set with cost vector c achieves mean(c)/max(c) of the
        utilization a perfectly balanced set would.  This is the metric
        the benchmarks report as 'simulated MFU fraction'.
        """
        c = self.costs(batches)
        m = float(c.max()) if c.size else 0.0
        return float(c.mean() / m) if m > 0 else 1.0


@dataclasses.dataclass(frozen=True)
class ServingCostModel:
    """Admission costs for the serving engine's scheduler.

    Serving reuses the training-time balancing machinery: the set of
    requests admitted to one engine step is a "mini-batch" whose cost a
    token budget caps, and the waiting queue is post-balanced across
    engine replicas with the same :class:`CostModel` objective
    (``post_balance`` over weighted lengths).  Modality Composition
    Incoherence shows up at serving time as prefill cost varying by
    orders of magnitude with the request's modality mix, so:

      prefill cost = f(modality-weighted length)
                     where weighted length = text tokens
                       + sum_m weight_m * modality-m tokens
      decode cost  = ``decode_cost`` (one token per step, length
                     independent to first order)

    ``modality_weights[m]`` is the per-token compute of a modality-m
    LLM token relative to a text token (its encoder + connector ride on
    top of the backbone); modalities without an entry cost 1.0.
    """

    model: CostModel = dataclasses.field(default_factory=CostModel)
    modality_weights: Mapping[str, float] = dataclasses.field(default_factory=dict)
    decode_cost: float = 1.0

    def weighted_length(self, text_len: float,
                        modality_tokens: Mapping[str, int] | None = None) -> float:
        total = float(text_len)
        for m, n in (modality_tokens or {}).items():
            total += self.modality_weights.get(m, 1.0) * float(n)
        return total

    def prefill_cost(self, text_len: float,
                     modality_tokens: Mapping[str, int] | None = None) -> float:
        """f(S) of a single-request prefill at its weighted length."""
        return self.model.cost([self.weighted_length(text_len, modality_tokens)])

    def weighted_lengths(
        self,
        text_lens: Sequence[float],
        modality_tokens: Sequence[Mapping[str, int] | None],
    ) -> np.ndarray:
        return np.array(
            [self.weighted_length(t, m) for t, m in zip(text_lens, modality_tokens)],
            dtype=np.float64,
        )


def transformer_cost_coeffs(
    hidden: int,
    ffn: int,
    n_layers: int,
    *,
    moe_experts_active: int = 1,
    ssm: bool = False,
) -> tuple[float, float]:
    """Derive (alpha, beta) from an architecture (used by dispatchers).

    alpha ~ per-token matmul FLOPs, beta ~ per-token-pair attention FLOPs.
    Both are scaled so alpha is O(1) -- only the *ratio* matters for the
    balancing objective.
    """
    lin = n_layers * (8.0 * hidden * hidden + 6.0 * hidden * ffn * moe_experts_active)
    quad = 0.0 if ssm else 4.0 * n_layers * hidden
    alpha = 1.0
    beta = quad / lin
    return alpha, beta


# ---------------------------------------------------------------------------
# Analytic cost-model derivation.  ONE home for hand-building CostModels
# from a config: the orchestrator's per-phase dispatchers, the serving
# scheduler, and the telemetry priors all route through these three
# helpers, so calibrated coefficients have a single injection point
# (``CostModel.with_coeffs`` on the helpers' output).


def phase_flops_per_unit(cfg) -> dict[str, float]:
    """Raw forward FLOPs behind ONE normalized cost unit, per phase.

    Every phase's :class:`CostModel` is normalized to ``alpha = 1`` (only
    the alpha/beta ratio matters for balancing *within* a phase), which
    makes costs from different phases incommensurable.  The pipeline
    scheduler (:mod:`repro.core.pipeline`) must place encoder microbatch
    compute against LLM stage compute on ONE clock, so it needs the
    un-normalized linear coefficient: per-token matmul FLOPs
    ``lin = N * (8H^2 + 6HF)`` from :func:`transformer_cost_coeffs`.
    ``cost * lin`` restores raw FLOPs (the quadratic term scales along,
    since ``beta = quad/lin``).  Keyed ``"llm"`` plus each encoder name.
    """
    moe_k = cfg.experts_per_token if cfg.family == "moe" else 1
    out = {
        "llm": cfg.n_layers
        * (8.0 * cfg.d_model**2
           + 6.0 * cfg.d_model * max(cfg.d_ff, 1) * max(moe_k, 1))
    }
    for e in cfg.encoders:
        out[e.name] = max(e.n_layers, 1) * (
            8.0 * e.d_model**2 + 6.0 * e.d_model * e.d_ff)
    return out


def llm_cost_model(cfg) -> CostModel:
    """f(S) of the LLM backbone phase (cfg: ModelConfig)."""
    if cfg.family in ("ssm", "hybrid"):
        # No (or windowed) quadratic term; balancing on token sums.
        return CostModel(alpha=1.0, beta=0.0)
    moe_k = cfg.experts_per_token if cfg.family == "moe" else 1
    a, b = transformer_cost_coeffs(
        cfg.d_model, max(cfg.d_ff, 1), cfg.n_layers,
        moe_experts_active=max(moe_k, 1),
    )
    return CostModel(alpha=a, beta=b)


def encoder_cost_model(e) -> CostModel:
    """f(S) of one encoder phase (e: EncoderConfig)."""
    a, b = transformer_cost_coeffs(e.d_model, e.d_ff, max(e.n_layers, 1))
    if e.conv_attention:
        return CostModel(alpha=a, beta=b, conv_attention=True)
    return CostModel(alpha=a, beta=b, padding=e.padded)


def serving_cost_model(cfg) -> ServingCostModel:
    """Derive the serving admission costs from an architecture.

    alpha/beta come from :func:`transformer_cost_coeffs` (so the
    quadratic attention term prices long prompts super-linearly, as in
    training).  Each encoder's modality weight is the encoder+connector
    compute riding on one post-connector LLM token, relative to a
    backbone token: ``1 + (enc_layers * enc_width^2 * downsample) /
    (layers * width^2)`` -- ``downsample`` because each LLM token
    aggregates that many encoder tokens."""
    alpha, beta = transformer_cost_coeffs(
        cfg.d_model, cfg.d_ff, max(1, cfg.n_layers),
        moe_experts_active=max(1, cfg.experts_per_token),
        ssm=cfg.family == "ssm")
    base = max(1, cfg.n_layers) * cfg.d_model ** 2
    weights = {
        e.name: 1.0 + (e.n_layers * e.d_model ** 2 * e.downsample) / base
        for e in cfg.encoders
    }
    return ServingCostModel(CostModel(alpha=alpha, beta=beta),
                            modality_weights=weights)
