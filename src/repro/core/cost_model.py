"""Cost models f(S) for Batch Post-Balancing (paper Eq. 1, Eq. 2, App. A).

A *batch* here is a collection of example sequence lengths assigned to one
DP instance for one phase.  The balancing objective is

    minimize over rearrangements Pi of   max_i f(S'_i(Pi))

where ``f`` models the compute (and, proportionally, memory) cost of the
batch on its instance.  The paper gives:

  Eq. (1)  batch length   L = b * max(l)      (padding)
                          L = sum(l)          (no padding)

  Eq. (2)  transformer    f = alpha*L + beta * L^2 / b          (padding)
                          f = alpha*L + beta * sum(l_j^2)       (no padding)

  App. A   conv-transformer (padded attention, unpadded batch):
                          f = L + lambda * b * max(l)^2

``alpha`` is the per-token linear cost (MLP + projections), ``beta`` the
quadratic attention coefficient.  For an architecture with hidden size H,
FFN size F, #layers N, per-token FLOPs scale like
``alpha ~ N*(8H^2 + 4HF(+MoE top-k scaling))`` and per-pair attention
FLOPs like ``beta ~ 4*N*H`` -- so ``beta/alpha ~ 1/(2H + F)``, i.e. the
paper's beta << alpha assumption holds until sequence lengths approach
the model width.  SSM (Mamba) layers have NO quadratic term (beta = 0).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

__all__ = [
    "CostModel",
    "ServingCostModel",
    "batch_length",
    "transformer_cost_coeffs",
]


def _segment_max(values: np.ndarray, ids: np.ndarray, n_segments: int) -> np.ndarray:
    """Max of ``values`` per segment id (empty segments -> 0)."""
    out = np.zeros(n_segments, dtype=np.float64)
    np.maximum.at(out, ids, values)
    return out


def batch_length(lengths: Sequence[int] | np.ndarray, padding: bool) -> int:
    """Paper Eq. (1): the batch length L of a mini-batch."""
    arr = np.asarray(lengths, dtype=np.int64)
    if arr.size == 0:
        return 0
    if padding:
        return int(arr.size * arr.max())
    return int(arr.sum())


@dataclasses.dataclass(frozen=True)
class CostModel:
    """f(S) for one phase.

    Attributes:
      alpha: linear per-token coefficient.
      beta: quadratic attention coefficient (0 for SSM phases).
      padding: whether the phase batches with padding (paper: audio yes,
        vision/LLM no).
      conv_attention: App. A ConvTransformer objective -- attention is
        computed on the *padded* length even though the batch is packed
        (f = L + lambda*b*max(l)^2).  Mutually exclusive with `padding`.
    """

    alpha: float = 1.0
    beta: float = 0.0
    padding: bool = False
    conv_attention: bool = False

    @property
    def lam(self) -> float:
        return self.beta / self.alpha if self.alpha else 0.0

    def cost(self, lengths: Sequence[int] | np.ndarray) -> float:
        """f(S) per paper Eq. (2) / App. A."""
        arr = np.asarray(lengths, dtype=np.float64)
        if arr.size == 0:
            return 0.0
        b = arr.size
        if self.conv_attention:
            L = float(arr.sum())
            return self.alpha * L + self.beta * b * float(arr.max()) ** 2
        if self.padding:
            L = b * float(arr.max())
            return self.alpha * L + self.beta * (L * L) / b
        L = float(arr.sum())
        return self.alpha * L + self.beta * float((arr * arr).sum())

    def costs(self, batches: Sequence[Sequence[int]]) -> np.ndarray:
        return np.array([self.cost(b) for b in batches], dtype=np.float64)

    # -- batched evaluators (vectorized balancing engine + oracle) ------
    def segment_costs(self, lengths: np.ndarray, batch_ids: np.ndarray,
                      d: int) -> np.ndarray:
        """f(S'_i) for every destination batch at once.

        ``lengths[k]`` belongs to batch ``batch_ids[k]``; returns shape
        (d,).  Agrees with :meth:`cost` per batch (empty batches cost 0).
        """
        lengths = np.asarray(lengths, dtype=np.float64)
        batch_ids = np.asarray(batch_ids)
        bsum = np.bincount(batch_ids, weights=lengths, minlength=d)
        if self.conv_attention:
            cnt = np.bincount(batch_ids, minlength=d)
            bmax = _segment_max(lengths, batch_ids, d)
            return self.alpha * bsum + self.beta * cnt * bmax * bmax
        if self.padding:
            cnt = np.bincount(batch_ids, minlength=d)
            bmax = _segment_max(lengths, batch_ids, d)
            L = cnt * bmax
            return self.alpha * L + self.beta * L * L / np.maximum(cnt, 1)
        sq = np.bincount(batch_ids, weights=lengths * lengths, minlength=d)
        return self.alpha * bsum + self.beta * sq

    def assignment_costs(self, lengths: np.ndarray,
                         assignments: np.ndarray, d: int) -> np.ndarray:
        """Per-batch costs for a whole matrix of candidate assignments.

        ``assignments`` has shape (m, n): row r assigns ``lengths[j]`` to
        batch ``assignments[r, j]``.  Returns shape (m, d).  This is the
        batched objective evaluator the brute-force oracle enumerates
        with (one bincount instead of m*d python cost() calls).
        """
        assignments = np.asarray(assignments, dtype=np.int64)
        m, n = assignments.shape
        flat_ids = (assignments + d * np.arange(m, dtype=np.int64)[:, None]).ravel()
        flat_lens = np.broadcast_to(lengths, (m, n)).ravel()
        return self.segment_costs(flat_lens, flat_ids, m * d).reshape(m, d)

    def max_cost(self, batches: Sequence[Sequence[int]]) -> float:
        c = self.costs(batches)
        return float(c.max()) if c.size else 0.0

    def utilization(self, batches: Sequence[Sequence[int]]) -> float:
        """Simulated utilization = mean(f) / max(f).

        Under synchronous DP every instance waits for the straggler, so a
        batch set with cost vector c achieves mean(c)/max(c) of the
        utilization a perfectly balanced set would.  This is the metric
        the benchmarks report as 'simulated MFU fraction'.
        """
        c = self.costs(batches)
        m = float(c.max()) if c.size else 0.0
        return float(c.mean() / m) if m > 0 else 1.0


@dataclasses.dataclass(frozen=True)
class ServingCostModel:
    """Admission costs for the serving engine's scheduler.

    Serving reuses the training-time balancing machinery: the set of
    requests admitted to one engine step is a "mini-batch" whose cost a
    token budget caps, and the waiting queue is post-balanced across
    engine replicas with the same :class:`CostModel` objective
    (``post_balance`` over weighted lengths).  Modality Composition
    Incoherence shows up at serving time as prefill cost varying by
    orders of magnitude with the request's modality mix, so:

      prefill cost = f(modality-weighted length)
                     where weighted length = text tokens
                       + sum_m weight_m * modality-m tokens
      decode cost  = ``decode_cost`` (one token per step, length
                     independent to first order)

    ``modality_weights[m]`` is the per-token compute of a modality-m
    LLM token relative to a text token (its encoder + connector ride on
    top of the backbone); modalities without an entry cost 1.0.
    """

    model: CostModel = dataclasses.field(default_factory=CostModel)
    modality_weights: Mapping[str, float] = dataclasses.field(default_factory=dict)
    decode_cost: float = 1.0

    def weighted_length(self, text_len: float,
                        modality_tokens: Mapping[str, int] | None = None) -> float:
        total = float(text_len)
        for m, n in (modality_tokens or {}).items():
            total += self.modality_weights.get(m, 1.0) * float(n)
        return total

    def prefill_cost(self, text_len: float,
                     modality_tokens: Mapping[str, int] | None = None) -> float:
        """f(S) of a single-request prefill at its weighted length."""
        return self.model.cost([self.weighted_length(text_len, modality_tokens)])

    def weighted_lengths(
        self,
        text_lens: Sequence[float],
        modality_tokens: Sequence[Mapping[str, int] | None],
    ) -> np.ndarray:
        return np.array(
            [self.weighted_length(t, m) for t, m in zip(text_lens, modality_tokens)],
            dtype=np.float64,
        )


def transformer_cost_coeffs(
    hidden: int,
    ffn: int,
    n_layers: int,
    *,
    moe_experts_active: int = 1,
    ssm: bool = False,
) -> tuple[float, float]:
    """Derive (alpha, beta) from an architecture (used by dispatchers).

    alpha ~ per-token matmul FLOPs, beta ~ per-token-pair attention FLOPs.
    Both are scaled so alpha is O(1) -- only the *ratio* matters for the
    balancing objective.
    """
    lin = n_layers * (8.0 * hidden * hidden + 6.0 * hidden * ffn * moe_experts_active)
    quad = 0.0 if ssm else 4.0 * n_layers * hidden
    alpha = 1.0
    beta = quad / lin
    return alpha, beta
