"""Cost models f(S) for Batch Post-Balancing (paper Eq. 1, Eq. 2, App. A).

A *batch* here is a collection of example sequence lengths assigned to one
DP instance for one phase.  The balancing objective is

    minimize over rearrangements Pi of   max_i f(S'_i(Pi))

where ``f`` models the compute (and, proportionally, memory) cost of the
batch on its instance.  The paper gives:

  Eq. (1)  batch length   L = b * max(l)      (padding)
                          L = sum(l)          (no padding)

  Eq. (2)  transformer    f = alpha*L + beta * L^2 / b          (padding)
                          f = alpha*L + beta * sum(l_j^2)       (no padding)

  App. A   conv-transformer (padded attention, unpadded batch):
                          f = L + lambda * b * max(l)^2

``alpha`` is the per-token linear cost (MLP + projections), ``beta`` the
quadratic attention coefficient.  For an architecture with hidden size H,
FFN size F, #layers N, per-token FLOPs scale like
``alpha ~ N*(8H^2 + 4HF(+MoE top-k scaling))`` and per-pair attention
FLOPs like ``beta ~ 4*N*H`` -- so ``beta/alpha ~ 1/(2H + F)``, i.e. the
paper's beta << alpha assumption holds until sequence lengths approach
the model width.  SSM (Mamba) layers have NO quadratic term (beta = 0).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "CostModel",
    "batch_length",
    "transformer_cost_coeffs",
]


def batch_length(lengths: Sequence[int] | np.ndarray, padding: bool) -> int:
    """Paper Eq. (1): the batch length L of a mini-batch."""
    arr = np.asarray(lengths, dtype=np.int64)
    if arr.size == 0:
        return 0
    if padding:
        return int(arr.size * arr.max())
    return int(arr.sum())


@dataclasses.dataclass(frozen=True)
class CostModel:
    """f(S) for one phase.

    Attributes:
      alpha: linear per-token coefficient.
      beta: quadratic attention coefficient (0 for SSM phases).
      padding: whether the phase batches with padding (paper: audio yes,
        vision/LLM no).
      conv_attention: App. A ConvTransformer objective -- attention is
        computed on the *padded* length even though the batch is packed
        (f = L + lambda*b*max(l)^2).  Mutually exclusive with `padding`.
    """

    alpha: float = 1.0
    beta: float = 0.0
    padding: bool = False
    conv_attention: bool = False

    @property
    def lam(self) -> float:
        return self.beta / self.alpha if self.alpha else 0.0

    def cost(self, lengths: Sequence[int] | np.ndarray) -> float:
        """f(S) per paper Eq. (2) / App. A."""
        arr = np.asarray(lengths, dtype=np.float64)
        if arr.size == 0:
            return 0.0
        b = arr.size
        if self.conv_attention:
            L = float(arr.sum())
            return self.alpha * L + self.beta * b * float(arr.max()) ** 2
        if self.padding:
            L = b * float(arr.max())
            return self.alpha * L + self.beta * (L * L) / b
        L = float(arr.sum())
        return self.alpha * L + self.beta * float((arr * arr).sum())

    def costs(self, batches: Sequence[Sequence[int]]) -> np.ndarray:
        return np.array([self.cost(b) for b in batches], dtype=np.float64)

    def max_cost(self, batches: Sequence[Sequence[int]]) -> float:
        c = self.costs(batches)
        return float(c.max()) if c.size else 0.0

    def utilization(self, batches: Sequence[Sequence[int]]) -> float:
        """Simulated utilization = mean(f) / max(f).

        Under synchronous DP every instance waits for the straggler, so a
        batch set with cost vector c achieves mean(c)/max(c) of the
        utilization a perfectly balanced set would.  This is the metric
        the benchmarks report as 'simulated MFU fraction'.
        """
        c = self.costs(batches)
        m = float(c.max()) if c.size else 0.0
        return float(c.mean() / m) if m > 0 else 1.0


def transformer_cost_coeffs(
    hidden: int,
    ffn: int,
    n_layers: int,
    *,
    moe_experts_active: int = 1,
    ssm: bool = False,
) -> tuple[float, float]:
    """Derive (alpha, beta) from an architecture (used by dispatchers).

    alpha ~ per-token matmul FLOPs, beta ~ per-token-pair attention FLOPs.
    Both are scaled so alpha is O(1) -- only the *ratio* matters for the
    balancing objective.
    """
    lin = n_layers * (8.0 * hidden * hidden + 6.0 * hidden * ffn * moe_experts_active)
    quad = 0.0 if ssm else 4.0 * n_layers * hidden
    alpha = 1.0
    beta = quad / lin
    return alpha, beta
