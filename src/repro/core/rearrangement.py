"""Rearrangement Pi: the consequence-invariant example permutation (paper S3.3).

A rearrangement maps example j of original mini-batch i to slot j' of new
mini-batch i'.  We key every example by its *original* (instance, slot) so
that rearrangements from different phases of the same iteration can be
composed (paper S6, "Rearrangement Composition"):

    A'_Ek = (Pi_M o Pi_Ek^{-1})(A_Ek)

i.e. data currently living at Pi_Ek's destinations moves directly to
Pi_M's destinations in ONE all-to-all instead of two.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["Rearrangement", "identity_rearrangement", "compose"]


@dataclasses.dataclass
class Rearrangement:
    """Flat representation over n examples.

    All arrays have shape (n,).  Example k originated at
    (orig_inst[k], orig_slot[k]); under this rearrangement its payload
    moves from (src_inst[k], src_slot[k]) to (dst_inst[k], dst_slot[k]).
    For a plain post-balancing plan src == orig; for a *composed* plan
    (encoder outputs) src is the encoder dispatcher's destination.
    """

    d: int
    orig_inst: np.ndarray
    orig_slot: np.ndarray
    src_inst: np.ndarray
    src_slot: np.ndarray
    dst_inst: np.ndarray
    dst_slot: np.ndarray
    lengths: np.ndarray  # token lengths of the moved payloads

    def __post_init__(self) -> None:
        n = len(self.orig_inst)
        for name in ("orig_slot", "src_inst", "src_slot", "dst_inst", "dst_slot", "lengths"):
            arr = getattr(self, name)
            if len(arr) != n:
                raise ValueError(f"{name} has length {len(arr)} != {n}")

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.orig_inst)

    @classmethod
    def from_batches(
        cls,
        new_batches: Sequence[Sequence[tuple[int, int, int]]],
        d: int,
    ) -> "Rearrangement":
        """Build from a list (len d') of batches of (src_inst, src_slot, length).

        ``d'`` may be < d (Alg 2 can produce fewer); the remaining
        destination batches are empty.
        """
        if len(new_batches) > d:
            raise ValueError(f"{len(new_batches)} batches > d={d}")
        oi, osl, di, dsl, ln = [], [], [], [], []
        for dst, batch in enumerate(new_batches):
            for slot, (si, sj, length) in enumerate(batch):
                oi.append(si)
                osl.append(sj)
                di.append(dst)
                dsl.append(slot)
                ln.append(length)
        oi = np.asarray(oi, dtype=np.int64)
        osl = np.asarray(osl, dtype=np.int64)
        return cls(
            d=d,
            orig_inst=oi,
            orig_slot=osl,
            src_inst=oi.copy(),
            src_slot=osl.copy(),
            dst_inst=np.asarray(di, dtype=np.int64),
            dst_slot=np.asarray(dsl, dtype=np.int64),
            lengths=np.asarray(ln, dtype=np.int64),
        )

    # ------------------------------------------------------------------
    def dest_batches(self) -> list[list[tuple[int, int, int]]]:
        """Inverse view: per destination instance, ordered (src_inst, src_slot, len)."""
        out: list[list[tuple[int, int, int]]] = [[] for _ in range(self.d)]
        order = np.lexsort((self.dst_slot, self.dst_inst))
        for k in order:
            out[int(self.dst_inst[k])].append(
                (int(self.src_inst[k]), int(self.src_slot[k]), int(self.lengths[k]))
            )
        return out

    def dest_lengths(self) -> list[np.ndarray]:
        """Per destination instance, the ordered sequence lengths."""
        order = np.lexsort((self.dst_slot, self.dst_inst))
        lens_sorted = np.asarray(self.lengths, dtype=np.int64)[order]
        counts = np.bincount(self.dst_inst[order], minlength=self.d)
        return np.split(lens_sorted, np.cumsum(counts)[:-1])

    def comm_matrix(self) -> np.ndarray:
        """V[i, j] = token volume moving from instance i to instance j (S5.2.2)."""
        V = np.zeros((self.d, self.d), dtype=np.int64)
        np.add.at(V, (self.src_inst, self.dst_inst), self.lengths)
        return V

    def internode_volume(self, instances_per_node: int) -> np.ndarray:
        """Per-source-instance volume leaving its node (paper Eq. 5 argument)."""
        V = self.comm_matrix()
        c = instances_per_node
        node_of = np.arange(self.d) // c
        same = node_of[:, None] == node_of[None, :]
        return (V * (~same)).sum(axis=1)

    def self_volume(self) -> int:
        """Bytes that never leave their shard (beyond-paper metric)."""
        stay = self.src_inst == self.dst_inst
        return int(self.lengths[stay].sum())

    # ------------------------------------------------------------------
    def permute_destinations(self, perm: np.ndarray) -> "Rearrangement":
        """Relabel destination batches: new dst of batch i is perm[i].

        The balancing objective only depends on the *contents* of each
        destination batch, not its index (paper S5.2.2) -- so this is
        objective-invariant and is the degree of freedom the Node-wise
        Rearrangement Algorithm optimizes.
        """
        perm = np.asarray(perm, dtype=np.int64)
        if perm.shape != (self.d,) or set(perm.tolist()) != set(range(self.d)):
            raise ValueError("perm must be a permutation of range(d)")
        return dataclasses.replace(self, dst_inst=perm[self.dst_inst])

    def inverse(self) -> "Rearrangement":
        """Pi^{-1}: moves payloads from dst back to src."""
        return dataclasses.replace(
            self,
            src_inst=self.dst_inst.copy(),
            src_slot=self.dst_slot.copy(),
            dst_inst=self.src_inst.copy(),
            dst_slot=self.src_slot.copy(),
        )


def identity_rearrangement(lengths_per_instance: Sequence[np.ndarray], d: int) -> Rearrangement:
    """The no-balancing baseline: every example stays where it was sampled."""
    batches = [
        [(i, j, int(l)) for j, l in enumerate(lens)]
        for i, lens in enumerate(lengths_per_instance)
    ]
    batches += [[] for _ in range(d - len(batches))]
    return Rearrangement.from_batches(batches, d)


def compose(pi_m: Rearrangement, pi_e: Rearrangement) -> Rearrangement:
    """Pi_M o Pi_E^{-1}: move encoder outputs (located per pi_e) straight to
    pi_m's destinations (paper S6).

    ``pi_e`` may cover a SUBSET of pi_m's examples (Modality Composition
    Incoherence: not every example has every modality); the composed
    rearrangement covers exactly pi_e's examples.  Lengths are taken from
    ``pi_e`` (the payload being moved is the *encoded* subsequence, whose
    length pi_e tracked).  Destination slots keep pi_m's example-level
    slots (gaps where other examples sit are fine: layouts sort by slot).
    """
    # Join on (orig_inst, orig_slot).
    idx_m = {(int(a), int(b)): k for k, (a, b) in enumerate(zip(pi_m.orig_inst, pi_m.orig_slot))}
    n = pi_e.n
    dst_inst = np.empty(n, dtype=np.int64)
    dst_slot = np.empty(n, dtype=np.int64)
    for k in range(n):
        key = (int(pi_e.orig_inst[k]), int(pi_e.orig_slot[k]))
        if key not in idx_m:
            raise KeyError(f"example {key} missing from backbone rearrangement")
        m = idx_m[key]
        dst_inst[k] = pi_m.dst_inst[m]
        dst_slot[k] = pi_m.dst_slot[m]
    return Rearrangement(
        d=pi_m.d,
        orig_inst=pi_e.orig_inst.copy(),
        orig_slot=pi_e.orig_slot.copy(),
        src_inst=pi_e.dst_inst.copy(),
        src_slot=pi_e.dst_slot.copy(),
        dst_inst=dst_inst,
        dst_slot=dst_slot,
        lengths=pi_e.lengths.copy(),
    )
