"""Node-wise Rearrangement Algorithm (paper S5.2.2, Algorithm 3).

Given a solved rearrangement Pi, any permutation of the *destination
batch indices* leaves the balancing objective unchanged but changes the
communication matrix's column order -- and therefore how much traffic
crosses the slow inter-node (TPU: inter-pod / DCI) links.

The paper formulates an ILP: assign the d destination batches to d/c
nodes (c instances per node), each node receiving exactly c batches,
minimizing the max over nodes of the volume its instances send to
batches placed on OTHER nodes:

    min max_g  sum_{i in node g} sum_{j : batch j not on node g} V[i, j]

We implement it three ways:
  * :func:`solve_ilp` -- exact, via scipy.optimize.milp (HiGHS), for
    moderate d (the paper used CVXPY+CBC).
  * :func:`solve_greedy` -- greedy + pairwise-swap local search for
    large d where exact ILP is impractical.
  * plus the beyond-paper refinement :func:`assign_within_node`:
    a per-node Hungarian assignment (linear_sum_assignment) of batches
    to *specific instances*, maximizing self-traffic (bytes that never
    leave the shard at all).  The paper stops at node granularity.
"""
from __future__ import annotations

import numpy as np

from repro.core.rearrangement import Rearrangement

try:  # scipy is available in this environment; keep a soft dependency anyway.
    from scipy.optimize import LinearConstraint, linear_sum_assignment, milp
    from scipy.optimize import Bounds

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False

__all__ = [
    "node_cost_matrix",
    "internode_objective",
    "solve_ilp",
    "solve_greedy",
    "assign_within_node",
    "nodewise_rearrange",
]


def node_cost_matrix(pi: Rearrangement) -> np.ndarray:
    """cost_matrix[i][j] = volume instance i sends to destination batch j
    (paper Alg 3 lines 1-4)."""
    V = np.zeros((pi.d, pi.d), dtype=np.int64)
    np.add.at(V, (pi.src_inst, pi.dst_inst), pi.lengths)
    return V


def internode_objective(V: np.ndarray, batch_to_node: np.ndarray, c: int) -> int:
    """max over nodes g of sum_{i in g} sum_{j not on g} V[i, j]."""
    d = V.shape[0]
    n_nodes = d // c
    worst = 0
    for g in range(n_nodes):
        rows = range(g * c, (g + 1) * c)
        off_node = batch_to_node != g
        worst = max(worst, int(V[list(rows)][:, off_node].sum()))
    return worst


def solve_ilp(V: np.ndarray, c: int, *, time_limit: float = 10.0) -> np.ndarray | None:
    """Exact ILP via HiGHS.  Returns batch_to_node (d,) or None on failure.

    Variables: x[j, g] in {0,1} (batch j -> node g), plus t = max cost.
    Constraints: sum_g x[j,g] = 1; sum_j x[j,g] = c;
                 for each g: sum_{i in g} sum_j V[i,j]*(1 - x[j,g]) <= t.
    """
    if not _HAVE_SCIPY:
        return None
    d = V.shape[0]
    n_nodes = d // c
    nx = d * n_nodes
    nvar = nx + 1  # + t

    def xi(j: int, g: int) -> int:
        return j * n_nodes + g

    cons = []
    # Each batch to exactly one node.
    A = np.zeros((d, nvar))
    for j in range(d):
        for g in range(n_nodes):
            A[j, xi(j, g)] = 1.0
    cons.append(LinearConstraint(A, 1.0, 1.0))
    # Each node gets exactly c batches.
    A = np.zeros((n_nodes, nvar))
    for g in range(n_nodes):
        for j in range(d):
            A[g, xi(j, g)] = 1.0
    cons.append(LinearConstraint(A, float(c), float(c)))
    # Max-cost epigraph: row_g . (1 - x[:,g]) - t <= 0
    A = np.zeros((n_nodes, nvar))
    ub = np.zeros(n_nodes)
    for g in range(n_nodes):
        rows = V[g * c : (g + 1) * c].sum(axis=0).astype(float)  # volume per dest batch
        total = rows.sum()
        # total_g - sum_j rows[j]*x[j,g] - t <= 0   <=>   -rows.x - t <= -total_g
        for j in range(d):
            A[g, xi(j, g)] = -rows[j]
        A[g, nx] = -1.0
        ub[g] = -total
    cons.append(LinearConstraint(A, -np.inf, ub))

    objective = np.zeros(nvar)
    objective[nx] = 1.0
    integrality = np.ones(nvar)
    integrality[nx] = 0
    bounds = Bounds(lb=np.zeros(nvar), ub=np.concatenate([np.ones(nx), [np.inf]]))
    res = milp(
        c=objective,
        constraints=cons,
        integrality=integrality,
        bounds=bounds,
        options={"time_limit": time_limit},
    )
    if res is None or res.x is None:
        return None
    x = res.x[:nx].reshape(d, n_nodes)
    batch_to_node = x.argmax(axis=1)
    # Validate feasibility (rounding can break counts).
    if not all((batch_to_node == g).sum() == c for g in range(n_nodes)):
        return None
    return batch_to_node.astype(np.int64)


def solve_greedy(V: np.ndarray, c: int, *, swap_rounds: int = 4) -> np.ndarray:
    """Greedy seed + pairwise swap local search on the minimax objective.

    Seed: for each node g (in order of total outgoing volume, desc),
    pick the c unassigned batches that receive the most volume *from g's
    instances* (affinity), so that volume stays on-node.
    """
    d = V.shape[0]
    n_nodes = d // c
    node_rows = np.stack([V[g * c : (g + 1) * c].sum(axis=0) for g in range(n_nodes)])
    batch_to_node = -np.ones(d, dtype=np.int64)
    order = np.argsort(-node_rows.sum(axis=1))
    taken = np.zeros(d, dtype=bool)
    for g in order:
        aff = np.where(taken, -1, node_rows[g])
        pick = np.argsort(-aff)[:c]
        batch_to_node[pick] = g
        taken[pick] = True

    def cost(assign: np.ndarray) -> int:
        return internode_objective(V, assign, c)

    best = cost(batch_to_node)
    for _ in range(swap_rounds):
        improved = False
        for j in range(d):
            for k in range(j + 1, d):
                if batch_to_node[j] == batch_to_node[k]:
                    continue
                batch_to_node[j], batch_to_node[k] = batch_to_node[k], batch_to_node[j]
                new = cost(batch_to_node)
                if new < best:
                    best = new
                    improved = True
                else:
                    batch_to_node[j], batch_to_node[k] = batch_to_node[k], batch_to_node[j]
        if not improved:
            break
    return batch_to_node


def assign_within_node(V: np.ndarray, batch_to_node: np.ndarray, c: int) -> np.ndarray:
    """Beyond-paper: inside each node, assign its c batches to specific
    instances maximizing self-traffic V[i, j] for batch j on instance i.
    Returns perm (d,): destination batch j is placed on instance perm[j].
    """
    d = V.shape[0]
    n_nodes = d // c
    perm = np.empty(d, dtype=np.int64)
    for g in range(n_nodes):
        insts = np.arange(g * c, (g + 1) * c)
        batches = np.where(batch_to_node == g)[0]
        # Maximize sum V[inst, batch] -> minimize negative.
        if _HAVE_SCIPY:
            costm = -V[np.ix_(insts, batches)].astype(float)
            r, col = linear_sum_assignment(costm)
            for ri, ci in zip(r, col):
                perm[batches[ci]] = insts[ri]
        else:  # pragma: no cover
            for bi, b in enumerate(batches):
                perm[b] = insts[bi]
    return perm


def nodewise_rearrange(
    pi: Rearrangement,
    instances_per_node: int,
    *,
    method: str = "auto",
    within_node: bool = True,
) -> Rearrangement:
    """Paper Algorithm 3 + beyond-paper within-node assignment.

    Permutes ``pi``'s destination batch indices so inter-node traffic is
    minimized; objective-invariant for the balancing problem.
    """
    c = instances_per_node
    d = pi.d
    if d % c != 0:
        raise ValueError(f"d={d} not divisible by instances_per_node={c}")
    if c == d:
        return pi  # single node: nothing to do
    V = node_cost_matrix(pi)
    batch_to_node: np.ndarray | None = None
    if method in ("auto", "ilp") and d * (d // c) <= 4096:
        batch_to_node = solve_ilp(V, c)
    if batch_to_node is None:
        if method == "ilp":
            raise RuntimeError("ILP solve failed")
        batch_to_node = solve_greedy(V, c)
    if within_node:
        perm = assign_within_node(V, batch_to_node, c)
    else:
        perm = np.empty(d, dtype=np.int64)
        slots = {g: list(range(g * c, (g + 1) * c)) for g in range(d // c)}
        for j in range(d):
            perm[j] = slots[int(batch_to_node[j])].pop()
    return pi.permute_destinations(perm)
