"""Batch Post-Balancing Dispatcher (paper S5).

The dispatcher is the per-phase unit that
  1. collects sequence *lengths* from every DP instance (in torch this is
     an All-Gather of scalars; under JAX's global-program model the host
     pipeline already sees all lengths -- we keep the accounting so the
     benchmarks can price the strawman vs. the paper's communicator),
  2. runs the Post-Balancing algorithm selected by the balance policy,
  3. optionally applies the Node-wise Rearrangement Algorithm,
  4. emits a :class:`DispatchPlan` -- everything the device-side
     communicator needs to perform the payload all-to-all with STATIC
     shapes (per-shard token capacity), plus bookkeeping for
     EXPERIMENTS.md-style accounting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.balancing import post_balance
from repro.core.cost_model import CostModel
from repro.core.nodewise import nodewise_rearrange
from repro.core.rearrangement import Rearrangement, identity_rearrangement

__all__ = ["DispatchPlan", "BatchPostBalancingDispatcher"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass
class DispatchPlan:
    """Host-side plan for one phase of one iteration.

    The device-side communicator consumes the token-level arrays; the
    orchestrator consumes ``pi`` for composition.
    """

    pi: Rearrangement
    d: int
    # Static per-shard token capacity for this phase (multiple of `pad_to`).
    token_capacity: int
    # Per destination shard: ordered example lengths (ragged).
    dest_lengths: list[np.ndarray]
    # Accounting:
    costs: np.ndarray  # f(S'_i) per destination shard
    utilization: float  # mean/max of costs
    solve_ms: float  # dispatcher computation time (paper Table 2 analog)

    @property
    def max_cost(self) -> float:
        return float(self.costs.max()) if self.costs.size else 0.0


class BatchPostBalancingDispatcher:
    """One dispatcher per phase (paper Fig. 4).

    Args:
      d: number of DP instances (= size of pod*data mesh axes).
      cost_model: the phase's f.
      algorithm: override the balance policy (see core.balancing).
      instances_per_node: node size c for Node-wise Rearrangement; ``None``
        disables the node-wise step (e.g. single-node microbenchmarks).
      pad_to: round per-shard token capacity up to this multiple
        (TPU lane alignment; 128 aligns the MXU).
      balance: False -> identity plan (the paper's 'OrchMLLM w/o balance'
        baseline).
    """

    def __init__(
        self,
        d: int,
        cost_model: CostModel,
        *,
        algorithm: str | None = None,
        instances_per_node: int | None = None,
        nodewise_method: str = "auto",
        within_node: bool = True,
        pad_to: int = 128,
        balance: bool = True,
    ) -> None:
        self.d = d
        self.cost_model = cost_model
        self.algorithm = algorithm
        self.instances_per_node = instances_per_node
        self.nodewise_method = nodewise_method
        self.within_node = within_node
        self.pad_to = pad_to
        self.balance = balance

    def plan(self, lengths_per_instance: Sequence[np.ndarray]) -> DispatchPlan:
        t0 = time.perf_counter()
        if self.balance:
            pi = post_balance(
                lengths_per_instance, self.d, self.cost_model, algorithm=self.algorithm
            )
            if self.instances_per_node and self.instances_per_node < self.d:
                pi = nodewise_rearrange(
                    pi,
                    self.instances_per_node,
                    method=self.nodewise_method,
                    within_node=self.within_node,
                )
        else:
            pi = identity_rearrangement(lengths_per_instance, self.d)
        solve_ms = (time.perf_counter() - t0) * 1e3

        dest_lengths = pi.dest_lengths()
        if self.cost_model.padding or self.cost_model.conv_attention:
            per_shard_tokens = [
                int(l.size * l.max()) if l.size else 0 for l in dest_lengths
            ]
        else:
            per_shard_tokens = [int(l.sum()) for l in dest_lengths]
        cap = _round_up(max(per_shard_tokens, default=0) or self.pad_to, self.pad_to)
        costs = np.array([self.cost_model.cost(l) for l in dest_lengths])
        maxc = costs.max() if costs.size else 0.0
        util = float(costs.mean() / maxc) if maxc > 0 else 1.0
        return DispatchPlan(
            pi=pi,
            d=self.d,
            token_capacity=cap,
            dest_lengths=dest_lengths,
            costs=costs,
            utilization=util,
            solve_ms=solve_ms,
        )
