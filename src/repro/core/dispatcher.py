"""Batch Post-Balancing Dispatcher (paper S5).

The dispatcher is the per-phase unit that
  1. collects sequence *lengths* from every DP instance (in torch this is
     an All-Gather of scalars; under JAX's global-program model the host
     pipeline already sees all lengths -- we keep the accounting so the
     benchmarks can price the strawman vs. the paper's communicator),
  2. runs the Post-Balancing algorithm selected by the balance policy,
  3. optionally applies the Node-wise Rearrangement Algorithm,
  4. emits a :class:`DispatchPlan` -- everything the device-side
     communicator needs to perform the payload all-to-all with STATIC
     shapes (per-shard token capacity), plus bookkeeping for
     EXPERIMENTS.md-style accounting.

Plan-ahead mode (paper S6, 'computation overhead overlapping'): the
dispatcher computation needs only lengths, which are known as soon as
mini-batches are sampled -- so :meth:`submit` hands the solve to a
background worker (bounded queue, one worker per dispatcher, mirroring
the paper's one-dispatcher-per-modality concurrency) and returns a
:class:`PlanTicket`; the caller collects ``ticket.result()`` a step
later, after the forward pass has hidden the host time.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Sequence

import numpy as np

from repro.core.balancing import post_balance
from repro.core.cost_model import CostModel, _segment_max
from repro.core.nodewise import nodewise_rearrange
from repro.core.rearrangement import Rearrangement, identity_rearrangement
from repro.utils import round_up as _round_up

__all__ = ["DispatchPlan", "PlanTicket", "BatchPostBalancingDispatcher"]


@dataclasses.dataclass
class DispatchPlan:
    """Host-side plan for one phase of one iteration.

    The device-side communicator consumes the token-level arrays; the
    orchestrator consumes ``pi`` for composition.
    """

    pi: Rearrangement
    d: int
    # Static per-shard token capacity for this phase (multiple of `pad_to`).
    token_capacity: int
    # Per destination shard: ordered example lengths (ragged).
    dest_lengths: list[np.ndarray]
    # Accounting:
    costs: np.ndarray  # f(S'_i) per destination shard
    utilization: float  # mean/max of costs
    solve_ms: float  # dispatcher computation time (paper Table 2 analog)
    # Per-shard feature vectors [L, L^2/b, sum l^2, b*max^2], shape
    # (d, 4): the telemetry calibrator pairs these with measured phase
    # times (costs == cost_model.cost_from_features(features)).
    features: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 4)))
    # Pipeline mode: per-(stage, shard) cost matrix, shape (pp, d) --
    # stage cost = stage_fraction (calibrated per-layer cost x
    # layers-on-stage, normalized) x the shard's f(S).  Empty when the
    # dispatcher has no stage_fractions attached (pp = 1).
    stage_costs: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 0)))

    @property
    def max_cost(self) -> float:
        return float(self.costs.max()) if self.costs.size else 0.0


class PlanTicket:
    """Handle for a plan computed on the dispatcher's worker thread."""

    def __init__(self) -> None:
        self._done = threading.Event()
        self._plan: DispatchPlan | None = None
        self._error: BaseException | None = None

    def _set(self, plan: DispatchPlan | None, error: BaseException | None) -> None:
        self._plan = plan
        self._error = error
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> DispatchPlan:
        if not self._done.wait(timeout):
            raise TimeoutError("dispatcher plan not ready")
        if self._error is not None:
            raise self._error
        assert self._plan is not None
        return self._plan


class BatchPostBalancingDispatcher:
    """One dispatcher per phase (paper Fig. 4).

    Args:
      d: number of DP instances (= size of pod*data mesh axes).
      cost_model: the phase's f.
      algorithm: override the balance policy (see core.balancing).
      instances_per_node: node size c for Node-wise Rearrangement; ``None``
        disables the node-wise step (e.g. single-node microbenchmarks).
      pad_to: round per-shard token capacity up to this multiple
        (TPU lane alignment; 128 aligns the MXU).
      balance: False -> identity plan (the paper's 'OrchMLLM w/o balance'
        baseline).
      backend: "vectorized" (default) or "python" post-balancing engine.
      queue_depth: bound on in-flight plan-ahead submissions.
      stage_fractions: pipeline mode -- per-stage share of this phase's
        cost (layers-on-stage x per-layer cost, normalized to sum 1);
        plans then carry a (pp, d) ``stage_costs`` matrix so the
        orchestrator's microbatch scheduler balances per-STAGE loads.
    """

    def __init__(
        self,
        d: int,
        cost_model: CostModel,
        *,
        algorithm: str | None = None,
        instances_per_node: int | None = None,
        nodewise_method: str = "auto",
        within_node: bool = True,
        pad_to: int = 128,
        balance: bool = True,
        backend: str = "vectorized",
        queue_depth: int = 2,
        stage_fractions: Sequence[float] | np.ndarray | None = None,
    ) -> None:
        self.d = d
        self.cost_model = cost_model
        self.stage_fractions = (None if stage_fractions is None
                                else np.asarray(stage_fractions, np.float64))
        self.algorithm = algorithm
        self.instances_per_node = instances_per_node
        self.nodewise_method = nodewise_method
        self.within_node = within_node
        self.pad_to = pad_to
        self.balance = balance
        self.backend = backend
        self.queue_depth = queue_depth
        self._work: queue.Queue | None = None
        self._worker: threading.Thread | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def plan(self, lengths_per_instance: Sequence[np.ndarray]) -> DispatchPlan:
        t0 = time.perf_counter()
        if self.balance:
            pi = post_balance(
                lengths_per_instance, self.d, self.cost_model,
                algorithm=self.algorithm, backend=self.backend,
            )
            if self.instances_per_node and self.instances_per_node < self.d:
                pi = nodewise_rearrange(
                    pi,
                    self.instances_per_node,
                    method=self.nodewise_method,
                    within_node=self.within_node,
                )
        else:
            pi = identity_rearrangement(lengths_per_instance, self.d)

        # Batched accounting: per-shard sums/counts/maxima in O(n) numpy
        # instead of a python loop over d ragged arrays.  Features are
        # kept on the plan so telemetry can regress measured phase times
        # onto them.
        lens = np.asarray(pi.lengths, dtype=np.float64)
        ids = pi.dst_inst
        features = self.cost_model.segment_features(lens, ids, self.d)
        costs = self.cost_model.cost_from_features(features)
        if self.cost_model.padding or self.cost_model.conv_attention:
            cnt = np.bincount(ids, minlength=self.d)
            bmax = _segment_max(lens, ids, self.d)
            per_shard_max = int((cnt * bmax).max()) if cnt.size else 0
        else:
            bsum = np.bincount(ids, weights=lens, minlength=self.d)
            per_shard_max = int(bsum.max()) if bsum.size else 0
        cap = _round_up(per_shard_max or self.pad_to, self.pad_to)
        maxc = costs.max() if costs.size else 0.0
        util = float(costs.mean() / maxc) if maxc > 0 else 1.0
        solve_ms = (time.perf_counter() - t0) * 1e3
        stage_costs = (np.outer(self.stage_fractions, costs)
                       if self.stage_fractions is not None
                       else np.zeros((0, 0)))
        return DispatchPlan(
            pi=pi,
            d=self.d,
            token_capacity=cap,
            dest_lengths=pi.dest_lengths(),
            costs=costs,
            utilization=util,
            solve_ms=solve_ms,
            features=features,
            stage_costs=stage_costs,
        )

    # -- plan-ahead mode ------------------------------------------------
    def _drain(self, work: queue.Queue) -> None:
        while True:
            item = work.get()
            if item is None:
                return
            lengths, ticket = item
            try:
                ticket._set(self.plan(lengths), None)
            except BaseException as e:  # propagate to result()
                ticket._set(None, e)

    def submit(self, lengths_per_instance: Sequence[np.ndarray]) -> PlanTicket:
        """Enqueue a plan computation on the background worker.

        Blocks only when ``queue_depth`` submissions are already in
        flight (bounded queue = backpressure, same discipline as the
        prefetching loader).
        """
        ticket = PlanTicket()
        # Enqueue under the lock so close()'s shutdown sentinel is always
        # the queue's last item -- a ticket can never land behind it and
        # hang.  The worker drains without the lock, so a blocking put
        # here (queue full) still makes progress.
        with self._lock:
            if self._worker is None or not self._worker.is_alive():
                self._work = queue.Queue(maxsize=self.queue_depth)
                self._worker = threading.Thread(
                    target=self._drain, args=(self._work,),
                    name="dispatcher-plan", daemon=True,
                )
                self._worker.start()
            self._work.put((list(lengths_per_instance), ticket))
        return ticket

    def close(self) -> None:
        """Stop the plan-ahead worker (idempotent)."""
        with self._lock:
            work, self._work, self._worker = self._work, None, None
            if work is not None:
                work.put(None)
