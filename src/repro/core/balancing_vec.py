"""Vectorized NumPy engine for the Batch Post-Balancing algorithms.

Array-at-once reformulations of the four algorithms in
:mod:`repro.core.balancing`, exactly equivalent to the per-item heapq
reference path (``backend="python"``) but ~1-2 orders of magnitude
faster at production sizes (n ~ 10^4 items, d ~ 10^2-10^3 instances).

The core engine is :func:`lpt_assign`: LPT greedy ("pop the batch with
the smallest running load") executed in *chunks*.  Per chunk we sort the
d running loads once, speculate that the next c descending items land on
the c smallest loads in order, and accept the longest prefix for which
the speculation provably matches the heap execution:

    item j may take the j-th smallest load  iff  loads_sorted[j] is
    STRICTLY below every load updated earlier in the chunk,

i.e. ``loads_sorted[j] < min_{k<j}(loads_sorted[k] + w_k)``.  Under that
condition the heap's (load, idx) minimum at step j is exactly the j-th
smallest pre-chunk load (stable argsort = the heap's index tie-break),
so the assignment is identical item by item -- not just in objective.
Ties (equality) are rejected and re-resolved next iteration, where the
first speculation step is the literal argmin and always exact.  Both the
early regime (flat loads) and the late regime (load spread below the
item scale) accept full chunks, so the per-item python overhead
amortizes away; the degenerate staircase case falls back to correct
per-item behavior.

Algorithm 2's first-fit packer needs no per-item work at all: with
ascending lengths the incoming item is the running max, so item j fits a
batch starting at s iff ``s >= m[j] = j + 1 - bound // l[j]``, and m is
monotone -- each bound probe builds a jump table ``jump[s] = first j
with m[j] > s`` from one bincount/cumsum and hops batch to batch.
Algorithm 4's bounded descending packer jumps whole batches at a time
(the first, largest item fixes the batch's max, hence its capacity
``bound // max``).

Destination slots are tracked *during* assignment (each batch's items
arrive in processed order), so no final per-item sort is needed; the
:class:`~repro.core.rearrangement.Rearrangement` is assembled from flat
gathers only.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.rearrangement import Rearrangement

__all__ = [
    "items_to_arrays",
    "arrays_from_instance_lengths",
    "lpt_assign",
    "nopad_vec",
    "pad_vec",
    "quad_vec",
    "conv_vec",
]


def items_to_arrays(
    items: Sequence[tuple[int, int, int]]
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(src_inst, src_slot, length) tuples -> three int64 arrays."""
    if not len(items):
        z = np.zeros(0, np.int64)
        return z, z.copy(), z.copy()
    arr = np.asarray(items, dtype=np.int64)
    return arr[:, 0], arr[:, 1], arr[:, 2]


def arrays_from_instance_lengths(
    lengths_per_instance: Sequence[np.ndarray],
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized :func:`repro.core.balancing.flatten_instance_lengths`."""
    lens = [np.asarray(x, dtype=np.int64).ravel() for x in lengths_per_instance]
    if not lens:
        z = np.zeros(0, np.int64)
        return z, z.copy(), z.copy()
    counts = np.array([x.size for x in lens], dtype=np.int64)
    n = int(counts.sum())
    inst = np.repeat(np.arange(len(lens), dtype=np.int64), counts)
    starts = np.cumsum(counts) - counts
    slot = np.arange(n, dtype=np.int64) - np.repeat(starts, counts)
    length = np.concatenate(lens) if n else np.zeros(0, np.int64)
    return inst, slot, length


def _build(
    inst: np.ndarray,
    slot: np.ndarray,
    length: np.ndarray,
    dst_inst: np.ndarray,
    dst_slot: np.ndarray,
    d: int,
) -> Rearrangement:
    """Assemble a Rearrangement from flat per-item arrays (any order)."""
    return Rearrangement(
        d=d,
        orig_inst=inst,
        orig_slot=slot,
        src_inst=inst.copy(),
        src_slot=slot.copy(),
        dst_inst=dst_inst.astype(np.int64, copy=False),
        dst_slot=dst_slot.astype(np.int64, copy=False),
        lengths=length,
    )


def _slots_for_blocks(sizes: np.ndarray) -> np.ndarray:
    """dst_slot for items laid out as consecutive blocks of `sizes`."""
    n = int(sizes.sum())
    starts = np.cumsum(sizes) - sizes
    return np.arange(n, dtype=np.int64) - np.repeat(starts, sizes)


# ----------------------------------------------------------------------
# Chunked-exact LPT engine (Alg 1, Alg 3 effective weights, Alg 4 tail).
# ----------------------------------------------------------------------
def lpt_assign(
    weights_desc: np.ndarray,
    d: int,
    init_loads: np.ndarray | None = None,
    init_counts: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exact LPT greedy over pre-sorted descending weights.

    Equivalent to: heapify d (load, idx) pairs, pop-min / push per item.
    Returns (assign, slots, final_loads) where slots[k] is item k's
    append position within its batch (continuing from ``init_counts``).
    Float weights accumulate in the same per-batch order as the heap
    path, so loads are bit-identical to the reference.
    """
    n = weights_desc.size
    assign = np.empty(n, dtype=np.int64)
    slots = np.empty(n, dtype=np.int64)
    loads = (np.zeros(d, dtype=np.float64) if init_loads is None
             else np.asarray(init_loads, dtype=np.float64).copy())
    counts = (np.zeros(d, dtype=np.int64) if init_counts is None
              else np.asarray(init_counts, dtype=np.int64).copy())
    i = 0
    while i < n:
        c = min(d, n - i)
        order = np.argsort(loads, kind="stable")
        if c < d:
            order = order[:c]
        ls = loads[order]
        new = ls + weights_desc[i : i + c]
        # Speculation j is exact iff ls[j] is strictly below every load
        # already updated in this chunk (prefix-min of `new`).
        ok = ls[1:] < np.minimum.accumulate(new)[:-1] if c > 1 else None
        if ok is None or ok.all():
            k = c
            sel = order
        else:
            k = int(np.argmin(ok)) + 1  # first False, offset for item 0
            sel = order[:k]
            new = new[:k]
        assign[i : i + k] = sel
        slots[i : i + k] = counts[sel]
        counts[sel] += 1
        loads[sel] = new
        i += k
    return assign, slots, loads


def _desc_order(length: np.ndarray) -> np.ndarray:
    """Stable descending sort = the reference `sorted(key=-len)`.

    numpy's kind="stable" is timsort for int64 (3-4x slower than
    introsort here), so when the values fit we pack (length, reversed
    index) into one int64 key and introsort that: ascending on the key
    then a reversal yields descending lengths with ties in original
    order.
    """
    n = length.size
    if n == 0:
        return np.zeros(0, np.int64)
    bits = int(n - 1).bit_length() if n > 1 else 1
    lmax = int(length.max())
    if lmax < (1 << (62 - bits)):
        key = (length << bits) | (n - 1 - np.arange(n, dtype=np.int64))
        return np.argsort(key)[::-1]
    return np.argsort(-length, kind="stable")


def _asc_order(length: np.ndarray) -> np.ndarray:
    """Stable ascending sort via the same packed-key trick."""
    n = length.size
    if n == 0:
        return np.zeros(0, np.int64)
    bits = int(n - 1).bit_length() if n > 1 else 1
    lmax = int(length.max())
    if lmax < (1 << (62 - bits)):
        key = (length << bits) | np.arange(n, dtype=np.int64)
        return np.argsort(key)
    return np.argsort(length, kind="stable")


# ----------------------------------------------------------------------
# Algorithm 1: LPT greedy without paddings.
# ----------------------------------------------------------------------
def nopad_vec(
    inst: np.ndarray, slot: np.ndarray, length: np.ndarray, d: int
) -> Rearrangement:
    order = _desc_order(length)
    desc = length[order]
    assign, slots, _ = lpt_assign(desc.astype(np.float64), d)
    return _build(inst[order], slot[order], desc, assign, slots, d)


# ----------------------------------------------------------------------
# Algorithm 2: binary search + first-fit with paddings.
# ----------------------------------------------------------------------
def _pad_jump_table(asc: np.ndarray, bound: int) -> np.ndarray:
    """jump[s] = index of the first item NOT fitting a batch started at
    item s (ascending first-fit under padded-batch-length `bound`).

    Item j fits a batch starting at s iff (j - s + 1) * asc[j] <= bound
    (ascending: the newcomer is the running max), i.e. s >= m[j] with
    m[j] = j + 1 - bound // asc[j].  m is monotone (capacity clamped to
    n keeps it so through zero-length items, which always fit), so
    jump[s] = #{j : m[j] <= s} falls out of one histogram + cumsum.
    """
    n = asc.size
    cap = np.full(n, n, dtype=np.int64)
    pos = asc > 0
    np.floor_divide(bound, asc, out=cap, where=pos)
    np.minimum(cap, n, out=cap)
    m = np.arange(1, n + 1, dtype=np.int64) - cap
    return np.cumsum(np.bincount(np.clip(m, 0, n), minlength=n + 1))


def _pad_batch_starts(asc: np.ndarray, bound: int, limit: int) -> list[int]:
    """First-fit batch start indices, stopping once more than `limit`
    batches are needed."""
    n = asc.size
    jump = _pad_jump_table(asc, bound)
    starts: list[int] = []
    s = 0
    while s < n:
        starts.append(s)
        if len(starts) > limit:
            break
        s = int(jump[s])
    return starts


def pad_vec(
    inst: np.ndarray, slot: np.ndarray, length: np.ndarray, d: int
) -> Rearrangement:
    order = _asc_order(length)  # ascending, stable
    asc = length[order]
    n = asc.size
    if n == 0:
        z = np.zeros(0, np.int64)
        return _build(inst, slot, length, z, z.copy(), d)
    # Bracket: a batch must fit the longest item alone; conversely every
    # feasible bound covers the per-batch token total, so >= ceil(sum/d).
    lo = max(int(asc[-1]), -(-int(asc.sum()) // d))
    hi = int(asc[-1]) * (n // d + 1)
    while lo < hi:
        mid = (lo + hi) // 2
        if len(_pad_batch_starts(asc, mid, d)) <= d:
            hi = mid
        else:
            lo = mid + 1
    starts = np.asarray(_pad_batch_starts(asc, lo, d), dtype=np.int64)
    sizes = np.diff(np.append(starts, n))
    assign = np.repeat(np.arange(starts.size, dtype=np.int64), sizes)
    return _build(inst[order], slot[order], asc, assign, _slots_for_blocks(sizes), d)


# ----------------------------------------------------------------------
# Algorithm 3: quadratic objective, LPT on effective weights.
# ----------------------------------------------------------------------
def quad_vec(
    inst: np.ndarray, slot: np.ndarray, length: np.ndarray, d: int,
    *, lam: float = 0.0,
) -> Rearrangement:
    order = _desc_order(length)
    desc = length[order]
    lens = desc.astype(np.float64)
    weights = lens + lam * (lens * lens)  # parenthesized: bit-matches the
    # reference path's `l + lam * float(l) ** 2` accumulation
    assign, slots, _ = lpt_assign(weights, d)
    return _build(inst[order], slot[order], desc, assign, slots, d)


# ----------------------------------------------------------------------
# Algorithm 4: ConvTransformer objective.
# ----------------------------------------------------------------------
def conv_vec(
    inst: np.ndarray, slot: np.ndarray, length: np.ndarray, d: int
) -> Rearrangement:
    order = _desc_order(length)
    desc = length[order]
    n = desc.size
    if n == 0:
        z = np.zeros(0, np.int64)
        return _build(inst, slot, length, z, z.copy(), d)

    # Bound = Alg 1's objective value (max batch token sum).
    _, _, loads1 = lpt_assign(desc.astype(np.float64), d)
    bound = int(loads1.max())

    # Phase 1: pack descending under the bound; the batch's first (and
    # largest) item fixes its padded row, so the batch holds exactly
    # max(1, bound // max) items -- whole batches jump at a time.
    sizes: list[int] = []
    s = 0
    while s < n and len(sizes) < d:
        m = int(desc[s])
        size = n - s if m == 0 else min(max(1, bound // m), n - s)
        sizes.append(size)
        s += size
    consumed = s
    sizes_arr = np.asarray(sizes, dtype=np.int64)
    assign = np.empty(n, dtype=np.int64)
    slots = np.empty(n, dtype=np.int64)
    assign[:consumed] = np.repeat(np.arange(sizes_arr.size, dtype=np.int64), sizes_arr)
    slots[:consumed] = _slots_for_blocks(sizes_arr)

    # Phase 2: LPT remainder on running token sums.
    if consumed < n:
        init_loads = np.bincount(
            assign[:consumed], weights=desc[:consumed].astype(np.float64),
            minlength=d,
        )
        init_counts = np.bincount(assign[:consumed], minlength=d)
        tail, tail_slots, _ = lpt_assign(
            desc[consumed:].astype(np.float64), d,
            init_loads=init_loads, init_counts=init_counts,
        )
        assign[consumed:] = tail
        slots[consumed:] = tail_slots
    return _build(inst[order], slot[order], desc, assign, slots, d)
