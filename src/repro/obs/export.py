"""Exporters: OpenMetrics textfile + JSONL flight recorder + alerts.

Both exporters are crash-oriented:

  * :func:`write_openmetrics` renders the whole registry to a
    Prometheus/OpenMetrics text exposition and installs it with
    ``tmp + os.replace`` -- a scraper (or a human) never sees a torn
    file, and a crashed run keeps its last complete snapshot.
  * :class:`FlightRecorder` appends structured JSONL events.  Events
    buffer in memory; ``flush()`` is a single ``write`` of the joined
    lines followed by ``fsync``, so after SIGKILL the file is valid
    JSONL up to the last flush (at worst one torn trailing line, which
    :func:`read_flight_record` tolerates).

:class:`AlertBridge` is the thin routing layer that turns the repo's
existing health signals -- CUSUM drift flags from
``telemetry/adaptive.py``, checkpoint corruption fallbacks, engine
preemption storms, ``moe_dropped_frac`` spikes and stale-plan replans
from the ledger -- into flight-recorder ``alert`` events plus an
``alerts_total{kind=...}`` counter.
"""
from __future__ import annotations

import json
import os
import time
from typing import IO, Mapping

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                get_registry)

__all__ = [
    "AlertBridge",
    "FlightRecorder",
    "read_flight_record",
    "render_openmetrics",
    "write_openmetrics",
]


# ----------------------------------------------------------------------
# OpenMetrics text exposition.
# ----------------------------------------------------------------------
def _fmt_value(v: float) -> str:
    if v != v:  # NaN
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(labels: Mapping[str, str], extra: Mapping[str, str] = ()) -> str:
    items = list(labels.items()) + list(dict(extra).items())
    if not items:
        return ""
    body = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", "\\\\").replace('"', '\\"')
                     .replace("\n", "\\n"))
        for k, v in items)
    return "{" + body + "}"


def render_openmetrics(registry: MetricsRegistry | None = None) -> str:
    """Render every family in the registry as Prometheus text format.

    Counters get a ``_total`` suffix; histograms expand into cumulative
    ``_bucket{le=...}`` series plus ``_sum``/``_count`` and sketch-backed
    ``_p50/_p95/_p99`` gauges (percentiles are not part of the exposition
    format proper, but are the whole point of carrying the sketch).
    """
    registry = registry if registry is not None else get_registry()
    lines: list[str] = []
    for fam in registry.families():
        if fam.kind == "counter":
            name, ptype = fam.name + "_total", "counter"
        else:
            name, ptype = fam.name, fam.kind
        lines.append(f"# HELP {name} {fam.help}")
        lines.append(f"# TYPE {name} {ptype}")
        for labels, child in fam.children():
            if isinstance(child, (Counter, Gauge)):
                lines.append(f"{name}{_fmt_labels(labels)} "
                             f"{_fmt_value(child.value)}")
            elif isinstance(child, Histogram):
                for le, cum in child.bucket_counts():
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(labels, {'le': _fmt_value(le)})} {cum}")
                lines.append(f"{name}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(child.sum)}")
                lines.append(f"{name}_count{_fmt_labels(labels)} {child.count}")
                if child.count:
                    for q, suffix in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
                        lines.append(
                            f"{name}_{suffix}{_fmt_labels(labels)} "
                            f"{_fmt_value(child.quantile(q))}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def write_openmetrics(path: str, registry: MetricsRegistry | None = None) -> str:
    """Atomically install the rendered exposition at ``path``."""
    text = render_openmetrics(registry)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


# ----------------------------------------------------------------------
# JSONL flight recorder.
# ----------------------------------------------------------------------
class FlightRecorder:
    """Append-only JSONL event log with atomic-ish buffered flushes.

    The first line is always a ``meta`` event carrying run metadata, so
    a flight record is self-describing even when found orphaned on disk.
    """

    def __init__(self, path: str, *, meta: Mapping | None = None,
                 flush_every: int = 64) -> None:
        self.path = path
        self.flush_every = max(1, int(flush_every))
        self._buf: list[str] = []
        self._f: IO[str] = open(path, "a")
        self.events_written = 0
        self.record("meta", **dict(meta or {}))
        self.flush()

    def record(self, kind: str, **fields) -> dict:
        event = {"kind": kind, "ts": time.time(), **fields}
        self._buf.append(json.dumps(event, default=str))
        if len(self._buf) >= self.flush_every:
            self.flush()
        return event

    def flush(self) -> None:
        """One write + fsync: readers see whole lines or nothing new."""
        if not self._buf:
            return
        blob = "\n".join(self._buf) + "\n"
        self._buf.clear()
        self._f.write(blob)
        self._f.flush()
        os.fsync(self._f.fileno())
        self.events_written += blob.count("\n")

    def close(self) -> None:
        self.flush()
        self._f.close()

    def __enter__(self) -> "FlightRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_flight_record(path: str) -> list[dict]:
    """Parse a flight record, tolerating one torn trailing line.

    A line that fails to parse is only acceptable at the very end of the
    file (a crash mid-write of the final buffer); anywhere else it is
    real corruption and raises.
    """
    events: list[dict] = []
    with open(path) as f:
        raw = f.read()
    lines = raw.split("\n")
    # Trailing "" after a final newline is normal.
    if lines and lines[-1] == "":
        lines.pop()
    for i, line in enumerate(lines):
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                break  # torn tail from a crash mid-write: drop it
            raise ValueError(f"{path}: corrupt flight record at line {i + 1}")
    return events


# ----------------------------------------------------------------------
# Alert routing.
# ----------------------------------------------------------------------
class AlertBridge:
    """Route existing health signals into flight-recorder alerts.

    Detection stays where it already lives (CUSUM in
    ``telemetry/adaptive.py``, fallback logic in ``checkpoint/``, the
    ledger's spike checks); this class only normalizes the events and
    counts them per kind.
    """

    PREEMPTION_STORM = 3  # preemptions within one window => storm

    def __init__(self, recorder: FlightRecorder | None,
                 registry: MetricsRegistry | None = None) -> None:
        self.recorder = recorder
        registry = registry if registry is not None else get_registry()
        self._c_alerts = registry.counter(
            "alerts", "structured alert events routed to the flight recorder",
            labels=("alert",))
        self.alerts: list[dict] = []

    def emit(self, alert: str, **fields) -> dict:
        self._c_alerts.inc(alert=alert)
        event = {"alert": alert, **fields}
        self.alerts.append(event)
        if self.recorder is not None:
            self.recorder.record("alert", **event)
        return event

    # -- adapters for the repo's existing signal shapes ----------------
    def on_drift(self, drift_flags: Mapping[str, bool], step: int) -> None:
        """CUSUM drift flags from ``AdaptiveOrchestration.observe``."""
        for phase, drifted in drift_flags.items():
            if drifted:
                self.emit("cost_model_drift", phase=phase, step=step)

    def on_checkpoint_fallback(self, corrupt_path: str, restored_step) -> None:
        self.emit("checkpoint_corruption_fallback", corrupt_path=corrupt_path,
                  restored_step=restored_step)

    def on_preemptions(self, n_preempted: int, step: int) -> None:
        if n_preempted >= self.PREEMPTION_STORM:
            self.emit("preemption_storm", n_preempted=n_preempted, step=step)

    def on_anomaly(self, anomaly) -> None:
        """Series anomaly from :class:`repro.obs.anomaly.AnomalyMonitor`
        -- recorded as ``anomaly_<kind>`` so the triage layer can split
        first-class anomalies from corroborating alerts."""
        self.emit(f"anomaly_{anomaly.kind}", series=anomaly.series,
                  step=anomaly.step, score=anomaly.score,
                  direction=anomaly.direction, value=anomaly.value,
                  baseline=anomaly.baseline)

    def on_ledger_events(self, events) -> None:
        """Alerts the :class:`StepLedger` detected (drop spikes, replans)."""
        for ev in events:
            ev = dict(ev)
            self.emit(ev.pop("alert"), **ev)
