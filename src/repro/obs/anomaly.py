"""Online robust anomaly detection over ledger / waterfall series.

The telemetry layer already runs a CUSUM drift detector over *cost
model coefficients* (``repro.telemetry.calibrate.DriftDetector``); this
module watches the *observability series themselves* -- MFU, goodput,
per-phase imbalance, every waterfall component -- and classifies
departures from baseline:

  * ``spike``       -- a single point far outside the robust band that
                       returns to baseline on the next point;
  * ``level_shift`` -- ``shift_run`` consecutive points outside the
                       band on the same side (the detector re-baselines
                       to the new level so a sustained shift alerts
                       exactly once);
  * ``trend``       -- a slow, same-signed drift of the fast EWMA away
                       from baseline sustained for ``trend_run`` steps
                       (catches ramps too gradual to trip the band).

Robustness: the baseline center is the warmup median and the scale is
the MAD (sigma-equivalent, floored), both EWMA-tracked afterwards with
Huberized updates -- out-of-band points never poison the baseline, so
a level shift is measured against the *pre-shift* regime.

:class:`AnomalyMonitor` fans a detector out per series, consumes
``(step, value)`` series incrementally (the :class:`StepLedger` and
:class:`GapWaterfall` layouts), and routes anomalies through
:class:`repro.obs.export.AlertBridge`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.obs.registry import MetricsRegistry, get_registry

__all__ = ["Anomaly", "SeriesDetector", "AnomalyMonitor"]


@dataclasses.dataclass
class Anomaly:
    """One detected departure from a series' baseline."""

    series: str
    step: int  # step the anomaly STARTED (first out-of-band point)
    kind: str  # "spike" | "level_shift" | "trend"
    value: float  # offending value (last point of the run)
    baseline: float  # robust center the deviation is measured against
    score: float  # robust z-score at detection time
    direction: int  # +1 above baseline, -1 below

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class SeriesDetector:
    """EWMA + MAD band detector for one scalar series."""

    def __init__(self, *, warmup: int = 8, z_spike: float = 6.0,
                 z_shift: float = 3.5, shift_run: int = 3,
                 trend_run: int = 7, trend_z: float = 1.5,
                 alpha: float = 0.05, fast_alpha: float = 0.3,
                 min_scale: float = 1e-4, rel_floor: float = 0.02) -> None:
        if warmup < 3:
            raise ValueError(f"warmup must be >= 3, got {warmup}")
        if z_spike < z_shift:
            raise ValueError("z_spike must be >= z_shift")
        self.warmup = warmup
        self.z_spike = z_spike
        self.z_shift = z_shift
        self.shift_run = shift_run
        self.trend_run = trend_run
        self.trend_z = trend_z
        self.alpha = alpha
        self.fast_alpha = fast_alpha
        self.min_scale = min_scale
        self.rel_floor = rel_floor
        self._warm: list[float] = []
        self.center: float | None = None
        self.scale: float | None = None
        self._fast: float | None = None
        # Out-of-band run state.
        self._run_len = 0
        self._run_sign = 0
        self._run_start = 0
        self._pending_spike: tuple[int, float, float, int] | None = None
        # Trend state: consecutive steps with a same-signed, material
        # fast-EWMA deviation whose magnitude is not shrinking.
        self._trend_len = 0
        self._trend_sign = 0
        self._trend_start = 0
        self._trend_prev_dev = 0.0

    # ------------------------------------------------------------------
    def _floor(self, center: float) -> float:
        return max(self.min_scale, self.rel_floor * abs(center))

    def _baseline(self, values: Sequence[float]) -> None:
        arr = np.asarray(values, dtype=np.float64)
        self.center = float(np.median(arr))
        mad = float(np.median(np.abs(arr - self.center)))
        self.scale = max(1.4826 * mad, self._floor(self.center))
        self._fast = self.center

    def _rebaseline(self, values: Sequence[float]) -> None:
        """Adopt a new regime after a level shift / trend fires, so a
        sustained change alerts once instead of every step."""
        center = float(np.mean(np.asarray(values, dtype=np.float64)))
        self.center = center
        self.scale = max(self.scale or 0.0, self._floor(center))
        self._fast = center
        self._run_len = 0
        self._run_sign = 0
        self._pending_spike = None
        self._trend_len = 0
        self._trend_sign = 0

    # ------------------------------------------------------------------
    def update(self, step: int, value: float, name: str = "") -> Anomaly | None:
        v = float(value)
        if self.center is None:
            self._warm.append(v)
            if len(self._warm) >= self.warmup:
                self._baseline(self._warm)
            return None

        z = (v - self.center) / self.scale
        sign = 1 if z >= 0 else -1
        out: Anomaly | None = None

        if abs(z) >= self.z_shift:
            if self._run_sign == sign:
                self._run_len += 1
            else:
                self._run_len = 1
                self._run_sign = sign
                self._run_start = step
                if abs(z) >= self.z_spike:
                    self._pending_spike = (step, v, z, sign)
                else:
                    self._pending_spike = None
            if self._run_len >= self.shift_run:
                out = Anomaly(series=name, step=self._run_start,
                              kind="level_shift", value=v,
                              baseline=self.center, score=float(abs(z)),
                              direction=sign)
                self._rebaseline([v])
            return out

        # Back in band: a one-point excursion that was spike-sized is a
        # spike; a shorter-than-shift_run run just dissolves.
        if self._pending_spike is not None and self._run_len == 1:
            s_step, s_val, s_z, s_sign = self._pending_spike
            out = Anomaly(series=name, step=s_step, kind="spike",
                          value=s_val, baseline=self.center,
                          score=float(abs(s_z)), direction=s_sign)
        self._pending_spike = None
        self._run_len = 0
        self._run_sign = 0

        # Trend: fast EWMA drifting away from the (slow) baseline.
        self._fast = ((1.0 - self.fast_alpha) * self._fast
                      + self.fast_alpha * v)
        dev = (self._fast - self.center) / self.scale
        dsign = 1 if dev >= 0 else -1
        if abs(dev) >= self.trend_z and (
                self._trend_sign != dsign
                or abs(dev) >= self._trend_prev_dev - 0.1):
            if self._trend_sign == dsign:
                self._trend_len += 1
            else:
                self._trend_len = 1
                self._trend_sign = dsign
                self._trend_start = step
            self._trend_prev_dev = abs(dev)
            if out is None and self._trend_len >= self.trend_run:
                out = Anomaly(series=name, step=self._trend_start,
                              kind="trend", value=v, baseline=self.center,
                              score=float(abs(dev)), direction=dsign)
                self._rebaseline([self._fast])
                return out
        else:
            self._trend_len = 0
            self._trend_sign = 0
            self._trend_prev_dev = 0.0

        # Huberized baseline update: clip the residual so outliers move
        # the center slowly; track scale as EWMA of |residual| * 1.253
        # (mean-abs-dev -> sigma), floored.
        resid = np.clip(v - self.center, -2.0 * self.scale, 2.0 * self.scale)
        self.center += self.alpha * float(resid)
        self.scale = max(
            (1.0 - self.alpha) * self.scale
            + self.alpha * 1.253 * abs(v - self.center),
            self._floor(self.center))
        return out


class AnomalyMonitor:
    """Per-series detectors over ``{name: [(step, value), ...]}`` maps.

    ``poll`` consumes series incrementally (tracks a cursor per name),
    so the caller can hand it the live ``StepLedger.series`` /
    ``GapWaterfall.series`` dicts every step.  Detected anomalies are
    counted in the registry (``anomalies_total{series,kind}``), routed
    through an optional :class:`AlertBridge`, and returned.
    """

    def __init__(self, *, alerts=None,
                 registry: MetricsRegistry | None = None,
                 include: Iterable[str] | None = None,
                 detector_kw: Mapping | None = None) -> None:
        self.alerts = alerts
        registry = registry if registry is not None else get_registry()
        self._c_anom = registry.counter(
            "anomalies", "anomalies detected on observability series",
            labels=("series", "kind"))
        self.include = tuple(include) if include is not None else None
        self.detector_kw = dict(detector_kw or {})
        self.detectors: dict[str, SeriesDetector] = {}
        self._cursor: dict[str, int] = {}
        self.anomalies: list[Anomaly] = []

    def _wanted(self, name: str) -> bool:
        if self.include is None:
            return True
        return any(name.startswith(p) for p in self.include)

    def update(self, step: int, values: Mapping[str, float]) -> list[Anomaly]:
        """Feed one step's {series: value} map directly."""
        out: list[Anomaly] = []
        for name, v in values.items():
            if not self._wanted(name):
                continue
            det = self.detectors.get(name)
            if det is None:
                det = self.detectors[name] = SeriesDetector(**self.detector_kw)
            a = det.update(step, v, name=name)
            if a is not None:
                out.append(a)
        self._emit(out)
        return out

    def poll(self, series: Mapping[str, Sequence[tuple[int, float]]],
             ) -> list[Anomaly]:
        """Consume any new points of every (step, value) series."""
        out: list[Anomaly] = []
        for name, points in series.items():
            if not self._wanted(name):
                continue
            start = self._cursor.get(name, 0)
            if start >= len(points):
                continue
            det = self.detectors.get(name)
            if det is None:
                det = self.detectors[name] = SeriesDetector(**self.detector_kw)
            for step, v in points[start:]:
                a = det.update(step, v, name=name)
                if a is not None:
                    out.append(a)
            self._cursor[name] = len(points)
        self._emit(out)
        return out

    def _emit(self, anomalies: list[Anomaly]) -> None:
        for a in anomalies:
            self.anomalies.append(a)
            self._c_anom.inc(series=a.series, kind=a.kind)
            if self.alerts is not None:
                self.alerts.on_anomaly(a)
