"""Unified observability plane: record, attribute, triage, aggregate.

Dependency-free (numpy + stdlib) metrics subsystem:

  * :mod:`repro.obs.registry` -- named Counters/Gauges/Histograms with
    ``(phase, shard, modality)``-style labels and a Greenwald-Khanna
    streaming quantile sketch behind every histogram.
  * :mod:`repro.obs.ledger` -- the canonical MFU / goodput / straggler /
    imbalance formulas and the per-step :class:`StepLedger`.
  * :mod:`repro.obs.decompose` -- the per-step MFU-gap waterfall:
    additive, closure-checked attribution of ``1 - goodput`` into
    per-(phase, modality) residual imbalance, exposed dispatcher
    latency, kernel dead tiles, MoE drops, preemption recompute and
    checkpoint stalls.
  * :mod:`repro.obs.anomaly` -- online robust detectors (EWMA + MAD
    bands; spike vs level-shift vs trend) over every recorded series.
  * :mod:`repro.obs.triage` -- flight-record correlator: waterfall
    history + anomalies + alerts -> a ranked root-cause report
    (``python -m repro.obs.triage <metrics-dir>``).
  * :mod:`repro.obs.aggregate` -- mergeable registries across DP
    shards / engine replicas (GK sketch merge with a tested post-merge
    rank-error bound), a strict OpenMetrics parser, and the live
    ``/metrics`` + ``/triage`` HTTP exporter.
  * :mod:`repro.obs.export` -- atomic OpenMetrics textfile, crash-safe
    JSONL flight recorder, and the alert bridge.
  * :mod:`repro.obs.timeline` -- one merged Perfetto timeline across
    orchestrator spans, engine step rows, checkpoint save/restore
    spans, and counter tracks.
"""
from repro.obs.aggregate import (MetricsServer, aggregate_registries,
                                 merge_sketches, parse_openmetrics,
                                 registry_from_state_dict,
                                 registry_state_dict, validate_openmetrics)
from repro.obs.anomaly import Anomaly, AnomalyMonitor, SeriesDetector
from repro.obs.decompose import GapWaterfall, WaterfallStep
from repro.obs.export import (AlertBridge, FlightRecorder, read_flight_record,
                              render_openmetrics, write_openmetrics)
from repro.obs.ledger import (StepLedger, goodput_fraction, hw_mfu,
                              phase_imbalance, projected_mfu, simulated_mfu,
                              straggler_overhead, useful_flops_ratio)
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                QuantileSketch, get_registry, set_registry)
from repro.obs.timeline import build_timeline, export_timeline
from repro.obs.triage import render_text, triage, triage_flight

__all__ = [
    "AlertBridge",
    "Anomaly",
    "AnomalyMonitor",
    "Counter",
    "FlightRecorder",
    "GapWaterfall",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsServer",
    "QuantileSketch",
    "SeriesDetector",
    "StepLedger",
    "WaterfallStep",
    "aggregate_registries",
    "build_timeline",
    "export_timeline",
    "get_registry",
    "goodput_fraction",
    "hw_mfu",
    "merge_sketches",
    "parse_openmetrics",
    "phase_imbalance",
    "projected_mfu",
    "read_flight_record",
    "registry_from_state_dict",
    "registry_state_dict",
    "render_openmetrics",
    "render_text",
    "set_registry",
    "simulated_mfu",
    "straggler_overhead",
    "triage",
    "triage_flight",
    "useful_flops_ratio",
    "validate_openmetrics",
    "write_openmetrics",
]
