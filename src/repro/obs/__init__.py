"""Unified observability plane: registry, ledger, exporters, timeline.

Dependency-free (numpy + stdlib) metrics subsystem:

  * :mod:`repro.obs.registry` -- named Counters/Gauges/Histograms with
    ``(phase, shard, modality)``-style labels and a Greenwald-Khanna
    streaming quantile sketch behind every histogram.
  * :mod:`repro.obs.ledger` -- the canonical MFU / goodput / straggler /
    imbalance formulas and the per-step :class:`StepLedger`.
  * :mod:`repro.obs.export` -- atomic OpenMetrics textfile, crash-safe
    JSONL flight recorder, and the alert bridge.
  * :mod:`repro.obs.timeline` -- one merged Perfetto timeline across
    orchestrator spans, engine step rows, and counter tracks.
"""
from repro.obs.export import (AlertBridge, FlightRecorder, read_flight_record,
                              render_openmetrics, write_openmetrics)
from repro.obs.ledger import (StepLedger, goodput_fraction, hw_mfu,
                              phase_imbalance, projected_mfu, simulated_mfu,
                              straggler_overhead, useful_flops_ratio)
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                QuantileSketch, get_registry, set_registry)
from repro.obs.timeline import build_timeline, export_timeline

__all__ = [
    "AlertBridge",
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "QuantileSketch",
    "StepLedger",
    "build_timeline",
    "export_timeline",
    "get_registry",
    "goodput_fraction",
    "hw_mfu",
    "phase_imbalance",
    "projected_mfu",
    "read_flight_record",
    "render_openmetrics",
    "set_registry",
    "simulated_mfu",
    "straggler_overhead",
    "useful_flops_ratio",
    "write_openmetrics",
]
