"""Per-step MFU / goodput / imbalance ledger -- the canonical formulas.

The paper's headline claim is an MFU number, so utilization must be a
first-class, always-on series rather than a per-benchmark proxy.  This
module is the ONE home of every utilization formula in the repo:

  * :func:`simulated_mfu` -- the paper's proxy: one iteration's mean
    useful time over straggler time, summed over synchronous phases
    (``sum_p mean(f_p) / sum_p max(f_p)``).  ``benchmarks/common.py``'s
    ``simulated_iteration_utilization`` is now a thin wrapper over this.
  * :func:`phase_imbalance` -- per-phase straggler ratio
    (``max/mean - 1``): the per-modality imbalance series that Modality
    Composition Incoherence shows up as.
  * :func:`hw_mfu` -- hardware MFU: model FLOPs over
    ``wall * peak * chips`` (what the paper reports as 41.6%).
  * :func:`useful_flops_ratio` -- MODEL_FLOPs / (HLO_FLOPs * chips):
    the compiled-efficiency term ``launch/roofline.py`` reports.
  * :func:`projected_mfu` -- roofline-projected MFU from the serial sum
    of the compute/memory/collective terms (``launch/perf.py``).

:class:`StepLedger` turns the orchestrator's :class:`OrchestratorReport`
(phase cost vectors, solve/exposed times) plus the train step's metrics
dict into labeled registry series -- gauges for the canonical ratios,
histograms for step/phase walls -- and keeps an in-memory
``(step, value)`` series per metric for the Perfetto counter tracks in
:mod:`repro.obs.timeline`.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from repro.obs.registry import MetricsRegistry, get_registry

__all__ = [
    "StepLedger",
    "goodput_fraction",
    "hw_mfu",
    "phase_imbalance",
    "projected_mfu",
    "simulated_mfu",
    "straggler_overhead",
    "useful_flops_ratio",
]


# ----------------------------------------------------------------------
# Canonical formulas (module functions so every consumer shares them).
# ----------------------------------------------------------------------
def simulated_mfu(phase_costs: Mapping[str, Sequence[float]]) -> float:
    """Paper's MFU proxy over one iteration's phase cost vectors.

    Each phase synchronizes across DP shards, so phase time = the
    straggler's cost; useful time is the mean.  Returns
    ``sum_p mean(c_p) / sum_p max(c_p)`` (1.0 when there is no work).
    """
    total_max = total_mean = 0.0
    for c in phase_costs.values():
        arr = np.asarray(c, dtype=np.float64)
        if arr.size == 0:
            continue
        total_max += float(arr.max())
        total_mean += float(arr.mean())
    return total_mean / total_max if total_max > 0 else 1.0


def straggler_overhead(phase_costs: Mapping[str, Sequence[float]]) -> float:
    """Fraction of the iteration spent waiting on stragglers."""
    return 1.0 - simulated_mfu(phase_costs)


def phase_imbalance(costs: Sequence[float]) -> float:
    """One phase's straggler ratio ``max/mean - 1`` (0 = balanced)."""
    arr = np.asarray(costs, dtype=np.float64)
    if arr.size == 0 or arr.mean() <= 0:
        return 0.0
    return float(arr.max() / arr.mean()) - 1.0


def hw_mfu(model_flops: float, wall_s: float, *, peak_flops: float,
           chips: int = 1) -> float:
    """Hardware MFU: useful model FLOPs / (wall * aggregate peak)."""
    denom = wall_s * peak_flops * max(chips, 1)
    return model_flops / denom if denom > 0 else 0.0


def useful_flops_ratio(model_flops_global: float, hlo_flops_per_chip: float,
                       chips: int) -> float:
    """MODEL_FLOPs / (HLO_FLOPs * chips): compiled-FLOP efficiency
    (rematerialization, padding and masking waste show up here)."""
    denom = hlo_flops_per_chip * max(chips, 1)
    return model_flops_global / denom if denom > 0 else 0.0


def projected_mfu(useful_ratio: float, compute_s: float, memory_s: float,
                  collective_s: float) -> float:
    """Roofline-projected MFU: compiled-FLOP efficiency discounted by
    the serial roofline sum (compute fraction of the projected step)."""
    total = compute_s + memory_s + collective_s
    return useful_ratio * compute_s / total if total > 0 else 0.0


def goodput_fraction(step_ms: float, exposed_ms: float, mfu: float) -> float:
    """Goodput = balanced-useful fraction of the measured step: the
    simulated MFU discounted by host latency the step actually waited
    on (exposed dispatcher solves, re-plans)."""
    if step_ms <= 0:
        return mfu
    return max(0.0, 1.0 - min(exposed_ms, step_ms) / step_ms) * mfu


# ----------------------------------------------------------------------
class StepLedger:
    """Per-step accounting: OrchestratorReport + metrics -> registry.

    One instance per training run.  ``record_step`` is the only hot-path
    call; everything it publishes is O(#phases) gauge/histogram updates.
    Alert *detection* lives here (drop spikes, replans); alert *routing*
    is the caller's job via the returned event list (the train loop
    forwards them to the flight recorder).
    """

    # moe_dropped_frac above this is an alert (drop-free dispatch should
    # keep it at exactly 0; the capacity-buffer legacy path stays low).
    MOE_DROP_ALERT = 0.05

    def __init__(self, cfg=None, *, d: int = 1,
                 registry: MetricsRegistry | None = None,
                 peak_flops: float | None = None, chips: int | None = None,
                 counter_track_prefixes: Sequence[str] = ("kernel_", "alerts_"),
                 ) -> None:
        self.cfg = cfg
        self.d = d
        self.registry = registry if registry is not None else get_registry()
        self.peak_flops = peak_flops
        self.chips = chips if chips is not None else d
        self.counter_track_prefixes = tuple(counter_track_prefixes)
        # FLOPs per token ~ 6 * active params (fwd + bwd); decode/prefill
        # callers can override per call.
        self._flops_per_token = None
        if cfg is not None:
            try:
                self._flops_per_token = 6.0 * float(cfg.active_param_count())
            except Exception:
                self._flops_per_token = 6.0 * float(cfg.param_count())
        r = self.registry
        self._g_mfu = r.gauge("train_mfu_simulated",
                              "paper MFU proxy: sum mean(f)/sum max(f)")
        self._g_goodput = r.gauge("train_goodput_frac",
                                  "simulated MFU minus exposed host latency")
        self._g_straggler = r.gauge("train_straggler_overhead_frac",
                                    "1 - simulated MFU")
        self._g_hw_mfu = r.gauge("train_mfu_hw",
                                 "model FLOPs / (wall * peak * chips)")
        self._g_imb = r.gauge("train_phase_imbalance",
                              "per-phase max/mean - 1", labels=("phase",))
        self._h_step = r.histogram("train_step_ms", "train step wall time",
                                   labels=())
        self._h_solve = r.histogram("orch_phase_solve_ms",
                                    "dispatcher solve time per phase",
                                    labels=("phase",))
        self._h_exposed = r.histogram("orch_exposed_ms",
                                      "host plan latency the step waited on")
        self._c_tokens = r.counter("train_tokens", "tokens trained on")
        self._c_steps = r.counter("train_steps", "train steps")
        self._c_replans = r.counter("orch_replans",
                                    "stale plan-ahead plans re-planned")
        self._g_metric = r.gauge("train_metric", "last train-step metrics",
                                 labels=("name",))
        # Pipeline mode (docs/pipeline.md): per-stage unfilled bubble
        # fraction + schedule-level fill/uplift gauges, published by
        # ``record_pipeline`` when the orchestrator runs with pp > 1.
        self._g_pipe_bubble = r.gauge(
            "pipeline_bubble_frac",
            "unfilled 1F1B bubble fraction of stage device time",
            labels=("stage",))
        self._g_pipe_fill = r.gauge(
            "pipeline_fill_fraction",
            "encoder compute placed / theoretical 1F1B bubble time")
        self._g_pipe_uplift = r.gauge(
            "pipeline_mfu_uplift",
            "projected MFU delta of bubble fill vs no-fill 1F1B")
        # (step, value) series for the timeline's counter tracks.
        self.series: dict[str, list[tuple[int, float]]] = {}
        self.steps_recorded = 0
        self._wall_ms_cum = 0.0
        self.step_ts_ms: dict[int, float] = {}

    # ------------------------------------------------------------------
    def _track(self, name: str, step: int, value: float) -> None:
        self.series.setdefault(name, []).append((step, float(value)))

    def record_step(self, step: int, *, report=None, step_ms: float | None = None,
                    metrics: Mapping[str, float] | None = None,
                    tokens: int | None = None) -> list[dict]:
        """Account one training step; returns alert events (possibly
        empty) for the caller to route to the flight recorder.

        ``report`` is an ``OrchestratorReport`` (phase costs, solve and
        exposed times); ``step_ms`` the measured device-complete wall
        time; ``metrics`` the train step's metrics dict (host scalars).
        """
        events: list[dict] = []
        self._c_steps.inc()
        self.steps_recorded += 1
        if step_ms is not None:
            self._h_step.observe(step_ms)
            self._wall_ms_cum += step_ms
        self.step_ts_ms[step] = self._wall_ms_cum

        mfu = None
        if report is not None:
            mfu = simulated_mfu(report.phase_costs)
            self._g_mfu.set(mfu)
            self._g_straggler.set(1.0 - mfu)
            self._track("mfu_simulated", step, mfu)
            for phase, costs in report.phase_costs.items():
                imb = phase_imbalance(costs)
                self._g_imb.set(imb, phase=phase)
                self._track(f"imbalance_{phase}", step, imb)
            for phase, ms in report.phase_solve_ms.items():
                self._h_solve.observe(ms, phase=phase)
            self._h_exposed.observe(report.exposed_ms)
            if step_ms:
                if report.exposed_ms > step_ms:
                    # goodput_fraction clamps exposed_ms to the step,
                    # but waiting longer on the plan than the whole
                    # step took means the two clocks disagree --
                    # surface it instead of only clamping silently.
                    events.append({"alert": "measurement_inconsistent",
                                   "step": step,
                                   "exposed_ms": float(report.exposed_ms),
                                   "step_ms": float(step_ms)})
                gp = goodput_fraction(step_ms, report.exposed_ms, mfu)
                self._g_goodput.set(gp)
                self._track("goodput_frac", step, gp)
            if report.replanned:
                self._c_replans.inc()
                events.append({"alert": "stale_plan_replanned", "step": step,
                               "coeff_version": report.coeff_version})

        if tokens is None and metrics is not None and "tokens" in metrics:
            tokens = int(metrics["tokens"])
        if tokens:
            self._c_tokens.inc(float(tokens))
            if (self._flops_per_token and step_ms and self.peak_flops):
                hm = hw_mfu(self._flops_per_token * tokens, step_ms * 1e-3,
                            peak_flops=self.peak_flops, chips=self.chips)
                self._g_hw_mfu.set(hm)
                self._track("mfu_hw", step, hm)

        if metrics is not None:
            for name, v in metrics.items():
                try:
                    fv = float(v)
                except (TypeError, ValueError):
                    continue
                self._g_metric.set(fv, name=name)
            drop = metrics.get("moe_dropped_frac")
            if drop is not None and float(drop) > self.MOE_DROP_ALERT:
                events.append({"alert": "moe_drop_spike", "step": step,
                               "moe_dropped_frac": float(drop),
                               "threshold": self.MOE_DROP_ALERT})

        # Counter tracks (kernel hit/skip counters, alert totals): poll
        # the registry so host-side kernel hooks show up on the step axis.
        for name, value in self.registry.snapshot_counters().items():
            if name.startswith(self.counter_track_prefixes):
                self._track(name, step, value)
        return events

    # ------------------------------------------------------------------
    def record_pipeline(self, step: int, plan) -> None:
        """Account one step's pipeline schedule (a ``PipelinePlan``).

        Publishes per-stage unfilled-bubble fractions (device-time
        share of each stage lane), the run's bubble-fill fraction and
        the projected MFU uplift, and keeps the per-stage series for
        the timeline / anomaly monitor."""
        if plan is None:
            return
        denom = float(plan.rank_total.max()) * plan.d
        stage_idle = plan.stage_idle.sum(axis=0)  # (pp,) over ranks
        for s in range(plan.pp):
            frac = stage_idle[s] / denom if denom > 0 else 0.0
            self._g_pipe_bubble.set(frac, stage=str(s))
            self._track(f"pipeline_bubble_s{s}", step, frac)
        self._g_pipe_fill.set(plan.fill_fraction)
        self._g_pipe_uplift.set(plan.mfu_uplift)
        self._track("pipeline_fill_fraction", step, plan.fill_fraction)
        self._track("pipeline_mfu_uplift", step, plan.mfu_uplift)

    # ------------------------------------------------------------------
    def record_kernel_stats(self, step: int, batch: Mapping[str, np.ndarray],
                            *, block_q: int | None = None,
                            block_kv: int | None = None) -> None:
        """Sample the flash tile-skip fraction from a host batch.

        Cheap interval math over seg/pos (the same accounting the kernel
        uses); call it every flush interval, not every step."""
        seg = pos = None
        for sk, pk in (("llm_seg", "llm_pos"), ("seg", "pos")):
            if sk in batch:
                seg, pos = np.asarray(batch[sk]), np.asarray(batch[pk])
                break
        if seg is None or self.cfg is None:
            return
        from repro.kernels.flash_attention import tile_skip_fraction
        bq = block_q or min(self.cfg.block_q, seg.shape[-1])
        bk = block_kv or min(self.cfg.block_kv, seg.shape[-1])
        if seg.shape[-1] % bq or seg.shape[-1] % bk:
            return
        frac = tile_skip_fraction(seg, seg, pos, pos, block_q=bq, block_kv=bk,
                                  causal=True, window=self.cfg.sliding_window)
        self._track("kernel_flash_skip_frac", step, frac)

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        """End-of-run canonical metrics (also what train.py prints)."""
        out = {
            "steps": self.steps_recorded,
            "tokens": self._c_tokens.labels().value,
            "step_ms_p50": self._h_step.labels().quantile(0.5),
            "step_ms_p95": self._h_step.labels().quantile(0.95),
            "step_ms_p99": self._h_step.labels().quantile(0.99),
            "mfu_simulated": self._g_mfu.labels().value,
            "goodput_frac": self._g_goodput.labels().value,
            "straggler_overhead_frac": self._g_straggler.labels().value,
        }
        if self.peak_flops:
            out["mfu_hw"] = self._g_hw_mfu.labels().value
        for labels, child in self._g_imb.children():
            out[f"imbalance_{labels['phase']}"] = child.value
        return out
