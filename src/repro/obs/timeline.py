"""One unified Perfetto timeline across orchestrator, engine, kernels.

PR 4's :meth:`TraceBuffer.to_chrome_trace` draws the orchestrator's
exec/plan spans; the serving engine separately keeps ``StepTiming``
rows; kernel hooks count autotune hits and tile skips.  Until now each
lived in its own export.  :func:`build_timeline` merges all three into
a single Chrome-trace / Perfetto JSON object (open in
``ui.perfetto.dev``):

  * orchestrator phase spans -- pid per phase, tid per DP shard (the
    existing TraceBuffer layout, reused verbatim);
  * engine step rows -- one pid per replica, schedule/prefill/decode as
    back-to-back "X" spans per step on tids 0/1/2;
  * counter tracks -- "C" events from the :class:`StepLedger`'s
    ``(step, value)`` series (MFU, goodput, per-phase imbalance, kernel
    hit/skip counters), placed on the step axis using the ledger's
    cumulative step wall clock so counters line up with the spans.
"""
from __future__ import annotations

import json
from typing import Iterable, Mapping, Sequence

__all__ = ["build_timeline", "export_timeline"]

# pid blocks so the five sources never collide.
_ENGINE_PID_BASE = 1000
_PIPELINE_PID = 7000
_CKPT_PID = 8000
_COUNTER_PID = 9000


def _pipeline_events(plan) -> list[dict]:
    """``PipelinePlan`` events (critical rank) -> one lane per stage.

    Times are abstract cost units; 1 cost unit renders as 1 us so the
    schedule SHAPE (warmup/steady/cooldown, encoder chunks in bubbles)
    is inspectable even before the waterfall's cost->ms calibration.
    """
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": _PIPELINE_PID,
         "args": {"name": f"pipeline:rank{plan.critical_rank}"}}]
    for s in range(plan.pp):
        events.append({"name": "thread_name", "ph": "M",
                       "pid": _PIPELINE_PID, "tid": s,
                       "args": {"name": f"stage{s} ({plan.partition[s]}L)"}})
    cat = {"F": "fwd", "B": "bwd", "encF": "enc_fill", "encB": "enc_fill"}
    for ev in plan.events:
        events.append({
            "name": f"{ev.kind}{ev.micro}", "cat": cat[ev.kind], "ph": "X",
            "pid": _PIPELINE_PID, "tid": ev.stage,
            "ts": ev.start, "dur": max(ev.end - ev.start, 0.0),
            "args": {"micro": ev.micro, "kind": ev.kind}})
    return events


def _engine_events(step_timings: Iterable, replica: int = 0) -> list[dict]:
    """StepTiming rows -> back-to-back spans, one tid per engine phase."""
    pid = _ENGINE_PID_BASE + replica
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": pid,
         "args": {"name": f"engine:replica{replica}"}}]
    for tid, name in enumerate(("schedule", "prefill", "decode")):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    cursor = 0.0
    for t in step_timings:
        parts = (("schedule", 0, t.schedule_ms,
                  {"step": t.step}),
                 ("prefill", 1, t.prefill_ms,
                  {"step": t.step, "n_seqs": t.n_prefill_seqs,
                   "tokens": t.prefill_tokens}),
                 ("decode", 2, t.decode_ms,
                  {"step": t.step, "n_seqs": t.n_decode_seqs}))
        ts = cursor
        for name, tid, dur_ms, args in parts:
            events.append({"name": name, "cat": "engine", "ph": "X",
                           "pid": pid, "tid": tid, "ts": ts * 1e3,
                           "dur": dur_ms * 1e3, "args": args})
            ts += dur_ms
        cursor = ts
    return events


def _checkpoint_events(ops: Iterable) -> list[dict]:
    """CheckpointManager op log -> save/restore spans on their own pid.

    Each op is a :class:`repro.checkpoint.CheckpointOp` (kind, step,
    start_s, wall_ms); spans land at real offsets relative to the first
    op, so a stalled step visibly overlaps its checkpoint save.
    """
    ops = list(ops)
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": _CKPT_PID,
         "args": {"name": "checkpoint"}},
        {"name": "thread_name", "ph": "M", "pid": _CKPT_PID, "tid": 0,
         "args": {"name": "save/restore"}}]
    if not ops:
        return events
    t0 = min(op.start_s for op in ops)
    for op in ops:
        events.append({
            "name": f"{op.kind}@step{op.step}", "cat": "checkpoint",
            "ph": "X", "pid": _CKPT_PID, "tid": 0,
            "ts": (op.start_s - t0) * 1e6,  # chrome trace wants us
            "dur": op.wall_ms * 1e3,
            "args": {"step": op.step, "kind": op.kind,
                     "wall_ms": op.wall_ms}})
    return events


def _counter_events(series: Mapping[str, Sequence[tuple[int, float]]],
                    step_ts_ms: Mapping[int, float] | None = None,
                    ) -> list[dict]:
    """Ledger ``(step, value)`` series -> Perfetto "C" counter tracks.

    When the ledger recorded a cumulative wall clock per step, counters
    land at real timestamps; otherwise the step index is the time axis
    (1 step = 1 ms), which still shows the *shape* of every series.
    """
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": _COUNTER_PID,
         "args": {"name": "counters"}}]
    for name, points in sorted(series.items()):
        for step, value in points:
            if step_ts_ms and step in step_ts_ms:
                ts = step_ts_ms[step]
            else:
                ts = float(step)
            events.append({"name": name, "ph": "C", "pid": _COUNTER_PID,
                           "ts": ts * 1e3, "args": {name: value}})
    return events


def build_timeline(*, trace_buffer=None, step_timings=None, ledger=None,
                   waterfall=None, checkpoint_ops=None, pipeline=None,
                   series: Mapping[str, Sequence[tuple[int, float]]] | None = None,
                   ) -> dict:
    """Merge every available source into one Chrome-trace JSON object.

    All arguments are optional, so each subsystem can be absent (a
    train-only run has no engine rows; a serving-only run has no
    orchestrator spans; checkpoint ops only exist when a
    ``CheckpointManager`` ran).  ``waterfall`` is a
    :class:`repro.obs.decompose.GapWaterfall` whose per-component
    series join the counter tracks; ``pipeline`` a
    :class:`repro.core.pipeline.PipelinePlan` whose critical-rank 1F1B
    schedule renders as one lane per stage (pp > 1 runs).
    """
    events: list[dict] = []
    if trace_buffer is not None:
        events.extend(trace_buffer.to_chrome_trace()["traceEvents"])
    if step_timings is not None:
        events.extend(_engine_events(step_timings))
    if pipeline is not None:
        events.extend(_pipeline_events(pipeline))
    if checkpoint_ops is not None:
        events.extend(_checkpoint_events(checkpoint_ops))
    merged_series: dict[str, Sequence[tuple[int, float]]] = {}
    step_ts = None
    if ledger is not None:
        merged_series.update(ledger.series)
        step_ts = ledger.step_ts_ms
    if waterfall is not None:
        merged_series.update(
            {f"waterfall_{k}": v for k, v in waterfall.series.items()})
    if series:
        merged_series.update(series)
    if merged_series:
        events.extend(_counter_events(merged_series, step_ts))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_timeline(path: str, **kwargs) -> str:
    """Build and write the unified timeline JSON; returns ``path``."""
    with open(path, "w") as f:
        json.dump(build_timeline(**kwargs), f)
    return path
