"""Per-step MFU-gap waterfall: additive, closure-checked attribution.

The ledger (:mod:`repro.obs.ledger`) records *that* a step lost MFU;
this module explains *where* it went.  Each training step's gap --
``1 - goodput`` against a perfectly balanced, zero-overhead step -- is
split into additive components, each a fraction of the measured step
wall time:

  * ``imbalance_<phase>`` -- residual post-balance straggler wait per
    synchronous phase: ``(max_p - mean_p)`` of the phase's per-shard
    cost vector, converted to wall time.  These are exactly the terms
    of ``1 - simulated_mfu`` re-expressed on the measured clock, so the
    per-(phase, modality) split is additive by construction.
  * ``exposed_dispatch`` -- dispatcher solve / re-plan host latency the
    step actually waited on (``OrchestratorReport.exposed_ms``).
  * ``checkpoint_stall`` -- save/restore wall time charged to the step
    that paid it (:class:`repro.checkpoint.CheckpointManager` op log).
  * ``kernel_dead_tiles`` -- compute spent on dead (padding) tiles the
    block-skipping kernels would have skipped (PR 6 tile counters).
  * ``moe_drop`` -- useful work lost to dropped MoE tokens.
  * ``preempt_recompute`` -- serving-side recompute of preempted
    context (teacher-forced re-prefill is real compute, zero goodput).
  * ``unattributed`` -- the signed residual: measured step time the
    model above does NOT explain.  This is the closure check -- a
    healthy run keeps it near zero; a cost-model drift (step time moves
    without the cost vectors moving) shows up *here*, which is exactly
    how the triage layer roots drift.

Closure is exact by algebra: with ``T`` the measured step time, the
named components plus ``unattributed`` telescope to the gap
``1 - useful_net/T``.  The *checked* property (gated in
``benchmarks/triage_accuracy.py``) is that on a healthy step the named
components alone sum to the measured gap within tolerance, i.e.
``|unattributed|`` stays small relative to the gap.

Cost vectors arrive in abstract cost units; the waterfall calibrates a
cost-to-ms scale online (EWMA over *previous* steps of
``(step_ms - host_ms) / sum_p max_p``), so the current step's closure
is a genuine out-of-sample check, not a tautology.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.obs.registry import MetricsRegistry, get_registry

__all__ = ["GapWaterfall", "WaterfallStep", "COMPONENT_ORDER"]

# Canonical component ordering (imbalance phases expand in report order;
# pipeline_bubble stages expand when the run is pipelined, pp > 1).
COMPONENT_ORDER = (
    "imbalance_*",
    "pipeline_bubble_s*",
    "exposed_dispatch",
    "checkpoint_stall",
    "kernel_dead_tiles",
    "moe_drop",
    "preempt_recompute",
    "unattributed",
)


@dataclasses.dataclass
class WaterfallStep:
    """One step's attributed MFU gap (all values are fractions of the
    measured step wall time)."""

    step: int
    step_ms: float
    gap: float  # 1 - goodput: everything that was not balanced useful work
    goodput: float  # useful_net / step_ms
    components: dict[str, float]  # named components, insertion-ordered
    unattributed: float  # signed residual the model does not explain
    closure_err: float  # |unattributed| / max(gap, floor)
    scale_ms_per_cost: float  # cost-unit -> ms scale used this step

    def to_dict(self) -> dict:
        return {
            "step": self.step, "step_ms": self.step_ms, "gap": self.gap,
            "goodput": self.goodput, "components": dict(self.components),
            "unattributed": self.unattributed,
            "closure_err": self.closure_err,
            "scale_ms_per_cost": self.scale_ms_per_cost,
        }

    @staticmethod
    def from_dict(d: Mapping) -> "WaterfallStep":
        return WaterfallStep(
            step=int(d["step"]), step_ms=float(d["step_ms"]),
            gap=float(d["gap"]), goodput=float(d["goodput"]),
            components=dict(d["components"]),
            unattributed=float(d["unattributed"]),
            closure_err=float(d["closure_err"]),
            scale_ms_per_cost=float(d.get("scale_ms_per_cost", 0.0)))


class GapWaterfall:
    """Online per-step MFU-gap decomposition.

    ``observe`` is the only hot-path call; it publishes each component
    as a labeled gauge (``mfu_gap_component{component=...}``) through
    the registry, keeps ``(step, value)`` series for the timeline /
    anomaly monitor, and returns the :class:`WaterfallStep` for the
    flight recorder.
    """

    # Relative-closure denominator floor: a near-zero gap makes any
    # residual look huge; below this gap closure is not meaningful.
    GAP_FLOOR = 0.02

    def __init__(self, *, registry: MetricsRegistry | None = None,
                 scale_ema: float = 0.3, warmup: int = 3,
                 history_cap: int = 100_000) -> None:
        self.registry = registry if registry is not None else get_registry()
        self.scale_ema = float(scale_ema)
        self.warmup = int(warmup)
        self.history_cap = int(history_cap)
        self._scale: float | None = None  # EWMA cost-unit -> ms
        self.history: list[WaterfallStep] = []
        self.series: dict[str, list[tuple[int, float]]] = {}
        r = self.registry
        self._g_comp = r.gauge(
            "mfu_gap_component",
            "per-step MFU-gap waterfall component (fraction of step)",
            labels=("component",))
        # NB not "_total": that suffix is counter-reserved in OpenMetrics
        # and the strict parser rejects negative values under it (the
        # gap goes signed-negative when measurement noise beats the
        # scale calibration).
        self._g_gap = r.gauge("mfu_gap",
                              "per-step total MFU gap (1 - goodput)")
        self._g_goodput = r.gauge(
            "mfu_goodput_attributed",
            "balanced useful fraction after waterfall attribution")
        self._g_closure = r.gauge(
            "mfu_gap_closure_err",
            "|unattributed| / gap -- waterfall closure check")

    # ------------------------------------------------------------------
    def _track(self, name: str, step: int, value: float) -> None:
        self.series.setdefault(name, []).append((step, float(value)))

    def observe(self, step: int, *, report=None,
                phase_costs: Mapping[str, Sequence[float]] | None = None,
                step_ms: float, exposed_ms: float | None = None,
                metrics: Mapping[str, float] | None = None,
                ckpt_ms: float = 0.0, dead_tile_frac: float = 0.0,
                recompute_frac: float = 0.0,
                pipeline=None) -> WaterfallStep:
        """Attribute one step's gap.

        ``report`` is an ``OrchestratorReport`` (or anything with
        ``phase_costs`` / ``exposed_ms``); alternatively pass
        ``phase_costs`` and ``exposed_ms`` directly.  ``ckpt_ms`` is
        checkpoint save/restore wall charged to this step;
        ``dead_tile_frac`` / ``recompute_frac`` are waste fractions of
        the useful compute (kernel padding tiles, preemption
        recompute).  ``metrics`` supplies ``moe_dropped_frac``.

        ``pipeline`` switches to the pipeline-mode algebra: a
        ``PipelinePlan`` (or its ``waterfall_inputs()`` mapping), taken
        from ``report.pipeline`` automatically when present.  Devices
        then live on a (d, pp) grid: per-stage unfilled bubble time
        becomes a ``pipeline_bubble_s{k}`` component, the cross-rank
        pipeline-makespan spread becomes ``imbalance_llm``, and closure
        follows from the simulator identity ``useful + sum_k idle_k =
        pp * rank_total`` per rank.
        """
        if report is not None:
            phase_costs = report.phase_costs
            if exposed_ms is None:
                exposed_ms = report.exposed_ms
            if pipeline is None:
                pipeline = getattr(report, "pipeline", None)
        if pipeline is not None and hasattr(pipeline, "waterfall_inputs"):
            pipeline = pipeline.waterfall_inputs()
        phase_costs = phase_costs or {}
        exposed_ms = float(exposed_ms or 0.0)
        step_ms = float(step_ms)
        if step_ms <= 0:
            raise ValueError(f"step_ms must be positive, got {step_ms}")

        # Host-side time is measured directly in ms; the remainder of
        # the step is compute, which calibrates the cost->ms scale.
        host_ms = min(exposed_ms + ckpt_ms, step_ms)
        compute_ms = max(step_ms - host_ms, 0.0)

        comps: dict[str, float] = {}
        if pipeline is not None:
            # ---- pipeline mode: attribute on the (d, pp) device grid.
            pp = int(pipeline["stages"])
            stage_bubble = np.asarray(pipeline["stage_bubble"], np.float64)
            totals = np.asarray(pipeline["rank_totals"], np.float64)
            crit = float(pipeline["critical_cost"])
            sum_max = crit  # cost on the critical path -> compute_ms
            scale_now = compute_ms / crit if crit > 0 else 0.0
            scale = self._scale if self._scale is not None else scale_now
            for k in range(pp):
                comps[f"pipeline_bubble_s{k}"] = (
                    float(stage_bubble[k]) * scale / (pp * step_ms))
            mean_total = float(totals.mean()) if totals.size else crit
            comps["imbalance_llm"] = (crit - mean_total) * scale / step_ms
            useful_raw = (float(pipeline["useful_per_device"])
                          * scale / step_ms)
        else:
            maxes: dict[str, float] = {}
            means: dict[str, float] = {}
            for phase, costs in phase_costs.items():
                arr = np.asarray(costs, dtype=np.float64)
                if arr.size == 0:
                    continue
                maxes[phase] = float(arr.max())
                means[phase] = float(arr.mean())
            sum_max = sum(maxes.values())
            scale_now = compute_ms / sum_max if sum_max > 0 else 0.0
            # Attribute with the scale learned from PREVIOUS steps so the
            # closure residual is a real check (warmup uses the current
            # estimate: nothing to check against yet).
            scale = self._scale if self._scale is not None else scale_now
            for phase in maxes:
                comps[f"imbalance_{phase}"] = (
                    (maxes[phase] - means[phase]) * scale / step_ms)
            useful_raw = sum(means.values()) * scale / step_ms
        warming = len(self.history) < self.warmup

        comps["exposed_dispatch"] = min(exposed_ms, step_ms) / step_ms
        comps["checkpoint_stall"] = min(ckpt_ms, step_ms) / step_ms
        drop_frac = float((metrics or {}).get("moe_dropped_frac", 0.0) or 0.0)
        comps["kernel_dead_tiles"] = max(dead_tile_frac, 0.0) * useful_raw
        comps["moe_drop"] = max(drop_frac, 0.0) * useful_raw
        comps["preempt_recompute"] = max(recompute_frac, 0.0) * useful_raw

        modeled = (sum_max * scale + min(exposed_ms, step_ms)
                   + min(ckpt_ms, step_ms)) / step_ms
        unattributed = 1.0 - modeled
        waste = (comps["kernel_dead_tiles"] + comps["moe_drop"]
                 + comps["preempt_recompute"])
        goodput = useful_raw - waste
        gap = 1.0 - goodput
        closure_err = (0.0 if warming
                       else abs(unattributed) / max(gap, self.GAP_FLOOR))

        wf = WaterfallStep(step=step, step_ms=step_ms, gap=gap,
                           goodput=goodput, components=comps,
                           unattributed=unattributed,
                           closure_err=closure_err,
                           scale_ms_per_cost=scale)
        if len(self.history) < self.history_cap:
            self.history.append(wf)
        for name, v in comps.items():
            self._g_comp.set(v, component=name)
            self._track(name, step, v)
        self._g_comp.set(unattributed, component="unattributed")
        self._track("unattributed", step, unattributed)
        self._g_gap.set(gap)
        self._g_goodput.set(goodput)
        self._g_closure.set(closure_err)
        self._track("gap", step, gap)
        self._track("goodput", step, goodput)

        # Fold this step's scale into the EWMA for the NEXT step.
        if scale_now > 0:
            if self._scale is None:
                self._scale = scale_now
            else:
                a = self.scale_ema
                self._scale = (1.0 - a) * self._scale + a * scale_now
        return wf

    # ------------------------------------------------------------------
    def closure(self, *, skip_warmup: bool = True) -> dict:
        """Run-level closure summary over the recorded history."""
        hist = self.history[self.warmup:] if skip_warmup else self.history
        if not hist:
            return {"steps": 0, "max_closure_err": 0.0,
                    "mean_closure_err": 0.0}
        errs = [w.closure_err for w in hist]
        return {"steps": len(hist),
                "max_closure_err": float(max(errs)),
                "mean_closure_err": float(sum(errs) / len(errs))}

    def summary(self) -> dict:
        """Mean per-component attribution over the run (fractions)."""
        if not self.history:
            return {}
        names: list[str] = []
        for w in self.history:
            for n in w.components:
                if n not in names:
                    names.append(n)
        out = {f"component_{n}": float(np.mean(
            [w.components.get(n, 0.0) for w in self.history])) for n in names}
        out["gap"] = float(np.mean([w.gap for w in self.history]))
        out["unattributed"] = float(np.mean(
            [w.unattributed for w in self.history]))
        out.update(self.closure())
        return out
