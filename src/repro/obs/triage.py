"""Root-cause triage: waterfall history + anomalies + alerts -> ranked
explanation of a run's MFU gap.

``python -m repro.obs.triage RUN`` (a ``--metrics-dir`` directory or a
``flight.jsonl`` path) replays the flight record -- the ``waterfall``
events the train loop records per step, the ``alert`` events routed
through :class:`repro.obs.export.AlertBridge` (anomalies, CUSUM drift,
replans, preemption storms, drop spikes, checkpoint fallbacks) -- and
prints a ranked root-cause report, e.g.::

    #1 straggler_audio (+6.2% of step time): imbalance_audio
       level-shift @ step 120 (z=9.3); corroborated by
       cost_model_drift@118, 3x stale_plan_replanned

Ranking: each waterfall component's mean contribution AFTER the
estimated fault step minus BEFORE it (its delta-gap, in fractions of
the step), boosted by anomalies on that component's series and by
corroborating alert kinds.  The ``unattributed`` residual is a
first-class candidate -- when the flight record carries CUSUM
``cost_model_drift`` alerts it is reported as ``cost_model_drift``
(step time moved while the cost vectors did not: the cost model is
stale), otherwise as ``unattributed_time``.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Mapping, Sequence

__all__ = ["CAUSE_OF_COMPONENT", "triage", "triage_flight",
           "render_text", "main"]

# Component -> canonical root-cause label.  imbalance_<phase> maps to
# straggler_<phase> (one per modality/phase); everything else is 1:1.
CAUSE_OF_COMPONENT = {
    "exposed_dispatch": "dispatcher_exposed",
    "checkpoint_stall": "checkpoint_stall",
    "kernel_dead_tiles": "kernel_dead_tiles",
    "moe_drop": "moe_drop_spike",
    "preempt_recompute": "preemption_storm",
    "unattributed": "unattributed_time",
}

# Alert kinds that corroborate a cause (alert -> cause label).
ALERT_SUPPORTS = {
    "stale_plan_replanned": "dispatcher_exposed",
    "cost_model_drift": "cost_model_drift",
    "moe_drop_spike": "moe_drop_spike",
    "preemption_storm": "preemption_storm",
    "checkpoint_corruption_fallback": "checkpoint_stall",
    "measurement_inconsistent": "dispatcher_exposed",
}

_KIND_WEIGHT = {"level_shift": 1.0, "trend": 0.9, "spike": 0.5}
_MIN_DELTA = 0.002  # components moving less than 0.2% of a step are noise


def _cause_of(component: str) -> str:
    if component.startswith("imbalance_"):
        return "straggler_" + component[len("imbalance_"):]
    return CAUSE_OF_COMPONENT.get(component, component)


def _mean(xs: Sequence[float]) -> float:
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def triage(waterfall: Sequence[Mapping], *,
           anomalies: Sequence[Mapping] = (),
           alerts: Sequence[Mapping] = (),
           meta: Mapping | None = None,
           warmup: int = 3, window: int = 10) -> dict:
    """Correlate a run's evidence into a ranked root-cause report.

    ``waterfall`` is a sequence of :meth:`WaterfallStep.to_dict` rows
    (ascending steps); ``anomalies`` dicts with at least
    ``series/step/kind/score/direction``; ``alerts`` dicts with
    ``alert/step``.  Returns a JSON-able report dict.
    """
    wf = [dict(w) for w in waterfall][warmup:]
    if not wf:
        return {"meta": dict(meta or {}), "fault_step": None, "causes": [],
                "note": "no waterfall history"}
    steps = [int(w["step"]) for w in wf]

    # 1. Estimate the fault step: earliest sustained anomaly, falling
    # back to spikes, falling back to the largest gap jump.
    anoms = sorted((dict(a) for a in anomalies), key=lambda a: a["step"])
    sustained = [a for a in anoms if a["kind"] in ("level_shift", "trend")]
    if sustained:
        fault_step = int(min(a["step"] for a in sustained))
    elif anoms:
        fault_step = int(min(a["step"] for a in anoms))
    else:
        gaps = [float(w["gap"]) for w in wf]
        jumps = [gaps[i] - gaps[i - 1] for i in range(1, len(gaps))]
        fault_step = (steps[jumps.index(max(jumps)) + 1]
                      if jumps else steps[0])
    before = [w for w in wf if int(w["step"]) < fault_step]
    after = [w for w in wf if int(w["step"]) >= fault_step]
    if not before or not after:  # fault at an edge: global split
        mid = max(len(wf) // 2, 1)
        before, after = wf[:mid], wf[mid:] or wf[:1]

    # 2. Per-component delta-gap across the split (unattributed rides
    # along as its own pseudo-component).
    names: list[str] = []
    for w in wf:
        for n in w["components"]:
            if n not in names:
                names.append(n)
    names.append("unattributed")

    def comp_val(w: Mapping, name: str) -> float:
        if name == "unattributed":
            return float(w["unattributed"])
        return float(w["components"].get(name, 0.0))

    alert_counts: dict[str, int] = {}
    alert_steps: dict[str, list[int]] = {}
    for ev in alerts:
        kind = str(ev.get("alert", ""))
        if kind.startswith("anomaly"):
            continue  # anomalies are first-class inputs, not corroboration
        alert_counts[kind] = alert_counts.get(kind, 0) + 1
        alert_steps.setdefault(kind, []).append(int(ev.get("step", -1)))

    causes: list[dict] = []
    for name in names:
        delta = _mean([comp_val(w, name) for w in after]) - _mean(
            [comp_val(w, name) for w in before])
        cause = _cause_of(name)
        evidence: list[str] = []
        score = max(delta, 0.0)
        # Anomalies on this component's series.
        comp_anoms = [a for a in anoms if a["series"] == name]
        for a in comp_anoms:
            w = _KIND_WEIGHT.get(a["kind"], 0.3)
            score += 0.5 * max(delta, 0.0) * w
            evidence.append(
                f"{name} {a['kind'].replace('_', '-')} @ step {a['step']} "
                f"(z={a['score']:.1f})")
        # Corroborating alert kinds.
        if name == "unattributed" and alert_counts.get("cost_model_drift"):
            cause = "cost_model_drift"
        for kind, n in sorted(alert_counts.items()):
            if ALERT_SUPPORTS.get(kind) != cause:
                continue
            score += 0.5 * max(delta, 0.0)
            at = [s for s in alert_steps[kind] if s >= 0]
            where = f"@ step {min(at)}" if at else ""
            evidence.append(f"{n}x {kind} {where}".rstrip())
        if delta < _MIN_DELTA and not comp_anoms:
            continue
        causes.append({
            "cause": cause, "component": name, "delta_gap": delta,
            "score": score, "fault_step": fault_step,
            "anomaly_kinds": sorted({a["kind"] for a in comp_anoms}),
            "evidence": evidence,
        })
    causes.sort(key=lambda c: c["score"], reverse=True)
    for rank, c in enumerate(causes, start=1):
        c["rank"] = rank

    gap_before = _mean([float(w["gap"]) for w in before])
    gap_after = _mean([float(w["gap"]) for w in after])
    closure = [float(w["closure_err"]) for w in wf]
    return {
        "meta": dict(meta or {}),
        "fault_step": fault_step,
        "gap_before": gap_before,
        "gap_after": gap_after,
        "gap_delta": gap_after - gap_before,
        "n_steps": len(wf),
        "n_anomalies": len(anoms),
        "n_alerts": sum(alert_counts.values()),
        "closure_err_max": max(closure) if closure else 0.0,
        "causes": causes,
    }


def triage_flight(events: Sequence[Mapping], **kw) -> dict:
    """Triage straight from flight-recorder events (``read_flight_record``
    output): ``waterfall`` events are the per-step history, ``alert``
    events split into anomalies (``anomaly_*``) and corroboration."""
    waterfall = [e for e in events if e.get("kind") == "waterfall"]
    anomalies = [
        {"series": e.get("series", ""), "step": int(e.get("step", 0)),
         "kind": e["alert"][len("anomaly_"):], "score": float(e.get("score", 0.0)),
         "direction": int(e.get("direction", 0))}
        for e in events
        if e.get("kind") == "alert" and str(e.get("alert", "")).startswith("anomaly_")]
    alerts = [e for e in events
              if e.get("kind") == "alert"
              and not str(e.get("alert", "")).startswith("anomaly_")]
    meta = next((e for e in events if e.get("kind") == "meta"), {})
    meta = {k: v for k, v in meta.items() if k not in ("kind", "ts")}
    return triage(waterfall, anomalies=anomalies, alerts=alerts, meta=meta,
                  **kw)


def render_text(report: Mapping) -> str:
    """Human-readable rendering of a triage report."""
    lines: list[str] = []
    meta = report.get("meta") or {}
    head = "MFU-gap triage"
    if meta.get("arch"):
        head += f" -- {meta['arch']}"
    lines.append(head)
    if report.get("fault_step") is None:
        lines.append("  (no waterfall history; nothing to explain)")
        return "\n".join(lines)
    lines.append(
        f"  gap {report['gap_before']:.1%} -> {report['gap_after']:.1%} "
        f"({report['gap_delta']:+.1%}) around step {report['fault_step']}; "
        f"{report['n_anomalies']} anomalies, {report['n_alerts']} alerts, "
        f"closure err max {report['closure_err_max']:.1%}")
    if not report["causes"]:
        lines.append("  no cause moved more than the noise floor")
    for c in report["causes"]:
        lines.append(
            f"  #{c['rank']} {c['cause']} ({c['delta_gap']:+.1%} of step "
            f"time): component {c['component']}")
        for ev in c["evidence"]:
            lines.append(f"       {ev}")
    return "\n".join(lines)


def main(argv: Sequence[str] | None = None) -> dict:
    ap = argparse.ArgumentParser(
        description="rank the root causes of a run's MFU gap from its "
                    "flight record")
    ap.add_argument("run", help="--metrics-dir directory or flight.jsonl path")
    ap.add_argument("--json", default=None,
                    help="also write the report as JSON here")
    ap.add_argument("--window", type=int, default=10)
    args = ap.parse_args(argv)
    path = args.run
    if os.path.isdir(path):
        path = os.path.join(path, "flight.jsonl")
    from repro.obs.export import read_flight_record
    report = triage_flight(read_flight_record(path), window=args.window)
    print(render_text(report))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, default=str)
        print(f"wrote {args.json}")
    return report


if __name__ == "__main__":
    main()
