"""Process-wide metrics registry: named Counters / Gauges / Histograms.

Dependency-free (pure stdlib) instrumentation substrate for the whole
repo: the orchestrator, the training loop, the serving engine, and the
kernel call sites all record into ONE :class:`MetricsRegistry` (the
process default from :func:`get_registry`, or an explicit instance for
tests), and the exporters in :mod:`repro.obs.export` /
:mod:`repro.obs.timeline` read it back out.

Design points:

  * **Labels** are first-class: a metric family created with
    ``labels=("phase", "shard")`` holds one child per label-value tuple
    (``fam.labels(phase="llm", shard=0).inc()``), so per-phase /
    per-shard / per-modality series never need name mangling.
  * **Histograms** keep both fixed buckets (OpenMetrics ``_bucket``
    export) and a streaming :class:`QuantileSketch`, so p50/p95/p99 are
    available online without retaining the raw stream -- that is what
    turns the serving engine's TTFT/ITL means into real tail metrics.
  * Everything on the hot path is O(1) amortized and allocation-light;
    the <2% overhead budget is gated in
    ``benchmarks/observability_overhead.py``.

Thread safety: one lock per metric family (the serving engine and the
plan-ahead worker record concurrently with the consumer thread).
"""
from __future__ import annotations

import math
import threading
from typing import Iterable, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricFamily",
    "MetricsRegistry",
    "QuantileSketch",
    "get_registry",
    "set_registry",
]


# ----------------------------------------------------------------------
# Streaming quantile sketch (Greenwald-Khanna).
# ----------------------------------------------------------------------
class QuantileSketch:
    """Greenwald-Khanna epsilon-approximate streaming quantiles.

    Maintains tuples ``(v, g, delta)`` such that for any query rank
    ``r`` the returned value's true rank is within ``eps * n`` of ``r``
    -- the classic GK invariant ``g + delta <= floor(2 * eps * n)``.
    Memory is O((1/eps) * log(eps * n)); inserts amortize to O(log)
    via a buffered batch insert.

    The rank-error bound is what the property tests in
    ``tests/test_obs.py`` verify against ``np.quantile`` on adversarial
    (sorted / reversed / constant / heavy-tailed) streams.
    """

    __slots__ = ("eps", "_tuples", "_n", "_buf", "_buf_cap")

    def __init__(self, eps: float = 0.005, buffer: int = 64) -> None:
        if not 0.0 < eps < 0.5:
            raise ValueError(f"eps must be in (0, 0.5), got {eps}")
        self.eps = float(eps)
        self._tuples: list[list[float]] = []  # [v, g, delta], sorted by v
        self._n = 0
        self._buf: list[float] = []
        self._buf_cap = max(1, int(buffer))

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return self._n + len(self._buf)

    def add(self, value: float) -> None:
        self._buf.append(float(value))
        if len(self._buf) >= self._buf_cap:
            self._drain()

    def extend(self, values: Iterable[float]) -> None:
        for v in values:
            self.add(v)

    def _drain(self) -> None:
        if not self._buf:
            return
        for v in sorted(self._buf):
            self._insert(v)
        self._buf.clear()
        self._compress()

    def _insert(self, v: float) -> None:
        t = self._tuples
        self._n += 1
        if not t or v < t[0][0]:
            t.insert(0, [v, 1.0, 0.0])
            return
        if v >= t[-1][0]:
            t.append([v, 1.0, 0.0])
            return
        # binary search for the first tuple with value > v
        lo, hi = 0, len(t)
        while lo < hi:
            mid = (lo + hi) // 2
            if t[mid][0] <= v:
                lo = mid + 1
            else:
                hi = mid
        cap = math.floor(2.0 * self.eps * self._n)
        t.insert(lo, [v, 1.0, max(0.0, cap - 1.0)])

    def _compress(self) -> None:
        t = self._tuples
        if len(t) < 3:
            return
        cap = math.floor(2.0 * self.eps * self._n)
        i = len(t) - 2
        while i >= 1:
            if t[i][1] + t[i + 1][1] + t[i + 1][2] <= cap:
                t[i + 1][1] += t[i][1]
                del t[i]
            i -= 1

    def quantile(self, q: float) -> float:
        """Value whose rank is within ``eps * n`` of ``ceil(q * n)``."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        self._drain()
        if self._n == 0:
            return float("nan")
        t = self._tuples
        target = max(1, math.ceil(q * self._n))  # 1-based target rank
        margin = self.eps * self._n
        rmin = 0.0
        prev_v = t[0][0]
        for v, g, delta in t:
            rmin += g
            if rmin + delta > target + margin:
                return prev_v
            prev_v = v
        return t[-1][0]

    def quantiles(self, qs: Sequence[float]) -> list[float]:
        return [self.quantile(q) for q in qs]

    # -- serialization (flight recorder / snapshots) --------------------
    def state_dict(self) -> dict:
        self._drain()
        return {"eps": self.eps, "n": self._n,
                "tuples": [list(t) for t in self._tuples]}

    @classmethod
    def from_state_dict(cls, state: dict) -> "QuantileSketch":
        sk = cls(eps=state["eps"])
        sk._n = int(state["n"])
        sk._tuples = [list(t) for t in state["tuples"]]
        return sk


# ----------------------------------------------------------------------
# Metric kinds.
# ----------------------------------------------------------------------
class Counter:
    """Monotone counter (export name gets a ``_total`` suffix)."""

    __slots__ = ("_value", "_lock")

    def __init__(self) -> None:
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time value (set / add)."""

    __slots__ = ("_value", "_lock")

    def __init__(self) -> None:
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, amount: float) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0, float("inf"))


class Histogram:
    """Fixed buckets (OpenMetrics export) + a quantile sketch (tails).

    ``observe`` is the only hot-path call: one bucket bisect + one
    amortized sketch insert.  ``quantile(q)`` answers p50/p95/p99 with
    the GK rank-error guarantee; bucket counts are cumulative
    (``le``-style) at export time.
    """

    __slots__ = ("buckets", "_counts", "_sum", "_count", "_sketch", "_lock")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS,
                 eps: float = 0.005) -> None:
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(bs):
            raise ValueError("buckets must be sorted ascending")
        if not bs or bs[-1] != float("inf"):
            bs = bs + (float("inf"),)
        self.buckets = bs
        self._counts = [0] * len(bs)
        self._sum = 0.0
        self._count = 0
        self._sketch = QuantileSketch(eps=eps)
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            # linear scan is faster than bisect for the short tails that
            # dominate in practice; buckets are small tuples.
            for i, b in enumerate(self.buckets):
                if v <= b:
                    self._counts[i] += 1
                    break
            self._sum += v
            self._count += 1
            self._sketch.add(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def bucket_counts(self) -> list[tuple[float, int]]:
        """Cumulative (le, count) pairs, OpenMetrics style."""
        out, cum = [], 0
        with self._lock:
            for b, c in zip(self.buckets, self._counts):
                cum += c
                out.append((b, cum))
        return out

    def quantile(self, q: float) -> float:
        with self._lock:
            return self._sketch.quantile(q)

    def quantiles(self, qs: Sequence[float] = (0.5, 0.95, 0.99)) -> list[float]:
        with self._lock:
            return self._sketch.quantiles(qs)

    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricFamily:
    """One named metric + its labeled children.

    A family with no label names has exactly one (unlabeled) child; a
    labeled family materializes children on first use.  Convenience
    pass-throughs (``inc`` / ``set`` / ``observe`` with label kwargs)
    keep call sites one-liners.
    """

    def __init__(self, name: str, kind: str, help: str = "",
                 labelnames: Sequence[str] = (), **metric_kw) -> None:
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}")
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self._metric_kw = metric_kw
        self._children: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def labels(self, **labels) -> "Counter | Gauge | Histogram":
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        key = tuple(str(labels[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = _KINDS[self.kind](**self._metric_kw)
                self._children[key] = child
        return child

    # -- one-liner pass-throughs ----------------------------------------
    def inc(self, amount: float = 1.0, **labels) -> None:
        self.labels(**labels).inc(amount)

    def set(self, value: float, **labels) -> None:
        self.labels(**labels).set(value)

    def observe(self, value: float, **labels) -> None:
        self.labels(**labels).observe(value)

    def children(self) -> list[tuple[dict[str, str], object]]:
        """(labels dict, metric) pairs, insertion-ordered."""
        with self._lock:
            return [(dict(zip(self.labelnames, key)), child)
                    for key, child in self._children.items()]


class MetricsRegistry:
    """Named metric families; the exporters' single read surface."""

    def __init__(self) -> None:
        self._families: dict[str, MetricFamily] = {}
        self._lock = threading.Lock()

    def _family(self, name: str, kind: str, help: str,
                labelnames: Sequence[str], **kw) -> MetricFamily:
        # "_total" is an exposition-reserved suffix: the renderer appends
        # it to counters, and the strict OpenMetrics parser treats any
        # series carrying it as a counter.  Baking it into a family name
        # either double-suffixes (counters) or miscategorizes (gauges).
        if name.endswith("_total"):
            raise ValueError(
                f"metric name {name!r} must not end with '_total' "
                "(reserved exposition suffix; the renderer adds it to "
                "counters)")
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = MetricFamily(name, kind, help, labelnames, **kw)
                self._families[name] = fam
            elif fam.kind != kind or fam.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered as {kind}{tuple(labelnames)} "
                    f"(was {fam.kind}{fam.labelnames})")
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> MetricFamily:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> MetricFamily:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  eps: float = 0.005) -> MetricFamily:
        return self._family(name, "histogram", help, labels,
                            buckets=buckets, eps=eps)

    def families(self) -> list[MetricFamily]:
        with self._lock:
            return list(self._families.values())

    def get(self, name: str) -> MetricFamily | None:
        with self._lock:
            return self._families.get(name)

    def snapshot_counters(self, prefix: str = "") -> dict[str, float]:
        """Flat {name{labels}: value} view of every counter -- the
        ledger polls this to lay counter tracks on the step axis."""
        out: dict[str, float] = {}
        for fam in self.families():
            if fam.kind != "counter" or not fam.name.startswith(prefix):
                continue
            for labels, child in fam.children():
                key = fam.name
                if labels:
                    key += "{" + ",".join(f"{k}={v}" for k, v in
                                          sorted(labels.items())) + "}"
                out[key] = child.value
        return out


# ----------------------------------------------------------------------
# Process-wide default.
# ----------------------------------------------------------------------
_default_registry = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (kernel hooks record here)."""
    return _default_registry


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process default (tests / multi-run isolation); returns
    the previous one."""
    global _default_registry
    with _default_lock:
        prev, _default_registry = _default_registry, registry
    return prev
