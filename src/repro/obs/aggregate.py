"""Cross-rank aggregation: mergeable registries + a live /metrics server.

A multi-shard / multi-replica run keeps one :class:`MetricsRegistry`
per rank (DP shard, engine replica, host process).  This module makes
those registries *mergeable* -- the algebra every metric kind needs for
a cluster-level view that is indistinguishable from having recorded
the union stream into one registry:

  * counters      -- per-labelset sum;
  * gauges        -- per-labelset mean by default (``gauge_mode="sum"``
                     or ``"last"`` where summing is the right algebra);
  * histograms    -- bucket-wise sum (identical bucket layouts
                     required), ``_sum``/``_count`` sums, and a proper
                     Greenwald-Khanna **sketch merge**
                     (:func:`merge_sketches`): the merged sketch
                     answers quantiles over the union stream with rank
                     error ``<= max(eps_a, eps_b) * n_total`` (the
                     mergeable-summaries bound, property-tested in
                     ``tests/test_aggregate.py``).

Registries also serialize (:func:`registry_state_dict` /
:func:`registry_from_state_dict`) so ranks can ship snapshots as JSON
and an aggregator process can merge them without sharing memory.

:class:`MetricsServer` is a stdlib ``http.server`` exporter serving
the (optionally aggregated) registry live at ``/metrics`` (OpenMetrics
text) and the current triage report at ``/triage`` (JSON) --
``launch/train.py --serve-metrics PORT`` wires it up.

:func:`parse_openmetrics` is the strict exposition parser the nightly
CI uses against the live endpoint: it rejects duplicate series,
out-of-order or non-cumulative histogram buckets, ``_bucket``/
``_count`` mismatches, negative or (given a previous scrape)
non-monotone ``_total`` values, and missing ``# EOF`` terminators.
"""
from __future__ import annotations

import argparse
import json
import re
import threading
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Mapping, Sequence

from repro.obs.export import render_openmetrics
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                QuantileSketch)

__all__ = [
    "MetricsServer",
    "aggregate_registries",
    "merge_sketches",
    "parse_openmetrics",
    "registry_from_state_dict",
    "registry_state_dict",
    "validate_openmetrics",
]


# ----------------------------------------------------------------------
# Greenwald-Khanna sketch merge.
# ----------------------------------------------------------------------
def _rank_tuples(sk: QuantileSketch) -> list[tuple[float, float, float]]:
    """(value, rmin, rmax) rows of a drained sketch."""
    sk._drain()
    out = []
    rmin = 0.0
    for v, g, delta in sk._tuples:
        rmin += g
        out.append((v, rmin, rmin + delta))
    return out


def merge_sketches(a: QuantileSketch, b: QuantileSketch) -> QuantileSketch:
    """Merge two GK sketches into one covering the union stream.

    Classic mergeable-summaries construction: for a tuple ``t`` from
    sketch A, its merged rank bounds are its own plus what the OTHER
    sketch pins around ``t.v`` -- ``rmin`` of B's predecessor and
    ``rmax`` of B's successor (minus one; ``n_B`` when no successor).
    The merged tuple widths then satisfy

        rmax - rmin  <=  2*eps_a*n_a + 2*eps_b*n_b
                     <=  2*max(eps_a, eps_b) * (n_a + n_b)

    i.e. the merged sketch preserves ``eps = max(eps_a, eps_b)`` --
    the post-merge rank-error bound the property tests check.
    """
    a._drain()
    b._drain()
    if a.n == 0 and b.n == 0:
        return QuantileSketch(eps=max(a.eps, b.eps))
    if a.n == 0 or b.n == 0:
        src = b if a.n == 0 else a
        out = QuantileSketch.from_state_dict(src.state_dict())
        out.eps = max(a.eps, b.eps)
        return out

    ra, rb = _rank_tuples(a), _rank_tuples(b)
    na, nb = a.n, b.n
    merged: list[tuple[float, float, float]] = []  # (v, rmin_m, rmax_m)

    for side, rows, other, n_other in ((0, ra, rb, nb), (1, rb, ra, na)):
        j = 0  # predecessor cursor into `other`
        for v, rmin, rmax in rows:
            while j < len(other) and other[j][0] <= v:
                j += 1
            pred_rmin = other[j - 1][1] if j > 0 else 0.0
            if j < len(other):
                succ_rmax = other[j][2] - 1.0
            else:
                succ_rmax = float(n_other)
            merged.append((v, rmin + pred_rmin, rmax + succ_rmax))
    merged.sort(key=lambda t: (t[0], t[1]))

    out = QuantileSketch(eps=max(a.eps, b.eps))
    out._n = na + nb
    tuples: list[list[float]] = []
    prev_rmin = 0.0
    for v, rmin_m, rmax_m in merged:
        g = rmin_m - prev_rmin
        tuples.append([v, g, max(rmax_m - rmin_m, 0.0)])
        prev_rmin = rmin_m
    out._tuples = tuples
    out._compress()
    return out


# ----------------------------------------------------------------------
# Registry serialization + merge.
# ----------------------------------------------------------------------
def registry_state_dict(registry: MetricsRegistry) -> dict:
    """JSON-able snapshot of a whole registry (for shipping cross-rank)."""
    fams = []
    for fam in registry.families():
        children = []
        for labels, child in fam.children():
            if isinstance(child, Histogram):
                with child._lock:
                    state = {"buckets": list(child.buckets),
                             "counts": list(child._counts),
                             "sum": child._sum, "count": child._count,
                             "sketch": child._sketch.state_dict()}
            else:
                state = {"value": child.value}
            children.append({"labels": labels, "state": state})
        fams.append({"name": fam.name, "kind": fam.kind, "help": fam.help,
                     "labelnames": list(fam.labelnames),
                     "children": children})
    return {"families": fams}


def registry_from_state_dict(state: Mapping) -> MetricsRegistry:
    reg = MetricsRegistry()
    for fd in state["families"]:
        kind, labelnames = fd["kind"], tuple(fd["labelnames"])
        if kind == "counter":
            fam = reg.counter(fd["name"], fd["help"], labels=labelnames)
        elif kind == "gauge":
            fam = reg.gauge(fd["name"], fd["help"], labels=labelnames)
        else:
            buckets = tuple(
                fd["children"][0]["state"]["buckets"]) if fd["children"] \
                else None
            kw = {"buckets": buckets} if buckets else {}
            fam = reg.histogram(fd["name"], fd["help"], labels=labelnames,
                                **kw)
        for ch in fd["children"]:
            child = fam.labels(**ch["labels"])
            s = ch["state"]
            if isinstance(child, (Counter, Gauge)):
                child._value = float(s["value"])
            else:
                child._counts = [int(c) for c in s["counts"]]
                child._sum = float(s["sum"])
                child._count = int(s["count"])
                child._sketch = QuantileSketch.from_state_dict(s["sketch"])
    return reg


def _merge_child_into(kind: str, dst, src, gauge_mode: str,
                      n_sources: int) -> None:
    if kind == "counter":
        dst._value += src.value
    elif kind == "gauge":
        if gauge_mode == "sum":
            dst._value += src.value
        elif gauge_mode == "last":
            dst._value = src.value
        else:  # mean: accumulate; divided once at the end
            dst._value += src.value
    else:  # histogram
        if tuple(src.buckets) != tuple(dst.buckets):
            raise ValueError(
                f"histogram bucket layouts differ: {dst.buckets} vs "
                f"{src.buckets}")
        with src._lock:
            counts = list(src._counts)
            hsum, hcount = src._sum, src._count
            sk = QuantileSketch.from_state_dict(src._sketch.state_dict())
        dst._counts = [c0 + c1 for c0, c1 in zip(dst._counts, counts)]
        dst._sum += hsum
        dst._count += hcount
        dst._sketch = merge_sketches(dst._sketch, sk)


def aggregate_registries(registries: Sequence[MetricsRegistry], *,
                         gauge_mode: str = "mean") -> MetricsRegistry:
    """Merge per-rank registries into one cluster-level registry.

    Counter and histogram merges are exact (equal to having recorded
    the union stream, up to the sketch's eps bound on quantiles);
    gauges have no canonical union algebra, so pick ``gauge_mode``:
    ``"mean"`` (default: utilization-style fractions), ``"sum"``
    (token counts carried in gauges), or ``"last"``.
    """
    if gauge_mode not in ("mean", "sum", "last"):
        raise ValueError(f"unknown gauge_mode {gauge_mode!r}")
    out = MetricsRegistry()
    # Count how many sources carry each (family, labelset) gauge so the
    # mean divides by the number of actual contributors.
    gauge_hits: dict[tuple[str, tuple], int] = {}
    for reg in registries:
        for fam in reg.families():
            if fam.kind == "counter":
                dst_fam = out.counter(fam.name, fam.help,
                                      labels=fam.labelnames)
            elif fam.kind == "gauge":
                dst_fam = out.gauge(fam.name, fam.help, labels=fam.labelnames)
            else:
                dst_fam = out.histogram(fam.name, fam.help,
                                        labels=fam.labelnames,
                                        **fam._metric_kw)
            for labels, child in fam.children():
                dst = dst_fam.labels(**labels)
                _merge_child_into(fam.kind, dst, child, gauge_mode,
                                  len(registries))
                if fam.kind == "gauge":
                    key = (fam.name, tuple(sorted(labels.items())))
                    gauge_hits[key] = gauge_hits.get(key, 0) + 1
    if gauge_mode == "mean":
        for fam in out.families():
            if fam.kind != "gauge":
                continue
            for labels, child in fam.children():
                key = (fam.name, tuple(sorted(labels.items())))
                child._value /= max(gauge_hits.get(key, 1), 1)
    return out


# ----------------------------------------------------------------------
# Strict OpenMetrics parsing / validation.
# ----------------------------------------------------------------------
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>\S+)\s*$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _parse_value(tok: str) -> float:
    if tok == "+Inf":
        return float("inf")
    if tok == "-Inf":
        return float("-inf")
    if tok == "NaN":
        return float("nan")
    return float(tok)


def parse_openmetrics(text: str) -> dict[str, float]:
    """Strictly parse a text exposition into ``{series_key: value}``.

    Raises :class:`ValueError` on any structural violation: garbage
    lines, duplicate ``(name, labelset)`` series, histogram ``le``
    buckets out of order or with decreasing cumulative counts,
    ``+Inf``-bucket / ``_count`` mismatches, negative ``_total``
    values, or a missing ``# EOF`` terminator.
    """
    samples: dict[str, float] = {}
    buckets: dict[str, list[tuple[float, float]]] = {}  # base{labels-sans-le}
    types: dict[str, str] = {}
    saw_eof = False
    for lineno, line in enumerate(text.split("\n"), start=1):
        if line == "":
            continue
        if saw_eof:
            raise ValueError(f"line {lineno}: content after # EOF")
        if line == "# EOF":
            saw_eof = True
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 4 or parts[3] not in ("counter", "gauge",
                                                  "histogram", "summary",
                                                  "untyped"):
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            if parts[2] in types:
                raise ValueError(
                    f"line {lineno}: duplicate TYPE for {parts[2]}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("# HELP "):
            if len(line.split(None, 3)) < 3:
                raise ValueError(f"line {lineno}: malformed HELP: {line!r}")
            continue
        if line.startswith("#"):
            raise ValueError(f"line {lineno}: unknown comment: {line!r}")
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparsable sample: {line!r}")
        name = m.group("name")
        raw_labels = m.group("labels") or ""
        labels = dict(_LABEL_RE.findall(raw_labels))
        consumed = "".join(f'{k}="{v}"' for k, v in _LABEL_RE.findall(
            raw_labels))
        if raw_labels.replace(",", "") != consumed:
            raise ValueError(f"line {lineno}: malformed labels: {line!r}")
        try:
            value = _parse_value(m.group("value"))
        except ValueError as e:
            raise ValueError(f"line {lineno}: bad value: {line!r}") from e
        key = name + "{" + ",".join(
            f'{k}="{v}"' for k, v in sorted(labels.items())) + "}"
        if key in samples:
            raise ValueError(f"line {lineno}: duplicate series {key}")
        samples[key] = value
        if name.endswith("_total") and (value < 0 or value != value):
            raise ValueError(
                f"line {lineno}: counter {key} has invalid value {value}")
        if name.endswith("_bucket") and "le" in labels:
            le = _parse_value(labels["le"])
            rest = {k: v for k, v in labels.items() if k != "le"}
            bkey = name[:-len("_bucket")] + "{" + ",".join(
                f'{k}="{v}"' for k, v in sorted(rest.items())) + "}"
            rows = buckets.setdefault(bkey, [])
            if rows:
                if le <= rows[-1][0]:
                    raise ValueError(
                        f"line {lineno}: {bkey} buckets out of order "
                        f"(le={le} after le={rows[-1][0]})")
                if value < rows[-1][1]:
                    raise ValueError(
                        f"line {lineno}: {bkey} cumulative bucket count "
                        f"decreases ({value} < {rows[-1][1]})")
            rows.append((le, value))
    if not saw_eof:
        raise ValueError("missing # EOF terminator")
    for bkey, rows in buckets.items():
        if rows[-1][0] != float("inf"):
            raise ValueError(f"{bkey}: no +Inf bucket")
        base, labels_part = bkey.split("{", 1)
        count_key = base + "_count{" + labels_part
        if count_key in samples and samples[count_key] != rows[-1][1]:
            raise ValueError(
                f"{bkey}: +Inf bucket {rows[-1][1]} != _count "
                f"{samples[count_key]}")
    return samples


def validate_openmetrics(text: str, *,
                         previous: Mapping[str, float] | None = None,
                         ) -> dict[str, float]:
    """Parse strictly; additionally reject ``_total`` series that went
    DOWN versus a previous scrape (counters must be monotone)."""
    samples = parse_openmetrics(text)
    if previous:
        for key, value in samples.items():
            name = key.split("{", 1)[0]
            if not name.endswith("_total"):
                continue
            prev = previous.get(key)
            if prev is not None and value < prev:
                raise ValueError(
                    f"counter {key} went backwards: {prev} -> {value}")
    return samples


# ----------------------------------------------------------------------
# Live HTTP exporter.
# ----------------------------------------------------------------------
class MetricsServer:
    """Serve ``/metrics`` (OpenMetrics) and ``/triage`` (JSON) live.

    ``registry_provider`` returns the registry to render per request --
    pass ``lambda: aggregate_registries([...])`` for a cross-rank view,
    or just ``lambda: registry`` for a single-rank run.  Pure stdlib
    (``ThreadingHTTPServer`` on a daemon thread); ``port=0`` picks a
    free port (read it back from ``.port``).
    """

    def __init__(self, registry_provider: Callable[[], MetricsRegistry], *,
                 triage_provider: Callable[[], Mapping] | None = None,
                 host: str = "127.0.0.1", port: int = 0) -> None:
        self.registry_provider = registry_provider
        self.triage_provider = triage_provider
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 (stdlib API name)
                path = self.path.split("?", 1)[0]
                if path in ("/metrics", "/"):
                    try:
                        body = render_openmetrics(
                            outer.registry_provider()).encode()
                    except Exception as e:  # surface, don't kill the thread
                        self._send(500, f"render error: {e}\n".encode(),
                                   "text/plain")
                        return
                    self._send(200, body,
                               "text/plain; version=0.0.4; charset=utf-8")
                elif path == "/triage":
                    if outer.triage_provider is None:
                        self._send(404, b"no triage provider\n", "text/plain")
                        return
                    try:
                        body = json.dumps(outer.triage_provider(),
                                          default=str).encode()
                    except Exception as e:
                        self._send(500, f"triage error: {e}\n".encode(),
                                   "text/plain")
                        return
                    self._send(200, body, "application/json")
                elif path == "/healthz":
                    self._send(200, b"ok\n", "text/plain")
                else:
                    self._send(404, b"not found\n", "text/plain")

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args) -> None:  # quiet
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread: threading.Thread | None = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-server", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# ----------------------------------------------------------------------
# CLI: validate a live endpoint or an exposition file (nightly CI).
# ----------------------------------------------------------------------
def _fetch(target: str) -> str:
    if target.startswith(("http://", "https://")):
        with urllib.request.urlopen(target, timeout=10) as resp:
            return resp.read().decode()
    with open(target) as f:
        return f.read()


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="strictly validate an OpenMetrics exposition "
                    "(URL or file)")
    ap.add_argument("target", help="http(s) URL or path to a .prom file")
    ap.add_argument("--previous", default=None,
                    help="earlier scrape (URL or file) to check _total "
                         "monotonicity against")
    ap.add_argument("--expect", action="append", default=[],
                    help="series name that must be present (repeatable)")
    args = ap.parse_args(argv)
    prev = None
    if args.previous:
        prev = parse_openmetrics(_fetch(args.previous))
    samples = validate_openmetrics(_fetch(args.target), previous=prev)
    names = {k.split("{", 1)[0] for k in samples}
    for want in args.expect:
        if want not in names:
            raise SystemExit(f"expected series {want!r} not found")
    print(f"openmetrics OK: {len(samples)} series, "
          f"{len(names)} metric names")


if __name__ == "__main__":
    main()
