"""Packed-stream assembly for post-balanced batches.

A *stream* is one DP shard's token buffer [cap]: examples laid out
contiguously in destination-slot order, ``seg`` carrying a per-example
id (0 = padding), ``pos`` restarting at 0 per example.  Padded phases
(audio, paper S8) lay each example out in a fixed ``max_len`` row inside
the stream so the compute cost matches the padded cost model while the
same segment machinery applies.
"""
from __future__ import annotations

import numpy as np

__all__ = ["pack_stream", "pack_padded_stream", "random_tokens"]


def pack_stream(
    dest_lengths: list[np.ndarray],
    cap: int,
    *,
    seg_ids: list[np.ndarray] | None = None,
    align: int = 1,
) -> tuple[np.ndarray, np.ndarray, list[np.ndarray]]:
    """Build (seg [S,cap], pos [S,cap], starts per shard) for packed layout.

    ``seg_ids[i][j]``: id (>0) of example j on shard i; defaults to a
    running counter unique per shard.  ``align``: round each example's
    start offset up to this multiple (connector downsample alignment).
    """
    S = len(dest_lengths)
    seg = np.zeros((S, cap), np.int32)
    pos = np.zeros((S, cap), np.int32)
    starts: list[np.ndarray] = []
    for i, lens in enumerate(dest_lengths):
        off = 0
        st = np.zeros(len(lens), np.int64)
        for j, l in enumerate(np.asarray(lens, np.int64)):
            sid = int(seg_ids[i][j]) if seg_ids is not None else j + 1
            if off + l > cap:
                raise ValueError(f"shard {i}: {off + l} tokens > cap {cap}")
            seg[i, off : off + l] = sid
            pos[i, off : off + l] = np.arange(l)
            st[j] = off
            off += int(l)
            off = -(-off // align) * align
        starts.append(st)
    return seg, pos, starts


def pack_padded_stream(
    dest_lengths: list[np.ndarray],
    cap: int,
    row_len: int,
    *,
    seg_ids: list[np.ndarray] | None = None,
) -> tuple[np.ndarray, np.ndarray, list[np.ndarray]]:
    """Padded layout: example j of a shard occupies row j*row_len; tokens
    beyond its length stay seg=0 (padding).  cap must be >= rows*row_len."""
    S = len(dest_lengths)
    seg = np.zeros((S, cap), np.int32)
    pos = np.zeros((S, cap), np.int32)
    starts: list[np.ndarray] = []
    for i, lens in enumerate(dest_lengths):
        st = np.zeros(len(lens), np.int64)
        for j, l in enumerate(np.asarray(lens, np.int64)):
            off = j * row_len
            if off + row_len > cap:
                raise ValueError(f"shard {i}: padded rows exceed cap {cap}")
            if l > row_len:
                raise ValueError(f"example len {l} > row_len {row_len}")
            sid = int(seg_ids[i][j]) if seg_ids is not None else j + 1
            seg[i, off : off + l] = sid
            pos[i, off : off + l] = np.arange(l)
            st[j] = off
        starts.append(st)
    return seg, pos, starts


def random_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    return rng.integers(1, vocab, size=shape, dtype=np.int32)
