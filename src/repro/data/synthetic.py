"""Synthetic multimodal dataset with Modality Composition Incoherence.

The paper (S3.1, Fig. 3) characterizes production MLLM instruction-tuning
data: the proportion of each modality's subsequence within the full
interleaved sequence varies dramatically across examples because the
dataset mixes tasks.  We reproduce that structure with a task mixture:

  asr       audio long, text ~ proportional to audio (positive corr)
  sqa       audio long, text short & UNcorrelated ('yes/no answers')
  caption   image medium, text short
  vqa       image large (anyres: 1-5 tiles), text medium
  text      text only, heavy-tailed lengths
  doc       image very large (many tiles), text long

Every example carries per-modality metadata token counts plus the
interleave order, which is exactly the structure the MLLM Global
Orchestrator gathers (paper S7: 'a structure to record ... the counts of
subsequences of different modalities and the order in which the
subsequences are interleaved').
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["Example", "TaskMix", "sample_examples", "modality_ratio_stats"]


@dataclasses.dataclass
class Example:
    """One multimodal example.  Subsequence lengths are in LLM tokens
    (post-connector, post-downsample); metadata lengths are in encoder
    tokens (pre-downsample)."""

    task: str
    text_len: int
    # per modality: encoder-input token count (0 = absent).
    vision_meta: int
    audio_meta: int
    # interleave order, e.g. ("vision", "text") or ("text", "audio", "text").
    order: tuple[str, ...]

    def subseq_len(self, modality: str, downsample: dict[str, int]) -> int:
        if modality == "text":
            return self.text_len
        meta = self.vision_meta if modality == "vision" else self.audio_meta
        ds = downsample.get(modality, 1)
        return int(np.ceil(meta / ds)) if meta else 0

    def total_len(self, downsample: dict[str, int]) -> int:
        return (
            self.text_len
            + self.subseq_len("vision", downsample)
            + self.subseq_len("audio", downsample)
        )


@dataclasses.dataclass(frozen=True)
class TaskMix:
    """Mixture weights; defaults roughly mimic an omni instruction mix."""

    asr: float = 0.2
    sqa: float = 0.15
    caption: float = 0.2
    vqa: float = 0.2
    text: float = 0.15
    doc: float = 0.1

    def names_probs(self):
        d = dataclasses.asdict(self)
        names = list(d)
        p = np.array([d[k] for k in names])
        return names, p / p.sum()


def _lognormal_int(rng, mean, sigma, lo, hi):
    return int(np.clip(rng.lognormal(np.log(mean), sigma), lo, hi))


def _sample_one(rng: np.random.Generator, task: str) -> Example:
    if task == "asr":
        audio = _lognormal_int(rng, 600, 0.6, 50, 1500)
        text = max(8, int(audio * rng.normal(0.25, 0.04)))  # corr w/ audio
        return Example(task, text, 0, audio, ("audio", "text"))
    if task == "sqa":
        audio = _lognormal_int(rng, 700, 0.7, 50, 1500)
        text = _lognormal_int(rng, 30, 0.9, 2, 300)  # uncorrelated
        return Example(task, text, 0, audio, ("audio", "text"))
    if task == "caption":
        vision = int(rng.choice([256, 576, 1024]))
        text = _lognormal_int(rng, 60, 0.7, 8, 400)
        return Example(task, text, vision, 0, ("vision", "text"))
    if task == "vqa":
        tiles = int(rng.integers(1, 6))  # anyres 1-5 tiles
        vision = tiles * 576
        text = _lognormal_int(rng, 150, 0.8, 16, 1200)
        return Example(task, text, vision, 0, ("vision", "text"))
    if task == "doc":
        tiles = int(rng.integers(4, 9))
        vision = tiles * 576
        text = _lognormal_int(rng, 700, 0.6, 64, 4000)
        return Example(task, text, vision, 0, ("text", "vision", "text"))
    # plain text, heavy-tailed
    text = _lognormal_int(rng, 400, 1.1, 10, 16384)
    return Example(task, text, 0, 0, ("text",))


def sample_examples(
    rng: np.random.Generator, n: int, mix: TaskMix | None = None,
    modalities: Sequence[str] = ("vision", "audio"),
) -> list[Example]:
    """Random i.i.d. sampling -- preserves batching randomness (S2.3)."""
    mix = mix or TaskMix()
    names, probs = mix.names_probs()
    out = []
    while len(out) < n:
        task = names[int(rng.choice(len(names), p=probs))]
        ex = _sample_one(rng, task)
        if "vision" not in modalities and ex.vision_meta:
            continue
        if "audio" not in modalities and ex.audio_meta:
            continue
        out.append(ex)
    return out


def modality_ratio_stats(
    examples: Sequence[Example], downsample: dict[str, int]
) -> dict[str, np.ndarray]:
    """Fig. 3 reproduction: per-example proportion of each modality's
    subsequence within the interleaved sequence."""
    ratios = {"vision": [], "audio": []}
    for ex in examples:
        tot = max(1, ex.total_len(downsample))
        ratios["vision"].append(ex.subseq_len("vision", downsample) / tot)
        ratios["audio"].append(ex.subseq_len("audio", downsample) / tot)
    return {k: np.array(v) for k, v in ratios.items()}
