"""Prefetching data pipeline with overlapped dispatcher computation.

Paper S6, 'Computation overhead overlapping': the Post-Balancing /
Node-wise / Composition *computation* needs only sequence lengths, which
are known as soon as the mini-batches are sampled -- so it runs inside
the prefetch worker, in parallel with the device's forward pass.  Only
the all-to-all *communication* stays on the critical path (inside the
jitted step).

``PrefetchingLoader`` runs sampling + ``plan_and_pack`` on a background
thread with a bounded queue; ``overlap_stats()`` reports how much
dispatcher time was hidden (benchmarks use it for the Table-2 analog).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator

import numpy as np

from repro.core.orchestrator import Capacities, MLLMGlobalOrchestrator
from repro.data.synthetic import Example, TaskMix, sample_examples

__all__ = ["PrefetchingLoader"]


class PrefetchingLoader:
    def __init__(
        self,
        orchestrator: MLLMGlobalOrchestrator,
        caps: Capacities,
        *,
        examples_per_instance: int,
        seed: int = 0,
        mix: TaskMix | None = None,
        modalities: tuple[str, ...] = ("vision", "audio"),
        sampler: Callable[[np.random.Generator, int], list[Example]] | None = None,
        depth: int = 2,
    ) -> None:
        self.orch = orchestrator
        self.caps = caps
        self.per = examples_per_instance
        self.rng = np.random.default_rng(seed)
        self.mix = mix
        self.modalities = modalities
        self.sampler = sampler
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.solve_ms_total = 0.0
        self.batches_produced = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _sample(self) -> list[list[Example]]:
        # Each DP instance samples independently (batching randomness,
        # paper S2.3) -- post-balancing happens AFTER this step.
        out = []
        for _ in range(self.orch.d):
            if self.sampler is not None:
                out.append(self.sampler(self.rng, self.per))
            else:
                out.append(sample_examples(self.rng, self.per, self.mix,
                                           self.modalities))
        return out

    def _worker(self) -> None:
        while not self._stop.is_set():
            t0 = time.perf_counter()
            examples = self._sample()
            try:
                batch, report = self.orch.plan_and_pack(examples, self.caps, self.rng)
            except ValueError:
                # Capacity overflow on a pathological draw: resample.
                continue
            dt = (time.perf_counter() - t0) * 1e3
            self.solve_ms_total += report.solve_ms
            self.batches_produced += 1
            item = (batch, report, dt)
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def overlap_stats(self) -> dict[str, float]:
        n = max(self.batches_produced, 1)
        return {
            "batches": self.batches_produced,
            "mean_solve_ms": self.solve_ms_total / n,
        }

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
