"""Prefetching data pipeline with overlapped dispatcher computation.

Paper S6, 'Computation overhead overlapping': the Post-Balancing /
Node-wise / Composition *computation* needs only sequence lengths, which
are known as soon as the mini-batches are sampled -- so it runs inside
the prefetch worker, in parallel with the device's forward pass.  Only
the all-to-all *communication* stays on the critical path (inside the
jitted step).

``PrefetchingLoader`` runs sampling + ``plan_and_pack`` on a background
thread with a bounded queue.  With ``plan_ahead=True`` it goes one step
further: step k+1's phase plans (``orchestrator.plan_phases``) are
launched *before* step k is packed, so the dispatcher solve overlaps
both the worker's own packing and the consumer's forward pass -- the
per-step ``report.exposed_ms`` then measures how much dispatcher time
was actually left on the critical path (~0 when fully hidden).
``overlap_stats()`` aggregates it for the Table-2 analog.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator

import numpy as np

from repro.core.orchestrator import Capacities, MLLMGlobalOrchestrator
from repro.data.synthetic import Example, TaskMix, sample_examples

__all__ = ["PrefetchingLoader"]


class PrefetchingLoader:
    def __init__(
        self,
        orchestrator: MLLMGlobalOrchestrator,
        caps: Capacities,
        *,
        examples_per_instance: int,
        seed: int = 0,
        mix: TaskMix | None = None,
        modalities: tuple[str, ...] = ("vision", "audio"),
        sampler: Callable[[np.random.Generator, int], list[Example]] | None = None,
        depth: int = 2,
        plan_ahead: bool = True,
    ) -> None:
        self.orch = orchestrator
        self.caps = caps
        self.per = examples_per_instance
        self.rng = np.random.default_rng(seed)
        self.mix = mix
        self.modalities = modalities
        self.sampler = sampler
        self.plan_ahead = plan_ahead
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.solve_ms_total = 0.0
        self.exposed_ms_total = 0.0
        self.batches_produced = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _sample(self) -> list[list[Example]]:
        # Each DP instance samples independently (batching randomness,
        # paper S2.3) -- post-balancing happens AFTER this step.
        out = []
        for _ in range(self.orch.d):
            if self.sampler is not None:
                out.append(self.sampler(self.rng, self.per))
            else:
                out.append(sample_examples(self.rng, self.per, self.mix,
                                           self.modalities))
        return out

    def _worker(self) -> None:
        pending = None  # (examples, PlanAheadHandle) for the next step
        while not self._stop.is_set():
            t0 = time.perf_counter()
            if pending is None:
                examples = self._sample()
                handle = (self.orch.plan_ahead(examples, self.caps)
                          if self.plan_ahead else None)
            else:
                examples, handle = pending
                pending = None
            if self.plan_ahead:
                # Launch step k+1's plans before packing step k: the
                # solve overlaps our packing of step k AND the consumer's
                # forward pass, so by the time the worker loops around
                # the plans are ready (exposed ~ 0).
                nxt = self._sample()
                pending = (nxt, self.orch.plan_ahead(nxt, self.caps))
            try:
                if handle is not None:
                    plans, exposed_ms = handle.result()
                    batch, report = self.orch.plan_and_pack(
                        examples, self.caps, self.rng, plans,
                        exposed_ms=exposed_ms,
                    )
                else:
                    batch, report = self.orch.plan_and_pack(
                        examples, self.caps, self.rng)
            except ValueError:
                # Capacity overflow on a pathological draw: resample.
                continue
            dt = (time.perf_counter() - t0) * 1e3
            self.solve_ms_total += report.solve_ms
            self.exposed_ms_total += report.exposed_ms
            self.batches_produced += 1
            item = (batch, report, dt)
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def overlap_stats(self) -> dict[str, float]:
        n = max(self.batches_produced, 1)
        return {
            "batches": self.batches_produced,
            "mean_solve_ms": self.solve_ms_total / n,
            "mean_exposed_ms": self.exposed_ms_total / n,
        }

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
