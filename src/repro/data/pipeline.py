"""Prefetching data pipeline with overlapped dispatcher computation.

Paper S6, 'Computation overhead overlapping': the Post-Balancing /
Node-wise / Composition *computation* needs only sequence lengths, which
are known as soon as the mini-batches are sampled -- so it runs inside
the prefetch worker, in parallel with the device's forward pass.  Only
the all-to-all *communication* stays on the critical path (inside the
jitted step).

``PrefetchingLoader`` runs sampling + ``plan_and_pack`` on a background
thread with a bounded queue.  With ``plan_ahead=True`` it goes one step
further: step k+1's phase plans (``orchestrator.plan_phases``) are
launched *before* step k is packed, so the dispatcher solve overlaps
both the worker's own packing and the consumer's forward pass -- the
per-step ``report.exposed_ms`` then measures how much dispatcher time
was actually left on the critical path (~0 when fully hidden).
``overlap_stats()`` aggregates it for the Table-2 analog.

Determinism contract (checkpoint resume): batch i's sampling RNG is
derived from ``(seed, i, attempt)`` -- never from wall time, thread
interleaving, or how many batches a previous consumer took.  A loader
constructed with ``start_index=i`` therefore replays the exact stream
an uninterrupted loader would have produced from batch i on, which is
what makes ``repro.checkpoint``'s save->resume loss trajectory bitwise
reproducible.  The retry path (capacity overflow on a pathological
draw) bumps ``attempt`` deterministically instead of consuming from a
shared stream.  ``cursor`` is the index of the next batch the consumer
will receive -- the value a checkpoint's ``DataCursor`` records.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator

import numpy as np

from repro.core.orchestrator import Capacities, MLLMGlobalOrchestrator
from repro.data.synthetic import Example, TaskMix, sample_examples

__all__ = ["PrefetchingLoader"]


class PrefetchingLoader:
    def __init__(
        self,
        orchestrator: MLLMGlobalOrchestrator,
        caps: Capacities,
        *,
        examples_per_instance: int,
        seed: int = 0,
        mix: TaskMix | None = None,
        modalities: tuple[str, ...] = ("vision", "audio"),
        sampler: Callable[[np.random.Generator, int], list[Example]] | None = None,
        depth: int = 2,
        plan_ahead: bool = True,
        start_index: int = 0,
    ) -> None:
        self.orch = orchestrator
        self.caps = caps
        self.per = examples_per_instance
        self.seed = seed
        self.start_index = start_index
        self.mix = mix
        self.modalities = modalities
        self.sampler = sampler
        self.plan_ahead = plan_ahead
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.solve_ms_total = 0.0
        self.exposed_ms_total = 0.0
        self.batches_produced = 0
        self.batches_consumed = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    @property
    def cursor(self) -> int:
        """Index of the next batch the consumer will receive -- what a
        checkpoint's ``DataCursor.batch_index`` records."""
        return self.start_index + self.batches_consumed

    def _batch_rng(self, index: int, attempt: int) -> np.random.Generator:
        """Batch ``index``'s deterministic RNG; ``attempt`` bumps on the
        (rare) capacity-overflow resample so retries stay replayable."""
        return np.random.default_rng((int(self.seed), int(index), int(attempt)))

    def _sample(self, index: int, attempt: int = 0) -> list[list[Example]]:
        # Each DP instance samples independently (batching randomness,
        # paper S2.3) -- post-balancing happens AFTER this step.  All d
        # instances draw sequentially from ONE per-index stream, so the
        # flattened example list depends only on (seed, index, attempt,
        # d*per): an elastic resume that re-splits the same global batch
        # across a different d sees the identical example multiset.
        rng = self._batch_rng(index, attempt)
        out = []
        for _ in range(self.orch.d):
            if self.sampler is not None:
                out.append(self.sampler(rng, self.per))
            else:
                out.append(sample_examples(rng, self.per, self.mix,
                                           self.modalities))
        return out

    def _worker(self) -> None:
        index = self.start_index
        attempts = 0
        pending = None  # (index, examples, PlanAheadHandle) for index+1
        while not self._stop.is_set():
            t0 = time.perf_counter()
            if pending is not None and pending[0] == index:
                _, examples, handle = pending
                pending = None
            else:
                examples = self._sample(index, attempts)
                handle = (self.orch.plan_ahead(examples, self.caps)
                          if self.plan_ahead else None)
            if self.plan_ahead and (pending is None or pending[0] != index + 1):
                # Launch step k+1's plans before packing step k: the
                # solve overlaps our packing of step k AND the consumer's
                # forward pass, so by the time the worker loops around
                # the plans are ready (exposed ~ 0).  On a retry of step
                # k the still-valid pending plan for k+1 is kept as is.
                nxt = self._sample(index + 1)
                pending = (index + 1, nxt, self.orch.plan_ahead(nxt, self.caps))
            try:
                rng = self._batch_rng(index, attempts)
                if handle is not None:
                    plans, exposed_ms = handle.result()
                    batch, report = self.orch.plan_and_pack(
                        examples, self.caps, rng, plans,
                        exposed_ms=exposed_ms,
                    )
                else:
                    batch, report = self.orch.plan_and_pack(
                        examples, self.caps, rng)
            except ValueError:
                # Capacity overflow on a pathological draw: retry the
                # SAME index with a bumped attempt counter (replayable).
                attempts += 1
                continue
            dt = (time.perf_counter() - t0) * 1e3
            self.solve_ms_total += report.solve_ms
            self.exposed_ms_total += report.exposed_ms
            self.batches_produced += 1
            item = (batch, report, dt)
            index += 1
            attempts = 0
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self.q.get()
        self.batches_consumed += 1
        return item

    def overlap_stats(self) -> dict[str, float]:
        n = max(self.batches_produced, 1)
        return {
            "batches": self.batches_produced,
            "mean_solve_ms": self.solve_ms_total / n,
            "mean_exposed_ms": self.exposed_ms_total / n,
        }

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
