"""Mamba-1 selective scan, Pallas TPU kernel (training grade).

TPU adaptation of the CUDA selective-scan: instead of warp-level
parallel prefix, we tile the CHANNEL dimension across the grid (each
channel block is an independent recurrence -> trivially parallel across
TPU cores) and walk TIME in VMEM-resident chunks, carrying the [bd, N]
state in scratch across sequential grid steps.  Segment-aware: the
state resets where the segment id changes (packed post-balanced
streams).

Grid: (n_channel_blocks, n_time_chunks) -- time innermost (sequential
on TPU), channels outer (parallelizable).

Differentiable: the forward kernel additionally emits the state at
every chunk boundary (``ckpt [n_t, di, N]`` -- the same residual style
as the flash backward's lse, one checkpoint per tile of sequential
work) plus the final state, and a reverse-time backward kernel
recomputes the per-step states inside each chunk from its checkpoint
while propagating the state cotangent across chunks in scratch.  The
recurrence

    h_t = keep_t * exp(dt_t A) * h_{t-1} + (dt_t u_t) B_t
    y_t = <h_t, C_t> + D u_t

gives, with ``g_t = dL/dh_t`` accumulated as
``g_t = dy_t C_t + keep_{t+1} exp(dt_{t+1} A) g_{t+1}``:

    du_t  = D dy_t + dt_t <g_t, B_t>
    ddt_t = <g_t, keep_t h_{t-1} A e^{dt_t A}> + u_t <g_t, B_t>
    dA   += keep_t dt_t g_t h_{t-1} e^{dt_t A}      (summed over t)
    dB_t  = sum_d g_t dt_t u_t       dC_t = sum_d dy_t h_t
    dD   += dy_t u_t                                (summed over t)

``selective_scan`` wraps the pair in a ``jax.custom_vjp`` (seg gets a
symbolic-zero cotangent like the flash kernel's seg/pos inputs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["selective_scan"]


def _fwd_kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, keep_ref,
                y_ref, ckpt_ref, hfin_ref, h_scr, *, chunk, n_t):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    ckpt_ref[0] = h_scr[...]  # state entering this chunk (bwd residual)

    u = u_ref[...].astype(jnp.float32)      # [ct, bd]
    dt = dt_ref[...].astype(jnp.float32)    # [ct, bd]
    A = A_ref[...].astype(jnp.float32)      # [bd, N]
    Bm = B_ref[...].astype(jnp.float32)     # [ct, N]
    Cm = C_ref[...].astype(jnp.float32)     # [ct, N]
    Dv = D_ref[...].astype(jnp.float32)     # [1, bd]
    keep = keep_ref[...]                    # [ct, 1] int32 (bool as int)

    def step(t, carry):
        h, ys = carry
        dA = jnp.exp(dt[t][:, None] * A)  # [bd, N]
        h = jnp.where(keep[t, 0] > 0, h, 0.0) * dA + (
            (dt[t] * u[t])[:, None] * Bm[t][None, :]
        )
        y = (h * Cm[t][None, :]).sum(axis=1) + Dv[0] * u[t]
        return h, ys.at[t].set(y)

    ys0 = jnp.zeros(u.shape, jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[...] = ys.astype(y_ref.dtype)

    @pl.when(it == n_t - 1)
    def _emit_final():
        hfin_ref[...] = h


def _bwd_kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, keep_ref,
                ckpt_ref, dy_ref, dhf_ref,
                du_ref, ddt_ref, dB_ref, dC_ref, dA_ref, dD_ref,
                g_scr, dA_scr, dD_scr, *, chunk, n_t):
    it = pl.program_id(1)  # 0 = LAST time chunk (index maps reverse)

    @pl.when(it == 0)
    def _init():
        g_scr[...] = dhf_ref[...]  # dL/dh_final enters the recurrence
        dA_scr[...] = jnp.zeros_like(dA_scr)
        dD_scr[...] = jnp.zeros_like(dD_scr)

    u = u_ref[...].astype(jnp.float32)      # [ct, bd]
    dt = dt_ref[...].astype(jnp.float32)
    A = A_ref[...].astype(jnp.float32)      # [bd, N]
    Bm = B_ref[...].astype(jnp.float32)     # [ct, N]
    Cm = C_ref[...].astype(jnp.float32)
    Dv = D_ref[...].astype(jnp.float32)     # [1, bd]
    keep = keep_ref[...]                    # [ct, 1]
    h0 = ckpt_ref[0]                        # [bd, N] state entering chunk
    dy = dy_ref[...].astype(jnp.float32)    # [ct, bd]

    # Recompute the post-step states of this chunk from its checkpoint.
    def fstep(t, carry):
        h, posts = carry
        dA = jnp.exp(dt[t][:, None] * A)
        h = jnp.where(keep[t, 0] > 0, h, 0.0) * dA + (
            (dt[t] * u[t])[:, None] * Bm[t][None, :]
        )
        return h, posts.at[t].set(h)

    posts0 = jnp.zeros((chunk,) + h0.shape, jnp.float32)
    _, posts = jax.lax.fori_loop(0, chunk, fstep, (h0, posts0))

    def bstep(r, carry):
        g_nxt, dus, ddts, dBs, dCs, dAa, dDa = carry
        t = chunk - 1 - r
        h_t = posts[t]
        h_prev = jnp.where(t > 0, posts[jnp.maximum(t - 1, 0)], h0)
        hm = jnp.where(keep[t, 0] > 0, h_prev, 0.0)
        dA_t = jnp.exp(dt[t][:, None] * A)
        g = dy[t][:, None] * Cm[t][None, :] + g_nxt        # [bd, N]
        gB = (g * Bm[t][None, :]).sum(axis=1)              # [bd]
        dus = dus.at[t].set(dy[t] * Dv[0] + dt[t] * gB)
        ddts = ddts.at[t].set((g * hm * A * dA_t).sum(axis=1) + u[t] * gB)
        dAa = dAa + g * hm * dt[t][:, None] * dA_t
        dBs = dBs.at[t].set((g * (dt[t] * u[t])[:, None]).sum(axis=0))
        dCs = dCs.at[t].set((dy[t][:, None] * h_t).sum(axis=0))
        dDa = dDa + dy[t] * u[t]
        g_prev = jnp.where(keep[t, 0] > 0, dA_t * g, 0.0)
        return g_prev, dus, ddts, dBs, dCs, dAa, dDa

    bd, N = h0.shape
    init = (g_scr[...],
            jnp.zeros((chunk, bd), jnp.float32),
            jnp.zeros((chunk, bd), jnp.float32),
            jnp.zeros((chunk, N), jnp.float32),
            jnp.zeros((chunk, N), jnp.float32),
            dA_scr[...],
            dD_scr[0])
    g, dus, ddts, dBs, dCs, dAa, dDa = jax.lax.fori_loop(
        0, chunk, bstep, init)

    g_scr[...] = g
    dA_scr[...] = dAa
    dD_scr[0] = dDa
    du_ref[...] = dus.astype(du_ref.dtype)
    ddt_ref[...] = ddts.astype(ddt_ref.dtype)
    dB_ref[0] = dBs
    dC_ref[0] = dCs

    @pl.when(it == n_t - 1)
    def _emit():
        dA_ref[...] = dA_scr[...]
        dD_ref[...] = dD_scr[...]


def _fwd_call(u, delta, A, B, C, D2, keep, *, bd, ct, interpret):
    T, di = u.shape
    N = A.shape[1]
    n_d, n_t = di // bd, T // ct
    kernel = functools.partial(_fwd_kernel, chunk=ct, n_t=n_t)
    return pl.pallas_call(
        kernel,
        grid=(n_d, n_t),
        in_specs=[
            pl.BlockSpec((ct, bd), lambda id_, it: (it, id_)),   # u
            pl.BlockSpec((ct, bd), lambda id_, it: (it, id_)),   # delta
            pl.BlockSpec((bd, N), lambda id_, it: (id_, 0)),     # A
            pl.BlockSpec((ct, N), lambda id_, it: (it, 0)),      # B
            pl.BlockSpec((ct, N), lambda id_, it: (it, 0)),      # C
            pl.BlockSpec((1, bd), lambda id_, it: (0, id_)),     # D
            pl.BlockSpec((ct, 1), lambda id_, it: (it, 0)),      # keep
        ],
        out_specs=[
            pl.BlockSpec((ct, bd), lambda id_, it: (it, id_)),       # y
            pl.BlockSpec((1, bd, N), lambda id_, it: (it, id_, 0)),  # ckpt
            pl.BlockSpec((bd, N), lambda id_, it: (id_, 0)),         # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, di), u.dtype),
            jax.ShapeDtypeStruct((n_t, di, N), jnp.float32),
            jax.ShapeDtypeStruct((di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(u, delta, A, B, C, D2, keep)


def _bwd_call(u, delta, A, B, C, D2, keep, ckpt, dy, dhf, *, bd, ct,
              interpret):
    T, di = u.shape
    N = A.shape[1]
    n_d, n_t = di // bd, T // ct
    rev = lambda it: n_t - 1 - it  # noqa: E731 - shared reversed time index
    kernel = functools.partial(_bwd_kernel, chunk=ct, n_t=n_t)
    du, ddt, dBp, dCp, dA, dD = pl.pallas_call(
        kernel,
        grid=(n_d, n_t),
        in_specs=[
            pl.BlockSpec((ct, bd), lambda id_, it: (rev(it), id_)),    # u
            pl.BlockSpec((ct, bd), lambda id_, it: (rev(it), id_)),    # delta
            pl.BlockSpec((bd, N), lambda id_, it: (id_, 0)),           # A
            pl.BlockSpec((ct, N), lambda id_, it: (rev(it), 0)),       # B
            pl.BlockSpec((ct, N), lambda id_, it: (rev(it), 0)),       # C
            pl.BlockSpec((1, bd), lambda id_, it: (0, id_)),           # D
            pl.BlockSpec((ct, 1), lambda id_, it: (rev(it), 0)),       # keep
            pl.BlockSpec((1, bd, N), lambda id_, it: (rev(it), id_, 0)),
            pl.BlockSpec((ct, bd), lambda id_, it: (rev(it), id_)),    # dy
            pl.BlockSpec((bd, N), lambda id_, it: (id_, 0)),           # dhf
        ],
        out_specs=[
            pl.BlockSpec((ct, bd), lambda id_, it: (rev(it), id_)),    # du
            pl.BlockSpec((ct, bd), lambda id_, it: (rev(it), id_)),    # ddt
            pl.BlockSpec((1, ct, N), lambda id_, it: (id_, rev(it), 0)),
            pl.BlockSpec((1, ct, N), lambda id_, it: (id_, rev(it), 0)),
            pl.BlockSpec((bd, N), lambda id_, it: (id_, 0)),           # dA
            pl.BlockSpec((1, bd), lambda id_, it: (0, id_)),           # dD
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, di), jnp.float32),
            jax.ShapeDtypeStruct((T, di), jnp.float32),
            jax.ShapeDtypeStruct((n_d, T, N), jnp.float32),
            jax.ShapeDtypeStruct((n_d, T, N), jnp.float32),
            jax.ShapeDtypeStruct((di, N), jnp.float32),
            jax.ShapeDtypeStruct((1, di), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bd, N), jnp.float32),   # g carry across chunks
            pltpu.VMEM((bd, N), jnp.float32),   # dA accumulator
            pltpu.VMEM((1, bd), jnp.float32),   # dD accumulator
        ],
        interpret=interpret,
    )(u, delta, A, B, C, D2, keep, ckpt, dy, dhf)
    # Per-channel-block partials -> full dB/dC reductions.
    return du, ddt, dA, dBp.sum(axis=0), dCp.sum(axis=0), dD[0]


@functools.lru_cache(maxsize=None)
def _make_diff_scan(bd, ct, interpret):
    @jax.custom_vjp
    def scan(u, delta, A, B, C, D2, keep):
        y, _, hf = _fwd_call(u, delta, A, B, C, D2, keep,
                             bd=bd, ct=ct, interpret=interpret)
        return y, hf

    def fwd(u, delta, A, B, C, D2, keep):
        y, ckpt, hf = _fwd_call(u, delta, A, B, C, D2, keep,
                                bd=bd, ct=ct, interpret=interpret)
        return (y, hf), (u, delta, A, B, C, D2, keep, ckpt)

    def bwd(res, cts):
        u, delta, A, B, C, D2, keep, ckpt = res
        dy, dhf = cts
        du, ddt, dA, dB, dC, dD = _bwd_call(
            u, delta, A, B, C, D2, keep, ckpt,
            dy.astype(jnp.float32), dhf.astype(jnp.float32),
            bd=bd, ct=ct, interpret=interpret)
        return (du.astype(u.dtype), ddt.astype(delta.dtype),
                dA.astype(A.dtype), dB.astype(B.dtype), dC.astype(C.dtype),
                dD[None].astype(D2.dtype),
                np.zeros(keep.shape, jax.dtypes.float0))

    scan.defvjp(fwd, bwd)
    return scan


def selective_scan(
    u: jnp.ndarray,
    delta: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    seg: jnp.ndarray,
    *,
    block_d: int = 128,
    chunk: int = 64,
    interpret: bool | None = None,
    return_state: bool = False,
):
    """u, delta [T, di]; A [di, N]; B, C [T, N]; D [di]; seg [T] int32.
    Returns y [T, di], or ``(y, h_final [di, N])`` with
    ``return_state=True``.  Differentiable (chunk-checkpointed custom
    VJP); ``interpret=None`` resolves via ``ops.default_interpret``."""
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    T, di = u.shape
    bd = min(block_d, di)
    ct = min(chunk, T)
    if di % bd or T % ct:
        raise ValueError(f"di={di} % {bd} or T={T} % {ct} != 0")

    prev = jnp.concatenate([seg[:1], seg[:-1]])
    keep = ((seg > 0) & (seg == prev)).at[0].set(False)
    keep = keep.astype(jnp.int32)[:, None]  # [T, 1]
    D2 = D[None, :]  # [1, di]

    fn = _make_diff_scan(bd, ct, bool(interpret))
    y, hf = fn(u, delta, A, B, C, D2, keep)
    return (y, hf) if return_state else y
