"""Mamba-1 selective scan, Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: instead of warp-level
parallel prefix, we tile the CHANNEL dimension across the grid (each
channel block is an independent recurrence -> trivially parallel across
TPU cores) and walk TIME in VMEM-resident chunks, carrying the [bd, N]
state in scratch across sequential grid steps.  Segment-aware: the
state resets where the segment id changes (packed post-balanced
streams).

Grid: (n_channel_blocks, n_time_chunks) -- time innermost (sequential
on TPU), channels outer (parallelizable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["selective_scan"]


def _kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, keep_ref, y_ref,
            h_scr, *, chunk):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[...].astype(jnp.float32)      # [ct, bd]
    dt = dt_ref[...].astype(jnp.float32)    # [ct, bd]
    A = A_ref[...].astype(jnp.float32)      # [bd, N]
    Bm = B_ref[...].astype(jnp.float32)     # [ct, N]
    Cm = C_ref[...].astype(jnp.float32)     # [ct, N]
    Dv = D_ref[...].astype(jnp.float32)     # [1, bd]
    keep = keep_ref[...]                    # [ct, 1] int32 (bool as int)

    def step(t, carry):
        h, ys = carry
        dA = jnp.exp(dt[t][:, None] * A)  # [bd, N]
        h = jnp.where(keep[t, 0] > 0, h, 0.0) * dA + (
            (dt[t] * u[t])[:, None] * Bm[t][None, :]
        )
        y = (h * Cm[t][None, :]).sum(axis=1) + Dv[0] * u[t]
        return h, ys.at[t].set(y)

    ys0 = jnp.zeros(u.shape, jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[...] = ys.astype(y_ref.dtype)


def selective_scan(
    u: jnp.ndarray,
    delta: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    seg: jnp.ndarray,
    *,
    block_d: int = 128,
    chunk: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    """u, delta [T, di]; A [di, N]; B, C [T, N]; D [di]; seg [T] int32.
    Returns y [T, di]."""
    T, di = u.shape
    N = A.shape[1]
    bd = min(block_d, di)
    ct = min(chunk, T)
    if di % bd or T % ct:
        raise ValueError(f"di={di} % {bd} or T={T} % {ct} != 0")
    n_d, n_t = di // bd, T // ct

    prev = jnp.concatenate([seg[:1], seg[:-1]])
    keep = ((seg > 0) & (seg == prev)).at[0].set(False)
    keep = keep.astype(jnp.int32)[:, None]  # [T, 1]
    D2 = D[None, :]  # [1, di]

    kernel = functools.partial(_kernel, chunk=ct)
    y = pl.pallas_call(
        kernel,
        grid=(n_d, n_t),
        in_specs=[
            pl.BlockSpec((ct, bd), lambda id_, it: (it, id_)),   # u
            pl.BlockSpec((ct, bd), lambda id_, it: (it, id_)),   # delta
            pl.BlockSpec((bd, N), lambda id_, it: (id_, 0)),     # A
            pl.BlockSpec((ct, N), lambda id_, it: (it, 0)),      # B
            pl.BlockSpec((ct, N), lambda id_, it: (it, 0)),      # C
            pl.BlockSpec((1, bd), lambda id_, it: (0, id_)),     # D
            pl.BlockSpec((ct, 1), lambda id_, it: (it, 0)),      # keep
        ],
        out_specs=pl.BlockSpec((ct, bd), lambda id_, it: (it, id_)),
        out_shape=jax.ShapeDtypeStruct((T, di), u.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(u, delta, A, B, C, D2, keep)
    return y
