"""Roofline-driven block-shape autotuner for the Pallas kernels.

The flash, selective-scan, and grouped-GEMM kernels all take block
shapes that trade VMEM residency against grid overhead, and the best
choice depends on the call shape, dtype, and backend.  Hardcoding
128x128 (the pre-autotuner default) leaves real throughput behind on
small or skewed shapes.  This module applies the PR-4
calibrate-against-measurement philosophy one level down:

  1. enumerate candidate block shapes for a call signature,
  2. score each with a roofline prediction (``launch/roofline.py`` HW
     presets: compute time vs HBM time, plus a per-grid-step launch
     overhead term) and PRUNE candidates predicted far off the best --
     the model is there to keep the sweep cheap, not to decide,
  3. measure wall time for the survivors and pick the winner,
  4. cache the winner per (kernel, shape signature, dtype, backend) in
     a JSON file consulted at trace time by the call sites
     (``resolve``), with an explicit-override escape hatch
     (``REPRO_KERNEL_BLOCKS`` env var) that always wins.

The cache stores plain data (block tuple + the prediction and
measurement that chose it), so a committed cache file is reviewable
and the escape hatch can pin any site without re-tuning.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.launch.roofline import HW, get_hw

__all__ = [
    "Candidate", "autotune", "resolve", "cache_key", "default_cache_path",
    "flash_candidates", "scan_candidates", "grouped_candidates",
    "predict_flash", "predict_scan", "predict_grouped",
]

# Per-grid-step launch/bookkeeping overhead (s).  On real TPUs this is
# the Mosaic grid-step cost (~microseconds); the exact value matters
# only relatively -- it penalizes tiny blocks that explode the grid.
STEP_OVERHEAD_S = 1e-6

_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_ENV_OVERRIDE = "REPRO_KERNEL_BLOCKS"


@dataclasses.dataclass(frozen=True)
class Candidate:
    blocks: tuple[int, ...]
    predicted_s: float
    measured_ms: float | None = None


def default_cache_path() -> str:
    env = os.environ.get(_ENV_CACHE)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "repro_autotune.json")


def cache_key(kernel: str, key: Mapping[str, object]) -> str:
    parts = [kernel] + [f"{k}={key[k]}" for k in sorted(key)]
    return "|".join(parts)


def _load_cache(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _save_cache(path: str, data: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


_GET_REGISTRY = None


def _count_resolve(kernel: str, outcome: str) -> None:
    """Count resolve outcomes (override/disabled/hit/miss) in the obs
    registry.  Lazy import: kernels must not depend on obs at import
    time, and the counter costs one dict hit per *trace*, not per step.
    Re-fetched from the process default registry each call so a test
    that swaps registries sees its own counts."""
    global _GET_REGISTRY
    if _GET_REGISTRY is None:
        try:
            from repro.obs.registry import get_registry
            _GET_REGISTRY = get_registry
        except Exception:
            _GET_REGISTRY = False  # obs unavailable: stay silent
    if _GET_REGISTRY:
        _GET_REGISTRY().counter(
            "kernel_autotune_resolves", "autotune block lookups by outcome",
            labels=("kernel", "outcome")).inc(kernel=kernel, outcome=outcome)


def _env_override(kernel: str) -> tuple[int, ...] | None:
    """REPRO_KERNEL_BLOCKS="flash=256x128,scan=128x64,grouped=128x128":
    an explicit pin that beats both the cache and the defaults."""
    raw = os.environ.get(_ENV_OVERRIDE)
    if not raw:
        return None
    for part in raw.split(","):
        if "=" not in part:
            continue
        name, _, val = part.partition("=")
        if name.strip() == kernel:
            return tuple(int(v) for v in val.strip().split("x"))
    return None


def resolve(
    kernel: str,
    key: Mapping[str, object],
    default: tuple[int, ...],
    *,
    enabled: bool = True,
    cache_path: str | None = None,
) -> tuple[int, ...]:
    """Trace-time block lookup for kernel call sites: env override >
    cached tuning winner > ``default``.  Never measures."""
    override = _env_override(kernel)
    if override is not None:
        _count_resolve(kernel, "override")
        return override
    if not enabled:
        _count_resolve(kernel, "disabled")
        return default
    entry = _load_cache(cache_path or default_cache_path()).get(
        cache_key(kernel, key))
    if entry is None:
        _count_resolve(kernel, "miss")
        return default
    _count_resolve(kernel, "hit")
    return tuple(int(b) for b in entry["blocks"])


def autotune(
    kernel: str,
    key: Mapping[str, object],
    candidates: Sequence[tuple[int, ...]],
    run_fn: Callable[[tuple[int, ...]], None],
    *,
    predict_fn: Callable[[tuple[int, ...]], float] | None = None,
    prune: float = 4.0,
    repeat: int = 3,
    cache_path: str | None = None,
    use_cache: bool = True,
) -> dict:
    """Sweep ``candidates``, cache and return the winner.

    ``run_fn(blocks)`` must execute the kernel to completion (jit +
    block_until_ready); it is called once for warmup/compile and
    ``repeat`` more times, keeping the best wall time.  ``predict_fn``
    maps blocks -> predicted seconds; candidates predicted worse than
    ``prune`` x the best prediction are skipped (the roofline model
    trims the sweep, measurement decides among survivors).  Returns
    ``{"blocks", "predicted_s", "measured_ms", "candidates", "cached"}``.
    """
    path = cache_path or default_cache_path()
    ck = cache_key(kernel, key)
    if use_cache:
        hit = _load_cache(path).get(ck)
        if hit is not None:
            return {**hit, "blocks": tuple(int(b) for b in hit["blocks"]),
                    "cached": True}

    preds = [float(predict_fn(c)) if predict_fn else 0.0 for c in candidates]
    best_pred = min(preds) if preds else 0.0
    rows: list[Candidate] = []
    for blocks, pred in zip(candidates, preds):
        if predict_fn and best_pred > 0 and pred > prune * best_pred:
            rows.append(Candidate(tuple(blocks), pred, None))  # pruned
            continue
        run_fn(tuple(blocks))  # warmup / compile
        best_ms = np.inf
        for _ in range(max(1, repeat)):
            t0 = time.perf_counter()
            run_fn(tuple(blocks))
            best_ms = min(best_ms, (time.perf_counter() - t0) * 1e3)
        rows.append(Candidate(tuple(blocks), pred, float(best_ms)))

    measured = [c for c in rows if c.measured_ms is not None]
    if not measured:
        raise ValueError(f"no measurable candidates for {ck}")
    winner = min(measured, key=lambda c: c.measured_ms)
    entry = {
        "blocks": list(winner.blocks),
        "predicted_s": winner.predicted_s,
        "measured_ms": winner.measured_ms,
        "candidates": [dataclasses.asdict(c) for c in rows],
    }
    if use_cache:
        data = _load_cache(path)
        data[ck] = entry
        _save_cache(path, data)
    return {**entry, "blocks": winner.blocks, "cached": False}


# ----------------------------------------------------------------------
# Candidate enumeration + roofline predictors.
# ----------------------------------------------------------------------
def _pow2_blocks(limit: int, lo: int = 16) -> list[int]:
    out = []
    b = lo
    while b <= limit:
        out.append(b)
        b *= 2
    return out or [limit]


def flash_candidates(Tq: int, Tkv: int) -> list[tuple[int, int]]:
    return [(bq, bk)
            for bq in _pow2_blocks(min(Tq, 512), 32) if Tq % bq == 0
            for bk in _pow2_blocks(min(Tkv, 512), 32) if Tkv % bk == 0]


def scan_candidates(T: int, di: int) -> list[tuple[int, int]]:
    return [(bd, ct)
            for bd in _pow2_blocks(min(di, 256), 16) if di % bd == 0
            for ct in _pow2_blocks(min(T, 512), 16) if T % ct == 0]


def grouped_candidates(M: int, N: int) -> list[tuple[int, int]]:
    return [(bm, bn)
            for bm in _pow2_blocks(min(M, 512), 32) if M % bm == 0
            for bn in _pow2_blocks(min(N, 512), 32) if N % bn == 0]


def _roofline_s(flops: float, mem_bytes: float, grid_steps: float,
                hw: HW) -> float:
    return max(flops / hw.peak_flops, mem_bytes / hw.hbm_bw) + (
        grid_steps * STEP_OVERHEAD_S)


def predict_flash(blocks, *, heads: int, Tq: int, Tkv: int, D: int,
                  live_frac: float = 1.0, dtype_bytes: int = 2,
                  hw: HW | None = None) -> float:
    """Forward-pass roofline: 4*Tq*Tkv*D MACs over the live tiles, K/V
    tiles re-streamed once per live (q-tile, kv-tile) pair."""
    hw = hw or get_hw()
    bq, bk = blocks
    tiles = (Tq // bq) * (Tkv // bk) * live_frac
    flops = 4.0 * heads * tiles * bq * bk * D
    mem = heads * dtype_bytes * (
        2 * Tq * D + tiles * 2 * bk * D)  # q in + out, live k/v tiles
    return _roofline_s(flops, mem, heads * tiles, hw)


def predict_scan(blocks, *, T: int, di: int, N: int, dtype_bytes: int = 4,
                 hw: HW | None = None) -> float:
    """Recurrence is bandwidth/latency bound: stream u/dt/y (+B/C per
    channel block) once, plus a chunk-boundary state checkpoint; the
    per-grid-step overhead is what penalizes tiny chunks."""
    hw = hw or get_hw()
    bd, ct = blocks
    n_d, n_t = di // bd, T // ct
    flops = 8.0 * T * di * N
    mem = dtype_bytes * (
        3 * T * di            # u, dt, y
        + n_d * 2 * T * N     # B, C re-streamed per channel block
        + n_t * di * N        # chunk-boundary checkpoints
    )
    return _roofline_s(flops, mem, n_d * n_t, hw)


def predict_grouped(blocks, *, M: int, K: int, N: int, E: int,
                    live_tiles: int | None = None, dtype_bytes: int = 2,
                    hw: HW | None = None) -> float:
    """Live (m-tile, expert) pairs do a [bm,K]x[K,bn] MAC; dead pairs
    still pay a grid step (the tile-skip saves MXU+HBM, not issue)."""
    hw = hw or get_hw()
    bm, bn = blocks
    n_m, n_n = M // bm, N // bn
    if live_tiles is None:
        live_tiles = n_m + E - 1  # contiguous groups: one overlap per seam
    live = live_tiles * n_n
    flops = 2.0 * live * bm * K * bn
    mem = dtype_bytes * (live * (bm * K + K * bn + bm * bn))
    return _roofline_s(flops, mem, n_m * n_n * E, hw)
