"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Python
execution of the kernel body -- the correctness-validation mode); on a
real TPU set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to
compile to Mosaic.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.grouped_gemm import grouped_matmul as _gmm
from repro.kernels.selective_scan import selective_scan as _scan

__all__ = ["flash_attention_op", "grouped_matmul_op", "selective_scan_op",
           "default_interpret"]


def default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                                   "interpret", "block_skip"))
def flash_attention_op(q, k, v, q_seg, kv_seg, q_pos, kv_pos, *,
                       causal=True, window=None, block_q=128, block_kv=128,
                       interpret=None, block_skip=True):
    interpret = default_interpret() if interpret is None else interpret
    return _flash(q, k, v, q_seg, kv_seg, q_pos, kv_pos, causal=causal,
                  window=window, block_q=block_q, block_kv=block_kv,
                  interpret=interpret, block_skip=block_skip)


@partial(jax.jit, static_argnames=("block_d", "chunk", "interpret",
                                   "return_state"))
def selective_scan_op(u, delta, A, B, C, D, seg, *, block_d=128, chunk=64,
                      interpret=None, return_state=False):
    interpret = default_interpret() if interpret is None else interpret
    return _scan(u, delta, A, B, C, D, seg, block_d=block_d, chunk=chunk,
                 interpret=interpret, return_state=return_state)


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def grouped_matmul_op(x, w, group_offsets, *, block_m=128, block_n=128,
                      interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return _gmm(x, w, group_offsets, block_m=block_m, block_n=block_n,
                interpret=interpret)
