"""Segment-aware flash attention, Pallas TPU kernel.

This is the TPU-native form of the packed-batch attention that
post-balancing relies on (no-padding batching, paper Alg 1/3): the
kernel masks by SEGMENT ID inside each tile, so one shard's stream can
hold many examples with zero cross-contamination and zero padding
FLOPs beyond tile granularity.

Tiling: grid (B*H, nQ, nK) with the KV dimension innermost (sequential
on TPU); VMEM scratch (m, l, acc) carries the online-softmax state
across KV tiles -- the standard FlashAttention-2 schedule mapped onto
the MXU: block_q x block_kv score tiles, 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30

__all__ = ["flash_attention"]


def _kernel(q_ref, k_ref, v_ref, qseg_ref, kseg_ref, qpos_ref, kpos_ref,
            out_ref, m_scr, l_scr, acc_scr, *, causal, window, scale, n_kv):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [bq, D]
    k = k_ref[0].astype(jnp.float32)  # [bk, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    qs = qseg_ref[0]
    ks = kseg_ref[0]
    qp = qpos_ref[0]
    kp = kpos_ref[0]
    mask = (qs[:, None] == ks[None, :]) & (qs[:, None] > 0)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= qp[:, None] - kp[None, :] < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # Masked entries contribute exactly zero (fully-masked rows would
    # otherwise see exp(NEG_INF - NEG_INF) = 1).
    p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        out_ref[0, ...] = (acc_scr[...] / l[:, None]).astype(out_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_seg: jnp.ndarray,
    kv_seg: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q [B,H,Tq,D]; k,v [B,H,Tkv,D]; seg/pos [B,T*] int32.

    ``interpret=True`` runs the kernel body in Python on CPU (the
    validation mode for this container); on real TPU pass False.
    """
    B, H, Tq, D = q.shape
    Tkv = k.shape[2]
    bq = min(block_q, Tq)
    bk = min(block_kv, Tkv)
    if Tq % bq or Tkv % bk:
        raise ValueError(f"T ({Tq},{Tkv}) must be divisible by blocks ({bq},{bk})")
    n_q, n_kv = Tq // bq, Tkv // bk
    scale = 1.0 / np.sqrt(D)

    qf = q.reshape(B * H, Tq, D)
    kf = k.reshape(B * H, Tkv, D)
    vf = v.reshape(B * H, Tkv, D)

    grid = (B * H, n_q, n_kv)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, scale=scale, n_kv=n_kv
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, ik, H=H: (b // H, iq)),
            pl.BlockSpec((1, bk), lambda b, iq, ik, H=H: (b // H, ik)),
            pl.BlockSpec((1, bq), lambda b, iq, ik, H=H: (b // H, iq)),
            pl.BlockSpec((1, bk), lambda b, iq, ik, H=H: (b // H, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running denom l
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf, q_seg, kv_seg, q_pos, kv_pos)
    return out.reshape(B, H, Tq, D)
