"""Segment-aware flash attention, Pallas TPU kernel (fwd + bwd).

This is the TPU-native form of the packed-batch attention that
post-balancing relies on (no-padding batching, paper Alg 1/3): the
kernel masks by SEGMENT ID inside each tile, so one shard's stream can
hold many examples with zero cross-contamination and zero padding
FLOPs beyond tile granularity.

Design notes
============

Tiling
------
Forward and dq grids are ``(B*H, nQ, nK)`` with the KV dimension
innermost (sequential on TPU); the dk/dv grid is ``(B*Hkv, nK, nQ*g)``
with the (GQA group member, Q tile) axis innermost so each KV tile owns
one scratch accumulator that sums its whole group.  GQA (Hkv < H) is
resolved purely by BlockSpec index maps (q head h reads kv head
``h // g``) -- K/V tiles are shared across the group, never
materialized per Q head.  VMEM scratch carries the online-softmax state
(m, l, acc) or the gradient accumulators across the innermost loop --
the standard FlashAttention-2 schedule mapped onto the MXU:
``block_q x block_kv`` score tiles, 128-aligned.

Residuals
---------
The forward pass emits, next to the output, the per-row logsumexp
``lse = m + log(l)`` (0 for fully-masked rows).  The backward pass
recomputes each score tile from (q, k) and reconstructs the softmax as
``p = exp(s - lse)`` -- O(Tq) residual memory instead of the O(Tq*Tkv)
probability matrix.  ``delta = rowsum(do * o)`` is precomputed outside
the kernels (a cheap O(T*D) contraction) and streamed in per Q tile:

    dv_j = sum_i p_ij do_i
    ds_ij = p_ij * (dp_ij - delta_i),  dp = do v^T
    dq_i = scale * sum_j ds_ij k_j,    dk_j = scale * sum_i ds_ij q_i

Block-skip index math
---------------------
``pack_stream`` lays examples out contiguously, so most (Q tile, KV
tile) pairs are FULLY masked: their segment-id ranges do not intersect,
or the KV tile lies entirely above the causal / sliding-window
frontier.  :func:`tile_stats` reduces each tile of the packed
``seg``/``pos`` arrays to interval summaries over the valid (seg > 0)
entries -- ``(smin, smax, pmin, pmax, any_valid)`` -- and
:func:`live_tile_mask` combines them into a ``[B, nQ, nK]`` visit mask.
A KV tile k is skipped for Q tile q when any of these hold:

    dead      :  no valid entry in q or in k
    segments  :  q.smax < k.smin  or  k.smax < q.smin
                 (interval disjointness => no equal segment ids)
    causal    :  k.pmin > q.pmax          (every key is in the future)
    window    :  q.pmin - k.pmax >= W     (every key fell out of the window)

Each rule is conservative (a skipped tile is provably all-masked for
ANY layout, contiguous or not); contiguous packed layouts are where the
intervals become tight and most of the grid drops out.  The mask is
computed once on the host side of the ``pallas_call`` (O(nQ*nK), not
O(T^2)) and read as an SMEM scalar; all three kernels wrap their tile
body in ``pl.when(live)`` so skipped tiles issue no MXU work.

Differentiation
---------------
``flash_attention`` carries a ``jax.custom_vjp``: gradients of packed
train steps flow through the Pallas dq/dk/dv kernels, never through a
dense ``[Tq, Tkv]`` mask.  seg/pos inputs get symbolic-zero (float0)
cotangents.

``interpret=True`` runs the kernel bodies in Python/XLA on CPU (the
validation mode for this container); on real TPU pass False to compile
via Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30
_BIG = np.int32(2**30)

__all__ = [
    "flash_attention",
    "tile_stats",
    "live_tile_mask",
    "count_live_tiles",
    "tile_skip_fraction",
]


# ----------------------------------------------------------------------
# Block-skip precomputation (host side).
# ----------------------------------------------------------------------
def tile_stats(seg: jnp.ndarray, pos: jnp.ndarray, block: int):
    """Interval summaries per tile of a packed stream.

    seg, pos: [B, T] int32 (seg 0 = padding).  Returns a dict of
    [B, T // block] arrays: smin/smax/pmin/pmax over valid entries and
    ``any`` (tile has at least one valid token).
    """
    B, T = seg.shape
    n = T // block
    s = seg.reshape(B, n, block)
    p = pos.reshape(B, n, block)
    valid = s > 0
    return {
        "smin": jnp.where(valid, s, _BIG).min(axis=-1),
        "smax": jnp.where(valid, s, -1).max(axis=-1),
        "pmin": jnp.where(valid, p, _BIG).min(axis=-1),
        "pmax": jnp.where(valid, p, -1).max(axis=-1),
        "any": valid.any(axis=-1),
    }


def live_tile_mask(
    q_seg: jnp.ndarray,
    kv_seg: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    *,
    block_q: int,
    block_kv: int,
    causal: bool,
    window: int | None,
) -> jnp.ndarray:
    """[B, nQ, nK] bool: True where the (Q tile, KV tile) pair may hold
    at least one unmasked score (see module docstring for the rules)."""
    qs = tile_stats(q_seg, q_pos, block_q)
    ks = tile_stats(kv_seg, kv_pos, block_kv)
    live = qs["any"][:, :, None] & ks["any"][:, None, :]
    live &= qs["smin"][:, :, None] <= ks["smax"][:, None, :]
    live &= ks["smin"][:, None, :] <= qs["smax"][:, :, None]
    if causal:
        live &= ks["pmin"][:, None, :] <= qs["pmax"][:, :, None]
    if window is not None:
        live &= qs["pmin"][:, :, None] - ks["pmax"][:, None, :] < window
    return live


def count_live_tiles(
    q_seg, kv_seg, q_pos, kv_pos, *, block_q, block_kv, causal, window
) -> tuple[int, int]:
    """(visited, total) KV-tile visits for ONE head's grid pass, summed
    over all streams in the batch (the mask is head-independent; every
    head of a stream visits the same tiles)."""
    live = live_tile_mask(q_seg, kv_seg, q_pos, kv_pos, block_q=block_q,
                          block_kv=block_kv, causal=causal, window=window)
    return int(jnp.sum(live)), int(np.prod(live.shape))


def tile_skip_fraction(
    q_seg, kv_seg, q_pos, kv_pos, *, block_q, block_kv, causal, window
) -> float:
    """Fraction of (Q tile, KV tile) grid cells the kernel skips on this
    batch -- the observability counterpart of :func:`count_live_tiles`.
    Host-side and data-dependent, so sample it at flush intervals (the
    ledger does), never inside the traced step."""
    visited, total = count_live_tiles(
        q_seg, kv_seg, q_pos, kv_pos, block_q=block_q, block_kv=block_kv,
        causal=causal, window=window)
    return 1.0 - visited / total if total else 0.0


# ----------------------------------------------------------------------
# Kernel bodies.
# ----------------------------------------------------------------------
def _tile_mask(qs, ks, qp, kp, *, causal, window):
    """[bq, bk] bool mask for one score tile."""
    mask = (qs[:, None] == ks[None, :]) & (qs[:, None] > 0)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= qp[:, None] - kp[None, :] < window
    return mask


def _fwd_kernel(live_ref, q_ref, k_ref, v_ref, qseg_ref, kseg_ref, qpos_ref,
                kpos_ref, out_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                causal, window, scale, n_kv):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(live_ref[0, 0, 0] > 0)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]

        mask = _tile_mask(qseg_ref[0], kseg_ref[0], qpos_ref[0], kpos_ref[0],
                          causal=causal, window=window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        # Masked entries contribute exactly zero (fully-masked rows would
        # otherwise see exp(NEG_INF - NEG_INF) = 1).
        p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        out_ref[0, ...] = (acc_scr[...] / l_safe[:, None]).astype(out_ref.dtype)
        lse_ref[0, ...] = jnp.where(l > 0.0, m_scr[...] + jnp.log(l_safe), 0.0)


def _dq_kernel(live_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               qseg_ref, kseg_ref, qpos_ref, kpos_ref, dq_ref, dq_scr, *,
               causal, window, scale, n_kv):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(live_ref[0, 0, 0] > 0)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _tile_mask(qseg_ref[0], kseg_ref[0], qpos_ref[0], kpos_ref[0],
                          causal=causal, window=window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None]) * mask.astype(jnp.float32)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ik == n_kv - 1)
    def _finalize():
        dq_ref[0, ...] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(live_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                qseg_ref, kseg_ref, qpos_ref, kpos_ref, dk_ref, dv_ref,
                dk_scr, dv_scr, *, causal, window, scale, n_t):
    """Grid (B*Hkv, nK, nQ * group): the innermost axis walks every
    (GQA group member, Q tile) pair, so dk/dv accumulate the full group
    sum in scratch and are emitted once per KV head -- no repeated K/V
    and no post-hoc reduction."""
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(live_ref[0, 0, 0] > 0)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        mask = _tile_mask(qseg_ref[0], kseg_ref[0], qpos_ref[0], kpos_ref[0],
                          causal=causal, window=window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None]) * mask.astype(jnp.float32)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bk, D]
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(iq == n_t - 1)
    def _finalize():
        dk_ref[0, ...] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, ...] = dv_scr[...].astype(dv_ref.dtype)


# ----------------------------------------------------------------------
# pallas_call wrappers (flat [B*H, T, D] layouts).
# ----------------------------------------------------------------------
def _live_spec(H):
    return pl.BlockSpec((1, 1, 1), lambda b, i, j, H=H: (b // H, i, j),
                        memory_space=pltpu.SMEM)


def _kv_head(b, H, Hkv):
    """Flat q index [0, B*H) -> flat kv index [0, B*Hkv) (GQA grouping:
    q head h reads kv head h // (H // Hkv), matching _gqa_* in
    repro.models.attention)."""
    return (b // H) * Hkv + (b % H) // (H // Hkv)


def _forward(qf, kf, vf, q_seg, kv_seg, q_pos, kv_pos, live, *, causal,
             window, scale, bq, bk, interpret):
    BH, Tq, D = qf.shape
    Tkv = kf.shape[1]
    H = BH // q_seg.shape[0]
    Hkv = kf.shape[0] // q_seg.shape[0]
    n_q, n_kv = Tq // bq, Tkv // bk
    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window, scale=scale, n_kv=n_kv
    )
    kvh = functools.partial(_kv_head, H=H, Hkv=Hkv)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            _live_spec(H),
            pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, D), lambda b, iq, ik: (kvh(b), ik, 0)),
            pl.BlockSpec((1, bk, D), lambda b, iq, ik: (kvh(b), ik, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, ik, H=H: (b // H, iq)),
            pl.BlockSpec((1, bk), lambda b, iq, ik, H=H: (b // H, ik)),
            pl.BlockSpec((1, bq), lambda b, iq, ik, H=H: (b // H, iq)),
            pl.BlockSpec((1, bk), lambda b, iq, ik, H=H: (b // H, ik)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tq, D), qf.dtype),
            jax.ShapeDtypeStruct((BH, Tq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running denom l
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(live, qf, kf, vf, q_seg, kv_seg, q_pos, kv_pos)


def _backward(qf, kf, vf, dof, lse, delta, q_seg, kv_seg, q_pos, kv_pos,
              live, *, causal, window, scale, bq, bk, interpret):
    BH, Tq, D = qf.shape
    BHkv, Tkv, _ = kf.shape
    B = q_seg.shape[0]
    H, Hkv = BH // B, BHkv // B
    g = H // Hkv
    n_q, n_kv = Tq // bq, Tkv // bk
    kvh = functools.partial(_kv_head, H=H, Hkv=Hkv)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, causal=causal, window=window,
                          scale=scale, n_kv=n_kv),
        grid=(BH, n_q, n_kv),
        in_specs=[
            _live_spec(H),
            pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, D), lambda b, iq, ik: (kvh(b), ik, 0)),
            pl.BlockSpec((1, bk, D), lambda b, iq, ik: (kvh(b), ik, 0)),
            pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
            pl.BlockSpec((1, bq), lambda b, iq, ik, H=H: (b // H, iq)),
            pl.BlockSpec((1, bk), lambda b, iq, ik, H=H: (b // H, ik)),
            pl.BlockSpec((1, bq), lambda b, iq, ik, H=H: (b // H, iq)),
            pl.BlockSpec((1, bk), lambda b, iq, ik, H=H: (b // H, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, D), qf.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(live, qf, kf, vf, dof, lse, delta, q_seg, kv_seg, q_pos, kv_pos)

    # dk/dv grid walks (group member, Q tile) pairs innermost so each KV
    # head's scratch accumulates the whole GQA group before one emit.
    def qb(b, t):
        return (b // Hkv) * H + (b % Hkv) * g + t // n_q

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, causal=causal, window=window,
                          scale=scale, n_t=n_q * g),
        grid=(BHkv, n_kv, n_q * g),
        in_specs=[
            pl.BlockSpec((1, 1, 1),
                         lambda b, ik, t: (b // Hkv, t % n_q, ik),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bq, D), lambda b, ik, t: (qb(b, t), t % n_q, 0)),
            pl.BlockSpec((1, bk, D), lambda b, ik, t: (b, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda b, ik, t: (b, ik, 0)),
            pl.BlockSpec((1, bq, D), lambda b, ik, t: (qb(b, t), t % n_q, 0)),
            pl.BlockSpec((1, bq), lambda b, ik, t: (qb(b, t), t % n_q)),
            pl.BlockSpec((1, bq), lambda b, ik, t: (qb(b, t), t % n_q)),
            pl.BlockSpec((1, bq), lambda b, ik, t: (b // Hkv, t % n_q)),
            pl.BlockSpec((1, bk), lambda b, ik, t: (b // Hkv, ik)),
            pl.BlockSpec((1, bq), lambda b, ik, t: (b // Hkv, t % n_q)),
            pl.BlockSpec((1, bk), lambda b, ik, t: (b // Hkv, ik)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda b, ik, t: (b, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda b, ik, t: (b, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHkv, Tkv, D), kf.dtype),
            jax.ShapeDtypeStruct((BHkv, Tkv, D), vf.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),
            pltpu.VMEM((bk, D), jnp.float32),
        ],
        interpret=interpret,
    )(live, qf, kf, vf, dof, lse, delta, q_seg, kv_seg, q_pos, kv_pos)
    return dq, dk, dv


# ----------------------------------------------------------------------
# custom_vjp assembly.
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _make_diff_flash(causal, window, bq, bk, interpret, block_skip):
    def _prep(q, q_seg, kv_seg, q_pos, kv_pos):
        B, H, Tq, D = q.shape
        scale = 1.0 / np.sqrt(D)
        if block_skip:
            live = live_tile_mask(q_seg, kv_seg, q_pos, kv_pos, block_q=bq,
                                  block_kv=bk, causal=causal, window=window)
            live = live.astype(jnp.int32)
        else:
            live = jnp.ones(
                (B, Tq // bq, kv_seg.shape[1] // bk), jnp.int32)
        return scale, live

    def _run_fwd(q, k, v, q_seg, kv_seg, q_pos, kv_pos):
        B, H, Tq, D = q.shape
        Hkv, Tkv = k.shape[1], k.shape[2]
        scale, live = _prep(q, q_seg, kv_seg, q_pos, kv_pos)
        out, lse = _forward(
            q.reshape(B * H, Tq, D), k.reshape(B * Hkv, Tkv, D),
            v.reshape(B * Hkv, Tkv, D), q_seg, kv_seg, q_pos, kv_pos,
            live, causal=causal, window=window, scale=scale, bq=bq, bk=bk,
            interpret=interpret)
        return out.reshape(B, H, Tq, D), lse, live

    @jax.custom_vjp
    def flash(q, k, v, q_seg, kv_seg, q_pos, kv_pos):
        out, _, _ = _run_fwd(q, k, v, q_seg, kv_seg, q_pos, kv_pos)
        return out

    def fwd(q, k, v, q_seg, kv_seg, q_pos, kv_pos):
        out, lse, live = _run_fwd(q, k, v, q_seg, kv_seg, q_pos, kv_pos)
        return out, (q, k, v, q_seg, kv_seg, q_pos, kv_pos, out, lse, live)

    def bwd(res, do):
        q, k, v, q_seg, kv_seg, q_pos, kv_pos, out, lse, live = res
        B, H, Tq, D = q.shape
        Hkv, Tkv = k.shape[1], k.shape[2]
        scale = 1.0 / np.sqrt(D)
        dof = do.reshape(B * H, Tq, D)
        outf = out.reshape(B * H, Tq, D)
        delta = (dof.astype(jnp.float32) * outf.astype(jnp.float32)).sum(-1)
        dq, dk, dv = _backward(
            q.reshape(B * H, Tq, D), k.reshape(B * Hkv, Tkv, D),
            v.reshape(B * Hkv, Tkv, D), dof, lse, delta, q_seg, kv_seg,
            q_pos, kv_pos, live, causal=causal, window=window, scale=scale,
            bq=bq, bk=bk, interpret=interpret)
        zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
        return (dq.reshape(q.shape), dk.reshape(k.shape), dv.reshape(v.shape),
                zero(q_seg), zero(kv_seg), zero(q_pos), zero(kv_pos))

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_seg: jnp.ndarray,
    kv_seg: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
    block_skip: bool = True,
) -> jnp.ndarray:
    """q [B,H,Tq,D]; k,v [B,Hkv,Tkv,D] with H a multiple of Hkv (GQA
    groups resolved by BlockSpec index maps -- K/V are never
    materialized per Q head); seg/pos [B,T*] int32.

    Differentiable (custom VJP through Pallas dq/dk/dv kernels) and
    block-sparse over fully-masked (Q tile, KV tile) pairs when
    ``block_skip`` is on.  T must divide by the block sizes -- the
    model-level wrapper (``repro.models.attention``) pads arbitrary
    lengths before calling in here.
    """
    B, H, Tq, D = q.shape
    Hkv, Tkv = k.shape[1], k.shape[2]
    if H % Hkv:
        raise ValueError(f"n_heads {H} not a multiple of kv heads {Hkv}")
    bq = min(block_q, Tq)
    bk = min(block_kv, Tkv)
    if Tq % bq or Tkv % bk:
        raise ValueError(f"T ({Tq},{Tkv}) must be divisible by blocks ({bq},{bk})")
    window = None if window is None else int(window)
    fn = _make_diff_flash(bool(causal), window, bq, bk, bool(interpret),
                          bool(block_skip))
    return fn(q, k, v, q_seg.astype(jnp.int32), kv_seg.astype(jnp.int32),
              q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32))
