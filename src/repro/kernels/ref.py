"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["flash_attention_ref", "selective_scan_ref"]

NEG_INF = -2.0**30


def flash_attention_ref(q, k, v, q_seg, kv_seg, q_pos, kv_pos, *,
                        causal=True, window=None):
    """q [B,H,Tq,D]; k,v [B,H,Tkv,D]; seg/pos [B,T*].  Segment-aware
    softmax attention; rows with no valid key output 0."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = (q_seg[:, None, :, None] == kv_seg[:, None, None, :]) & (
        q_seg[:, None, :, None] > 0
    )
    if causal:
        mask &= kv_pos[:, None, None, :] <= q_pos[:, None, :, None]
    if window is not None:
        mask &= q_pos[:, None, :, None] - kv_pos[:, None, None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def selective_scan_ref(u, delta, A, B, C, D, seg):
    """Mamba-1 selective scan oracle.  u,delta [T,di]; A [di,N];
    B,C [T,N]; D [di]; seg [T].  State resets at segment boundaries."""
    T, di = u.shape
    N = A.shape[1]
    keep = (seg > 0) & (seg == jnp.concatenate([seg[:1], seg[:-1]]))
    keep = keep.at[0].set(False)

    def step(h, t):
        dA = jnp.exp(delta[t][:, None] * A)
        h = jnp.where(keep[t], h, 0.0) * dA + (delta[t] * u[t])[:, None] * B[t][None, :]
        y = (h * C[t][None, :]).sum(-1) + D * u[t]
        return h, y

    h0 = jnp.zeros((di, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(T))
    return ys.astype(u.dtype)
