"""Grouped GEMM for MoE expert dispatch, Pallas TPU kernel.

Computes ``out[s] = x[s] @ w[e]`` for every row ``s`` in expert ``e``'s
contiguous token group -- the megablocks-style layout where tokens are
pre-sorted by expert so each expert owns one variable-length row range
``[offsets[e], offsets[e+1])`` of ``x``.  Dispatching through a dense
``[E, capacity, d]`` buffer (the legacy ``moe_ffn`` path) pays
``E * capacity`` rows of matmul no matter how imbalanced the routing is
and silently drops overflow tokens; the grouped layout pays exactly the
routed rows, aligned up to the tile size, and drops nothing.

Two kernels:

  _gmm   out[M, N] = x[M, K] @ w[group(m), K, N]
         grid (n_m, n_n, E), expert innermost.  Group offsets arrive via
         scalar prefetch (SMEM) so the index maps and the tile-skip
         predicate can read them before the tile body runs.  A
         ``pl.when``-gated body (the flash kernel's live-tile pattern)
         skips every (m-tile, expert) pair whose row ranges don't
         intersect -- for E experts and roughly balanced routing only
         ~1/E of the grid does MXU work.  Rows of a tile that belong to
         a different (or no) expert are masked to zero before the dot.

  _tgmm  dw[E, K, N] = sum over group(e) of x[s]^T dy[s]
         grid (E, n_n, n_m), m innermost, accumulating [K, bn] in VMEM
         scratch across the m sweep; dead (expert, m-tile) pairs skip.

``grouped_matmul`` wraps both in a ``jax.custom_vjp``: dx reuses _gmm
with the transposed weights, dw is one _tgmm call, and the integer
offsets get a symbolic-zero (float0) cotangent like seg/pos in the
flash kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["grouped_matmul", "count_live_group_tiles",
           "group_tile_skip_fraction"]


def _row_mask(tile_start, bm, start, end):
    """[bm, 1] f32 mask of rows in [start, end)."""
    rows = tile_start + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    return ((rows >= start) & (rows < end)).astype(jnp.float32)


# ----------------------------------------------------------------------
# Forward: out[M, N] = x @ w[expert-of-row].
# ----------------------------------------------------------------------
def _gmm_kernel(off_ref, x_ref, w_ref, o_ref, acc, *, bm, n_e):
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    tile_start = pl.program_id(0) * bm
    start, end = off_ref[e], off_ref[e + 1]

    @pl.when((start < tile_start + bm) & (end > tile_start))
    def _body():
        mask = _row_mask(tile_start, bm, start, end)
        xm = x_ref[...].astype(jnp.float32) * mask
        acc[...] += jax.lax.dot_general(
            xm, w_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(e == n_e - 1)
    def _emit():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _gmm(x, w, offsets, *, bm, bn, interpret):
    M, K = x.shape
    E, _, N = w.shape
    n_m, n_n = M // bm, N // bn
    kernel = functools.partial(_gmm_kernel, bm=bm, n_e=E)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_m, n_n, E),
            in_specs=[
                pl.BlockSpec((bm, K), lambda im, jn, e, off: (im, 0)),
                pl.BlockSpec((1, K, bn), lambda im, jn, e, off: (e, 0, jn)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda im, jn, e, off: (im, jn)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(offsets, x, w)


# ----------------------------------------------------------------------
# Weight gradient: dw[e] = x[group(e)]^T @ dy[group(e)].
# ----------------------------------------------------------------------
def _tgmm_kernel(off_ref, x_ref, dy_ref, dw_ref, acc, *, bm, n_m):
    im = pl.program_id(2)

    @pl.when(im == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    tile_start = im * bm
    e = pl.program_id(0)
    start, end = off_ref[e], off_ref[e + 1]

    @pl.when((start < tile_start + bm) & (end > tile_start))
    def _body():
        mask = _row_mask(tile_start, bm, start, end)
        xm = x_ref[...].astype(jnp.float32) * mask
        acc[...] += jax.lax.dot_general(
            xm, dy_ref[...].astype(jnp.float32),
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(im == n_m - 1)
    def _emit():
        dw_ref[0] = acc[...].astype(dw_ref.dtype)


def _tgmm(x, dy, offsets, E, *, bm, bn, interpret):
    M, K = x.shape
    N = dy.shape[1]
    n_m, n_n = M // bm, N // bn
    kernel = functools.partial(_tgmm_kernel, bm=bm, n_m=n_m)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E, n_n, n_m),
            in_specs=[
                pl.BlockSpec((bm, K), lambda e, jn, im, off: (im, 0)),
                pl.BlockSpec((bm, bn), lambda e, jn, im, off: (im, jn)),
            ],
            out_specs=pl.BlockSpec((1, K, bn), lambda e, jn, im, off: (e, 0, jn)),
            scratch_shapes=[pltpu.VMEM((K, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((E, K, N), x.dtype),
        interpret=interpret,
    )(offsets, x, dy)


# ----------------------------------------------------------------------
# custom_vjp assembly.
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _make_diff_gmm(bm, bn, interpret):
    @jax.custom_vjp
    def gmm(x, w, offsets):
        return _gmm(x, w, offsets, bm=bm, bn=bn, interpret=interpret)

    def fwd(x, w, offsets):
        return gmm(x, w, offsets), (x, w, offsets)

    def bwd(res, dy):
        x, w, offsets = res
        K = x.shape[1]
        bk = next(b for b in range(min(bn, K), 0, -1) if K % b == 0)
        dx = _gmm(dy, jnp.swapaxes(w, 1, 2), offsets,
                  bm=bm, bn=bk, interpret=interpret)
        dw = _tgmm(x, dy, offsets, w.shape[0], bm=bm, bn=bn,
                   interpret=interpret)
        return dx.astype(x.dtype), dw.astype(w.dtype), np.zeros(
            offsets.shape, jax.dtypes.float0)

    gmm.defvjp(fwd, bwd)
    return gmm


def grouped_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    group_offsets: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """x [M, K]; w [E, K, N]; group_offsets [E+1] int32 ascending with
    ``group_offsets[0] == 0`` and ``group_offsets[E] <= M``.  Row ``s``
    belongs to expert ``e`` iff ``offsets[e] <= s < offsets[e+1]``; rows
    at or beyond ``offsets[E]`` (padding) produce zeros.  Returns
    ``[M, N]`` in x.dtype (f32 accumulation).  Differentiable in x and w
    (custom VJP through the transposed-_gmm / _tgmm kernels)."""
    M, K = x.shape
    E, Kw, N = w.shape
    if Kw != K:
        raise ValueError(f"x K={K} != w K={Kw}")
    if group_offsets.shape != (E + 1,):
        raise ValueError(f"offsets shape {group_offsets.shape} != ({E + 1},)")
    bm = min(block_m, M)
    bn = min(block_n, N)
    if M % bm or N % bn:
        raise ValueError(f"M={M} % {bm} or N={N} % {bn} != 0")
    fn = _make_diff_gmm(bm, bn, bool(interpret))
    return fn(x, w, group_offsets.astype(jnp.int32))


def count_live_group_tiles(group_sizes, block_m: int) -> int:
    """Host-side accounting: number of (m-tile, expert) grid cells that
    do MXU work for the given per-expert row counts, vs the dense
    ``n_m_tiles * E`` sweep.  Mirrors the kernel's intersection test."""
    sizes = np.asarray(group_sizes, np.int64)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    live = 0
    for e in range(len(sizes)):
        if sizes[e] == 0:
            continue
        live += (offs[e + 1] - 1) // block_m - offs[e] // block_m + 1
    return int(live)


def group_tile_skip_fraction(group_sizes, block_m: int) -> float:
    """Fraction of the dense ``n_m_tiles * E`` grid that holds no rows
    for its expert -- cells the kernel's live-tile test skips.  Pure
    host numpy over the routing counts; cheap enough to sample per step
    from the already-host-fetched MoE metrics."""
    sizes = np.asarray(group_sizes, np.int64)
    total_rows = int(sizes.sum())
    if total_rows == 0 or len(sizes) == 0:
        return 0.0
    n_m = -(-total_rows // block_m)  # ceil
    total = n_m * len(sizes)
    return 1.0 - count_live_group_tiles(sizes, block_m) / total if total else 0.0
