"""Attention for packed (post-balanced) batches: the unified backend.

Everything below is segment-aware: post-balancing produces per-shard
PACKED token streams (no padding, paper Alg 1/3), so attention must not
leak across example boundaries.  Convention: ``segment id 0 = padding``,
positive ids are example ids; positions restart at 0 per example.

Every attention site in the repo (encoder stacks, the LLM backbone,
enc-dec cross attention, decode) funnels through :func:`attention`,
selected by ``backend``:

  * ``reference``       full [Tq, Tkv] score matrix (oracle; small shapes).
  * ``chunked``         flash-style online-softmax over KV blocks
                        (lax.scan) with a recompute-based custom VJP --
                        the portable pure-jnp path.
  * ``chunked_unrolled``  same, scans unrolled (roofline cost probes).
  * ``flash``           the Pallas TPU kernel
                        (``repro.kernels.flash_attention``): fwd + bwd
                        kernels, custom VJP, block-level segment
                        sparsity.  Compiles via Mosaic on TPU; falls
                        back to interpret execution off-TPU.
  * ``flash_interpret`` the same kernel forced through the Pallas
                        interpreter (CPU-container validation mode).
  * ``windowed[...]``   window-chunked wrapper over any of the above
                        (see ``_windowed``); e.g. ``windowed_flash``.

Supports GQA (n_kv_heads < n_heads), RoPE applied by the caller,
sliding-window (h2o-danube / mistral), qk-norm (qwen3, applied by the
caller), causal & bidirectional, and cross-attention (whisper decoder).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["attention", "make_segment_mask", "windowed_variant",
           "ATTENTION_BACKENDS"]

ATTENTION_BACKENDS = ("reference", "chunked", "chunked_unrolled", "flash",
                      "flash_interpret")

NEG_INF = -2.0**30


def make_segment_mask(
    q_seg: jnp.ndarray,
    kv_seg: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    *,
    causal: bool,
    window: int | None,
) -> jnp.ndarray:
    """Boolean [.., Tq, Tkv] mask: True = attend."""
    same = (q_seg[..., :, None] == kv_seg[..., None, :]) & (q_seg[..., :, None] > 0)
    if causal:
        same &= kv_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        same &= q_pos[..., :, None] - kv_pos[..., None, :] < window
    return same


def _gqa_scores(q, k):
    """q [B,Tq,H,D], k [B,Tkv,Hkv,D] -> scores [B,H,Tq,Tkv]."""
    B, Tq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Tq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)
    return s.reshape(B, Hkv * g, Tq, k.shape[1])


def _gqa_out(p, v):
    """p [B,H,Tq,Tkv], v [B,Tkv,Hkv,D] -> [B,Tq,H,D]."""
    B, H, Tq, Tkv = p.shape
    Hkv = v.shape[2]
    g = H // Hkv
    pg = p.reshape(B, Hkv, g, Tq, Tkv)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v)
    return o.reshape(B, Tq, H, v.shape[-1])


def _reference(q, k, v, mask, scale):
    s = _gqa_scores(q, k).astype(jnp.float32) * scale
    s = jnp.where(mask[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows (padding queries) -> zero output.
    p = jnp.where(mask[:, None, :, :].any(axis=-1, keepdims=True), p, 0.0)
    return _gqa_out(p.astype(q.dtype), v)


def _chunked(q, k, v, q_seg, kv_seg, q_pos, kv_pos, *, causal, window,
             scale, block_q, block_kv, unroll=1):
    """Flash-style online softmax; scan over KV blocks.  Returns
    (out, m, l) -- softmax row statistics feed the custom backward."""
    B, Tq, H, D = q.shape
    Tkv = k.shape[1]
    bq = min(block_q, Tq)
    bkv = min(block_kv, Tkv)
    nq = -(-Tq // bq)
    nk = -(-Tkv // bkv)
    pad_q = nq * bq - Tq
    pad_k = nk * bkv - Tkv

    def padq(x, val=0):
        return jnp.pad(x, [(0, 0), (0, pad_q)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=val)

    def padk(x, val=0):
        return jnp.pad(x, [(0, 0), (0, pad_k)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=val)

    q = padq(q)
    q_seg = padq(q_seg)          # pad -> seg 0 = masked out
    q_pos = padq(q_pos)
    k = padk(k)
    v = padk(v)
    kv_seg = padk(kv_seg)
    kv_pos = padk(kv_pos, val=np.iinfo(np.int32).max if causal else 0)

    # Blocked views.
    qb = q.reshape(B, nq, bq, H, D)
    qsb = q_seg.reshape(B, nq, bq)
    qpb = q_pos.reshape(B, nq, bq)
    kb = k.reshape(B, nk, bkv, k.shape[2], D)
    vb = v.reshape(B, nk, bkv, v.shape[2], D)
    ksb = kv_seg.reshape(B, nk, bkv)
    kpb = kv_pos.reshape(B, nk, bkv)

    def process_block(qi, qs, qp, kj, vj, ks, kp):
        # qi [B,bq,H,D]; kj [B,bkv,Hkv,D]
        s = _gqa_scores(qi, kj).astype(jnp.float32) * scale  # [B,H,bq,bkv]
        m = make_segment_mask(qs, ks, qp, kp, causal=causal, window=window)
        return jnp.where(m[:, None], s, NEG_INF)

    def kv_scan(carry, blk):
        m_run, l_run, acc = carry
        kj, vj, ks, kp = blk

        def one_q(qi, qs, qp, m_r, l_r, a_r):
            s = process_block(qi, qs, qp, kj, vj, ks, kp)  # [B,H,bq,bkv]
            m_new = jnp.maximum(m_r, s.max(axis=-1))
            # Masked entries must contribute exactly zero (fully-masked
            # rows would otherwise see exp(NEG_INF - NEG_INF) = 1).
            p = jnp.exp(s - m_new[..., None]) * (s > NEG_INF / 2)
            corr = jnp.exp(m_r - m_new)
            l_new = l_r * corr + p.sum(axis=-1)
            pv = _gqa_out(p.astype(vj.dtype), vj)  # [B,bq,H,D]
            a_new = a_r * corr.transpose(0, 2, 1)[..., None] + pv.astype(jnp.float32)
            return m_new, l_new, a_new

        m2, l2, a2 = jax.vmap(one_q, in_axes=(1, 1, 1, 1, 1, 1), out_axes=1)(
            qb, qsb, qpb, m_run, l_run, acc
        )
        return (m2, l2, a2), None

    m0 = jnp.full((B, nq, H, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, H, bq), jnp.float32)
    a0 = jnp.zeros((B, nq, bq, H, D), jnp.float32)
    blocks = (
        jnp.moveaxis(kb, 1, 0),
        jnp.moveaxis(vb, 1, 0),
        jnp.moveaxis(ksb, 1, 0),
        jnp.moveaxis(kpb, 1, 0),
    )
    (m_f, l_f, acc_f), _ = jax.lax.scan(kv_scan, (m0, l0, a0), blocks,
                                        unroll=unroll)
    l_safe = jnp.where(l_f == 0, 1.0, l_f)  # fully-masked query rows
    out = acc_f / l_safe.transpose(0, 1, 3, 2)[..., None]
    out = out.reshape(B, nq * bq, H, D)[:, :Tq]
    return out.astype(q.dtype), m_f, l_safe


# ----------------------------------------------------------------------
# Flash custom VJP: backward recomputes score blocks instead of storing
# per-KV-block residuals (without this, the scan's saved residuals are
# O(Tq * Tkv) and the train step does not fit HBM).
# ----------------------------------------------------------------------
def _flash_bwd_blocks(res, do, *, causal, window, scale, block_q, block_kv):
    q, k, v, q_seg, kv_seg, q_pos, kv_pos, out, m_f, l_f = res
    B, Tq, H, D = q.shape
    Tkv = k.shape[1]
    Hkv = k.shape[2]
    g = H // Hkv
    bq = min(block_q, Tq)
    bkv = min(block_kv, Tkv)
    nq = -(-Tq // bq)
    nk = -(-Tkv // bkv)
    pad_q = nq * bq - Tq
    pad_k = nk * bkv - Tkv

    def padq(x, val=0):
        return jnp.pad(x, [(0, 0), (0, pad_q)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=val)

    def padk(x, val=0):
        return jnp.pad(x, [(0, 0), (0, pad_k)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=val)

    qp_ = padq(q)
    do_ = padq(do.astype(jnp.float32))
    out_ = padq(out.astype(jnp.float32))
    qs_ = padq(q_seg)
    qpos_ = padq(q_pos)
    kp_ = padk(k)
    vp_ = padk(v)
    ks_ = padk(kv_seg)
    kpos_ = padk(kv_pos, val=np.iinfo(np.int32).max if causal else 0)

    qb = qp_.reshape(B, nq, bq, H, D)
    dob = do_.reshape(B, nq, bq, H, D)
    outb = out_.reshape(B, nq, bq, H, D)
    qsb = qs_.reshape(B, nq, bq)
    qpb = qpos_.reshape(B, nq, bq)
    kb = kp_.reshape(B, nk, bkv, Hkv, D)
    vb = vp_.reshape(B, nk, bkv, Hkv, D)
    ksb = ks_.reshape(B, nk, bkv)
    kpb = kpos_.reshape(B, nk, bkv)

    # Delta = rowsum(do * o)  [B,nq,H,bq]
    Dl = (dob * outb).sum(-1).transpose(0, 1, 3, 2)

    def kv_step(dq_acc, blk):
        kj, vj, ks, kp = blk  # [B,bkv,Hkv,D], seg/pos [B,bkv]

        def one_q(qi, qs, qp, m_r, l_r, doi, Di):
            s = _gqa_scores(qi, kj).astype(jnp.float32) * scale  # [B,H,bq,bkv]
            msk = make_segment_mask(qs, ks, qp, kp, causal=causal, window=window)
            s = jnp.where(msk[:, None], s, NEG_INF)
            p = jnp.exp(s - m_r[..., None]) * (s > NEG_INF / 2)
            p = p / l_r[..., None]
            # dv_j contribution: p^T do  -> [B,bkv,Hkv,D]
            pg = p.reshape(B, Hkv, g, bq, bkv)
            dog = doi.reshape(B, bq, Hkv, g, D)
            dv = jnp.einsum("bhgqk,bqhgd->bkhd", pg, dog)
            # dp = do . v^T  [B,H,bq,bkv]
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog, vj.astype(jnp.float32))
            dp = dp.reshape(B, H, bq, bkv)
            ds = p * (dp - Di[..., None]) * scale
            dsg = ds.reshape(B, Hkv, g, bq, bkv)
            dq = jnp.einsum("bhgqk,bkhd->bqhgd", dsg, kj.astype(jnp.float32))
            dq = dq.reshape(B, bq, H, D)
            qg = qi.reshape(B, bq, Hkv, g, D)
            dk = jnp.einsum("bhgqk,bqhgd->bkhd", dsg, qg.astype(jnp.float32))
            return dq, dk, dv

        dq_b, dk_b, dv_b = jax.vmap(one_q, in_axes=(1, 1, 1, 1, 1, 1, 1),
                                    out_axes=1)(qb, qsb, qpb, m_f, l_f, dob, Dl)
        # dq_b [B,nq,bq,H,D] accumulates; dk/dv summed over q blocks.
        return dq_acc + dq_b, (dk_b.sum(axis=1), dv_b.sum(axis=1))

    dq0 = jnp.zeros((B, nq, bq, H, D), jnp.float32)
    blocks = (
        jnp.moveaxis(kb, 1, 0),
        jnp.moveaxis(vb, 1, 0),
        jnp.moveaxis(ksb, 1, 0),
        jnp.moveaxis(kpb, 1, 0),
    )
    dq_f, (dk_blocks, dv_blocks) = jax.lax.scan(kv_step, dq0, blocks)
    dq = dq_f.reshape(B, nq * bq, H, D)[:, :Tq].astype(q.dtype)
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(B, nk * bkv, Hkv, D)[:, :Tkv]
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(B, nk * bkv, Hkv, D)[:, :Tkv]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


def _make_flash(causal, window, scale, block_q, block_kv, unroll):
    @jax.custom_vjp
    def flash(q, k, v, q_seg, kv_seg, q_pos, kv_pos):
        out, _, _ = _chunked(q, k, v, q_seg, kv_seg, q_pos, kv_pos,
                             causal=causal, window=window, scale=scale,
                             block_q=block_q, block_kv=block_kv, unroll=unroll)
        return out

    def fwd(q, k, v, q_seg, kv_seg, q_pos, kv_pos):
        out, m, l = _chunked(q, k, v, q_seg, kv_seg, q_pos, kv_pos,
                             causal=causal, window=window, scale=scale,
                             block_q=block_q, block_kv=block_kv, unroll=unroll)
        return out, (q, k, v, q_seg, kv_seg, q_pos, kv_pos, out, m, l)

    def bwd(res, do):
        dq, dk, dv = _flash_bwd_blocks(
            res, do, causal=causal, window=window, scale=scale,
            block_q=block_q, block_kv=block_kv,
        )
        zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
        _, _, _, qs, ks, qp, kp, *_ = res
        return dq, dk, dv, zero(qs), zero(ks), zero(qp), zero(kp)

    flash.defvjp(fwd, bwd)
    return flash


# ----------------------------------------------------------------------
# Window-chunked segment attention (beyond-paper S-Perf optimization).
#
# Post-balancing packs examples into long per-shard streams (e.g. 64k
# tokens of 4k-token examples).  Plain flash over the stream computes
# T_stream^2 score blocks even though segment masking zeroes all
# cross-example pairs -- 16x wasted FLOPs at train_4k.  But balancing
# gives a hard bound: every segment is <= the example max length W.  A
# segment therefore spans at most two consecutive W-sized stream chunks,
# so chunk i's queries only ever need keys from chunks {i-1, i}:
# attention over [nw, W] x [nw, 2W] windows is EXACT and costs
# T*2W instead of T^2.
# ----------------------------------------------------------------------
def _windowed(q, k, v, q_seg, kv_seg, q_pos, kv_pos, *, causal, window,
              backend, block_q, block_kv, chunk_w):
    B, T, H, D = q.shape
    if k.shape[1] != T:
        raise ValueError("windowed attention requires self-attention layout")
    W = chunk_w
    nw = -(-T // W)
    pad = nw * W - T

    def padt(x, val=0):
        return jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=val)

    def chunks(x):
        x = padt(x)
        return x.reshape((B, nw, W) + x.shape[2:])

    def with_prev(x, val=0):
        xc = chunks(x)
        prev = jnp.concatenate(
            [jnp.full_like(xc[:, :1], val), xc[:, :-1]], axis=1)
        return jnp.concatenate([prev, xc], axis=2)  # [B, nw, 2W, ...]

    qc = chunks(q).reshape((B * nw, W, H, D))
    qs = chunks(q_seg).reshape(B * nw, W)
    qp = chunks(q_pos).reshape(B * nw, W)
    kc = with_prev(k).reshape((B * nw, 2 * W, k.shape[2], D))
    vc = with_prev(v).reshape((B * nw, 2 * W, v.shape[2], D))
    ks = with_prev(kv_seg, val=0).reshape(B * nw, 2 * W)  # pad seg 0 = masked
    kp = with_prev(kv_pos, val=np.iinfo(np.int32).max if causal else 0)
    kp = kp.reshape(B * nw, 2 * W)

    out = attention(
        qc, kc, vc, q_seg=qs, kv_seg=ks, q_pos=qp, kv_pos=kp,
        causal=causal, window=window, backend=backend,
        block_q=block_q, block_kv=block_kv,
    )
    return out.reshape(B, nw * W, H, D)[:, :T]


# ----------------------------------------------------------------------
# Pallas flash backend: the TPU kernel (fwd + custom-VJP bwd + block
# skipping) behind the model-level [B,T,H,D] / GQA / ragged-length
# calling convention.
# ----------------------------------------------------------------------
def _pallas_flash(q, k, v, q_seg, kv_seg, q_pos, kv_pos, *, causal, window,
                  block_q, block_kv, interpret):
    from repro.kernels.ops import flash_attention_op
    from repro.utils import round_up

    B, Tq, H, D = q.shape
    Tkv = k.shape[1]
    bq = min(block_q, round_up(Tq, 8))
    bk = min(block_kv, round_up(Tkv, 8))
    pad_q = round_up(Tq, bq) - Tq
    pad_k = round_up(Tkv, bk) - Tkv

    def padt(x, n):
        return jnp.pad(x, [(0, 0), (0, n)] + [(0, 0)] * (x.ndim - 2))

    # Pad to tile multiples; padded slots carry seg 0 => masked out, and
    # padded query rows are sliced off (their cotangents never reach q).
    qt = jnp.moveaxis(padt(q, pad_q), 1, 2)  # [B,H,Tq',D]
    kt = jnp.moveaxis(padt(k, pad_k), 1, 2)
    vt = jnp.moveaxis(padt(v, pad_k), 1, 2)
    out = flash_attention_op(
        qt, kt, vt,
        padt(q_seg.astype(jnp.int32), pad_q),
        padt(kv_seg.astype(jnp.int32), pad_k),
        padt(q_pos.astype(jnp.int32), pad_q),
        padt(kv_pos.astype(jnp.int32), pad_k),
        causal=causal, window=None if window is None else int(window),
        block_q=bq, block_kv=bk, interpret=interpret,
    )
    return jnp.moveaxis(out, 1, 2)[:, :Tq]


def windowed_variant(backend: str) -> str:
    """Name of the window-chunked wrapper around ``backend``."""
    if backend.startswith("windowed"):
        return backend
    if backend.startswith("chunked"):
        return backend.replace("chunked", "windowed")
    return "windowed_" + backend


def _windowed_inner(backend: str) -> str:
    suffix = backend[len("windowed"):].lstrip("_")
    if suffix in ("", "unrolled"):
        return "chunked" + ("_" + suffix if suffix else "")
    return suffix


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_seg: jnp.ndarray,
    kv_seg: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    backend: str | None = None,
    impl: str | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    chunk_w: int | None = None,
) -> jnp.ndarray:
    """Segment-aware GQA attention behind a selectable ``backend``
    (module docstring lists them; ``impl`` is the legacy alias).

    Shapes: q [B,Tq,H,D]; k,v [B,Tkv,Hkv,D]; seg/pos [B,T*] int32.
    Returns [B,Tq,H,D].
    """
    backend = backend or impl or "chunked"
    if q.shape[2] % k.shape[2] != 0:
        raise ValueError(f"n_heads {q.shape[2]} not multiple of kv heads {k.shape[2]}")
    scale = 1.0 / np.sqrt(q.shape[-1])
    if backend.startswith("windowed"):
        if chunk_w is None:
            raise ValueError("windowed attention needs chunk_w (max segment len)")
        return _windowed(q, k, v, q_seg, kv_seg, q_pos, kv_pos, causal=causal,
                         window=window, backend=_windowed_inner(backend),
                         block_q=block_q, block_kv=block_kv, chunk_w=chunk_w)
    if backend == "reference":
        mask = make_segment_mask(q_seg, kv_seg, q_pos, kv_pos, causal=causal, window=window)
        return _reference(q, k, v, mask, scale)
    if backend in ("flash", "flash_interpret"):
        return _pallas_flash(
            q, k, v, q_seg, kv_seg, q_pos, kv_pos, causal=causal,
            window=window, block_q=block_q, block_kv=block_kv,
            interpret=True if backend == "flash_interpret" else None,
        )
    if backend in ("chunked", "chunked_unrolled"):
        unroll = 10**9 if backend == "chunked_unrolled" else 1
        flash = _make_flash(causal, window, scale, block_q, block_kv,
                            min(unroll, -(-k.shape[1] // min(block_kv, k.shape[1]))))
        return flash(q, k, v, q_seg.astype(jnp.int32), kv_seg.astype(jnp.int32),
                     q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32))
    raise ValueError(f"unknown attention backend {backend!r}")
