"""Single-token decode (serve_step) with per-family caches.

Cache layouts (see repro.configs.registry.cache_specs):
  dense/moe/vlm : k,v [L,B,S,Hkv,hd] (S = sliding window if any),
                  kv_pos/kv_seg [B,S] shared across layers
  ssm           : conv [L,B,K-1,di], h [L,B,di,N]
  hybrid        : mamba2 conv/h + per-group shared-attn caches
                  sa_k/sa_v [G,B,S,Hkv,hd]
  audio         : decoder self k/v + precomputed cross k/v per layer

The new token is written at ring index ``t % S`` (full cache: S =
seq_len, so the ring never wraps within the benchmarked step).

Paged mode (the serving engine's continuous-batching path): pass
``block_tables [B, W]`` and the pool cache layout from
``registry.paged_cache_specs`` (k/v ``[L, NB, bs, Hkv, hd]``, kv_pos /
kv_seg ``[NB, bs]``).  Each sequence's logical cache of S = W*bs slots
is read through a block-table *gather* -- slot i lives at pool block
``table[i // bs]``, offset ``i % bs`` -- so the exact same attention
computation runs on paged storage.  ``t`` becomes a per-row [B] vector
(continuous batching mixes sequences at different positions); a
negative ``t[b]`` marks row b inactive: its cache writes are dropped
(out-of-bounds scatter with mode="drop") and its logits are garbage the
caller ignores.  Block tables padded with the reserved null block 0
(all-zero k/v, kv_seg == 0) gather exactly what a dense zero-initialized
cache holds in unwritten slots, which is what makes paged decode
bit-identical to the dense path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import attention
from repro.models.layers import apply_rope, gelu_mlp, layer_norm, rms_norm, rotary_embedding, swiglu
from repro.models.moe import moe_ffn
from repro.models.ssm import mamba1_decode_step, mamba2_decode_step

__all__ = ["decode_step"]


def _norm(cfg, x, scale):
    if cfg.nonparametric_norm:
        return layer_norm(x, None, None)
    if cfg.family == "audio":
        return layer_norm(x, scale, None)
    return rms_norm(x, scale)


def _proj_qkv(cfg, lp, x):
    D = x.shape[-1]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = jnp.einsum("bd,dhe->bhe", x, lp["wq"].reshape(D, H, hd))
    k = jnp.einsum("bd,dhe->bhe", x, lp["wk"].reshape(D, Hkv, hd))
    v = jnp.einsum("bd,dhe->bhe", x, lp["wv"].reshape(D, Hkv, hd))
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    return q, k, v


def _attn_decode(cfg, lp, x, k_cache, v_cache, kv_pos, kv_seg, t, *, window,
                 paged=None):
    """x [B,D].  Returns (out [B,D], new k, new v).

    Dense mode (``paged=None``): k/v_cache [B,S,Hkv,hd], scalar ``t``;
    the new token lands at ring slot ``t % S``.  Paged mode: k/v_cache
    are pool blocks [NB,bs,Hkv,hd], ``paged = (block_tables [B,W],
    write_blk [B], write_off [B])`` and ``t`` is a per-row [B] vector
    (negative = inactive row, writes dropped); kv_pos/kv_seg arrive
    already gathered to [B, W*bs].  The returned k/v are the updated
    dense cache resp. the updated pool blocks."""
    B, D = x.shape
    q, k, v = _proj_qkv(cfg, lp, x)
    if paged is None:
        S = k_cache.shape[1]
        sin, cos = rotary_embedding(jnp.full((B, 1), t), cfg.head_dim_, cfg.rope_theta)
        q = apply_rope(q[:, None], sin, cos)  # [B,1,H,hd]
        k = apply_rope(k[:, None], sin, cos)[:, 0]
        idx = jnp.mod(t, S)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k[:, None], idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v[:, None], idx, axis=1)
        k_read, v_read = k_cache, v_cache
        q_pos = jnp.full((B, 1), t, jnp.int32)
    else:
        bt, wblk, woff = paged
        bs = k_cache.shape[1]
        S = bt.shape[1] * bs
        tc = jnp.maximum(t, 0)
        sin, cos = rotary_embedding(tc[:, None], cfg.head_dim_, cfg.rope_theta)
        q = apply_rope(q[:, None], sin, cos)
        k = apply_rope(k[:, None], sin, cos)[:, 0]
        k_cache = k_cache.at[wblk, woff].set(k, mode="drop")
        v_cache = v_cache.at[wblk, woff].set(v, mode="drop")
        k_read = k_cache[bt].reshape((B, S) + k_cache.shape[2:])
        v_read = v_cache[bt].reshape((B, S) + v_cache.shape[2:])
        q_pos = tc[:, None].astype(jnp.int32)
    out = attention(
        q, k_read, v_read,
        q_seg=jnp.ones((B, 1), jnp.int32),
        kv_seg=kv_seg,
        q_pos=q_pos,
        kv_pos=kv_pos,
        causal=True, window=window, backend=cfg.decode_backend,
        block_q=cfg.block_q, block_kv=cfg.block_kv,
    )  # [B,1,H,hd]
    H, hd = cfg.n_heads, cfg.head_dim_
    o = jnp.einsum("bhe,hed->bd", out[:, 0], lp["wo"].reshape(H, hd, D))
    return o, k_cache, v_cache


def _update_pos_seg(cache, t, S):
    idx = jnp.mod(t, S)
    kv_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["kv_pos"], jnp.broadcast_to(t, (cache["kv_pos"].shape[0], 1)).astype(jnp.int32), idx, axis=1)
    kv_seg = jax.lax.dynamic_update_slice_in_dim(
        cache["kv_seg"], jnp.ones((cache["kv_seg"].shape[0], 1), jnp.int32), idx, axis=1)
    return kv_pos, kv_seg


def decode_step(cfg: ModelConfig, params, tokens, cache, t, *, block_tables=None):
    """tokens [B,1] int32; t scalar int32 (current position).

    With ``block_tables`` (paged mode, module docstring) ``cache`` is
    the pool layout and ``t`` may be a per-row [B] vector with negative
    entries marking inactive rows.

    Returns (logits [B, vocab], new_cache)."""
    x = jnp.take(params["embed"], tokens[:, 0], axis=0)  # [B,D]

    if block_tables is not None:
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"paged decode supports dense/moe/vlm families, not {cfg.family!r}")
        x, cache = _decode_dense_paged(cfg, params, x, cache, t, block_tables)
    elif cfg.family in ("dense", "moe", "vlm"):
        x, cache = _decode_dense(cfg, params, x, cache, t)
    elif cfg.family == "ssm":
        x, cache = _decode_ssm(cfg, params, x, cache)
    elif cfg.family == "hybrid":
        x, cache = _decode_hybrid(cfg, params, x, cache, t)
    elif cfg.family == "audio":
        x, cache = _decode_encdec(cfg, params, x, cache, t)
    else:
        raise ValueError(cfg.family)

    x = _final(cfg, params, x)
    lm_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x.astype(jnp.float32), lm_head.astype(jnp.float32))
    return logits, cache


def _final(cfg, params, x):
    if cfg.nonparametric_norm:
        return layer_norm(x, None, None)
    if cfg.family == "audio":
        return layer_norm(x, params["final_norm"], None)
    return rms_norm(x, params["final_norm"])


def _dense_ffn(cfg, lp, h):
    """The dense-family FFN half of a decode layer ([B,D] -> [B,D])."""
    if cfg.family == "moe":
        ff, _ = moe_ffn(h[:, None, :], lp["router"], lp["w_gate"], lp["w_up"],
                        lp["w_down"], top_k=cfg.experts_per_token,
                        capacity_factor=cfg.capacity_factor,
                        backend=cfg.moe_backend, block_m=cfg.moe_block_m,
                        block_n=cfg.moe_block_n)
        return ff[:, 0]
    return swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])


def _decode_dense(cfg, params, x, cache, t):
    S = cache["k"].shape[2]
    kv_pos, kv_seg = _update_pos_seg(cache, t, S)

    def body(carry, inp):
        lp, kc, vc = inp
        h = _norm(cfg, carry, lp.get("attn_norm"))
        o, kc, vc = _attn_decode(cfg, lp, h, kc, vc, kv_pos, kv_seg, t,
                                 window=cfg.sliding_window)
        carry = carry + o
        h = _norm(cfg, carry, lp.get("mlp_norm"))
        return carry + _dense_ffn(cfg, lp, h), (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]),
        unroll=min(cfg.scan_unroll, cfg.n_layers))
    return x, {**cache, "k": k_new, "v": v_new, "kv_pos": kv_pos, "kv_seg": kv_seg}


def _decode_dense_paged(cfg, params, x, cache, t, block_tables):
    """Dense-family decode on the paged pool (module docstring).

    ``cache``: pool layout from ``registry.paged_cache_specs``;
    ``block_tables`` [B, W] int32 (null block 0 pads unallocated tail
    slots); ``t`` scalar or [B] (negative = inactive row)."""
    B = x.shape[0]
    NB, bs = cache["kv_seg"].shape
    S = block_tables.shape[1] * bs
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    active = t >= 0
    tc = jnp.maximum(t, 0)
    idx = jnp.mod(tc, S)  # logical ring slot (= sliding-window ring)
    wblk = jnp.where(
        active, block_tables[jnp.arange(B), idx // bs].astype(jnp.int32), NB)
    woff = jnp.mod(idx, bs)
    kv_pos = cache["kv_pos"].at[wblk, woff].set(tc, mode="drop")
    kv_seg = cache["kv_seg"].at[wblk, woff].set(1, mode="drop")
    kv_pos_g = kv_pos[block_tables].reshape(B, S)
    kv_seg_g = kv_seg[block_tables].reshape(B, S)

    def body(carry, inp):
        lp, kc, vc = inp
        h = _norm(cfg, carry, lp.get("attn_norm"))
        o, kc, vc = _attn_decode(cfg, lp, h, kc, vc, kv_pos_g, kv_seg_g, t,
                                 window=cfg.sliding_window,
                                 paged=(block_tables, wblk, woff))
        carry = carry + o
        h = _norm(cfg, carry, lp.get("mlp_norm"))
        return carry + _dense_ffn(cfg, lp, h), (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]),
        unroll=min(cfg.scan_unroll, cfg.n_layers))
    return x, {"k": k_new, "v": v_new, "kv_pos": kv_pos, "kv_seg": kv_seg}


def _decode_ssm(cfg, params, x, cache):
    def body(carry, inp):
        lp, conv, h = inp
        hid = rms_norm(carry, lp["norm"])
        o, st = mamba1_decode_step(lp, hid, {"conv": conv, "h": h},
                                   ssm_state=cfg.ssm_state)
        return carry + o, (st["conv"], st["h"])

    x, (conv_new, h_new) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["h"]),
        unroll=min(cfg.scan_unroll, cfg.n_layers)
    )
    return x, {"conv": conv_new, "h": h_new}


def _decode_hybrid(cfg, params, x, cache, t):
    every = cfg.shared_attn_every
    G = cfg.n_layers // every
    S = cache["sa_k"].shape[2]
    kv_pos, kv_seg = _update_pos_seg(
        {"kv_pos": cache["sa_kv_pos"], "kv_seg": cache["sa_kv_seg"]}, t, S
    )
    shared = params["shared_attn"]
    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape((G, every) + a.shape[1:]), params["layers"]
    )
    conv_g = cache["conv"].reshape((G, every) + cache["conv"].shape[1:])
    h_g = cache["h"].reshape((G, every) + cache["h"].shape[1:])

    def group(carry, inp):
        gp, conv, h = inp

        def mamba(c2, inp2):
            lp, cv, hh = inp2
            hid = rms_norm(c2, lp["norm"])
            o, st = mamba2_decode_step(lp, hid, {"conv": cv, "h": hh},
                                       ssm_state=cfg.ssm_state,
                                       headdim=cfg.ssm_headdim)
            return c2 + o, (st["conv"], st["h"])

        carry, (cv_new, h_new) = jax.lax.scan(
            mamba, carry, (gp, conv, h),
            unroll=min(cfg.scan_unroll, every))
        return carry, (cv_new, h_new)

    # Interleave: groups of mamba followed by the shared attention block.
    sa_k, sa_v = [], []
    ks, vs = cache["sa_k"], cache["sa_v"]
    conv_out, h_out = [], []
    carry = x
    for g in range(G):
        gp = jax.tree_util.tree_map(lambda a: a[g], grouped)
        carry, (cv, hh) = group(carry, (gp, conv_g[g], h_g[g]))
        conv_out.append(cv)
        h_out.append(hh)
        hnorm = rms_norm(carry, shared["attn_norm"])
        o, knew, vnew = _attn_decode(cfg, shared, hnorm, ks[g], vs[g],
                                     kv_pos, kv_seg, t, window=None)
        carry = carry + o
        hnorm = rms_norm(carry, shared["mlp_norm"])
        carry = carry + swiglu(hnorm, shared["w_gate"], shared["w_up"], shared["w_down"])
        sa_k.append(knew)
        sa_v.append(vnew)

    return carry, {
        "conv": jnp.stack(conv_out).reshape(cache["conv"].shape),
        "h": jnp.stack(h_out).reshape(cache["h"].shape),
        "sa_k": jnp.stack(sa_k),
        "sa_v": jnp.stack(sa_v),
        "sa_kv_pos": kv_pos,
        "sa_kv_seg": kv_seg,
    }


def _decode_encdec(cfg, params, x, cache, t):
    S = cache["k"].shape[2]
    kv_pos, kv_seg = _update_pos_seg(cache, t, S)

    def body(carry, inp):
        lp, kc, vc, xk, xv = inp
        h = _norm(cfg, carry, lp.get("attn_norm"))
        o, kc, vc = _attn_decode(cfg, lp, h, kc, vc, kv_pos, kv_seg, t, window=None)
        carry = carry + o
        # Cross attention against precomputed encoder K/V.
        h = _norm(cfg, carry, lp.get("cross_norm"))
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        D = h.shape[-1]
        q = jnp.einsum("bd,dhe->bhe", h, lp["xwq"].reshape(D, H, hd))
        out = attention(
            q[:, None], xk, xv,
            q_seg=jnp.ones((h.shape[0], 1), jnp.int32),
            kv_seg=cache["cross_seg"],
            q_pos=jnp.full((h.shape[0], 1), t, jnp.int32),
            kv_pos=cache["cross_pos"],
            causal=False, window=None, backend=cfg.decode_backend,
            block_q=cfg.block_q, block_kv=cfg.block_kv,
        )
        carry = carry + jnp.einsum("bhe,hed->bd", out[:, 0], lp["xwo"].reshape(H, hd, D))
        h = _norm(cfg, carry, lp.get("mlp_norm"))
        return carry + gelu_mlp(h, lp["w_in"], lp["w_out"]), (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x,
        (params["layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
        unroll=min(cfg.scan_unroll, cfg.n_layers),
    )
    return x, {**cache, "k": k_new, "v": v_new, "kv_pos": kv_pos, "kv_seg": kv_seg}
