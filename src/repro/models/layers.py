"""Shared neural building blocks (pure JAX, functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "layer_norm",
    "swiglu",
    "gelu_mlp",
    "rotary_embedding",
    "apply_rope",
    "init_dense",
]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray | None, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm; ``scale=None`` gives the non-parametric variant (OLMo
    uses non-parametric LayerNorm; we expose both)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    return x.astype(dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray | None = None,
               bias: jnp.ndarray | None = None, eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm; with scale=bias=None this is OLMo's non-parametric LN
    (arXiv:2402.00838)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP (llama/qwen/mistral family)."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray) -> jnp.ndarray:
    """Plain GELU MLP (whisper / ViT style)."""
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in)), w_out)


def rotary_embedding(positions: jnp.ndarray, head_dim: int,
                     theta: float = 10_000.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(sin, cos) tables for the given integer positions; [..., head_dim/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; sin/cos: [..., seq, head_dim/2]."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def init_dense(key: jax.Array, shape: tuple[int, ...], dtype,
               scale: float | None = None) -> jnp.ndarray:
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)
