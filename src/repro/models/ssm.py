"""Selective-state-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Segment-aware for packed post-balanced streams: the recurrent state
resets at example boundaries (seg change) so balancing rearrangements
stay consequence-invariant for SSMs too.

Training path, two backends behind ``mamba1_scan``/``mamba2_scan``
(``backend=``):

  "scan"    chunked sequential scan -- outer ``lax.scan`` over chunks
            carries only the small state; the chunk body is
            ``jax.checkpoint``ed so backward keeps per-chunk states
            instead of per-step residuals (the standard memory
            treatment for long-sequence SSM training).
  "pallas"  the fused kernel (``kernels/selective_scan.py``): channel
            blocks across the grid, time walked in VMEM-resident
            chunks, chunk-checkpointed custom VJP.  Mamba-2's
            per-head scalar decay maps onto the same kernel by
            broadcasting head quantities over the head dim (the
            broadcasts sit outside the kernel's custom_vjp, so their
            gradient reductions are plain JAX transposes).

Decode path: O(1) per-token state update (this is why the long_500k
shape is SSM/hybrid-only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "causal_conv1d",
    "mamba1_scan",
    "mamba2_scan",
    "mamba1_block",
    "mamba2_block",
    "mamba1_decode_step",
    "mamba2_decode_step",
]


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, seg: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, segment-aware.  x [B,T,C]; w [K,C]; seg [B,T]."""
    K = w.shape[0]
    out = x * w[-1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        sseg = jnp.pad(seg, ((0, 0), (i, 0)))[:, : seg.shape[1]]
        ok = (sseg == seg) & (seg > 0)
        out = out + shifted * ok[..., None] * w[K - 1 - i]
    return out


def _chunked_scan(step_fn, state0, xs, chunk: int):
    """lax.scan over chunks; chunk body checkpointed; xs leaves are [T, ...]."""
    T = jax.tree_util.tree_leaves(xs)[0].shape[0]
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T

    def pad_t(a):
        return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))

    xs_p = jax.tree_util.tree_map(pad_t, xs)
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((n_chunks, chunk) + a.shape[1:]), xs_p
    )

    @jax.checkpoint
    def chunk_body(state, chunk_xs):
        return jax.lax.scan(step_fn, state, chunk_xs)

    state_f, ys = jax.lax.scan(chunk_body, state0, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((n_chunks * chunk,) + a.shape[2:])[:T], ys
    )
    return state_f, ys


def _fit_block(size: int, target: int) -> int:
    """Largest block <= target dividing size (kernel divisibility)."""
    for b in range(min(target, size), 0, -1):
        if size % b == 0:
            return b
    return 1


def mamba1_scan(u, delta, A, B, C, D, seg, *, chunk: int = 256, h0=None,
                backend: str = "scan", block_d: int = 128):
    """Selective scan.  Shapes (single stream; vmap over batch):
      u [T, di], delta [T, di], A [di, N], B [T, N], C [T, N], D [di],
      seg [T].  Returns (y [T, di], h_final [di, N])."""
    if backend == "pallas":
        if h0 is not None:
            raise ValueError("pallas selective scan starts from h=0 "
                             "(h0 is a scan-backend knob)")
        from repro.kernels.ops import selective_scan_op

        T, di = u.shape
        return selective_scan_op(
            u, delta, A, B, C, D, seg,
            block_d=_fit_block(di, block_d), chunk=_fit_block(T, chunk),
            return_state=True)
    if backend != "scan":
        raise ValueError(f"unknown ssm backend {backend!r}")
    keep = (seg > 0) & (seg == jnp.concatenate([seg[:1], seg[:-1]]))
    keep = keep.at[0].set(False)  # first token always starts a segment

    def step(h, inp):
        u_t, d_t, B_t, C_t, k_t = inp
        dA = jnp.exp(d_t[:, None] * A)  # [di, N]
        h = jnp.where(k_t, h, 0.0) * dA + (d_t * u_t)[:, None] * B_t[None, :]
        y = (h * C_t[None, :]).sum(-1) + D * u_t
        return h, y

    h0 = jnp.zeros((u.shape[1], A.shape[1]), jnp.float32) if h0 is None else h0
    hf, y = _chunked_scan(
        step, h0, (u.astype(jnp.float32), delta.astype(jnp.float32),
                   B.astype(jnp.float32), C.astype(jnp.float32), keep), chunk
    )
    return y.astype(u.dtype), hf


def mamba2_scan(x, delta, A_log, B, C, D, seg, *, chunk: int = 256, h0=None,
                backend: str = "scan", block_d: int = 128):
    """Mamba-2 SSD (scalar decay per head).  Shapes (single stream):
      x [T, H, P], delta [T, H], A_log [H], B [T, N], C [T, N], D [H],
      seg [T].  Returns (y [T, H, P], h_final [H, P, N])."""
    A = -jnp.exp(A_log.astype(jnp.float32))  # [H]
    if backend == "pallas":
        if h0 is not None:
            raise ValueError("pallas selective scan starts from h=0 "
                             "(h0 is a scan-backend knob)")
        from repro.kernels.ops import selective_scan_op

        T, H, P = x.shape
        N = B.shape[-1]
        # Broadcast per-head scalars over the head dim: channel (h, p)
        # runs the mamba1 recurrence with dt/A/D of head h.
        u2 = x.reshape(T, H * P)
        d2 = jnp.repeat(delta, P, axis=1)
        A2 = jnp.broadcast_to(jnp.repeat(A, P)[:, None], (H * P, N))
        D2 = jnp.repeat(D, P)
        y, hf = selective_scan_op(
            u2, d2, A2, B, C, D2, seg,
            block_d=_fit_block(H * P, block_d), chunk=_fit_block(T, chunk),
            return_state=True)
        return y.reshape(T, H, P), hf.reshape(H, P, N)
    if backend != "scan":
        raise ValueError(f"unknown ssm backend {backend!r}")
    keep = (seg > 0) & (seg == jnp.concatenate([seg[:1], seg[:-1]]))
    keep = keep.at[0].set(False)

    def step(h, inp):
        x_t, d_t, B_t, C_t, k_t = inp  # [H,P], [H], [N], [N], scalar
        dA = jnp.exp(d_t * A)  # [H]
        h = jnp.where(k_t, h, 0.0) * dA[:, None, None] + (
            (d_t[:, None] * x_t)[..., None] * B_t[None, None, :]
        )
        y = (h * C_t[None, None, :]).sum(-1) + D[:, None] * x_t
        return h, y

    H, P = x.shape[1], x.shape[2]
    N = B.shape[-1]
    h0 = jnp.zeros((H, P, N), jnp.float32) if h0 is None else h0
    hf, y = _chunked_scan(
        step, h0, (x.astype(jnp.float32), delta.astype(jnp.float32),
                   B.astype(jnp.float32), C.astype(jnp.float32), keep), chunk
    )
    return y.astype(x.dtype), hf


# ----------------------------------------------------------------------
# Full blocks (projections + conv + scan + gate), matching param layout
# in repro.models.model.
# ----------------------------------------------------------------------
def mamba1_block(p, x, seg, *, ssm_state: int, chunk: int = 256,
                 backend: str = "scan", block_d: int = 128):
    """x [B,T,d] -> [B,T,d].  p: dict of this block's params."""
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])  # [B,T,2*di]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = causal_conv1d(xi, p["conv_w"], seg)
    xi = jax.nn.silu(xi)
    dbc = jnp.einsum("bte,ef->btf", xi, p["x_proj"])  # [B,T,dt_rank+2N]
    dt_rank = p["dt_proj"].shape[0]
    dt, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + ssm_state], axis=-1)
    delta = jax.nn.softplus(jnp.einsum("btr,re->bte", dt, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    def one(u_s, delta_s, B_s, C_s, seg_s):
        y, _ = mamba1_scan(u_s, delta_s, A, B_s, C_s, p["D"], seg_s,
                           chunk=chunk, backend=backend, block_d=block_d)
        return y

    y = jax.vmap(one)(xi, delta, Bm, Cm, seg)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", y, p["out_proj"])


def mamba2_block(p, x, seg, *, ssm_state: int, headdim: int, chunk: int = 256,
                 backend: str = "scan", block_d: int = 128):
    """x [B,T,d] -> [B,T,d] (Mamba-2, n_groups=1)."""
    di = p["out_proj"].shape[0]
    H = di // headdim
    zxbcdt = jnp.einsum("btd,de->bte", x, p["in_proj"])
    z, xi, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ssm_state, 2 * di + 2 * ssm_state], axis=-1
    )
    xi = causal_conv1d(xi, p["conv_w"], seg)
    xi = jax.nn.silu(xi)
    delta = jax.nn.softplus(dt + p["dt_bias"])  # [B,T,H]
    xh = xi.reshape(xi.shape[0], xi.shape[1], H, headdim)

    def one(x_s, delta_s, B_s, C_s, seg_s):
        y, _ = mamba2_scan(x_s, delta_s, p["A_log"], B_s, C_s, p["D"], seg_s,
                           chunk=chunk, backend=backend, block_d=block_d)
        return y

    y = jax.vmap(one)(xh, delta, Bm, Cm, seg)
    y = y.reshape(x.shape[0], x.shape[1], di)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", y, p["out_proj"])


# ----------------------------------------------------------------------
# Decode: O(1) state update per new token.
# ----------------------------------------------------------------------
def mamba1_decode_step(p, x_t, state, *, ssm_state: int):
    """x_t [B,d]; state dict {conv: [B,K-1,di], h: [B,di,N]}."""
    xz = jnp.einsum("bd,de->be", x_t, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_in = jnp.concatenate([state["conv"], xi[:, None, :]], axis=1)  # [B,K,di]
    xi = (conv_in * p["conv_w"][None]).sum(axis=1)
    new_conv = conv_in[:, 1:]
    xi = jax.nn.silu(xi)
    dbc = jnp.einsum("be,ef->bf", xi, p["x_proj"])
    dt_rank = p["dt_proj"].shape[0]
    dt, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + ssm_state], axis=-1)
    delta = jax.nn.softplus(jnp.einsum("br,re->be", dt, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(delta[..., None] * A[None])  # [B,di,N]
    h = state["h"] * dA + (delta * xi)[..., None] * Bm[:, None, :]
    y = (h * Cm[:, None, :]).sum(-1) + p["D"] * xi
    y = y * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y.astype(x_t.dtype), p["out_proj"])
    return out, {"conv": new_conv, "h": h}


def mamba2_decode_step(p, x_t, state, *, ssm_state: int, headdim: int):
    di = p["out_proj"].shape[0]
    H = di // headdim
    zxbcdt = jnp.einsum("bd,de->be", x_t, p["in_proj"])
    z, xi, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ssm_state, 2 * di + 2 * ssm_state], axis=-1
    )
    conv_in = jnp.concatenate([state["conv"], xi[:, None, :]], axis=1)
    xi = (conv_in * p["conv_w"][None]).sum(axis=1)
    new_conv = conv_in[:, 1:]
    xi = jax.nn.silu(xi)
    delta = jax.nn.softplus(dt + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(delta * A[None])  # [B,H]
    xh = xi.reshape(-1, H, headdim)
    h = state["h"] * dA[..., None, None] + (
        (delta[..., None] * xh)[..., None] * Bm[:, None, None, :]
    )
    y = (h * Cm[:, None, None, :]).sum(-1) + p["D"][None, :, None] * xh
    y = y.reshape(-1, di) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y.astype(x_t.dtype), p["out_proj"])
    return out, {"conv": new_conv, "h": h}
